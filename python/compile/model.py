"""Layer-2 JAX model: the batched DSE evaluation graph.

Wraps the L1 Pallas kernel (`kernels.dse_eval`) at the fixed shapes the
AOT artifact exports. The Rust runtime (`rust/src/runtime/mod.rs`) pads
its case tables and design batches to these shapes; keep the constants
in sync (an integration test on the Rust side checks the artifact's
entry layout).
"""

import jax.numpy as jnp

from .kernels import dse_eval as dse_eval_kernel

# Artifact shapes — must match rust/src/runtime/mod.rs.
C_MAX = 128    # case rows per invocation (row-chunked by the Rust runtime)
D_MAX = 512    # design points per invocation
S_WIDTH = 32   # scalar vector width


# Design-axis block for the exported artifact. On CPU-PJRT the grid loop
# lowers to an HLO while-loop whose per-step slicing dominates small
# batches; one full-width grid step is fastest (EXPERIMENTS.md §Perf).
# On a real TPU, BLOCK_D-sized steps bound VMEM (kernels/dse_eval.py).
EXPORT_BLOCK_D = D_MAX


def evaluate_designs(cases, designs, scalars):
    """The exported entry point.

    cases   f32[C_MAX, 8], designs f32[D_MAX, 4], scalars f32[S_WIDTH]
    returns (runtime, energy, area, power, valid), each f32[D_MAX].
    """
    cases = jnp.asarray(cases, jnp.float32)
    designs = jnp.asarray(designs, jnp.float32)
    scalars = jnp.asarray(scalars, jnp.float32)
    return dse_eval_kernel.dse_eval(cases, designs, scalars, block_d=EXPORT_BLOCK_D)


def example_shapes():
    """ShapeDtypeStructs for AOT lowering."""
    import jax

    return (
        jax.ShapeDtypeStruct((C_MAX, 8), jnp.float32),
        jax.ShapeDtypeStruct((D_MAX, 4), jnp.float32),
        jax.ShapeDtypeStruct((S_WIDTH,), jnp.float32),
    )
