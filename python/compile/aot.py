"""AOT pipeline: lower the L2 evaluation graph (containing the L1 Pallas
kernel) to HLO *text* and write `artifacts/dse_eval.hlo.txt`.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts` (a no-op when the artifact is newer than its
inputs). Python never runs on the request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_path: str) -> int:
    lowered = jax.jit(model.evaluate_designs).lower(*model.example_shapes())
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/dse_eval.hlo.txt")
    args = ap.parse_args()
    n = build(args.out)
    print(f"wrote {n} chars of HLO text to {args.out} "
          f"(C_MAX={model.C_MAX}, D_MAX={model.D_MAX}, S_WIDTH={model.S_WIDTH})")


if __name__ == "__main__":
    main()
