"""Pure-jnp oracle for the batched DSE evaluator.

Implements exactly the formulas of the Rust scalar evaluator
(`rust/src/dse/engine.rs::eval_runtime` / `eval_energy` and
`rust/src/hw/area.rs::evaluate`); the Pallas kernel is checked against
this module, and this module is cross-checked against Rust by the
integration test `rust/tests/pjrt_runtime.rs`.

Inputs (see `rust/src/runtime/mod.rs::scalars_layout` for the scalar
vector layout):

* ``cases``   f32[C, 8]  — rows ``(occ, ingress, egress, compute,
  inner_comm, inner_steps, red_delay, is_init)``; zero-occurrence rows
  are padding.
* ``designs`` f32[D, 4]  — rows ``(bandwidth, latency, l1, l2)``.
* ``scalars`` f32[32]    — activity totals, energy-curve and area/power
  regression constants, budgets.

Outputs: ``(runtime[D], energy[D], area[D], power[D], valid[D])``.
"""

import jax.numpy as jnp

# Scalar-vector indices (mirrors rust/src/runtime/mod.rs).
S_UNITS0 = 0
S_MACS = 1
S_L2R = 2
S_L2W = 3
S_L1R = 4
S_L1W = 5
S_NOC = 6
S_HOPS = 7
S_PES = 8
S_AREA_BUDGET = 9
S_POWER_BUDGET = 10
S_L1A = 11
S_L1B = 12
S_L2A = 13
S_L2B = 14
S_WF = 15
S_MAC_PJ = 16
S_HOP_PJ = 17
S_PE_AREA = 18
S_SRAM_AREA = 19
S_BUS_AREA = 20
S_ARB_AREA = 21
S_PE_POWER = 22
S_SRAM_POWER = 23
S_BUS_POWER = 24
S_ARB_POWER = 25


def runtime_ref(cases, designs, scalars):
    """Runtime (cycles) per design: sum over cases of occ x delay."""
    occ = cases[:, 0][None, :]          # (1, C)
    ingress = cases[:, 1][None, :]
    egress = cases[:, 2][None, :]
    compute = cases[:, 3][None, :]
    inner_comm = cases[:, 4][None, :]
    inner_steps = cases[:, 5][None, :]
    red = cases[:, 6][None, :]
    is_init = cases[:, 7][None, :]

    bw = jnp.maximum(designs[:, 0], 1.0)[:, None]   # (D, 1)
    lat = designs[:, 1][:, None]

    in_d = jnp.where(ingress > 0.0, jnp.ceil(ingress / bw) + lat, 0.0)
    out_d = jnp.where(egress > 0.0, jnp.ceil(egress / bw) + lat, 0.0)
    bw_share = jnp.maximum(bw / jnp.maximum(scalars[S_UNITS0], 1.0), 1.0)
    inner_d = jnp.where(
        inner_comm > 0.0,
        jnp.ceil(inner_comm / bw_share) + lat * inner_steps,
        0.0,
    )
    cmp_d = jnp.maximum(compute + red, inner_d)
    steady = jnp.maximum(jnp.maximum(in_d, cmp_d), out_d)
    delay = jnp.where(is_init > 0.5, in_d + cmp_d + out_d, steady)
    return jnp.sum(occ * delay, axis=1)


def energy_ref(designs, scalars):
    """Energy (pJ) per design from activity totals + Cacti-fit curves."""
    l1 = jnp.maximum(designs[:, 2], 1.0)
    l2 = jnp.maximum(designs[:, 3], 1.0)
    e_l1r = scalars[S_L1A] + scalars[S_L1B] * jnp.sqrt(l1)
    e_l2r = scalars[S_L2A] + scalars[S_L2B] * jnp.sqrt(l2)
    wf = scalars[S_WF]
    return (
        scalars[S_MACS] * scalars[S_MAC_PJ]
        + scalars[S_L1R] * e_l1r
        + scalars[S_L1W] * e_l1r * wf
        + scalars[S_L2R] * e_l2r
        + scalars[S_L2W] * e_l2r * wf
        + scalars[S_NOC] * scalars[S_HOPS] * scalars[S_HOP_PJ]
    )


def area_power_ref(designs, scalars):
    """Area (mm2) and power (mW) regressions (bus linear, arbiter
    quadratic — paper §5.2)."""
    bw = designs[:, 0]
    l1 = designs[:, 2]
    l2 = designs[:, 3]
    pes = scalars[S_PES]
    arb_pairs = pes * pes
    area = (
        pes * scalars[S_PE_AREA]
        + pes * l1 * scalars[S_SRAM_AREA]
        + l2 * scalars[S_SRAM_AREA]
        + bw * scalars[S_BUS_AREA]
        + arb_pairs * scalars[S_ARB_AREA]
    )
    power = (
        pes * scalars[S_PE_POWER]
        + pes * l1 * scalars[S_SRAM_POWER]
        + l2 * scalars[S_SRAM_POWER]
        + bw * scalars[S_BUS_POWER]
        + arb_pairs * scalars[S_ARB_POWER]
    )
    return area, power


def evaluate_ref(cases, designs, scalars):
    """Full reference: (runtime, energy, area, power, valid).

    Power = static regression + dynamic (workload energy over runtime;
    1 pJ/cycle = 1 mW at the 1 GHz reference clock).
    """
    runtime = runtime_ref(cases, designs, scalars)
    energy = energy_ref(designs, scalars)
    area, static_power = area_power_ref(designs, scalars)
    power = static_power + energy / jnp.maximum(runtime, 1.0)
    valid = jnp.where(
        (area <= scalars[S_AREA_BUDGET]) & (power <= scalars[S_POWER_BUDGET]),
        1.0,
        0.0,
    )
    return runtime, energy, area, power, valid
