"""Layer-1 Pallas kernel: batched DSE design-point evaluation.

The DSE hot path evaluates the same flattened case table against
thousands of (bandwidth, latency, L1, L2) design points. That is a dense
rank-2 broadcast + reduction — a VPU workload, tiled over the design
axis so each grid step works on a ``BLOCK_D x C`` tile with the case
table resident in VMEM across steps (its BlockSpec index map is
constant).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper targets
no accelerator for MAESTRO itself; the kernel is written for TPU VMEM
budgets — a ``(BLOCK_D=128) x (C=1024)`` f32 intermediate is 512 KB,
several of which fit comfortably in 16 MB VMEM alongside the 32 KB case
table — but always *executed* with ``interpret=True`` because the CPU
PJRT plugin cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Design points per grid step.
BLOCK_D = 128


def _dse_kernel(cases_ref, designs_ref, scalars_ref, rt_ref, en_ref, ar_ref, pw_ref, va_ref):
    """One grid step: evaluate BLOCK_D designs against the case table."""
    cases = cases_ref[...]        # (C, 8), VMEM-resident across steps
    designs = designs_ref[...]    # (BLOCK_D, 4)
    scalars = scalars_ref[...]    # (32,)

    occ = cases[:, 0][None, :]
    ingress = cases[:, 1][None, :]
    egress = cases[:, 2][None, :]
    compute = cases[:, 3][None, :]
    inner_comm = cases[:, 4][None, :]
    inner_steps = cases[:, 5][None, :]
    red = cases[:, 6][None, :]
    is_init = cases[:, 7][None, :]

    bw = jnp.maximum(designs[:, 0], 1.0)[:, None]
    lat = designs[:, 1][:, None]

    # Pipe-model delays (broadcast to (BLOCK_D, C)).
    in_d = jnp.where(ingress > 0.0, jnp.ceil(ingress / bw) + lat, 0.0)
    out_d = jnp.where(egress > 0.0, jnp.ceil(egress / bw) + lat, 0.0)
    bw_share = jnp.maximum(bw / jnp.maximum(scalars[ref.S_UNITS0], 1.0), 1.0)
    inner_d = jnp.where(
        inner_comm > 0.0,
        jnp.ceil(inner_comm / bw_share) + lat * inner_steps,
        0.0,
    )
    cmp_d = jnp.maximum(compute + red, inner_d)
    steady = jnp.maximum(jnp.maximum(in_d, cmp_d), out_d)
    delay = jnp.where(is_init > 0.5, in_d + cmp_d + out_d, steady)
    runtime = jnp.sum(occ * delay, axis=1)
    rt_ref[...] = runtime

    # Energy from activity totals + Cacti-fit curves.
    l1 = jnp.maximum(designs[:, 2], 1.0)
    l2 = jnp.maximum(designs[:, 3], 1.0)
    e_l1r = scalars[ref.S_L1A] + scalars[ref.S_L1B] * jnp.sqrt(l1)
    e_l2r = scalars[ref.S_L2A] + scalars[ref.S_L2B] * jnp.sqrt(l2)
    wf = scalars[ref.S_WF]
    energy = (
        scalars[ref.S_MACS] * scalars[ref.S_MAC_PJ]
        + scalars[ref.S_L1R] * e_l1r
        + scalars[ref.S_L1W] * e_l1r * wf
        + scalars[ref.S_L2R] * e_l2r
        + scalars[ref.S_L2W] * e_l2r * wf
        + scalars[ref.S_NOC] * scalars[ref.S_HOPS] * scalars[ref.S_HOP_PJ]
    )
    en_ref[...] = energy

    # Area/power regressions (bus linear, arbiter quadratic).
    bw1 = designs[:, 0]
    pes = scalars[ref.S_PES]
    arb = pes * pes
    area = (
        pes * scalars[ref.S_PE_AREA]
        + pes * l1 * scalars[ref.S_SRAM_AREA]
        + l2 * scalars[ref.S_SRAM_AREA]
        + bw1 * scalars[ref.S_BUS_AREA]
        + arb * scalars[ref.S_ARB_AREA]
    )
    # Total power = static regression + dynamic (1 pJ/cycle = 1 mW at
    # the 1 GHz reference clock).
    power = (
        pes * scalars[ref.S_PE_POWER]
        + pes * l1 * scalars[ref.S_SRAM_POWER]
        + l2 * scalars[ref.S_SRAM_POWER]
        + bw1 * scalars[ref.S_BUS_POWER]
        + arb * scalars[ref.S_ARB_POWER]
        + energy / jnp.maximum(runtime, 1.0)
    )
    ar_ref[...] = area
    pw_ref[...] = power
    va_ref[...] = jnp.where(
        (area <= scalars[ref.S_AREA_BUDGET]) & (power <= scalars[ref.S_POWER_BUDGET]),
        1.0,
        0.0,
    )


@functools.partial(jax.jit, static_argnames=("block_d",))
def dse_eval(cases, designs, scalars, block_d: int = BLOCK_D):
    """Batched evaluation: ``(runtime, energy, area, power, valid)``.

    ``designs.shape[0]`` must be a multiple of ``block_d``.
    """
    c, f = cases.shape
    d, w = designs.shape
    assert f == 8 and w == 4, (cases.shape, designs.shape)
    assert d % block_d == 0, f"designs ({d}) must be a multiple of block_d ({block_d})"
    grid = (d // block_d,)
    out_shape = [jax.ShapeDtypeStruct((d,), jnp.float32) for _ in range(5)]
    vec_spec = pl.BlockSpec((block_d,), lambda i: (i,))
    return pl.pallas_call(
        _dse_kernel,
        grid=grid,
        in_specs=[
            # Case table + scalars: resident, same block every step.
            pl.BlockSpec((c, f), lambda i: (0, 0)),
            pl.BlockSpec((block_d, 4), lambda i: (i, 0)),
            pl.BlockSpec((32,), lambda i: (0,)),
        ],
        out_specs=[vec_spec] * 5,
        out_shape=out_shape,
        # CPU PJRT cannot execute Mosaic custom-calls; interpret=True
        # lowers to plain HLO (see /opt/xla-example/README.md).
        interpret=True,
    )(cases, designs, scalars)
