"""Layer-1 Pallas kernels for the MAESTRO reproduction.

`dse_eval` is the DSE hot-spot: batched evaluation of design points
against a flattened iteration-case table. `ref` is the pure-jnp oracle
the pytest suite checks the kernel against.
"""

from . import dse_eval, ref  # noqa: F401
