"""Kernel-vs-oracle correctness: the CORE L1 signal.

The Pallas kernel must agree with the pure-jnp reference (`ref.py`) to
float32 tolerance across randomized case tables, design batches and
scalar vectors. Hypothesis sweeps shapes and value ranges.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dse_eval, ref


def make_inputs(rng, n_cases, n_designs, pad_to=None):
    """Random but realistic case table / design batch / scalars."""
    c = pad_to or n_cases
    cases = np.zeros((c, 8), np.float32)
    cases[:n_cases, 0] = rng.integers(1, 1_000_000, n_cases)          # occ
    cases[:n_cases, 1] = rng.integers(0, 100_000, n_cases)            # ingress
    cases[:n_cases, 2] = rng.integers(0, 50_000, n_cases)             # egress
    cases[:n_cases, 3] = rng.integers(1, 10_000, n_cases)             # compute
    cases[:n_cases, 4] = rng.integers(0, 20_000, n_cases)             # inner comm
    cases[:n_cases, 5] = rng.integers(0, 64, n_cases)                 # inner steps
    cases[:n_cases, 6] = rng.integers(0, 8, n_cases)                  # red delay
    cases[0, 7] = 1.0                                                 # init row

    designs = np.zeros((n_designs, 4), np.float32)
    designs[:, 0] = rng.integers(1, 256, n_designs)                   # bw
    designs[:, 1] = rng.integers(0, 8, n_designs)                     # lat
    designs[:, 2] = rng.integers(64, 65_536, n_designs)               # l1
    designs[:, 3] = rng.integers(1_024, 4_000_000, n_designs)         # l2

    scalars = np.zeros(32, np.float32)
    scalars[ref.S_UNITS0] = rng.integers(1, 64)
    scalars[ref.S_MACS] = rng.integers(1, 10**9)
    scalars[ref.S_L2R] = rng.integers(1, 10**8)
    scalars[ref.S_L2W] = rng.integers(1, 10**8)
    scalars[ref.S_L1R] = rng.integers(1, 10**9)
    scalars[ref.S_L1W] = rng.integers(1, 10**9)
    scalars[ref.S_NOC] = rng.integers(1, 10**8)
    scalars[ref.S_HOPS] = 2.0
    scalars[ref.S_PES] = rng.integers(8, 2048)
    scalars[ref.S_AREA_BUDGET] = 16.0
    scalars[ref.S_POWER_BUDGET] = 450.0
    scalars[ref.S_L1A] = 0.35
    scalars[ref.S_L1B] = 0.0266
    scalars[ref.S_L2A] = 2.0
    scalars[ref.S_L2B] = 0.0138
    scalars[ref.S_WF] = 1.1
    scalars[ref.S_MAC_PJ] = 0.2
    scalars[ref.S_HOP_PJ] = 0.06
    scalars[ref.S_PE_AREA] = 0.0016
    scalars[ref.S_SRAM_AREA] = 7.0e-6
    scalars[ref.S_BUS_AREA] = 0.004
    scalars[ref.S_ARB_AREA] = 1.0e-7
    scalars[ref.S_PE_POWER] = 0.12
    scalars[ref.S_SRAM_POWER] = 2.2e-4
    scalars[ref.S_BUS_POWER] = 0.8
    scalars[ref.S_ARB_POWER] = 2.0e-5
    return cases, designs, scalars


def assert_kernel_matches_ref(cases, designs, scalars, block_d):
    got = dse_eval.dse_eval(cases, designs, scalars, block_d=block_d)
    want = ref.evaluate_ref(cases, designs, scalars)
    names = ["runtime", "energy", "area", "power", "valid"]
    for g, w, name in zip(got, want, names):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-5,
            err_msg=f"kernel vs ref mismatch on {name}",
        )


@settings(max_examples=25, deadline=None)
@given(
    n_cases=st.integers(min_value=1, max_value=96),
    n_designs=st.sampled_from([8, 16, 32, 64]),
    block_d=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n_cases, n_designs, block_d, seed):
    if n_designs % block_d != 0:
        block_d = n_designs
    rng = np.random.default_rng(seed)
    cases, designs, scalars = make_inputs(rng, n_cases, n_designs)
    assert_kernel_matches_ref(cases, designs, scalars, block_d)


def test_kernel_at_artifact_shapes():
    """Exercise the exact shapes the AOT artifact exports."""
    from compile import model

    rng = np.random.default_rng(7)
    cases, designs, scalars = make_inputs(rng, model.C_MAX - 20, model.D_MAX, pad_to=model.C_MAX)
    assert_kernel_matches_ref(cases, designs, scalars, dse_eval.BLOCK_D)


def test_zero_padding_is_inert():
    """Padded (occ=0) rows must not change the runtime."""
    rng = np.random.default_rng(11)
    cases, designs, scalars = make_inputs(rng, 20, 16)
    padded = np.zeros((64, 8), np.float32)
    padded[:20] = cases[:20]
    r1 = np.asarray(dse_eval.dse_eval(cases, designs, scalars, block_d=16)[0])
    r2 = np.asarray(dse_eval.dse_eval(padded, designs, scalars, block_d=16)[0])
    np.testing.assert_allclose(r1, r2, rtol=1e-6)


def test_runtime_monotone_in_bandwidth():
    rng = np.random.default_rng(3)
    cases, _, scalars = make_inputs(rng, 40, 8)
    bws = np.array([1, 2, 4, 8, 16, 32, 64, 128], np.float32)
    designs = np.zeros((8, 4), np.float32)
    designs[:, 0] = bws
    designs[:, 1] = 2.0
    designs[:, 2] = 1024.0
    designs[:, 3] = 200_000.0
    rt = np.asarray(dse_eval.dse_eval(cases, designs, scalars, block_d=8)[0])
    assert (np.diff(rt) <= 1e-3).all(), rt


def test_validity_budget_edges():
    """Designs exactly at the budget are valid; beyond are not."""
    rng = np.random.default_rng(5)
    cases, designs, scalars = make_inputs(rng, 10, 8)
    _, _, area, power, valid = (np.asarray(x) for x in ref.evaluate_ref(cases, designs, scalars))
    inside = (area <= scalars[ref.S_AREA_BUDGET]) & (power <= scalars[ref.S_POWER_BUDGET])
    np.testing.assert_array_equal(valid > 0.5, inside)


def test_bad_shapes_rejected():
    rng = np.random.default_rng(9)
    cases, designs, scalars = make_inputs(rng, 10, 8)
    with pytest.raises(AssertionError):
        dse_eval.dse_eval(cases[:, :7], designs, scalars, block_d=8)
    with pytest.raises(AssertionError):
        dse_eval.dse_eval(cases, designs, scalars, block_d=3)  # 8 % 3 != 0
