"""AOT pipeline checks: lowering succeeds, the HLO text parses-ish, and
executing the lowered computation matches the eager kernel."""

import os

import jax
import numpy as np

from compile import aot, model
from tests.test_kernel import make_inputs


def test_lowering_produces_hlo_text(tmp_path):
    out = tmp_path / "dse_eval.hlo.txt"
    n = aot.build(str(out))
    assert n > 1000
    text = out.read_text()
    assert text.startswith("HloModule")
    # Entry layout: three params of the agreed shapes.
    assert f"f32[{model.C_MAX},8]" in text
    assert f"f32[{model.D_MAX},4]" in text
    assert f"f32[{model.S_WIDTH}]" in text


def test_lowered_computation_matches_eager():
    rng = np.random.default_rng(21)
    cases, designs, scalars = make_inputs(rng, model.C_MAX - 28, model.D_MAX, pad_to=model.C_MAX)
    lowered = jax.jit(model.evaluate_designs).lower(*model.example_shapes())
    compiled = lowered.compile()
    got = compiled(cases, designs, scalars)
    want = model.evaluate_designs(cases, designs, scalars)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_make_artifacts_output_exists_or_buildable(tmp_path):
    """`make artifacts` writes to artifacts/; simulate it here."""
    out = tmp_path / "a" / "dse_eval.hlo.txt"
    aot.build(str(out))
    assert os.path.getsize(out) > 0
