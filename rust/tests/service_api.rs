//! Golden + round-trip tests for the service wire schema
//! (`maestro::service::api`).
//!
//! The goldens pin the **exact** encoded bytes of representative frames
//! — the daemon's protocol and the CLI's `--json` output are the same
//! encoder, so a golden change here is a wire-format break and must be
//! deliberate (bump `WIRE_VERSION` or keep the field optional). The
//! round-trips assert `decode(parse(dump(encode(x)))) == x` for every
//! `Request` and `Response` variant, including `ApiError`, in both the
//! fully-populated and the minimal (optional-fields-omitted) shapes.
//! Malformed frames must produce structured `ApiError`s, never panics.

use maestro::engine::analysis::Objective;
use maestro::service::api::{
    AnalyzeReply, AnalyzeRequest, ApiError, DoneReply, DseReply, DseRequest, DseSearch, LayerRow,
    MapReply, MapRequest, MapSearch, MetricCounter, MetricGauge, MetricHistogram, MetricsReply,
    PointRow, ProgressReply, Ratios, Request, RequestStats, Response, ShapeRow, SideTotals,
    SkippedRow, StatusReply,
};
use maestro::util::json::Json;

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

fn roundtrip_request(r: &Request) {
    let line = r.encode().dump();
    let parsed = Json::parse(&line).unwrap_or_else(|e| panic!("parse {line}: {e}"));
    let decoded = Request::decode(&parsed).unwrap_or_else(|e| panic!("decode {line}: {e:?}"));
    assert_eq!(decoded, *r, "request round trip via {line}");
}

fn roundtrip_response(r: &Response) {
    let line = r.encode_line();
    assert!(!line.contains('\n'), "one frame, one line: {line}");
    let parsed = Json::parse(&line).unwrap_or_else(|e| panic!("parse {line}: {e}"));
    let decoded = Response::decode(&parsed).unwrap_or_else(|e| panic!("decode {line}: {e:?}"));
    assert_eq!(decoded, *r, "response round trip via {line}");
}

fn decode_request_err(line: &str) -> ApiError {
    let parsed = Json::parse(line).unwrap_or_else(|e| panic!("parse {line}: {e}"));
    Request::decode(&parsed).expect_err("must not decode")
}

fn sample_stats() -> RequestStats {
    RequestStats {
        analyses: 3,
        disk_hits: 2,
        warm_hits: 8,
        profile_hits: 1,
        designs_evaluated: 96,
        wall_seconds: 0.25,
    }
}

fn sample_point() -> PointRow {
    PointRow {
        dataflow: "kc-p@256".into(),
        pes: 256,
        bandwidth: 64,
        l1: 512,
        l2: 262144,
        runtime: 123456.0,
        energy_pj: 7.5e9,
        area_mm2: 12.25,
        power_mw: 420.5,
    }
}

// ---------------------------------------------------------------------
// Goldens: exact wire bytes
// ---------------------------------------------------------------------

#[test]
fn golden_analyze_request() {
    let r = Request::Analyze(AnalyzeRequest {
        id: Some(7),
        model: "vgg16".into(),
        dataflow: "adaptive".into(),
        pes: 256,
        bw: 16,
        objective: Objective::Runtime,
        tile_resolution: 6,
        per_layer: false,
    });
    assert_eq!(
        r.encode().dump(),
        r#"{"v":1,"kind":"analyze","id":7,"model":"vgg16","dataflow":"adaptive","pes":256,"bw":16,"objective":"runtime","tile_resolution":6,"per_layer":false}"#
    );
}

#[test]
fn golden_map_request() {
    let r = Request::Map(MapRequest {
        id: Some(2),
        model: "alexnet".into(),
        pes: 64,
        bw: 32,
        objective: Objective::Edp,
        tile_resolution: 4,
        budget: 100,
        budget_seconds: 1.5,
        threads: 2,
        stream: false,
    });
    let line = r.encode().dump();
    assert_eq!(
        line,
        r#"{"v":1,"kind":"map","id":2,"model":"alexnet","pes":64,"bw":32,"objective":"edp","tile_resolution":4,"budget":100,"budget_seconds":1.5,"threads":2}"#
    );
    assert!(!line.contains("\"stream\""), "stream=false must be omitted, not encoded: {line}");
}

#[test]
fn golden_dse_request_omits_empty_layer() {
    let r = Request::Dse(DseRequest {
        id: None,
        family: "kc-p".into(),
        model: "vgg16".into(),
        layer: String::new(),
        network: true,
        resolution: 12,
        bw_resolution: 12,
        mapspace: false,
        tile_resolution: 6,
        strategy: "guided".into(),
        seed: 9,
        budget: 5000,
        budget_seconds: 0.0,
        threads: 2,
        keep_points: false,
        stream: false,
    });
    let line = r.encode().dump();
    assert_eq!(
        line,
        r#"{"v":1,"kind":"dse","family":"kc-p","model":"vgg16","network":true,"resolution":12,"bw_resolution":12,"mapspace":false,"tile_resolution":6,"strategy":"guided","seed":9,"budget":5000,"budget_seconds":0,"threads":2,"keep_points":false}"#
    );
    assert!(!line.contains("\"layer\""), "empty layer must be omitted, not null: {line}");
    assert!(!line.contains("\"id\""), "absent id must be omitted: {line}");
}

#[test]
fn golden_streaming_requests_append_the_stream_flag() {
    // `stream: true` is the only difference from the non-streaming
    // goldens above — the flag appends after the existing fields, so
    // pre-streaming consumers see unchanged frames.
    let r = Request::Map(MapRequest {
        id: Some(2),
        model: "alexnet".into(),
        pes: 64,
        bw: 32,
        objective: Objective::Edp,
        tile_resolution: 4,
        budget: 100,
        budget_seconds: 1.5,
        threads: 2,
        stream: true,
    });
    assert_eq!(
        r.encode().dump(),
        r#"{"v":1,"kind":"map","id":2,"model":"alexnet","pes":64,"bw":32,"objective":"edp","tile_resolution":4,"budget":100,"budget_seconds":1.5,"threads":2,"stream":true}"#
    );
}

#[test]
fn golden_progress_frame() {
    let r = Response::Progress(ProgressReply {
        id: Some(9),
        wave: 3,
        evaluated: 1280,
        frontier_add: vec![sample_point()],
        frontier_remove: Vec::new(),
    });
    assert_eq!(
        r.encode_line(),
        r#"{"v":1,"kind":"progress","id":9,"ok":true,"wave":3,"evaluated":1280,"frontier_add":[{"dataflow":"kc-p@256","pes":256,"bandwidth":64,"l1":512,"l2":262144,"runtime":123456,"energy_pj":7500000000,"area_mm2":12.25,"power_mw":420.5}],"frontier_remove":[]}"#
    );
}

#[test]
fn golden_control_requests() {
    assert_eq!(Request::Status.encode().dump(), r#"{"v":1,"kind":"status"}"#);
    assert_eq!(Request::Metrics.encode().dump(), r#"{"v":1,"kind":"metrics"}"#);
    assert_eq!(Request::Cancel { id: 42 }.encode().dump(), r#"{"v":1,"kind":"cancel","id":42}"#);
    assert_eq!(Request::Shutdown.encode().dump(), r#"{"v":1,"kind":"shutdown"}"#);
}

#[test]
fn golden_status_and_done_replies() {
    // The uptime/requests fields grew in PR 10: appended at the end of
    // the frame so pre-PR-10 consumers see an unchanged prefix.
    let status = Response::Status(StatusReply {
        entries: 12,
        max_entries: 0,
        hits: 34,
        disk_hits: 5,
        misses: 13,
        evictions: 0,
        queue_depth: 2,
        inflight: 1,
        workers: 4,
        pool_utilization: 0.75,
        uptime_ms: 61234,
        requests_done: 40,
        requests_failed: 2,
    });
    assert_eq!(
        status.encode_line(),
        r#"{"v":1,"kind":"status","ok":true,"entries":12,"max_entries":0,"hits":34,"disk_hits":5,"misses":13,"evictions":0,"queue_depth":2,"inflight":1,"workers":4,"pool_utilization":0.75,"uptime_ms":61234,"requests_done":40,"requests_failed":2}"#
    );
    let done = Response::Done(DoneReply { id: None, what: "shutdown".into() });
    assert_eq!(done.encode_line(), r#"{"v":1,"kind":"done","ok":true,"what":"shutdown"}"#);
}

#[test]
fn golden_metrics_reply() {
    let r = Response::Metrics(MetricsReply {
        uptime_ms: 61234,
        counters: vec![MetricCounter { name: "serve.requests_done".into(), value: 40 }],
        gauges: vec![MetricGauge { name: "serve.pool_utilization".into(), value: 0.75 }],
        histograms: vec![MetricHistogram {
            name: "serve.wave_seconds".into(),
            bounds: vec![0.5, 2.0],
            buckets: vec![3, 1, 0],
            count: 4,
            sum: 2.25,
        }],
    });
    assert_eq!(
        r.encode_line(),
        r#"{"v":1,"kind":"metrics","ok":true,"uptime_ms":61234,"counters":[{"name":"serve.requests_done","value":40}],"gauges":[{"name":"serve.pool_utilization","value":0.75}],"histograms":[{"name":"serve.wave_seconds","bounds":[0.5,2],"buckets":[3,1,0],"count":4,"sum":2.25}]}"#
    );
}

#[test]
fn golden_overloaded_error_reply() {
    let r = Response::error(Some(3), ApiError::overloaded(500, 16));
    assert_eq!(
        r.encode_line(),
        r#"{"v":1,"kind":"error","id":3,"ok":false,"error":{"code":"overloaded","message":"job queue full (16 request(s) queued); retry later","retry_after_ms":500,"diagnostics":[]}}"#
    );
}

#[test]
fn golden_bad_request_with_diagnostics() {
    let err = ApiError::bad_request("unknown model 'vgg17'")
        .with_diagnostics(vec!["known: vgg16, alexnet".into()]);
    let r = Response::error(None, err);
    assert_eq!(
        r.encode_line(),
        r#"{"v":1,"kind":"error","ok":false,"error":{"code":"bad_request","message":"unknown model 'vgg17'","diagnostics":["known: vgg16, alexnet"]}}"#
    );
}

// ---------------------------------------------------------------------
// Round trips: every variant, populated and minimal
// ---------------------------------------------------------------------

#[test]
fn every_request_variant_round_trips() {
    roundtrip_request(&Request::Analyze(AnalyzeRequest {
        id: Some(1),
        model: "resnet50".into(),
        dataflow: "mapped".into(),
        pes: 1024,
        bw: 128,
        objective: Objective::Energy,
        tile_resolution: 8,
        per_layer: true,
    }));
    roundtrip_request(&Request::Map(MapRequest {
        id: None,
        model: "mobilenetv2".into(),
        pes: 168,
        bw: 24,
        objective: Objective::Runtime,
        tile_resolution: 6,
        budget: 0,
        budget_seconds: 2.5,
        threads: 8,
        stream: true,
    }));
    roundtrip_request(&Request::Dse(DseRequest {
        id: Some(11),
        family: "yr-p".into(),
        model: "unet".into(),
        layer: "conv1".into(),
        network: false,
        resolution: 16,
        bw_resolution: 8,
        mapspace: true,
        tile_resolution: 5,
        strategy: "random".into(),
        seed: 77,
        budget: 123456,
        budget_seconds: 0.5,
        threads: 4,
        keep_points: true,
        stream: true,
    }));
    roundtrip_request(&Request::Status);
    roundtrip_request(&Request::Metrics);
    roundtrip_request(&Request::Cancel { id: 9 });
    roundtrip_request(&Request::Shutdown);
}

#[test]
fn analyze_reply_round_trips_full_and_minimal() {
    roundtrip_response(&Response::Analyze(AnalyzeReply {
        id: Some(4),
        network: "vgg16".into(),
        dataflow: "mapped".into(),
        layers: 13,
        shapes: 9,
        runtime_cycles: 1.5e8,
        energy_uj: 421.75,
        gmacs: 15.35,
        mapspace_candidates: Some(188),
        per_layer: vec![LayerRow {
            layer: "conv1_1".into(),
            dataflow: "kc-p".into(),
            runtime: 80000.0,
            energy_uj: 3.5,
            util: 0.875,
        }],
        skipped: vec![SkippedRow { layer: "fc6".into(), reason: "unmappable: K > PEs".into() }],
        stats: sample_stats(),
    }));
    // Minimal: no id, no mapspace union, empty rows.
    roundtrip_response(&Response::Analyze(AnalyzeReply {
        id: None,
        network: "alexnet".into(),
        dataflow: "adaptive".into(),
        layers: 5,
        shapes: 5,
        runtime_cycles: 0.0,
        energy_uj: 0.0,
        gmacs: 0.0,
        mapspace_candidates: None,
        per_layer: Vec::new(),
        skipped: Vec::new(),
        stats: RequestStats::default(),
    }));
}

#[test]
fn map_reply_round_trips_full_and_minimal() {
    roundtrip_response(&Response::Map(MapReply {
        id: Some(5),
        network: "vgg16".into(),
        objective: "runtime".into(),
        per_shape: vec![ShapeRow {
            representative: "conv3_1".into(),
            members: 2,
            mapping: "kc-p ct=4 kt=32".into(),
            runtime: 65536.0,
            energy_uj: 12.5,
            util: 0.96875,
        }],
        skipped: vec![SkippedRow { layer: "fc8".into(), reason: "no candidate maps".into() }],
        mapper: SideTotals { layers: 13, runtime: 1.0e7, energy_uj: 400.25 },
        fixed: SideTotals { layers: 13, runtime: 1.5e7, energy_uj: 410.5 },
        ratios: Some(Ratios { runtime: 1.5, energy: 1.0256, edp: 1.5384 }),
        search: MapSearch {
            shapes: 9,
            combos: 1260,
            candidates: 188,
            evaluated: 1692,
            budget_skipped: 0,
            defaulted: 1,
        },
        stats: sample_stats(),
    }));
    // Minimal: no ratios (layer sets differ), nothing mapped.
    roundtrip_response(&Response::Map(MapReply {
        id: None,
        network: "dcgan".into(),
        objective: "edp".into(),
        per_shape: Vec::new(),
        skipped: Vec::new(),
        mapper: SideTotals { layers: 0, runtime: 0.0, energy_uj: 0.0 },
        fixed: SideTotals { layers: 4, runtime: 2.0e6, energy_uj: 55.0 },
        ratios: None,
        search: MapSearch::default(),
        stats: RequestStats::default(),
    }));
}

#[test]
fn dse_reply_round_trips_full_and_minimal() {
    roundtrip_response(&Response::Dse(DseReply {
        id: Some(6),
        family: "kc-p".into(),
        workload: "vgg16/conv2".into(),
        layers: 1,
        shapes: 1,
        gmacs: 1.85,
        search: DseSearch {
            strategy: "guided".into(),
            total_designs: 2304,
            evaluated: 640,
            valid: 512,
            pruned: 96,
            unmappable: 32,
            budget_skipped: 0,
            waves: 5,
        },
        frontier: vec![sample_point(), PointRow { pes: 512, ..sample_point() }],
        throughput_opt: Some(sample_point()),
        energy_opt: Some(PointRow { energy_pj: 1.25e9, ..sample_point() }),
        stats: sample_stats(),
    }));
    // Minimal: empty frontier, no optima.
    roundtrip_response(&Response::Dse(DseReply {
        id: None,
        family: "yx-p".into(),
        workload: "vgg16 (network)".into(),
        layers: 13,
        shapes: 9,
        gmacs: 15.35,
        search: DseSearch::default(),
        frontier: Vec::new(),
        throughput_opt: None,
        energy_opt: None,
        stats: RequestStats::default(),
    }));
}

#[test]
fn control_replies_round_trip() {
    roundtrip_response(&Response::Status(StatusReply {
        entries: 1,
        max_entries: 4096,
        hits: 2,
        disk_hits: 1,
        misses: 3,
        evictions: 4,
        queue_depth: 7,
        inflight: 2,
        workers: 8,
        pool_utilization: 0.25,
        uptime_ms: 120500,
        requests_done: 9,
        requests_failed: 1,
    }));
    roundtrip_response(&Response::Done(DoneReply { id: Some(42), what: "cancel".into() }));
}

#[test]
fn metrics_reply_round_trips_full_and_minimal() {
    roundtrip_response(&Response::Metrics(MetricsReply {
        uptime_ms: 5000,
        counters: vec![
            MetricCounter { name: "cache.flushes".into(), value: 3 },
            MetricCounter { name: "serve.requests_done".into(), value: 17 },
        ],
        gauges: vec![MetricGauge { name: "serve.queue_depth".into(), value: 2.0 }],
        histograms: vec![MetricHistogram {
            name: "serve.request_seconds".into(),
            bounds: vec![0.001, 0.02, 0.5],
            buckets: vec![4, 9, 3, 1],
            count: 17,
            sum: 1.75,
        }],
    }));
    // Minimal: a daemon with no instruments registered yet.
    roundtrip_response(&Response::Metrics(MetricsReply::default()));
}

#[test]
fn progress_frames_round_trip_full_and_minimal() {
    roundtrip_response(&Response::Progress(ProgressReply {
        id: Some(8),
        wave: 12,
        evaluated: 4096,
        frontier_add: vec![sample_point()],
        frontier_remove: vec![PointRow { pes: 1024, ..sample_point() }],
    }));
    roundtrip_response(&Response::Progress(ProgressReply {
        id: None,
        wave: 1,
        evaluated: 0,
        frontier_add: Vec::new(),
        frontier_remove: Vec::new(),
    }));
}

#[test]
fn every_error_code_round_trips() {
    for err in [
        ApiError::bad_request("nope"),
        ApiError::overloaded(250, 4),
        ApiError::cancelled(),
        ApiError::internal("executor dropped the request")
            .with_diagnostics(vec!["cause 1".into(), "cause 2".into()]),
    ] {
        roundtrip_response(&Response::error(Some(13), err.clone()));
        roundtrip_response(&Response::error(None, err));
    }
}

#[test]
fn strings_with_escapes_survive_the_wire() {
    // Layer names and diagnostics can carry quotes/newlines (anyhow
    // context chains do); the frame must stay one line and round-trip.
    let err = ApiError::bad_request("bad \"flag\"\nsecond line\ttabbed")
        .with_diagnostics(vec!["path\\with\\backslashes".into()]);
    roundtrip_response(&Response::error(None, err));
}

// ---------------------------------------------------------------------
// Malformed frames: structured errors, never panics
// ---------------------------------------------------------------------

#[test]
fn version_mismatch_is_rejected() {
    let e = decode_request_err(r#"{"v":2,"kind":"status"}"#);
    assert_eq!(e.code, "bad_request");
    assert!(e.message.contains("unsupported wire version 2"), "{}", e.message);

    let e = decode_request_err(r#"{"kind":"status"}"#);
    assert!(e.message.contains("missing wire version"), "{}", e.message);
}

#[test]
fn missing_and_unknown_kinds_are_rejected() {
    let e = decode_request_err(r#"{"v":1}"#);
    assert!(e.message.contains("missing 'kind'"), "{}", e.message);

    let e = decode_request_err(r#"{"v":1,"kind":"frobnicate"}"#);
    assert!(e.message.contains("unknown request kind 'frobnicate'"), "{}", e.message);
    assert!(
        e.message.contains("analyze | map | dse | status | metrics | cancel | shutdown"),
        "{}",
        e.message
    );
}

#[test]
fn field_type_errors_are_structured() {
    // analyze requires a model.
    let e = decode_request_err(r#"{"v":1,"kind":"analyze"}"#);
    assert!(e.message.contains("'model'"), "{}", e.message);
    // ids must be non-negative integers.
    let e = decode_request_err(r#"{"v":1,"kind":"analyze","id":"seven","model":"vgg16"}"#);
    assert!(e.message.contains("'id'"), "{}", e.message);
    let e = decode_request_err(r#"{"v":1,"kind":"dse","seed":-3}"#);
    assert!(e.message.contains("'seed'"), "{}", e.message);
    // cancel without a target.
    let e = decode_request_err(r#"{"v":1,"kind":"cancel"}"#);
    assert!(e.message.contains("cancel: missing 'id'"), "{}", e.message);
}

#[test]
fn unknown_fields_are_ignored_for_forward_compat() {
    let line = r#"{"v":1,"kind":"status","future_field":{"deep":[1,2,3]}}"#;
    let parsed = Json::parse(line).unwrap();
    assert_eq!(Request::decode(&parsed).unwrap(), Request::Status);
}

#[test]
fn truncated_frames_fail_parse_not_decode() {
    for bad in [r#"{"v":1,"kind":"analyze""#, "", "not json at all", "{]"] {
        assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
    }
}
