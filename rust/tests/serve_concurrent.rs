//! Concurrency tests for the shared-pool daemon scheduler: many
//! simultaneous clients with overlapping analyze / map / dse traffic
//! against a multi-worker daemon.
//!
//! The acceptance bar these tests pin:
//!
//! * **Bit-identical replies** — every reply under concurrent shared-
//!   pool execution matches a serial in-process reference run
//!   byte-for-byte, modulo the documented diagnostic carve-out (the
//!   `stats` cache counters and wall clock, which depend on who warmed
//!   the store first).
//! * **Deterministic streams** — a streaming dse emits the same
//!   progress-frame sequence for any worker count and any concurrent
//!   traffic, and replaying its frontier deltas reconstructs exactly
//!   the final reply's frontier.
//! * **Cancellation** — cancelling a streaming dse mid-flight ends its
//!   frame sequence with a well-formed `cancelled` error while other
//!   requests on the same pool complete normally.
//!
//! Note the strategy choice: `exhaustive` emits its whole space as one
//! wave (a single progress frame), so the streaming tests use `guided`,
//! whose refinement loop produces a genuine multi-wave frame sequence
//! with nonempty frontier deltas.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use maestro::cache::SharedStore;
use maestro::engine::analysis::Objective;
use maestro::service::api::{
    AnalyzeRequest, DseRequest, MapRequest, PointRow, ProgressReply, Request, Response,
};
use maestro::service::daemon::{Daemon, ServeConfig};
use maestro::service::exec;
use maestro::util::json::Json;

/// A blocking line-framed client that understands streaming replies.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { stream, reader }
    }

    fn send(&mut self, request: &Request) {
        writeln!(self.stream, "{}", request.encode().dump()).expect("write frame");
        self.stream.flush().expect("flush frame");
    }

    fn read_frame(&mut self) -> Response {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read reply");
        assert!(n > 0, "daemon closed the connection instead of replying");
        let v = Json::parse(line.trim())
            .unwrap_or_else(|e| panic!("reply not JSON ({e}): {line}"));
        Response::decode(&v).unwrap_or_else(|e| panic!("undecodable reply {e:?}: {}", v.dump()))
    }

    /// One request, one (non-streaming) reply.
    fn request(&mut self, request: &Request) -> Response {
        self.send(request);
        self.read_frame()
    }

    /// One streaming request: collect every progress frame, return them
    /// with the final (non-progress) frame.
    fn request_streaming(&mut self, request: &Request) -> (Vec<ProgressReply>, Response) {
        self.send(request);
        let mut frames = Vec::new();
        loop {
            match self.read_frame() {
                Response::Progress(p) => frames.push(p),
                other => return (frames, other),
            }
        }
    }
}

fn analyze_request(id: u64, model: &str) -> Request {
    Request::Analyze(AnalyzeRequest {
        id: Some(id),
        model: model.into(),
        dataflow: "adaptive".into(),
        pes: 256,
        bw: 16,
        objective: Objective::Runtime,
        tile_resolution: 6,
        per_layer: false,
    })
}

fn map_request(id: u64) -> Request {
    Request::Map(MapRequest {
        id: Some(id),
        model: "vgg16".into(),
        pes: 256,
        bw: 16,
        objective: Objective::Runtime,
        tile_resolution: 4,
        budget: 32,
        budget_seconds: 0.0,
        threads: 1,
        stream: false,
    })
}

fn exhaustive_dse(id: u64, resolution: usize) -> Request {
    Request::Dse(DseRequest {
        id: Some(id),
        family: "kc-p".into(),
        model: "vgg16".into(),
        layer: String::new(),
        network: false,
        resolution,
        bw_resolution: resolution,
        mapspace: false,
        tile_resolution: 6,
        strategy: "exhaustive".into(),
        seed: 1,
        budget: 0,
        budget_seconds: 0.0,
        threads: 1,
        keep_points: false,
        stream: false,
    })
}

fn guided_dse(id: u64, model: &str, network: bool, resolution: usize, bw: usize) -> Request {
    Request::Dse(DseRequest {
        id: Some(id),
        family: "kc-p".into(),
        model: model.into(),
        layer: String::new(),
        network,
        resolution,
        bw_resolution: bw,
        mapspace: false,
        tile_resolution: 6,
        strategy: "guided".into(),
        seed: 1,
        budget: 0,
        budget_seconds: 0.0,
        threads: 1,
        keep_points: false,
        stream: true,
    })
}

/// Run one request serially, in process, on `store` — the reference
/// the daemon's concurrent replies must match bit-for-bit.
fn reference_reply(store: &Arc<SharedStore>, request: &Request) -> Response {
    match request {
        Request::Analyze(r) => {
            let out = exec::run_analyze(store, r).expect("reference analyze");
            Response::Analyze(exec::analyze_reply(r, &out))
        }
        Request::Map(r) => {
            let out = exec::run_map(store, r, None).expect("reference map");
            Response::Map(exec::map_reply(r, &out))
        }
        Request::Dse(r) => {
            let prep = exec::prepare_dse(r).expect("reference dse prep");
            let out = exec::run_prepared_dse(store, &prep, r, true, None).expect("reference dse");
            Response::Dse(exec::dse_reply(r, &prep, &out))
        }
        other => panic!("not a work request: {other:?}"),
    }
}

/// Encode a work reply with the diagnostic `stats` fields (cache
/// split + wall clock) zeroed. Everything else — including the
/// deterministic `search` counters and `stats.designs_evaluated` —
/// must match byte-for-byte.
fn scrubbed_line(reply: &Response) -> String {
    let mut reply = reply.clone();
    let stats = match &mut reply {
        Response::Analyze(r) => &mut r.stats,
        Response::Map(r) => &mut r.stats,
        Response::Dse(r) => &mut r.stats,
        other => panic!("work reply expected, got {other:?}"),
    };
    stats.analyses = 0;
    stats.disk_hits = 0;
    stats.warm_hits = 0;
    stats.profile_hits = 0;
    stats.wall_seconds = 0.0;
    reply.encode_line()
}

/// Replay a progress-frame sequence's frontier deltas (removes, then
/// adds, per frame — the wire contract) into the accumulated frontier,
/// checking well-formedness along the way.
fn replay_frontier(frames: &[ProgressReply]) -> Vec<PointRow> {
    let mut acc: Vec<PointRow> = Vec::new();
    let mut last_evaluated = 0;
    for (i, f) in frames.iter().enumerate() {
        assert_eq!(f.wave, (i + 1) as u64, "waves must arrive 1-based and in order");
        assert!(
            f.evaluated >= last_evaluated,
            "evaluated must be nondecreasing: wave {} reports {} after {}",
            f.wave,
            f.evaluated,
            last_evaluated
        );
        last_evaluated = f.evaluated;
        for rm in &f.frontier_remove {
            let pos = acc
                .iter()
                .position(|p| p == rm)
                .unwrap_or_else(|| panic!("wave {} removed a point not on the frontier", f.wave));
            acc.remove(pos);
        }
        for add in &f.frontier_add {
            assert!(!acc.iter().any(|p| p == add), "wave {} re-added a live point", f.wave);
            acc.push(add.clone());
        }
    }
    acc
}

/// Order-insensitive view of a point set (PointRow is PartialEq but
/// not Ord; the Debug form is a faithful total key).
fn sorted_points(points: &[PointRow]) -> Vec<String> {
    let mut v: Vec<String> = points.iter().map(|p| format!("{p:?}")).collect();
    v.sort();
    v
}

/// Six clients fire overlapping analyze / map / dse requests at a
/// 2-worker daemon at once; every reply must match the serial
/// in-process reference byte-for-byte outside the diagnostic carve-out.
#[test]
fn concurrent_mixed_traffic_is_bit_identical_to_serial_references() {
    let requests = vec![
        analyze_request(1, "vgg16"),
        analyze_request(2, "resnet50"),
        map_request(3),
        exhaustive_dse(4, 4),
        exhaustive_dse(5, 6),
        // Same workload as id 1: coalescing onto the shared store must
        // not change the payload, only the (scrubbed) cache counters.
        analyze_request(6, "vgg16"),
    ];

    let store = Arc::new(SharedStore::new());
    let references: Vec<String> =
        requests.iter().map(|r| scrubbed_line(&reference_reply(&store, r))).collect();

    let daemon = Daemon::spawn(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..ServeConfig::default()
    })
    .expect("spawn daemon");
    let addr = daemon.addr();

    let replies: Vec<Response> = std::thread::scope(|scope| {
        let handles: Vec<_> = requests
            .iter()
            .map(|req| scope.spawn(move || Client::connect(addr).request(req)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    for (i, (reply, reference)) in replies.iter().zip(&references).enumerate() {
        assert_eq!(
            &scrubbed_line(reply),
            reference,
            "request {} diverged from its serial reference",
            requests[i].id().unwrap()
        );
    }

    let mut client = Client::connect(addr);
    match client.request(&Request::Status) {
        Response::Status(s) => {
            assert_eq!(s.workers, 2, "status must report the shared pool size");
            assert!(s.entries > 0, "the shared store must hold the traffic's analyses");
        }
        other => panic!("expected status reply, got {other:?}"),
    }
    match client.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean daemon exit");
}

/// A streaming guided dse must emit the same frame sequence on a
/// 1-worker idle daemon and a 2-worker daemon handling concurrent
/// traffic — and replaying the deltas must land exactly on the final
/// reply's frontier.
#[test]
fn streamed_frontier_deltas_are_deterministic_and_replay_to_the_final() {
    let run = |workers: usize, with_traffic: bool| -> (Vec<ProgressReply>, Response) {
        let daemon = Daemon::spawn(ServeConfig {
            addr: "127.0.0.1:0".into(),
            workers,
            ..ServeConfig::default()
        })
        .expect("spawn daemon");
        let addr = daemon.addr();

        // Concurrent load sharing the pool while the stream runs.
        let traffic = with_traffic.then(|| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                for id in 100..103 {
                    match c.request(&analyze_request(id, "vgg16")) {
                        Response::Analyze(r) => assert_eq!(r.id, Some(id)),
                        other => panic!("expected analyze reply, got {other:?}"),
                    }
                }
            })
        });

        let mut client = Client::connect(addr);
        let (frames, final_reply) = client.request_streaming(&guided_dse(11, "vgg16", false, 10, 6));
        if let Some(t) = traffic {
            t.join().expect("traffic thread");
        }
        match client.request(&Request::Shutdown) {
            Response::Done(d) => assert_eq!(d.what, "shutdown"),
            other => panic!("expected done reply, got {other:?}"),
        }
        daemon.join().expect("clean daemon exit");
        (frames, final_reply)
    };

    let (quiet_frames, quiet_final) = run(1, false);
    let (busy_frames, busy_final) = run(2, true);

    // Determinism: worker count and concurrent traffic must not change
    // a single frame or the final payload.
    assert_eq!(quiet_frames, busy_frames, "frame sequences must be identical");
    assert_eq!(scrubbed_line(&quiet_final), scrubbed_line(&busy_final));

    let dse = match &quiet_final {
        Response::Dse(r) => r,
        other => panic!("expected dse reply, got {other:?}"),
    };
    assert_eq!(dse.id, Some(11));
    assert!(dse.search.evaluated > 0);
    assert!(!dse.frontier.is_empty());
    assert!(
        quiet_frames.len() >= 2,
        "a guided sweep must stream multiple waves, got {}",
        quiet_frames.len()
    );
    for f in &quiet_frames {
        assert_eq!(f.id, Some(11), "progress frames must echo the request id");
    }

    // The streamed prefix is the final result: one frame per wave, the
    // last frame's counters equal the final counters, and the replayed
    // delta sequence reconstructs the final frontier exactly.
    let last = quiet_frames.last().unwrap();
    assert_eq!(last.wave, dse.search.waves, "one progress frame per absorbed wave");
    assert_eq!(last.evaluated, dse.search.evaluated);
    let replayed = replay_frontier(&quiet_frames);
    assert_eq!(
        sorted_points(&replayed),
        sorted_points(&dse.frontier),
        "replayed frontier deltas must land on the final frontier"
    );
}

/// The observation-only telemetry contract (PR 10): replies and
/// streamed frame sequences are **bit-identical** with tracing off,
/// fully on, and sampled — and the `metrics`/`status` frames actually
/// carry the traffic that ran.
///
/// (Trace *structure* is validated in `rust/tests/obs_trace.rs`, where
/// the test owns every recording thread; here other tests' daemons may
/// legitimately have spans open mid-export.)
#[test]
fn telemetry_on_off_or_sampled_never_changes_replies_or_streams() {
    use maestro::obs::trace;

    // One fixed traffic mix, exercised per telemetry mode against a
    // fresh 2-worker daemon: three plain requests, one streaming
    // guided dse, then status + metrics probes.
    let run = |sample: Option<u64>| {
        match sample {
            None => trace::disable(),
            Some(n) => trace::enable(n),
        }
        let daemon = Daemon::spawn(ServeConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            ..ServeConfig::default()
        })
        .expect("spawn daemon");
        let addr = daemon.addr();
        let replies: Vec<String> = [analyze_request(1, "vgg16"), map_request(2), exhaustive_dse(3, 4)]
            .iter()
            .map(|r| scrubbed_line(&Client::connect(addr).request(r)))
            .collect();
        let mut client = Client::connect(addr);
        let (frames, final_reply) = client.request_streaming(&guided_dse(4, "vgg16", false, 8, 6));
        let status = client.request(&Request::Status);
        let metrics = client.request(&Request::Metrics);
        match client.request(&Request::Shutdown) {
            Response::Done(d) => assert_eq!(d.what, "shutdown"),
            other => panic!("expected done reply, got {other:?}"),
        }
        daemon.join().expect("clean daemon exit");
        (replies, frames, scrubbed_line(&final_reply), status, metrics)
    };

    let (off_replies, off_frames, off_final, _, _) = run(None);
    let (on_replies, on_frames, on_final, on_status, on_metrics) = run(Some(1));
    let (sampled_replies, sampled_frames, sampled_final, _, _) = run(Some(3));
    trace::disable();

    // The determinism pin: telemetry mode must not move a single byte
    // of any reply or any streamed frame.
    assert_eq!(off_replies, on_replies, "replies changed with tracing on");
    assert_eq!(off_replies, sampled_replies, "replies changed with sampled tracing");
    assert_eq!(off_frames, on_frames, "stream frames changed with tracing on");
    assert_eq!(off_frames, sampled_frames, "stream frames changed with sampled tracing");
    assert_eq!(off_final, on_final, "final stream reply changed with tracing on");
    assert_eq!(off_final, sampled_final, "final stream reply changed with sampled tracing");

    // The instrumented daemon saw exactly this test's 4 work requests
    // (per-daemon counters), all successful.
    match &on_status {
        Response::Status(s) => {
            assert_eq!(s.requests_done, 4, "status must count concluded work requests");
            assert_eq!(s.requests_failed, 0, "no request in this mix fails");
            assert!(s.uptime_ms > 0, "uptime must tick while requests run");
        }
        other => panic!("expected status reply, got {other:?}"),
    }

    // The metrics frame reflects the traffic (registry is process-wide,
    // so counts are lower bounds under parallel tests).
    match &on_metrics {
        Response::Metrics(m) => {
            let done = m
                .counters
                .iter()
                .find(|c| c.name == "serve.requests_done")
                .expect("serve.requests_done counter registered");
            assert!(done.value >= 4, "at least this test's requests counted: {}", done.value);
            let waves = m
                .histograms
                .iter()
                .find(|h| h.name == "serve.wave_seconds")
                .expect("serve.wave_seconds histogram registered");
            assert!(waves.count > 0, "scheduler waves must be observed");
            assert_eq!(
                waves.buckets.len(),
                waves.bounds.len() + 1,
                "histogram carries its overflow bucket"
            );
            assert_eq!(waves.count, waves.buckets.iter().sum::<u64>());
        }
        other => panic!("expected metrics reply, got {other:?}"),
    }
}

/// Cancelling a big streaming dse mid-flight must end its frame
/// sequence with a well-formed `cancelled` error frame, while a small
/// concurrent stream on the same pool completes normally.
#[test]
fn midstream_cancel_ends_the_stream_while_other_streams_complete() {
    let daemon = Daemon::spawn(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..ServeConfig::default()
    })
    .expect("spawn daemon");
    let addr = daemon.addr();

    // Victim: a guided network sweep big enough that the cancel lands
    // between its refinement waves.
    let victim = std::thread::spawn(move || {
        Client::connect(addr).request_streaming(&guided_dse(77, "resnet50", true, 12, 12))
    });

    // Survivor: a small stream sharing the pool throughout.
    let survivor = std::thread::spawn(move || {
        Client::connect(addr).request_streaming(&guided_dse(78, "vgg16", false, 6, 4))
    });

    // Canceller: retry until the victim's id shows up in flight.
    let mut canceller = Client::connect(addr);
    let mut acknowledged = false;
    for _ in 0..2000 {
        match canceller.request(&Request::Cancel { id: 77 }) {
            Response::Done(d) => {
                assert_eq!(d.what, "cancel");
                acknowledged = true;
                break;
            }
            Response::Error(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
            other => panic!("expected done or error reply, got {other:?}"),
        }
    }
    assert!(acknowledged, "cancel never found the in-flight dse");

    // The victim's stream ends with a well-formed cancelled error; the
    // frames before it are still a valid prefix of the sweep.
    let (victim_frames, victim_final) = victim.join().expect("victim thread");
    match &victim_final {
        Response::Error(e) => {
            assert_eq!(e.error.code, "cancelled", "cancel must end the stream: {e:?}");
            assert_eq!(e.id, Some(77), "the error frame must echo the request id");
        }
        other => panic!("cancelled dse must reply with a cancelled error, got {other:?}"),
    }
    replay_frontier(&victim_frames); // prefix well-formedness only

    // The survivor is untouched: full frame sequence, normal final.
    let (survivor_frames, survivor_final) = survivor.join().expect("survivor thread");
    let dse = match &survivor_final {
        Response::Dse(r) => r,
        other => panic!("survivor stream must complete normally, got {other:?}"),
    };
    assert_eq!(dse.id, Some(78));
    assert!(dse.search.evaluated > 0);
    assert_eq!(
        sorted_points(&replay_frontier(&survivor_frames)),
        sorted_points(&dse.frontier),
        "survivor's streamed deltas must still replay to its final frontier"
    );

    // The daemon is healthy afterwards.
    match canceller.request(&Request::Status) {
        Response::Status(_) => {}
        other => panic!("daemon wedged after cancel: {other:?}"),
    }
    match canceller.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean daemon exit");
}
