//! End-to-end integration: the whole pipeline — zoo model -> dataflow
//! styles -> analysis -> case tables -> coordinator -> Pareto — plus the
//! paper's qualitative claims as assertions (weaker than the figures'
//! exact numbers, strong enough to catch regressions in the model's
//! *shape*).

use maestro::coordinator::{run_jobs, Backend, DseJob};
use maestro::dse::engine::{sweep, SweepConfig};
use maestro::dse::pareto::{best, Optimize};
use maestro::dse::space::DesignSpace;
use maestro::engine::analysis::{adaptive_network, analyze_layer, analyze_network, Objective};
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::network::Network;
use maestro::model::tensor::TensorKind;
use maestro::model::zoo::{self, mobilenet_v2, resnet50, vgg16};
use maestro::runtime::DesignIn;

#[test]
fn paper_shape_yr_p_has_higher_early_layer_reuse_than_kc_p() {
    // §5.1: "The YR-P dataflow has 5.8x and 15.17x higher activation and
    // filter reuse factors in early layers" — assert the direction and
    // a conservative magnitude.
    let hw = HwConfig::fig10_default();
    let early = resnet50::conv1();
    let yr = analyze_layer(&early, &styles::yr_p(), &hw).unwrap();
    let kc = analyze_layer(&early, &styles::kc_p(), &hw).unwrap();
    let act_ratio = yr.reuse_factor(TensorKind::Input) / kc.reuse_factor(TensorKind::Input);
    assert!(act_ratio > 1.5, "YR-P early activation reuse ratio {act_ratio} should exceed KC-P clearly");
}

#[test]
fn paper_shape_late_layer_reuse_converges() {
    // §5.1: "in late layers, the reuse factors of YR-P and KC-P are
    // almost similar" — assert they are within ~2x while early layers
    // differ by much more.
    let hw = HwConfig::fig10_default();
    let late = vgg16::conv13();
    let yr = analyze_layer(&late, &styles::yr_p(), &hw).unwrap();
    let kc = analyze_layer(&late, &styles::kc_p(), &hw).unwrap();
    let late_ratio = yr.reuse_factor(TensorKind::Input) / kc.reuse_factor(TensorKind::Input);
    let early = resnet50::conv1();
    let yr_e = analyze_layer(&early, &styles::yr_p(), &hw).unwrap();
    let kc_e = analyze_layer(&early, &styles::kc_p(), &hw).unwrap();
    let early_ratio = yr_e.reuse_factor(TensorKind::Input) / kc_e.reuse_factor(TensorKind::Input);
    assert!(
        early_ratio > late_ratio,
        "activation-reuse gap should shrink from early ({early_ratio}) to late ({late_ratio}) layers"
    );
}

#[test]
fn paper_shape_pointwise_needs_more_bandwidth_under_yx_p() {
    // §5.1: "YX-P requires high bandwidth for point-wise convolution as
    // it has no convolutional reuse."
    let hw = HwConfig::fig10_default();
    let pw = mobilenet_v2::bottleneck1_pw();
    let conv = vgg16::conv13();
    let yx_pw = analyze_layer(&pw, &styles::yx_p(), &hw).unwrap();
    let yx_conv = analyze_layer(&conv, &styles::yx_p(), &hw).unwrap();
    assert!(
        yx_pw.peak_bw_need > yx_conv.peak_bw_need,
        "YX-P pointwise bw need {} should exceed dense-conv need {}",
        yx_pw.peak_bw_need,
        yx_conv.peak_bw_need
    );
}

#[test]
fn paper_shape_adaptive_beats_static_on_mixed_models() {
    let hw = HwConfig::fig10_default();
    let net = zoo::by_name("mobilenetv2").unwrap();
    let candidates = styles::all_styles();
    let adaptive = adaptive_network(&net, &candidates, &hw, Objective::Runtime).unwrap();
    for df in &candidates {
        if let Ok(s) = analyze_network(&net, df, &hw, true) {
            if s.per_layer.len() == adaptive.per_layer.len() {
                assert!(adaptive.runtime <= s.runtime * 1.0001, "adaptive worse than {}", df.name);
            }
        }
    }
}

#[test]
fn dse_finds_valid_pareto_points_within_budget() {
    let layer = vgg16::conv13();
    let space = DesignSpace::fig13("kc-p", 8);
    let cfg = SweepConfig { keep_all_points: true, ..SweepConfig::default() };
    let out = sweep(&Network::single(layer.clone()), &space, 2, &cfg).unwrap();
    let (points, stats) = (out.points, out.stats);
    assert!(stats.valid > 10, "expected a populated valid region, got {}", stats.valid);
    let macs = layer.macs() as f64;
    let t = best(&points, Optimize::Throughput, macs).expect("throughput optimum");
    let e = best(&points, Optimize::Energy, macs).expect("energy optimum");
    assert!(t.area_mm2 <= 16.0 && t.power_mw <= 450.0);
    assert!(e.energy_pj <= t.energy_pj * 1.0001, "energy-opt should not cost more energy");
    assert!(t.throughput(macs) >= e.throughput(macs) * 0.9999, "throughput-opt should not be slower");
}

#[test]
fn coordinator_pipeline_scalar_backend_full_network() {
    // Whole VGG16 conv stack through the coordinator as one workload.
    let net = vgg16::conv_only();
    let designs: Vec<DesignIn> = [2u64, 8, 32, 128]
        .iter()
        .map(|&bw| DesignIn { bandwidth: bw as f64, latency: 2.0, l1: 0.0, l2: 0.0 })
        .collect();
    let jobs: Vec<DseJob> = [64u64, 256]
        .iter()
        .enumerate()
        .map(|(i, &pes)| DseJob {
            id: i as u64,
            network: net.clone(),
            variant: styles::kc_p(),
            pes,
            designs: designs.clone(),
            noc_hops: 2,
            area_budget: 1e9,
            power_budget: 1e9,
        })
        .collect();
    let (results, metrics) = run_jobs(jobs, Backend::Scalar, 3).unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(metrics.designs_evaluated.load(std::sync::atomic::Ordering::Relaxed), 8);
    for r in &results {
        // Runtime decreases with bandwidth within each job.
        let rts: Vec<f64> = r.outputs.iter().map(|(_, o)| o.runtime).collect();
        assert!(rts.windows(2).all(|w| w[1] <= w[0] + 1.0), "{rts:?}");
        // More PEs should not be slower at the top bandwidth.
    }
    let rt64 = results.iter().find(|r| r.pes == 64).unwrap().outputs.last().unwrap().1.runtime;
    let rt256 = results.iter().find(|r| r.pes == 256).unwrap().outputs.last().unwrap().1.runtime;
    assert!(rt256 <= rt64, "256 PEs ({rt256}) should beat 64 PEs ({rt64}) at high bandwidth");
}

#[test]
fn network_text_format_roundtrips_through_analysis() {
    let text = "\
network custom
c1: conv2d 1 32 3 66 66 3 3 1
d1: depthwise 1 32 34 34 3 3 1
p1: conv2d 1 64 32 32 32 1 1 1
f1: fc 1 100 512
";
    let net = maestro::model::network::Network::parse(text).unwrap();
    let hw = HwConfig::fig10_default();
    let s = analyze_network(&net, &styles::kc_p(), &hw, true).unwrap();
    assert!(!s.per_layer.is_empty());
    assert_eq!(s.per_layer.len() + s.skipped.len(), net.layers.len(), "no silent layer drops");
    let a = adaptive_network(&net, &styles::all_styles(), &hw, Objective::Energy).unwrap();
    assert_eq!(a.per_layer.len(), net.layers.len());
    assert!(a.skipped.is_empty());
}

mod cli {
    //! Smoke tests of the `maestro` leader binary itself.
    use std::process::Command;

    fn run(args: &[&str]) -> (bool, String) {
        let out = Command::new(env!("CARGO_BIN_EXE_maestro"))
            .args(args)
            .output()
            .expect("binary runs");
        let text = format!(
            "{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        (out.status.success(), text)
    }

    #[test]
    fn cli_zoo_lists_networks() {
        let (ok, text) = run(&["zoo"]);
        assert!(ok, "{text}");
        assert!(text.contains("vgg16") && text.contains("unet"), "{text}");
    }

    #[test]
    fn cli_analyze_layer() {
        let (ok, text) = run(&["analyze", "--model", "vgg16", "--layer", "conv2_2", "--dataflow", "kc-p"]);
        assert!(ok, "{text}");
        assert!(text.contains("KC-P"), "{text}");
    }

    #[test]
    fn cli_table1() {
        let (ok, text) = run(&["table1"]);
        assert!(ok, "{text}");
        assert!(text.contains("Multicast") && text.contains("Reduction"), "{text}");
    }

    #[test]
    fn cli_validate_small() {
        let (ok, text) = run(&[
            "validate", "--model", "alexnet", "--layer", "conv3", "--dataflow", "x-p", "--pes", "32",
        ]);
        assert!(ok, "{text}");
        assert!(text.contains("runtime error"), "{text}");
    }

    #[test]
    fn cli_rejects_unknown_flag() {
        let (ok, text) = run(&["analyze", "--frobnicate", "yes"]);
        assert!(!ok);
        assert!(text.contains("unknown flag"), "{text}");
    }

    #[test]
    fn cli_network_adaptive() {
        let (ok, text) = run(&["network", "--model", "mobilenetv2", "--dataflow", "adaptive"]);
        assert!(ok, "{text}");
        assert!(text.contains("adaptive"), "{text}");
        assert!(text.contains("analyzer cache:"), "cache stats surface: {text}");
    }

    #[test]
    fn cli_network_per_layer_breakdown() {
        let (ok, text) = run(&["network", "--model", "vgg16", "--dataflow", "kc-p", "--per-layer"]);
        assert!(ok, "{text}");
        assert!(text.contains("conv2_2"), "per-layer rows present: {text}");
        assert!(text.contains("shapes"), "unique-shape column present: {text}");
    }

    #[test]
    fn cli_dse_network_rejects_layer_flag() {
        // Contradictory flags must fail loudly, not silently drop one.
        let (ok, text) = run(&[
            "dse", "--layer-model", "vgg16", "--layer", "conv2_2", "--network", "--resolution", "5",
        ]);
        assert!(!ok);
        assert!(text.contains("--layer"), "{text}");
    }

    #[test]
    fn cli_dse_network_mode() {
        // Whole-network sweep on a tiny space: must report the workload
        // and the cache split.
        let (ok, text) = run(&[
            "dse", "--family", "kc-p", "--layer-model", "vgg16-conv", "--network", "--resolution", "5",
        ]);
        assert!(ok, "{text}");
        assert!(text.contains("unique shape"), "{text}");
        assert!(text.contains("cache="), "{text}");
    }
}

#[test]
fn lstm_and_residual_layers_analyzable() {
    // §4.4: "MAESTRO can model a variety of layers (LSTM hidden layer,
    // pooling, fully-connected, transposed convolution...)".
    let hw = HwConfig::fig10_default();
    let lstm = maestro::model::layer::Layer::lstm_gate("gate", 1, 512, 1024);
    let res = maestro::model::layer::Layer::residual("skip", 1, 256, 28, 28);
    for layer in [lstm, res] {
        let mut mapped = 0;
        for df in styles::all_styles() {
            if let Ok(s) = analyze_layer(&layer, &df, &hw) {
                assert!((s.macs - layer.macs() as f64).abs() < 1.0, "{} {}", layer.name, df.name);
                mapped += 1;
            }
        }
        assert!(mapped >= 2, "layer {} mapped by only {mapped} dataflows", layer.name);
    }
}

#[test]
fn transposed_conv_sparsity_discount() {
    // §4.4 uniform-sparsity model: transposed convs skip zero-inserted
    // rows, so effective MACs and runtime drop below the dense count.
    let hw = HwConfig::fig10_default();
    let dense = maestro::model::layer::Layer::conv2d("dense", 1, 64, 128, 56, 56, 2, 2, 1);
    let sparse = maestro::model::layer::Layer::transposed_conv("up", 1, 64, 128, 28, 28, 2, 2, 2);
    assert_eq!(dense.macs(), sparse.macs()); // same dense geometry
    let d = analyze_layer(&dense, &styles::kc_p(), &hw).unwrap();
    let s = analyze_layer(&sparse, &styles::kc_p(), &hw).unwrap();
    assert!(s.macs < d.macs * 0.5, "sparsity discount missing: {} vs {}", s.macs, d.macs);
}
