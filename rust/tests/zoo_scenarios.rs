//! Zoo-wide scenario audit: every zoo network under every Table 3
//! dataflow style, at the reference Fig 10 hardware, must either
//! analyze cleanly or fail with a diagnostic — never silently drop
//! layers — and analyzed MAC totals must conserve the layers' effective
//! (sparsity-discounted) MAC counts, which for dense networks equal
//! `Network::macs()`.

use maestro::engine::analysis::{analyze_network_with, Analyzer};
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::zoo;

#[test]
fn every_zoo_network_analyzes_or_diagnoses_under_every_style() {
    let hw = HwConfig::fig10_default();
    // One Analyzer across the whole matrix: the zoo shares shapes
    // across styles' hardware-identical runs.
    let mut analyzer = Analyzer::new();
    for name in zoo::ALL {
        let mut net = zoo::by_name(name).unwrap();
        // Name-uniquify this copy: the MAC audit below matches analyzed
        // layers back to network layers by name, and zoo networks are
        // free to reuse a name across different shapes (which would
        // let a skipped twin's MACs leak into the expected total).
        // Shape memoization is name-independent, so the rename is
        // invisible to the analysis itself.
        for (i, layer) in net.layers.iter_mut().enumerate() {
            layer.name = format!("{}#{i}", layer.name);
        }
        let n_shapes = net.unique_shapes().len();
        assert!(n_shapes <= net.layers.len());
        for df in styles::all_styles() {
            match analyze_network_with(&mut analyzer, &net, &df, &hw, true) {
                Ok(stats) => {
                    // No silent drops: every layer is analyzed or named.
                    assert_eq!(
                        stats.per_layer.len() + stats.skipped.len(),
                        net.layers.len(),
                        "{name}/{}: accounting",
                        df.name
                    );
                    for s in &stats.skipped {
                        assert!(!s.reason.is_empty(), "{name}/{}: skip without diagnostic", df.name);
                    }
                    // MAC conservation over the analyzed layers.
                    let analyzed: Vec<&str> = stats.per_layer.iter().map(|s| s.layer.as_str()).collect();
                    let want: f64 = net
                        .layers
                        .iter()
                        .filter(|l| analyzed.contains(&l.name.as_str()))
                        .map(|l| l.effective_macs())
                        .sum();
                    assert!(
                        (stats.macs - want).abs() <= 1e-6 * want.max(1.0),
                        "{name}/{}: analyzed MACs {} != effective total {want}",
                        df.name,
                        stats.macs
                    );
                    // A fully dense, fully analyzable network conserves
                    // the closed-form dense total exactly.
                    let dense = net.layers.iter().all(|l| l.sparsity_macs_scale() == 1.0);
                    if dense && stats.skipped.is_empty() {
                        let total = net.macs() as f64;
                        assert!(
                            (stats.macs - total).abs() <= 1e-6 * total,
                            "{name}/{}: {} != Network::macs() {total}",
                            df.name,
                            stats.macs
                        );
                    }
                    assert!(stats.runtime > 0.0 && stats.energy.total() > 0.0);
                }
                Err(e) => {
                    // A whole-network failure is acceptable only with a
                    // usable diagnostic.
                    let msg = format!("{e:#}");
                    assert!(!msg.is_empty(), "{name}/{}: empty diagnostic", df.name);
                }
            }
        }
    }
    assert!(analyzer.cache_hits() > 0, "the zoo matrix must exercise the shape cache");
}

#[test]
fn duplicate_names_do_not_confuse_mac_accounting() {
    // The audit above matches analyzed layers by name; shape dedup must
    // keep per-layer stats one-per-layer even when names repeat.
    use maestro::model::layer::Layer;
    use maestro::model::network::Network;
    let l = Layer::conv2d("twin", 1, 32, 16, 30, 30, 3, 3, 1);
    let net = Network::new("twins", vec![l.clone(), l]);
    let hw = HwConfig::fig10_default();
    let stats = analyze_network_with(&mut Analyzer::new(), &net, &styles::kc_p(), &hw, true).unwrap();
    assert_eq!(stats.per_layer.len(), 2);
    let want: f64 = net.layers.iter().map(|x| x.effective_macs()).sum();
    assert!((stats.macs - want).abs() <= 1e-6 * want);
}
