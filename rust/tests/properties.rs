//! Property-based tests over the analysis engines, driven by the
//! hand-rolled `propcheck` harness (proptest substitute — DESIGN.md §4):
//! random layers and random *valid* dataflows must satisfy the model's
//! conservation laws and monotonicities.

use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::HwConfig;
use maestro::ir::dataflow::Dataflow;
use maestro::ir::dims::Dim;
use maestro::ir::directive::{Directive, Extent};
use maestro::ir::parser;
use maestro::model::layer::Layer;
use maestro::model::tensor::{tensor_elements, TensorKind};
use maestro::util::propcheck::{check, close, Check, Config};
use maestro::util::rng::Rng;

/// Random small conv layer.
fn gen_layer(rng: &mut Rng) -> Layer {
    let r = *rng.pick(&[1u64, 3, 5]);
    let s = *rng.pick(&[1u64, 3]);
    let stride = if r > 1 && rng.chance(0.3) { 2 } else { 1 };
    let y = r + stride * rng.range(2, 12);
    let x = s + stride * rng.range(2, 12);
    Layer::conv2d(
        "prop",
        rng.range(1, 2),
        rng.range(1, 24),
        rng.range(1, 24),
        y,
        x,
        r,
        s,
        stride,
    )
}

/// Random valid dataflow for a layer: a shuffled set of maps with
/// offsets that satisfy the gapless/non-overlap rules, at most one
/// spatial map, optional second cluster level over C or K.
fn gen_dataflow(rng: &mut Rng, layer: &Layer) -> Dataflow {
    let mut dims = vec![Dim::K, Dim::C, Dim::Y, Dim::X];
    rng.shuffle(&mut dims);
    let spatial_dim = *rng.pick(&[Dim::K, Dim::C, Dim::X]);
    let mut directives = Vec::new();
    for d in dims {
        let total = layer.dim(d);
        let (size, offset) = match d {
            Dim::Y | Dim::X => {
                // Windowed: size >= win; user offsets are output-step
                // slides in [1, size - win + 1] (the builder augments to
                // the stride-aware step).
                let win = if d == Dim::Y { layer.r } else { layer.s };
                let extra = rng.range(0, 3) * layer.stride;
                let size = (win + extra).min(total).max(win);
                (size, rng.range(1, size - win + 1))
            }
            _ => {
                let size = rng.range(1, total.max(1));
                (size, size)
            }
        };
        let dir = if d == spatial_dim && !matches!(d, Dim::Y) {
            Directive::spatial(Extent::lit(size), Extent::lit(offset), d)
        } else {
            Directive::temporal(Extent::lit(size), Extent::lit(offset), d)
        };
        directives.push(dir);
    }
    // Occasionally add an inner cluster level parallel over C.
    if rng.chance(0.3) && spatial_dim != Dim::C {
        directives.push(Directive::cluster(Extent::lit(rng.range(2, 8))));
        directives.push(Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::C));
    }
    Dataflow::new("prop-df", directives)
}

fn hw(rng: &mut Rng) -> HwConfig {
    HwConfig {
        num_pes: *rng.pick(&[16u64, 32, 64, 256]),
        noc_bandwidth: *rng.pick(&[2u64, 8, 16, 64]),
        noc_latency: rng.range(0, 4),
        ..HwConfig::fig10_default()
    }
}

#[test]
fn prop_mac_conservation() {
    check("mac-conservation", Config { cases: 200, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        let df = gen_dataflow(rng, &layer);
        let h = hw(rng);
        match analyze_layer(&layer, &df, &h) {
            Err(_) => Check::Discard, // generator may still produce unmappables
            Ok(s) => close(
                &format!("macs of {layer} under\n{df}"),
                s.macs,
                layer.macs() as f64,
                1e-9,
            ),
        }
    });
}

#[test]
fn prop_runtime_at_least_both_rooflines() {
    check("runtime-roofline", Config { cases: 150, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        let df = gen_dataflow(rng, &layer);
        let h = hw(rng);
        let Ok(s) = analyze_layer(&layer, &df, &h) else { return Check::Discard };
        let compute_roofline = layer.macs() as f64 / (h.num_pes * h.pe_throughput) as f64;
        // Communication roofline: at least the unique input traffic
        // over the NoC bandwidth.
        let comm_roofline = (tensor_elements(&layer, TensorKind::Input)
            + tensor_elements(&layer, TensorKind::Filter)) as f64
            / h.noc_bandwidth as f64;
        if s.runtime + 1.0 >= compute_roofline && s.runtime + 1.0 >= comm_roofline * 0.99 {
            Check::Pass
        } else {
            Check::Fail(format!(
                "runtime {} below roofline max({compute_roofline}, {comm_roofline}) for {layer} under\n{df}",
                s.runtime
            ))
        }
    });
}

#[test]
fn prop_traffic_covers_tensors() {
    check("traffic-lower-bound", Config { cases: 150, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        let df = gen_dataflow(rng, &layer);
        let h = hw(rng);
        let Ok(s) = analyze_layer(&layer, &df, &h) else { return Check::Discard };
        for (i, kind) in [TensorKind::Filter, TensorKind::Input, TensorKind::Output].iter().enumerate() {
            let mut size = tensor_elements(&layer, *kind) as f64;
            if *kind == TensorKind::Input && layer.stride > 1 {
                // Strided convs with stride > window legitimately skip
                // input rows/columns; bound by the touched fraction.
                let touched = |act: u64, win: u64, out: u64| -> f64 {
                    (out * win.min(layer.stride) + win.saturating_sub(layer.stride)).min(act) as f64
                        / act as f64
                };
                size *= touched(layer.y, layer.r, layer.y_out()) * touched(layer.x, layer.s, layer.x_out());
            }
            let traffic = if *kind == TensorKind::Output { s.l2_writes[i] } else { s.l2_reads[i] };
            if traffic + 0.5 < size * 0.999 {
                return Check::Fail(format!(
                    "{:?} traffic {traffic} < tensor size {size} for {layer} under\n{df}",
                    kind
                ));
            }
            // And refetch cannot exceed one fetch per MAC.
            if traffic > s.macs + size {
                return Check::Fail(format!("{:?} traffic {traffic} > macs {}", kind, s.macs));
            }
        }
        Check::Pass
    });
}

#[test]
fn prop_bandwidth_monotonicity() {
    check("bw-monotone", Config { cases: 80, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        let df = gen_dataflow(rng, &layer);
        let mut h = hw(rng);
        h.noc_bandwidth = 2;
        let Ok(slow) = analyze_layer(&layer, &df, &h) else { return Check::Discard };
        h.noc_bandwidth = 128;
        let Ok(fast) = analyze_layer(&layer, &df, &h) else { return Check::Discard };
        if fast.runtime <= slow.runtime + 1.0 {
            Check::Pass
        } else {
            Check::Fail(format!("bw 128 runtime {} > bw 2 runtime {}", fast.runtime, slow.runtime))
        }
    });
}

#[test]
fn prop_dsl_roundtrip() {
    check("dsl-roundtrip", Config { cases: 200, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        let df = gen_dataflow(rng, &layer);
        let text = parser::emit(&df);
        match parser::parse_dataflow(&text) {
            Err(e) => Check::Fail(format!("emit->parse failed: {e}\n{text}")),
            Ok(back) if back == df => Check::Pass,
            Ok(back) => Check::Fail(format!("roundtrip mismatch:\n{df}\nvs\n{back}")),
        }
    });
}

#[test]
fn prop_case_table_matches_full_engine_single_level() {
    use maestro::dse::engine::{build_case_table, eval_runtime};
    check("flatten-consistency", Config { cases: 60, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        // Single-level only (flattening of inner levels approximates).
        let mut df = gen_dataflow(rng, &layer);
        if df.directives.iter().any(|d| d.is_cluster()) {
            return Check::Discard;
        }
        df.name = "flat".into();
        let h = hw(rng);
        let Ok(full) = analyze_layer(&layer, &df, &h) else { return Check::Discard };
        let Ok(table) = build_case_table(&[&layer], &df, h.num_pes) else {
            return Check::Fail("analyze ok but case table failed".into());
        };
        let flat = eval_runtime(&table, h.noc_bandwidth, h.noc_latency);
        close("flattened vs full runtime", flat, full.runtime, 0.02)
    });
}

/// Field-for-field bit equality between two [`LayerStats`] — the
/// two-phase contract is *bit* identity, not tolerance.
fn stats_bits_equal(
    a: &maestro::engine::analysis::LayerStats,
    b: &maestro::engine::analysis::LayerStats,
) -> Result<(), String> {
    if a.layer != b.layer || a.dataflow != b.dataflow {
        return Err(format!("labels: ({}, {}) vs ({}, {})", a.layer, a.dataflow, b.layer, b.dataflow));
    }
    let scalars = [
        ("runtime", a.runtime, b.runtime),
        ("macs", a.macs, b.macs),
        ("util", a.util, b.util),
        ("l1_fills", a.l1_fills, b.l1_fills),
        ("l1_reads", a.l1_reads, b.l1_reads),
        ("l1_writes", a.l1_writes, b.l1_writes),
        ("noc_delivered", a.noc_delivered, b.noc_delivered),
        ("peak_bw_need", a.peak_bw_need, b.peak_bw_need),
        ("energy.mac", a.energy.mac, b.energy.mac),
        ("energy.l1", a.energy.l1, b.energy.l1),
        ("energy.l2", a.energy.l2, b.energy.l2),
        ("energy.noc", a.energy.noc, b.energy.noc),
    ];
    for (name, x, y) in scalars {
        if x.to_bits() != y.to_bits() {
            return Err(format!("{name}: {x} vs {y}"));
        }
    }
    for i in 0..3 {
        if a.l2_reads[i].to_bits() != b.l2_reads[i].to_bits() {
            return Err(format!("l2_reads[{i}]: {} vs {}", a.l2_reads[i], b.l2_reads[i]));
        }
        if a.l2_writes[i].to_bits() != b.l2_writes[i].to_bits() {
            return Err(format!("l2_writes[{i}]: {} vs {}", a.l2_writes[i], b.l2_writes[i]));
        }
    }
    if (a.l1_req, a.l2_req) != (b.l1_req, b.l2_req) {
        return Err(format!(
            "buffer reqs: ({}, {}) vs ({}, {})",
            a.l1_req, a.l2_req, b.l1_req, b.l2_req
        ));
    }
    Ok(())
}

#[test]
fn prop_profile_finalize_bit_identical_to_monolithic() {
    // The two-phase acceptance property: for random (shape, dataflow,
    // hardware, bandwidth) tuples, building a bandwidth-invariant
    // profile and finalizing it at the tuple's bandwidth is
    // bit-identical — every field — to the monolithic reference, and
    // the two paths accept/reject exactly the same inputs.
    use maestro::engine::profile::ReuseProfile;
    check("profile-bit-identity", Config { cases: 150, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        let df = gen_dataflow(rng, &layer);
        let h = hw(rng);
        let mono = analyze_layer(&layer, &df, &h);
        let built = df.resolve(&layer, h.num_pes).and_then(|r| ReuseProfile::build(&layer, &r, &h));
        match (mono, built) {
            (Err(_), Err(_)) => Check::Pass, // failure parity
            (Ok(m), Ok(p)) => match stats_bits_equal(&p.finalize(&h), &m) {
                Ok(()) => Check::Pass,
                Err(msg) => Check::Fail(format!("{msg} for {layer} under\n{df}")),
            },
            (Ok(_), Err(e)) => Check::Fail(format!("profile rejects what monolithic accepts: {e:#}")),
            (Err(e), Ok(_)) => Check::Fail(format!("profile accepts what monolithic rejects: {e:#}")),
        }
    });
}

#[test]
fn prop_one_profile_serves_every_bandwidth() {
    // One profile built at a random bandwidth, finalized across the
    // whole shared Fig 13 bandwidth axis, must match a fresh monolithic
    // analysis at every point — the bandwidth-invariance claim.
    use maestro::dse::space::bandwidth_axis;
    use maestro::engine::profile::ReuseProfile;
    check("profile-bw-axis", Config { cases: 40, ..Default::default() }, |rng| {
        let layer = gen_layer(rng);
        let df = gen_dataflow(rng, &layer);
        let base = hw(rng);
        let Ok(resolved) = df.resolve(&layer, base.num_pes) else { return Check::Discard };
        let Ok(profile) = ReuseProfile::build(&layer, &resolved, &base) else {
            return Check::Discard;
        };
        for bw in bandwidth_axis(9) {
            let h = HwConfig { noc_bandwidth: bw, ..base.clone() };
            let fresh = match analyze_layer(&layer, &df, &h) {
                Ok(s) => s,
                Err(e) => return Check::Fail(format!("monolithic failed at bw={bw}: {e:#}")),
            };
            if let Err(msg) = stats_bits_equal(&profile.finalize(&h), &fresh) {
                return Check::Fail(format!("bw={bw}: {msg} for {layer} under\n{df}"));
            }
        }
        Check::Pass
    });
}

#[test]
fn prop_pareto_front_is_nondominated() {
    use maestro::dse::engine::DesignPoint;
    use maestro::dse::pareto::pareto_front;
    check("pareto-nondominated", Config { cases: 100, ..Default::default() }, |rng| {
        let n = rng.range(2, 60) as usize;
        let points: Vec<DesignPoint> = (0..n)
            .map(|i| DesignPoint {
                dataflow: "p".into(),
                pes: 64,
                bandwidth: 8,
                l1: 512,
                l2: 1024,
                runtime: rng.range(1, 1000) as f64,
                energy_pj: rng.range(1, 1000) as f64,
                area_mm2: 1.0,
                power_mw: 1.0,
                valid: i % 7 != 0,
            })
            .collect();
        let front = pareto_front(&points, |p| p.runtime, |p| p.energy_pj);
        for &i in &front {
            for (j, q) in points.iter().enumerate() {
                if i == j || !q.valid {
                    continue;
                }
                let p = &points[i];
                if q.runtime < p.runtime && q.energy_pj < p.energy_pj {
                    return Check::Fail(format!("front point {i} dominated by {j}"));
                }
            }
        }
        Check::Pass
    });
}
