//! Integration: the AOT artifact (L1 Pallas kernel + L2 JAX graph,
//! lowered to HLO text) loads on the PJRT CPU client and its outputs
//! match the Rust scalar evaluator — the two implementations of the DSE
//! evaluation contract.
//!
//! Requires `make artifacts`; tests exit early (with a loud message)
//! when the artifact is absent so `cargo test` remains runnable on a
//! fresh checkout.

use maestro::dse::engine::build_case_table;
use maestro::dse::space::kc_p_ct;
use maestro::ir::styles;
use maestro::model::zoo::vgg16;
use maestro::runtime::{evaluate_scalar, BatchEvaluator, DesignIn, D_MAX};

fn artifact() -> Option<BatchEvaluator> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature — BatchEvaluator is the scalar-fallback stub");
        return None;
    }
    let path = BatchEvaluator::default_path();
    if !path.exists() {
        eprintln!(
            "SKIP: {} missing — run `make artifacts` for the PJRT integration tests",
            path.display()
        );
        return None;
    }
    Some(BatchEvaluator::load(&path).expect("artifact must compile on PJRT CPU"))
}

fn designs(n: usize) -> Vec<DesignIn> {
    (0..n)
        .map(|i| DesignIn {
            bandwidth: (1 + (i * 7) % 255) as f64,
            latency: (i % 5) as f64,
            l1: (64 + (i * 131) % 65_000) as f64,
            l2: (1024 + (i * 7919) % 3_000_000) as f64,
        })
        .collect()
}

#[test]
fn artifact_matches_scalar_evaluator_kc_p() {
    let Some(eval) = artifact() else { return };
    let layer = vgg16::conv13();
    let table = build_case_table(&[&layer], &kc_p_ct(64), 256).unwrap();
    let ds = designs(64);
    let pjrt = eval.evaluate(&table, &ds, 2, 16.0, 450.0).unwrap();
    let scalar = evaluate_scalar(&table, &ds, 2, 16.0, 450.0);
    for (i, (p, s)) in pjrt.iter().zip(&scalar).enumerate() {
        let rel = (p.runtime - s.runtime).abs() / s.runtime.max(1.0);
        assert!(rel < 2e-3, "design {i}: runtime pjrt {} vs scalar {} (rel {rel})", p.runtime, s.runtime);
        let erel = (p.energy_pj - s.energy_pj).abs() / s.energy_pj.max(1.0);
        assert!(erel < 2e-3, "design {i}: energy {} vs {} ({erel})", p.energy_pj, s.energy_pj);
        assert!((p.area_mm2 - s.area_mm2).abs() / s.area_mm2.max(1e-9) < 1e-3, "design {i} area");
        assert!((p.power_mw - s.power_mw).abs() / s.power_mw.max(1e-9) < 1e-3, "design {i} power");
        assert_eq!(p.valid, s.valid, "design {i} validity");
    }
}

#[test]
fn artifact_matches_scalar_across_styles() {
    let Some(eval) = artifact() else { return };
    let layer = vgg16::conv2();
    for df in styles::all_styles() {
        let Ok(table) = build_case_table(&[&layer], &df, 256) else { continue };
        let ds = designs(16);
        let pjrt = eval.evaluate(&table, &ds, 2, 16.0, 450.0).unwrap();
        let scalar = evaluate_scalar(&table, &ds, 2, 16.0, 450.0);
        for (p, s) in pjrt.iter().zip(&scalar) {
            let rel = (p.runtime - s.runtime).abs() / s.runtime.max(1.0);
            assert!(rel < 5e-3, "{}: runtime {} vs {} ({rel})", df.name, p.runtime, s.runtime);
        }
    }
}

#[test]
fn artifact_handles_full_batch_and_multi_layer_tables() {
    let Some(eval) = artifact() else { return };
    // 13 conv layers stacked into one table: rows well past 100.
    let net = vgg16::conv_only();
    let layers: Vec<&maestro::model::layer::Layer> = net.layers.iter().collect();
    let table = build_case_table(&layers, &kc_p_ct(64), 256).unwrap();
    let ds = designs(D_MAX);
    let pjrt = eval.evaluate(&table, &ds, 2, 16.0, 450.0).unwrap();
    let scalar = evaluate_scalar(&table, &ds, 2, 16.0, 450.0);
    assert_eq!(pjrt.len(), D_MAX);
    let mut worst = 0.0f64;
    for (p, s) in pjrt.iter().zip(&scalar) {
        worst = worst.max((p.runtime - s.runtime).abs() / s.runtime.max(1.0));
    }
    assert!(worst < 5e-3, "worst relative runtime error {worst}");
}

#[test]
fn coordinator_end_to_end_with_pjrt_backend() {
    if !BatchEvaluator::default_path().exists() {
        eprintln!("SKIP: artifact missing");
        return;
    }
    use maestro::coordinator::{run_jobs, Backend, DseJob};
    let layer = vgg16::conv13();
    let jobs: Vec<DseJob> = [64u64, 128, 256]
        .iter()
        .enumerate()
        .map(|(i, &pes)| DseJob {
            id: i as u64,
            network: maestro::model::network::Network::single(layer.clone()),
            variant: kc_p_ct(16),
            pes,
            designs: designs(32),
            noc_hops: 2,
            area_budget: 16.0,
            power_budget: 450.0,
        })
        .collect();
    let (results, metrics) =
        run_jobs(jobs.clone(), Backend::Pjrt(BatchEvaluator::default_path()), 2).unwrap();
    assert_eq!(results.len(), 3);
    assert_eq!(
        metrics.designs_evaluated.load(std::sync::atomic::Ordering::Relaxed),
        96
    );
    // Same jobs through the scalar backend agree.
    let (scalar_results, _) = run_jobs(jobs, Backend::Scalar, 2).unwrap();
    for r in &results {
        let s = scalar_results.iter().find(|s| s.id == r.id).unwrap();
        for ((_, a), (_, b)) in r.outputs.iter().zip(&s.outputs) {
            let rel = (a.runtime - b.runtime).abs() / b.runtime.max(1.0);
            assert!(rel < 5e-3, "job {} runtime {} vs {}", r.id, a.runtime, b.runtime);
        }
    }
}
