//! In-process integration tests for the `maestro serve` daemon: a real
//! TCP client against [`Daemon::spawn`] on an ephemeral port.
//!
//! Covers the tentpole's acceptance behaviors end to end:
//!
//! * **Warm store** — the second identical analyze request reports zero
//!   analyses (all warm hits), and `status` sees the resident entries.
//! * **Persistence** — shutdown flushes the store to `--cache-file`; a
//!   fresh daemon started on that file answers from disk
//!   (`disk_hits > 0`, `analyses == 0`).
//! * **Robustness** — malformed frames, wrong wire versions, unknown
//!   models, and bogus cancels all get structured [`ApiError`] replies
//!   on a connection that stays usable; the daemon never dies.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use maestro::cache::SharedStore;
use maestro::engine::analysis::Objective;
use maestro::service::api::{AnalyzeRequest, MapRequest, Request, Response};
use maestro::service::daemon::{Daemon, ServeConfig};
use maestro::util::json::Json;

/// A blocking line-framed client: one request out, one reply line back.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { stream, reader }
    }

    /// Send one raw line (not necessarily valid JSON) and read one
    /// reply line.
    fn send_raw(&mut self, line: &str) -> Json {
        writeln!(self.stream, "{line}").expect("write frame");
        self.stream.flush().expect("flush frame");
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).expect("read reply");
        assert!(n > 0, "daemon closed the connection instead of replying");
        Json::parse(reply.trim()).unwrap_or_else(|e| panic!("reply not JSON ({e}): {reply}"))
    }

    fn request(&mut self, request: &Request) -> Response {
        let v = self.send_raw(&request.encode().dump());
        Response::decode(&v).unwrap_or_else(|e| panic!("undecodable reply {e:?}: {}", v.dump()))
    }
}

fn analyze_request(id: u64) -> Request {
    Request::Analyze(AnalyzeRequest {
        id: Some(id),
        model: "vgg16".into(),
        dataflow: "adaptive".into(),
        pes: 256,
        bw: 16,
        objective: Objective::Runtime,
        tile_resolution: 6,
        per_layer: false,
    })
}

fn temp_cache(tag: &str) -> PathBuf {
    let path =
        std::env::temp_dir().join(format!("maestro_serve_{tag}_{}.mcache", std::process::id()));
    let _ = std::fs::remove_file(&path);
    path
}

#[test]
fn warm_store_serves_repeats_and_shutdown_flushes() {
    let cache = temp_cache("warm");
    let daemon = Daemon::spawn(ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_file: Some(cache.display().to_string()),
        flush_every: 0.0, // shutdown-flush only: the test asserts that path
        ..ServeConfig::default()
    })
    .expect("spawn daemon");
    let mut client = Client::connect(daemon.addr());

    // Cold: the first analyze actually runs the analytical model.
    let cold = match client.request(&analyze_request(1)) {
        Response::Analyze(r) => r,
        other => panic!("expected analyze reply, got {other:?}"),
    };
    assert_eq!(cold.id, Some(1), "reply must echo the client id");
    assert!(cold.layers > 0 && cold.runtime_cycles > 0.0);
    assert!(cold.stats.analyses > 0, "cold request must run analyses: {:?}", cold.stats);

    // Warm: the identical request answers from the resident store.
    let warm = match client.request(&analyze_request(2)) {
        Response::Analyze(r) => r,
        other => panic!("expected analyze reply, got {other:?}"),
    };
    assert_eq!(warm.stats.analyses, 0, "warm request must not re-analyze: {:?}", warm.stats);
    assert!(warm.stats.warm_hits > 0, "warm request must hit the store: {:?}", warm.stats);
    assert_eq!(warm.runtime_cycles, cold.runtime_cycles, "warm replay must be bit-identical");
    assert_eq!(warm.energy_uj, cold.energy_uj);

    // The resident store is visible through `status`.
    let status = match client.request(&Request::Status) {
        Response::Status(s) => s,
        other => panic!("expected status reply, got {other:?}"),
    };
    assert!(status.entries > 0, "store must hold the analyses: {status:?}");
    assert!(status.hits > 0, "the warm request's hits must show: {status:?}");

    // Shutdown acknowledges, then flushes everything to the cache file.
    match client.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean daemon exit");
    let bytes = std::fs::metadata(&cache).expect("cache file must exist").len();
    assert!(bytes > 0, "shutdown flush must write records");

    // Second daemon generation: loads the flushed file and answers the
    // same request from disk without a single fresh analysis.
    let daemon = Daemon::spawn(ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_file: Some(cache.display().to_string()),
        flush_every: 0.0,
        ..ServeConfig::default()
    })
    .expect("spawn second daemon");
    let mut client = Client::connect(daemon.addr());
    let disk = match client.request(&analyze_request(3)) {
        Response::Analyze(r) => r,
        other => panic!("expected analyze reply, got {other:?}"),
    };
    assert_eq!(disk.stats.analyses, 0, "disk-warm request must not re-analyze: {:?}", disk.stats);
    assert!(disk.stats.disk_hits > 0, "hits must be attributed to disk: {:?}", disk.stats);
    assert_eq!(disk.runtime_cycles, cold.runtime_cycles, "disk replay must be bit-identical");
    match client.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean second daemon exit");

    // Sanity: the flushed file is loadable standalone.
    let store = SharedStore::new();
    let report = store.load(&cache);
    assert!(report.warning.is_none(), "{:?}", report.warning);
    assert!(report.loaded > 0, "flushed file must replay");
    let _ = std::fs::remove_file(&cache);
}

#[test]
fn malformed_frames_get_structured_errors_and_the_daemon_stays_up() {
    let daemon =
        Daemon::spawn(ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() })
            .expect("spawn daemon");
    let mut client = Client::connect(daemon.addr());

    let expect_error = |v: &Json, code: &str, needle: &str| {
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{}", v.dump());
        let err = v.get("error").unwrap_or_else(|| panic!("no error object: {}", v.dump()));
        assert_eq!(err.get("code").and_then(Json::as_str), Some(code), "{}", v.dump());
        let message = err.get("message").and_then(Json::as_str).unwrap_or_default();
        assert!(message.contains(needle), "wanted {needle:?} in {message:?}");
    };

    // Not JSON at all -> structured bad_request, connection survives.
    let v = client.send_raw("this is not json");
    expect_error(&v, "bad_request", "malformed frame");

    // Valid JSON, invalid request shapes.
    let v = client.send_raw(r#"{"v":1,"kind":"analyze"}"#);
    expect_error(&v, "bad_request", "'model'");
    let v = client.send_raw(r#"{"v":2,"kind":"status"}"#);
    expect_error(&v, "bad_request", "unsupported wire version 2");
    let v = client.send_raw(r#"{"v":1,"kind":"frobnicate"}"#);
    expect_error(&v, "bad_request", "unknown request kind");

    // A well-formed request for a nonexistent model fails in the
    // executor; the cause still comes back as a structured error.
    let v = client.send_raw(r#"{"v":1,"kind":"analyze","id":5,"model":"no-such-model"}"#);
    expect_error(&v, "bad_request", "no-such-model");
    assert_eq!(v.get("id").and_then(Json::as_u64), Some(5), "error must echo the id");

    // Cancelling an id that is not in flight is an error, not a hang.
    let v = client.send_raw(r#"{"v":1,"kind":"cancel","id":999}"#);
    expect_error(&v, "bad_request", "no in-flight request with id 999");

    // After all of that abuse the same connection still does real work.
    match client.request(&Request::Status) {
        Response::Status(_) => {}
        other => panic!("daemon wedged after malformed frames: {other:?}"),
    }
    match client.request(&analyze_request(7)) {
        Response::Analyze(r) => assert_eq!(r.id, Some(7)),
        other => panic!("expected analyze reply, got {other:?}"),
    }

    match client.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean daemon exit");
}

/// Cancelling an in-flight `map` from a second connection must degrade
/// gracefully, not error: the mapper drops every not-yet-searched shape
/// to its Table 3 default binding and the submitter still receives a
/// complete, well-formed mapping with `search.defaulted > 0`.
#[test]
fn cancelling_an_inflight_map_degrades_gracefully_to_defaults() {
    let daemon =
        Daemon::spawn(ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() })
            .expect("spawn daemon");
    let addr = daemon.addr();

    // Submitter: a map big enough (resnet50, fine tiles, no budget)
    // that it cannot finish before the cancel lands.
    let submit = std::thread::spawn(move || {
        let mut client = Client::connect(addr);
        client.request(&Request::Map(MapRequest {
            id: Some(42),
            model: "resnet50".into(),
            pes: 256,
            bw: 16,
            objective: Objective::Runtime,
            tile_resolution: 10,
            budget: 0,
            budget_seconds: 0.0,
            threads: 1,
            stream: false,
        }))
    });

    // Canceller: a separate connection retries until the map's id shows
    // up in the in-flight table (the submit thread races us to it).
    let mut canceller = Client::connect(addr);
    let mut acknowledged = false;
    for _ in 0..500 {
        match canceller.request(&Request::Cancel { id: 42 }) {
            Response::Done(d) => {
                assert_eq!(d.what, "cancel");
                acknowledged = true;
                break;
            }
            Response::Error(_) => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            other => panic!("expected done or error reply, got {other:?}"),
        }
    }
    assert!(acknowledged, "cancel never found the in-flight map");

    // The submitter gets a complete mapping back — graceful
    // degradation, never a `cancelled` error.
    let reply = submit.join().expect("submit thread");
    let map = match reply {
        Response::Map(m) => m,
        other => panic!("cancelled map must still produce a mapping, got {other:?}"),
    };
    assert_eq!(map.id, Some(42), "reply must echo the client id");
    assert!(
        map.search.defaulted > 0,
        "cancel must leave defaulted shapes behind: {:?}",
        map.search
    );
    assert!(
        map.per_shape.len() as u64 == map.search.shapes || !map.skipped.is_empty(),
        "every shape must still resolve to a mapping or a diagnostic: {:?}",
        map.search
    );
    assert!(map.mapper.layers > 0, "the degraded mapping still covers the network");

    // The daemon is healthy afterwards.
    match canceller.request(&Request::Status) {
        Response::Status(_) => {}
        other => panic!("daemon wedged after map cancel: {other:?}"),
    }
    match canceller.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean daemon exit");
}

#[test]
fn empty_lines_are_ignored_and_multiple_clients_share_the_store() {
    let daemon =
        Daemon::spawn(ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() })
            .expect("spawn daemon");

    // Client A pays the cold cost.
    let mut a = Client::connect(daemon.addr());
    // Blank lines between frames must be skipped, not answered.
    writeln!(a.stream).unwrap();
    writeln!(a.stream).unwrap();
    let cold = match a.request(&analyze_request(1)) {
        Response::Analyze(r) => r,
        other => panic!("expected analyze reply, got {other:?}"),
    };
    assert!(cold.stats.analyses > 0);

    // Client B, a separate connection, rides A's warm store.
    let mut b = Client::connect(daemon.addr());
    let warm = match b.request(&analyze_request(2)) {
        Response::Analyze(r) => r,
        other => panic!("expected analyze reply, got {other:?}"),
    };
    assert_eq!(warm.stats.analyses, 0, "store is shared across connections: {:?}", warm.stats);
    assert!(warm.stats.warm_hits > 0);

    match b.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean daemon exit");
}
