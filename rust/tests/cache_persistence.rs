//! The cache subsystem's persistence contract: warm starts from disk
//! replay cold analyses bit for bit, and no cache file — truncated,
//! garbage, stale, or half-written — can panic, fail a run, or poison
//! results (the worst case is always "fewer entries + a warning").

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use maestro::cache::SharedStore;
use maestro::engine::analysis::{analyze_network_with, Analyzer};
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::zoo;

/// A per-test temp path (tests share one process; the test name keys
/// uniqueness, the pid keeps parallel CI checkouts apart).
fn temp_cache(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("maestro_cache_{tag}_{}.mcache", std::process::id()))
}

fn hw() -> HwConfig {
    HwConfig::fig10_default()
}

#[test]
fn warm_start_replays_cold_network_analysis_bit_for_bit() {
    // The acceptance scenario behind the CLI's --cache-file: analyze a
    // zoo network cold, flush, reload in a "new process" (a fresh
    // store), and the warm run must report disk hits and identical
    // stats.
    let path = temp_cache("warm_roundtrip");
    fs::remove_file(&path).ok();
    let net = zoo::by_name("resnet50").unwrap();
    let df = styles::kc_p();

    let cold_store = Arc::new(SharedStore::new());
    let load = cold_store.load(&path);
    assert_eq!((load.loaded, load.dropped_bytes), (0, 0), "missing file is a clean cold start");
    assert!(load.warning.is_none());
    let mut cold = Analyzer::with_store(Arc::clone(&cold_store));
    let cold_stats = analyze_network_with(&mut cold, &net, &df, &hw(), true).unwrap();
    assert_eq!(cold.disk_hits(), 0);
    let flushed = cold_store.flush(&path).unwrap();
    assert_eq!(flushed.written, cold_store.len());
    assert!(flushed.written > 0);

    let warm_store = Arc::new(SharedStore::new());
    let report = warm_store.load(&path);
    assert!(report.warning.is_none(), "{:?}", report.warning);
    assert_eq!(report.loaded, cold_store.len());
    let mut warm = Analyzer::with_store(Arc::clone(&warm_store));
    let warm_stats = analyze_network_with(&mut warm, &net, &df, &hw(), true).unwrap();
    assert!(warm.disk_hits() >= 1, "a warm run must report disk hits");
    assert_eq!(warm.cache_misses(), 0, "everything replays from disk");
    assert_eq!(warm_stats.per_layer, cold_stats.per_layer, "warm stats must be bit-identical");
    assert_eq!(warm_stats.skipped, cold_stats.skipped);
    assert_eq!(warm_stats.runtime, cold_stats.runtime);
    fs::remove_file(&path).ok();
}

#[test]
fn flush_appends_and_reload_unions() {
    // Second session: load, analyze something new, flush to the same
    // path — the file must grow by exactly the new records and a third
    // load must see the union.
    let path = temp_cache("append");
    fs::remove_file(&path).ok();

    let s1 = Arc::new(SharedStore::new());
    let mut a1 = Analyzer::with_store(Arc::clone(&s1));
    let vgg = zoo::by_name("vgg16-conv").unwrap();
    analyze_network_with(&mut a1, &vgg, &styles::kc_p(), &hw(), true).unwrap();
    s1.flush(&path).unwrap();
    let first_len = fs::metadata(&path).unwrap().len();
    let first_entries = s1.len();

    let s2 = Arc::new(SharedStore::new());
    assert_eq!(s2.load(&path).loaded, first_entries);
    let mut a2 = Analyzer::with_store(Arc::clone(&s2));
    analyze_network_with(&mut a2, &vgg, &styles::x_p(), &hw(), true).unwrap();
    let added = s2.len() - first_entries;
    assert!(added > 0, "a second dataflow must add entries");
    let report = s2.flush(&path).unwrap();
    assert_eq!(report.written, added, "append must write only the new records");
    assert!(fs::metadata(&path).unwrap().len() > first_len);

    let s3 = SharedStore::new();
    assert_eq!(s3.load(&path).loaded, s2.len(), "reload sees the union");
    fs::remove_file(&path).ok();
}

#[test]
fn loading_two_files_then_flushing_writes_the_union() {
    // load(fileA); load(fileB); flush(fileB): fileA's entries must land
    // in fileB. "Persisted somewhere" must not be conflated with
    // "persisted here" — flush diffs the store against the *target*
    // file's current contents, so records that only exist in some other
    // file (or only in memory) are appended rather than silently
    // omitted forever.
    let pa = temp_cache("merge_a");
    let pb = temp_cache("merge_b");
    fs::remove_file(&pa).ok();
    fs::remove_file(&pb).ok();
    let net = zoo::by_name("vgg16-conv").unwrap();

    let sa = Arc::new(SharedStore::new());
    analyze_network_with(&mut Analyzer::with_store(Arc::clone(&sa)), &net, &styles::kc_p(), &hw(), true)
        .unwrap();
    sa.flush(&pa).unwrap();
    let sb = Arc::new(SharedStore::new());
    analyze_network_with(&mut Analyzer::with_store(Arc::clone(&sb)), &net, &styles::x_p(), &hw(), true)
        .unwrap();
    sb.flush(&pb).unwrap();

    let merged = Arc::new(SharedStore::new());
    let la = merged.load(&pa);
    let lb = merged.load(&pb);
    assert_eq!(la.loaded + lb.loaded, sa.len() + sb.len(), "distinct fingerprints, disjoint keys");
    merged.flush(&pb).unwrap();
    let reread = SharedStore::new();
    assert_eq!(reread.load(&pb).loaded, merged.len(), "fileB must now hold the union");
    fs::remove_file(&pa).ok();
    fs::remove_file(&pb).ok();
}

#[test]
fn interleaved_flushes_from_two_stores_union_instead_of_clobbering() {
    // Two "daemons" sharing one --cache-file: both open the (missing)
    // file, each computes different entries, and they flush in turn.
    // The later flush must not truncate away the earlier one's records
    // — flush diffs against the file's current contents, so the file
    // converges on the union.
    let path = temp_cache("two_writers");
    fs::remove_file(&path).ok();
    let net = zoo::by_name("vgg16-conv").unwrap();

    let sa = Arc::new(SharedStore::new());
    let sb = Arc::new(SharedStore::new());
    assert_eq!(sa.load(&path).loaded, 0);
    assert_eq!(sb.load(&path).loaded, 0);

    analyze_network_with(&mut Analyzer::with_store(Arc::clone(&sa)), &net, &styles::kc_p(), &hw(), true)
        .unwrap();
    sa.flush(&path).unwrap();
    analyze_network_with(&mut Analyzer::with_store(Arc::clone(&sb)), &net, &styles::x_p(), &hw(), true)
        .unwrap();
    let rb = sb.flush(&path).unwrap();
    assert_eq!(rb.written, sb.len(), "B appends only its own records, keeping A's");
    // A flush with nothing new to say writes nothing.
    assert_eq!(sa.flush(&path).unwrap().written, 0, "re-flush of persisted records is a no-op");

    let reread = SharedStore::new();
    assert_eq!(reread.load(&path).loaded, sa.len() + sb.len(), "the union survives both flushes");
    fs::remove_file(&path).ok();
}

/// Build a valid cache file for corruption scenarios; returns (path,
/// bytes, entry count).
fn valid_file(tag: &str) -> (PathBuf, Vec<u8>, usize) {
    let path = temp_cache(tag);
    fs::remove_file(&path).ok();
    let store = Arc::new(SharedStore::new());
    let mut a = Analyzer::with_store(Arc::clone(&store));
    let net = zoo::by_name("vgg16-conv").unwrap();
    analyze_network_with(&mut a, &net, &styles::kc_p(), &hw(), true).unwrap();
    store.flush(&path).unwrap();
    let bytes = fs::read(&path).unwrap();
    (path, bytes, store.len())
}

#[test]
fn truncated_file_keeps_valid_prefix() {
    let (path, bytes, entries) = valid_file("truncated");
    // Chop mid-way through the last record.
    fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
    let store = SharedStore::new();
    let report = store.load(&path);
    assert!(report.warning.is_some(), "truncation must warn");
    assert_eq!(report.loaded, entries - 1, "all but the severed record survive");
    assert!(report.dropped_bytes > 0);
    fs::remove_file(&path).ok();
}

#[test]
fn garbage_tail_is_dropped_not_fatal() {
    let (path, mut bytes, entries) = valid_file("garbage_tail");
    bytes.extend_from_slice(b"\xde\xad\xbe\xef not a record at all \x00\x01\x02");
    fs::write(&path, &bytes).unwrap();
    let store = SharedStore::new();
    let report = store.load(&path);
    assert_eq!(report.loaded, entries, "every intact record loads");
    assert!(report.warning.is_some() && report.dropped_bytes > 0);
    // Flushing after such a load truncates the bad tail away.
    let clean_len = fs::metadata(&path).unwrap().len() - report.dropped_bytes;
    store.flush(&path).unwrap();
    assert_eq!(fs::metadata(&path).unwrap().len(), clean_len);
    assert!(SharedStore::new().load(&path).warning.is_none(), "flush healed the file");
    fs::remove_file(&path).ok();
}

#[test]
fn flipped_bit_invalidates_only_the_tail() {
    let (path, mut bytes, entries) = valid_file("bitflip");
    // Flip one bit early in the record region: everything from that
    // record on is dropped, nothing panics, nothing poisons.
    let idx = 20; // inside the first record (header is 16 bytes)
    bytes[idx] ^= 0x01;
    fs::write(&path, &bytes).unwrap();
    let store = SharedStore::new();
    let report = store.load(&path);
    assert!(report.loaded < entries);
    assert!(report.warning.is_some());
    fs::remove_file(&path).ok();
}

#[test]
fn pure_garbage_and_empty_files_start_cold() {
    let path = temp_cache("garbage");
    fs::write(&path, b"this is not a cache file, it is a text file").unwrap();
    let store = Arc::new(SharedStore::new());
    let report = store.load(&path);
    assert_eq!(report.loaded, 0);
    assert!(report.warning.is_some());
    // And the store still works + flushes a valid file over the junk.
    let mut a = Analyzer::with_store(Arc::clone(&store));
    let net = zoo::by_name("dcgan").unwrap();
    analyze_network_with(&mut a, &net, &styles::kc_p(), &hw(), true).unwrap();
    store.flush(&path).unwrap();
    let reread = SharedStore::new().load(&path);
    assert!(reread.warning.is_none(), "flush healed the file: {:?}", reread.warning);
    assert_eq!(reread.loaded, store.len());
    fs::remove_file(&path).ok();

    let empty = temp_cache("empty");
    fs::write(&empty, b"").unwrap();
    let report = SharedStore::new().load(&empty);
    assert_eq!(report.loaded, 0);
    assert!(report.warning.is_none(), "an empty file is a clean cold start");
    fs::remove_file(&empty).ok();
}

#[test]
fn compact_rewrites_duplicate_records_with_unique_keys() {
    use maestro::cache::compact_file;
    let (path, bytes, entries) = valid_file("compact");
    // Simulate the append-only duplicate accumulation ROADMAP describes
    // (e.g. a store re-bound across --cache-file paths flushing its
    // contents again): append every record a second time. The frames
    // are self-delimiting and checksummed, so the doubled file is fully
    // valid — just wasteful.
    let mut doubled = bytes.clone();
    doubled.extend_from_slice(&bytes[16..]); // skip the 16-byte header
    fs::write(&path, &doubled).unwrap();
    // Loading tolerates the duplicates (first record per key wins)...
    let probe = SharedStore::new();
    let before = probe.load(&path);
    assert!(before.warning.is_none(), "{:?}", before.warning);
    assert_eq!(before.loaded, entries, "duplicates dedupe on load");
    // ...and compaction reclaims them on disk.
    let report = compact_file(&path).unwrap();
    assert_eq!(report.records_before, 2 * entries);
    assert_eq!(report.records_after, entries);
    assert_eq!(report.dropped_bytes, 0);
    assert!(report.warning.is_none());
    assert!(fs::metadata(&path).unwrap().len() < doubled.len() as u64);
    // The compacted file round-trips cleanly and completely.
    let after = SharedStore::new();
    let reread = after.load(&path);
    assert!(reread.warning.is_none(), "{:?}", reread.warning);
    assert_eq!(reread.loaded, entries);
    // Compaction is idempotent.
    let again = compact_file(&path).unwrap();
    assert_eq!((again.records_before, again.records_after), (entries, entries));
    fs::remove_file(&path).ok();
}

#[test]
fn compact_drops_corrupt_tails_but_refuses_foreign_files() {
    use maestro::cache::compact_file;
    // A corrupt tail is dropped (that is the point of compaction)...
    let (path, mut bytes, entries) = valid_file("compact_tail");
    bytes.extend_from_slice(b"torn half-record \x00\xff");
    fs::write(&path, &bytes).unwrap();
    let report = compact_file(&path).unwrap();
    assert_eq!(report.records_after, entries);
    assert!(report.dropped_bytes > 0);
    assert!(report.warning.is_some());
    assert!(SharedStore::new().load(&path).warning.is_none(), "compaction healed the file");
    fs::remove_file(&path).ok();

    // ...but a file this code cannot read is never rewritten: that
    // would destroy someone else's data.
    let foreign = temp_cache("compact_foreign");
    let junk = b"definitely not a maestro cache file".to_vec();
    fs::write(&foreign, &junk).unwrap();
    assert!(compact_file(&foreign).is_err());
    assert_eq!(fs::read(&foreign).unwrap(), junk, "refused file must be untouched");
    fs::remove_file(&foreign).ok();
    // And a missing path is an error, not a silently created file.
    assert!(compact_file(&temp_cache("compact_missing")).is_err());
}

#[test]
fn stale_version_starts_cold() {
    let (path, mut bytes, _) = valid_file("stale");
    // Pretend the analysis version moved on.
    bytes[12] ^= 0xff;
    fs::write(&path, &bytes).unwrap();
    let report = SharedStore::new().load(&path);
    assert_eq!(report.loaded, 0, "stale analyses must never replay");
    assert!(report.warning.as_deref().unwrap_or("").contains("version"), "{:?}", report.warning);
    fs::remove_file(&path).ok();
}
