//! Mapping-space subsystem contracts (ISSUE 5):
//!
//! * **Legality (property)** — every tiling the enumerator emits for a
//!   random layer shape resolves on that shape at the stated PE count,
//!   the emitted list is fingerprint-unique, and enumeration is a pure
//!   function (same inputs, same bits) — including across threads.
//! * **Compatibility** — the pinned fig13/ci_smoke variant lists,
//!   now instantiated through the style templates, are bit-identical
//!   to the hand-coded ones (names and directives), so every
//!   pre-mapspace sweep pin in `dse_parallel.rs`/`dse_strategies.rs`
//!   holds unchanged.
//! * **Acceptance** — the layer-wise mapper finds a mapping that
//!   *strictly* beats the best fixed Table 3 style on runtime or EDP
//!   for at least one layer of the CI-smoke network (and never loses
//!   on any layer: the enumeration is a superset of the fixed styles),
//!   deterministically for any thread count.

use std::collections::HashSet;
use std::sync::Arc;

use maestro::cache::SharedStore;
use maestro::dse::engine::{sweep, SweepConfig};
use maestro::dse::pareto::objective_values;
use maestro::dse::space::DesignSpace;
use maestro::dse::strategy::{SearchBudget, SearchStrategy};
use maestro::engine::analysis::{objective_score, Analyzer, Objective};
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::mapspace::{
    enumerate, enumerate_all, Mapper, MapperConfig, MapperStats, MappingOutcome, StyleTemplate,
};
use maestro::model::layer::Layer;
use maestro::model::network::Network;
use maestro::model::zoo::vgg16;
use maestro::util::propcheck::{check, Check, Config};

#[test]
fn every_generated_tiling_resolves_dedupes_and_replays() {
    check("mapspace-legality", Config { cases: 48, ..Config::default() }, |rng| {
        let r = rng.range(1, 4);
        let s = rng.range(1, 4);
        let layer = Layer::conv2d(
            "prop",
            1,
            rng.range(1, 96),
            rng.range(1, 96),
            rng.range(r, 40),
            rng.range(s, 40),
            r,
            s,
            rng.range(1, 2),
        );
        if layer.validate().is_err() {
            return Check::Discard;
        }
        let pes = *rng.pick(&[64u64, 256]);
        let resolution = rng.range(2, 8) as usize;
        // Enumeration is a function of the *shape*, not the layer
        // object: a layer rebuilt from its ShapeKey enumerates
        // identically.
        let rebuilt = layer.shape_key().to_layer("rebuilt");
        for t in StyleTemplate::all() {
            let en = enumerate(&t, &layer, pes, resolution);
            let again = enumerate(&t, &rebuilt, pes, resolution);
            if en.dataflows != again.dataflows || en.coords != again.coords {
                return Check::Fail(format!("{}: enumeration not replayable on {layer}", t.name));
            }
            if en.combos != en.dataflows.len() as u64 + en.unmappable + en.duplicates {
                return Check::Fail(format!("{}: accounting leak on {layer}", t.name));
            }
            let mut seen = HashSet::new();
            for df in &en.dataflows {
                if let Err(e) = df.resolve(&layer, pes) {
                    return Check::Fail(format!("{}: '{}' does not resolve on {layer} at {pes} PEs: {e:#}", t.name, df.name));
                }
                if !seen.insert(df.fingerprint()) {
                    return Check::Fail(format!("{}: duplicate fingerprint for '{}' on {layer}", t.name, df.name));
                }
            }
        }
        Check::Pass
    });
}

#[test]
fn enumeration_is_bit_deterministic_across_threads() {
    let layer = vgg16::conv13();
    let reference: Vec<_> = StyleTemplate::all()
        .iter()
        .map(|t| enumerate(t, &layer, 256, 6).dataflows)
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let reference = &reference;
            let layer = &layer;
            scope.spawn(move || {
                let got: Vec<_> = StyleTemplate::all()
                    .iter()
                    .map(|t| enumerate(t, layer, 256, 6).dataflows)
                    .collect();
                assert_eq!(&got, reference, "enumeration must not depend on the thread");
            });
        }
    });
}

#[test]
fn compat_variant_lists_are_bit_identical_to_the_hand_coded_ones() {
    use maestro::dse::space::{kc_p_ct, kc_p_variants, yr_p_ck, yr_p_variants, yx_p_variants, yx_p_xt};
    // The exact lists the fig13/ci_smoke pins were recorded against.
    let kc: Vec<_> = [4u64, 8, 16, 32, 64, 128].iter().map(|&ct| kc_p_ct(ct)).collect();
    assert_eq!(kc_p_variants(), kc);
    let mut yr = Vec::new();
    for c in [1u64, 2, 4, 8] {
        for k in [1u64, 2, 4] {
            yr.push(yr_p_ck(c, k));
        }
    }
    assert_eq!(yr_p_variants(), yr);
    let yx: Vec<_> = [2u64, 4, 8, 16, 32].iter().map(|&xt| yx_p_xt(xt)).collect();
    assert_eq!(yx_p_variants(), yx);
    // Template defaults are the fixed Table 3 styles, structurally.
    assert_eq!(StyleTemplate::kc_p().instantiate(&[64]).fingerprint(), styles::kc_p().fingerprint());
    assert_eq!(StyleTemplate::yr_p().instantiate(&[2, 2]).fingerprint(), styles::yr_p().fingerprint());
    assert_eq!(StyleTemplate::yx_p().instantiate(&[8]).fingerprint(), styles::yx_p().fingerprint());
}

#[test]
fn enumeration_contains_every_fixed_style_that_maps() {
    let hw = HwConfig::fig10_default();
    for layer in vgg16::conv_only().layers {
        let en = enumerate_all(&StyleTemplate::all(), &layer, hw.num_pes, 6);
        for fixed in styles::all_styles() {
            if fixed.resolve(&layer, hw.num_pes).is_ok() {
                assert!(
                    en.dataflows.iter().any(|d| d.fingerprint() == fixed.fingerprint()),
                    "{}: fixed style {} missing from the enumeration",
                    layer.name,
                    fixed.name
                );
            }
        }
    }
}

/// The ISSUE 5 acceptance pin: the mapper never loses to a fixed
/// Table 3 style on any layer (its space is a superset), and strictly
/// beats the per-layer best fixed style on runtime or EDP for at least
/// one CI-smoke-network layer.
#[test]
fn mapper_strictly_beats_the_best_fixed_style_on_a_ci_smoke_layer() {
    let net = vgg16::conv_only();
    let hw = HwConfig::fig10_default();
    let mut strictly_better = false;
    for objective in [Objective::Runtime, Objective::Edp] {
        let mut mapper = Mapper::new();
        let cfg = MapperConfig { objective, ..MapperConfig::default() };
        let out = mapper.map_network(&net, &hw, &cfg).unwrap();
        assert!(out.network.skipped.is_empty(), "every smoke layer must map");
        assert_eq!(out.network.per_layer.len(), net.layers.len());
        let mut analyzer = Analyzer::new();
        for (layer, mapped) in net.layers.iter().zip(&out.network.per_layer) {
            let mut best_fixed = f64::INFINITY;
            for df in styles::all_styles() {
                if let Ok(s) = analyzer.analyze(layer, &df, &hw) {
                    best_fixed = best_fixed.min(objective_score(&s, objective));
                }
            }
            let got = objective_score(mapped, objective);
            assert!(
                got <= best_fixed * (1.0 + 1e-9),
                "{} ({:?}): mapper {} must not lose to the best fixed style {}",
                layer.name,
                objective,
                got,
                best_fixed
            );
            if got < best_fixed * (1.0 - 1e-9) {
                strictly_better = true;
            }
        }
    }
    assert!(
        strictly_better,
        "the mapper must strictly beat the best fixed Table 3 style on runtime or EDP for at \
         least one ci_smoke-network layer"
    );
}

#[test]
fn mapper_is_deterministic_for_any_thread_count_and_warmth() {
    let net = vgg16::conv_only();
    let hw = HwConfig::fig10_default();
    let run = || {
        let mut mapper = Mapper::new();
        mapper.map_network(&net, &hw, &MapperConfig::default()).unwrap()
    };
    let reference = run();
    // Identical reruns, bit for bit.
    let again = run();
    assert_eq!(reference.network.runtime.to_bits(), again.network.runtime.to_bits());
    assert_eq!(reference.network.energy.total().to_bits(), again.network.energy.total().to_bits());
    for (a, b) in reference.per_shape.iter().zip(&again.per_shape) {
        assert_eq!(a.dataflow, b.dataflow);
        assert_eq!(a.stats, b.stats);
    }
    // Concurrent mappers (the default config is the serial reference
    // path, so N parallel mappers must all agree with it; the pooled
    // path is pinned against it below).
    std::thread::scope(|scope| {
        for _ in 0..3 {
            let reference = &reference;
            let net = &net;
            let hw = &hw;
            scope.spawn(move || {
                let mut mapper = Mapper::new();
                let got = mapper.map_network(net, hw, &MapperConfig::default()).unwrap();
                assert_eq!(got.network.runtime.to_bits(), reference.network.runtime.to_bits());
                for (a, b) in got.per_shape.iter().zip(&reference.per_shape) {
                    assert_eq!(a.dataflow, b.dataflow);
                }
            });
        }
    });
    // A warm shared store moves no bits and re-analyzes nothing.
    let store = Arc::new(SharedStore::new());
    let mut cold = Mapper::with_store(Arc::clone(&store));
    let cold_out = cold.map_network(&net, &hw, &MapperConfig::default()).unwrap();
    assert!(cold_out.stats.cache_misses > 0);
    let mut warm = Mapper::with_store(store);
    let warm_out = warm.map_network(&net, &hw, &MapperConfig::default()).unwrap();
    assert_eq!(warm_out.stats.cache_misses, 0, "fully warm mapper must replay everything");
    assert_eq!(warm_out.network.runtime.to_bits(), reference.network.runtime.to_bits());
    for (a, b) in warm_out.per_shape.iter().zip(&reference.per_shape) {
        assert_eq!(a.dataflow, b.dataflow);
    }
}

/// Everything the determinism contract covers, minus what it excludes:
/// wall clock and the cache hit/miss/eviction split (partition- and
/// warmth-dependent, like the sweep's — `dse_parallel.rs` comparable()).
fn comparable(stats: &MapperStats) -> MapperStats {
    MapperStats {
        seconds: 0.0,
        cache_hits: 0,
        cache_disk_hits: 0,
        cache_misses: 0,
        evictions: 0,
        profile_hits: 0,
        ..stats.clone()
    }
}

fn assert_mapping_eq(got: &MappingOutcome, want: &MappingOutcome, ctx: &str) {
    assert_eq!(got.network.runtime.to_bits(), want.network.runtime.to_bits(), "{ctx}: runtime");
    assert_eq!(
        got.network.energy.total().to_bits(),
        want.network.energy.total().to_bits(),
        "{ctx}: energy"
    );
    assert_eq!(got.per_shape.len(), want.per_shape.len(), "{ctx}: shape count");
    for (g, w) in got.per_shape.iter().zip(&want.per_shape) {
        assert_eq!(g.dataflow, w.dataflow, "{ctx}: winner for {}", w.representative);
        assert_eq!(g.stats.runtime.to_bits(), w.stats.runtime.to_bits(), "{ctx}: {}", w.representative);
        assert_eq!(g.evaluated, w.evaluated, "{ctx}: evaluated for {}", w.representative);
    }
    assert_eq!(comparable(&got.stats), comparable(&want.stats), "{ctx}: stats");
}

/// The ISSUE 7 acceptance pin: the pooled mapper is bit-identical to
/// the serial reference for threads in {1, 2, 8} (and 0 = all cores),
/// on a cold store and on a pre-warmed one — winners, network bits,
/// per-shape stats, and every budget counter, including a
/// `budget_skipped`-producing prefix cut.
#[test]
fn threaded_mapper_is_bit_identical_to_the_serial_reference_for_any_warmth() {
    let net = vgg16::conv_only();
    let hw = HwConfig::fig10_default();
    // A budget that actually cuts, so budget accounting is exercised
    // across the thread axis too.
    let base = MapperConfig {
        budget: SearchBudget { max_designs: 12, ..SearchBudget::default() },
        ..MapperConfig::default()
    };
    let reference = Mapper::new().map_network(&net, &hw, &base).unwrap();
    assert!(reference.stats.budget_skipped > 0, "the pin must exercise budget cuts");
    for threads in [1usize, 2, 8, 0] {
        let cfg = MapperConfig { threads, ..base.clone() };
        // Cold store.
        let cold = Mapper::new().map_network(&net, &hw, &cfg).unwrap();
        assert_mapping_eq(&cold, &reference, &format!("cold, threads={threads}"));
        // Warm store: pre-warmed by a serial run through the same
        // SharedStore; the pooled run must replay it without a single
        // re-analysis and still move no bits.
        let store = Arc::new(SharedStore::new());
        Mapper::with_store(Arc::clone(&store)).map_network(&net, &hw, &base).unwrap();
        let warm = Mapper::with_store(store).map_network(&net, &hw, &cfg).unwrap();
        assert_mapping_eq(&warm, &reference, &format!("warm, threads={threads}"));
        assert_eq!(warm.stats.cache_misses, 0, "warm run must replay (threads={threads})");
    }
}

#[test]
fn mapspace_backed_space_sweeps_deterministically_and_guided_reaches_it() {
    let layer = vgg16::conv13();
    let space = DesignSpace::mapspace("kc-p", &layer, 5, 4, 3).unwrap();
    assert!(space.variants.len() >= 2);
    let net = Network::single(layer);
    let serial = sweep(&net, &space, 2, &SweepConfig { keep_all_points: true, ..SweepConfig::serial() }).unwrap();
    assert!(!serial.frontier.is_empty());
    for threads in [2usize, 4] {
        let cfg = SweepConfig { threads, keep_all_points: true, ..SweepConfig::default() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(out.frontier, serial.frontier, "threads={threads}");
        assert_eq!(out.points, serial.points, "threads={threads}");
    }
    // The guided strategy — expanding along tile-coordinate adjacency —
    // still reaches the exhaustive frontier's objective values.
    let guided = sweep(
        &net,
        &space,
        2,
        &SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::serial() },
    )
    .unwrap();
    assert_eq!(objective_values(&guided.frontier), objective_values(&serial.frontier));
    assert!(guided.stats.evaluated <= serial.stats.evaluated);
}
