//! ANALYSIS_VERSION discipline guard (ROADMAP item): cached analysis
//! values are functions of the key *and of the analysis formulas*, so
//! `cache::persist::ANALYSIS_VERSION` must be bumped in the same commit
//! as any change to the engine sources — otherwise stale cache files
//! replay wrong numbers silently. This test fingerprints
//! `rust/src/engine/*.rs` with the crate's own process-stable FNV-128
//! and fails loudly against a pinned constant when they drift, turning
//! "remember to bump the version" into a red test.
//!
//! On a legitimate engine change:
//!  1. if analysis *outputs* changed for any key, bump
//!     `cache::persist::ANALYSIS_VERSION` (same commit);
//!  2. repin `ENGINE_SRC_FINGERPRINT` below to the value the failure
//!     message prints.

use maestro::util::stablehash::Fnv128;

/// FNV-128 over the sorted engine sources (name, NUL, length, bytes
/// with `\r` stripped so checkout line-ending policy cannot move it).
// PR 10 repin: engine/analysis.rs gained observation-only trace spans
// (profile.build / profile.finalize). No formula changed — outputs are
// bit-identical for every key (telemetry on/off identity is pinned in
// rust/tests/serve_concurrent.rs) — so ANALYSIS_VERSION stays.
const ENGINE_SRC_FINGERPRINT: u128 = 0x83b85732f1167bc61a5e42b5cbfcd869;

fn engine_fingerprint() -> u128 {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src/engine");
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .expect("rust/src/engine must exist")
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".rs"))
        .collect();
    names.sort();
    assert!(!names.is_empty(), "no engine sources found in {}", dir.display());
    let mut h = Fnv128::new();
    for name in &names {
        let mut data = std::fs::read(dir.join(name)).expect("read engine source");
        data.retain(|&b| b != b'\r');
        h.write(name.as_bytes());
        h.write_u8(0);
        h.write_u64(data.len() as u64);
        h.write(&data);
    }
    h.finish()
}

#[test]
fn engine_sources_match_pinned_fingerprint() {
    let got = engine_fingerprint();
    assert_eq!(
        got, ENGINE_SRC_FINGERPRINT,
        "\nrust/src/engine sources changed (fingerprint {got:#034x}).\n\
         Cached analyses may now be stale: if analysis outputs changed for any key,\n\
         bump `cache::persist::ANALYSIS_VERSION` in the SAME commit, then repin\n\
         `ENGINE_SRC_FINGERPRINT` in rust/tests/engine_version_guard.rs to the value above.\n"
    );
}

#[test]
fn fingerprint_is_stable_across_calls() {
    assert_eq!(engine_fingerprint(), engine_fingerprint());
}
