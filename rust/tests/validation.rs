//! Integration: analytical engine vs cycle-level simulator across a
//! matrix of (layer x dataflow x hardware) — the Fig 9 validation
//! contract at test scale. The simulator shares only the schedule
//! semantics with the analytical engine, making it an independent
//! ground truth for runtime and traffic.

use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::{HwConfig, ReductionSupport};
use maestro::ir::styles;
use maestro::model::layer::Layer;
use maestro::model::tensor::{tensor_elements, TensorKind};
use maestro::sim::cycle::simulate;

const MAX_STEPS: u64 = 40_000_000;

fn layers() -> Vec<Layer> {
    vec![
        Layer::conv2d("early", 1, 16, 4, 26, 26, 3, 3, 1),
        Layer::conv2d("late", 1, 48, 48, 12, 12, 3, 3, 1),
        Layer::conv2d("pw", 1, 48, 24, 20, 20, 1, 1, 1),
        Layer::conv2d("strided", 1, 16, 8, 23, 23, 3, 3, 2),
        Layer::conv2d("rect", 2, 8, 6, 17, 25, 3, 5, 1),
        Layer::depthwise("dw", 1, 24, 22, 22, 3, 3, 1),
        Layer::fully_connected("fc", 1, 96, 128),
    ]
}

fn hws() -> Vec<HwConfig> {
    vec![
        HwConfig { num_pes: 32, ..HwConfig::fig10_default() },
        HwConfig { num_pes: 64, noc_bandwidth: 4, ..HwConfig::fig10_default() },
        HwConfig { num_pes: 128, noc_bandwidth: 64, noc_latency: 4, ..HwConfig::fig10_default() },
    ]
}

#[test]
fn runtime_agreement_across_matrix() {
    let mut checked = 0;
    let mut worst: (f64, String) = (0.0, String::new());
    for layer in layers() {
        for df in styles::all_styles() {
            for hw in hws() {
                let Ok(sim) = simulate(&layer, &df, &hw, MAX_STEPS) else { continue };
                let Ok(ana) = analyze_layer(&layer, &df, &hw) else {
                    panic!("{} analyzable mismatch on {}", df.name, layer.name)
                };
                let err = (ana.runtime - sim.cycles).abs() / sim.cycles;
                let tag = format!("{} / {} / {}pes bw{}", layer.name, df.name, hw.num_pes, hw.noc_bandwidth);
                assert!(
                    err < 0.25,
                    "{tag}: analytical {} vs sim {} ({:.1}% off)",
                    ana.runtime,
                    sim.cycles,
                    err * 100.0
                );
                if err > worst.0 {
                    worst = (err, tag);
                }
                checked += 1;
            }
        }
    }
    assert!(checked >= 50, "matrix too small: only {checked} pairs simulated");
    println!("validated {checked} (layer, dataflow, hw) pairs; worst error {:.1}% at {}", worst.0 * 100.0, worst.1);
}

#[test]
fn mac_counts_agree_exactly() {
    for layer in layers() {
        for df in styles::all_styles() {
            let hw = HwConfig { num_pes: 32, ..HwConfig::fig10_default() };
            let Ok(sim) = simulate(&layer, &df, &hw, MAX_STEPS) else { continue };
            let ana = analyze_layer(&layer, &df, &hw).unwrap();
            let lm = layer.macs() as f64 * layer.sparsity_macs_scale();
            assert!(
                (sim.macs - lm).abs() < 1e-6 * lm.max(1.0),
                "{} / {}: sim macs {} vs layer {}",
                layer.name,
                df.name,
                sim.macs,
                lm
            );
            assert!(
                (ana.macs - lm).abs() < 1e-6 * lm.max(1.0),
                "{} / {}: model macs {} vs layer {}",
                layer.name,
                df.name,
                ana.macs,
                lm
            );
        }
    }
}

#[test]
fn traffic_lower_bounds_hold_in_both_models() {
    let hw = HwConfig { num_pes: 32, ..HwConfig::fig10_default() };
    for layer in layers() {
        for df in styles::all_styles() {
            let Ok(sim) = simulate(&layer, &df, &hw, MAX_STEPS) else { continue };
            let ana = analyze_layer(&layer, &df, &hw).unwrap();
            for (ti, kind) in [TensorKind::Filter, TensorKind::Input].iter().enumerate() {
                let size = tensor_elements(&layer, *kind) as f64;
                if size == 0.0 {
                    continue;
                }
                assert!(sim.l2_reads[ti] >= size * 0.999, "{} {} sim reads {} < {size}", layer.name, df.name, sim.l2_reads[ti]);
                assert!(ana.l2_reads[ti] >= size * 0.999, "{} {} ana reads {} < {size}", layer.name, df.name, ana.l2_reads[ti]);
            }
            let osize = tensor_elements(&layer, TensorKind::Output) as f64;
            assert!(sim.l2_writes >= osize * 0.999, "{} {} sim writes", layer.name, df.name);
        }
    }
}

#[test]
fn hardware_knobs_move_both_models_in_the_same_direction() {
    let layer = Layer::conv2d("knob", 1, 16, 8, 18, 18, 3, 3, 1);
    let df = styles::c_p();
    let base = HwConfig { num_pes: 32, ..HwConfig::fig10_default() };

    // Bandwidth down -> runtime up, in both.
    let slow = HwConfig { noc_bandwidth: 1, ..base.clone() };
    let (sb, ss) = (
        simulate(&layer, &df, &base, MAX_STEPS).unwrap(),
        simulate(&layer, &df, &slow, MAX_STEPS).unwrap(),
    );
    assert!(ss.cycles >= sb.cycles);
    let (ab, a_s) = (
        analyze_layer(&layer, &df, &base).unwrap(),
        analyze_layer(&layer, &df, &slow).unwrap(),
    );
    assert!(a_s.runtime >= ab.runtime);

    // Reduction support off -> egress up, in both.
    let nored = HwConfig { reduction: ReductionSupport::None, ..base.clone() };
    let sn = simulate(&layer, &df, &nored, MAX_STEPS).unwrap();
    let an = analyze_layer(&layer, &df, &nored).unwrap();
    assert!(sn.l2_writes > sb.l2_writes * 1.2, "sim egress should inflate");
    assert!(an.l2_writes[2] > ab.l2_writes[2] * 1.2, "model egress should inflate");
}

#[test]
fn row_stationary_fig6_six_pe_example() {
    // The paper's extended example: 6 PEs, two clusters of Sz(R)=3.
    let layer = Layer::conv2d("fig6", 1, 2, 2, 8, 8, 3, 3, 1);
    let df = styles::row_stationary_fig6();
    let hw = HwConfig { num_pes: 6, noc_bandwidth: 8, ..HwConfig::fig10_default() };
    let ana = analyze_layer(&layer, &df, &hw).unwrap();
    let sim = simulate(&layer, &df, &hw, MAX_STEPS).unwrap();
    assert!((ana.macs - layer.macs() as f64).abs() < 1.0);
    let err = (ana.runtime - sim.cycles).abs() / sim.cycles;
    assert!(err < 0.25, "fig6 example err {:.1}%", err * 100.0);
}
