//! Strategy-level contracts of the budgeted DSE search (ISSUE 4):
//!
//! * `Exhaustive` under a budget truncates deterministically and still
//!   accounts for every candidate in the space.
//! * `RandomSample` frontiers are dominated-or-equal by the exhaustive
//!   frontier (they evaluate a subset of the same space).
//! * `ParetoGuided` **reaches** the exhaustive Pareto frontier
//!   (objective-value set equality) on the fig13 CI-smoke space while
//!   evaluating under half of what the exhaustive sweep evaluates —
//!   the acceptance pin, also asserted by the `DSE_SMOKE` bench and a
//!   dedicated CI step.
//!
//! Why guided equality is guaranteed and not a fluke: per (variant,
//! PEs) pair the energy is bandwidth-independent and runtime is
//! monotone non-increasing in bandwidth (both pinned by
//! `dse::engine` unit tests), so a pair's best objective values sit at
//! its highest *valid* bandwidth; the guided strategy binary-searches
//! exactly that point for every pair it cannot prove dominated (its
//! top-bandwidth runtime is a lower bound on anything the pair can
//! achieve), and probes every pair at least once before converging.

use maestro::dse::engine::{sweep, DesignPoint, SweepConfig};
use maestro::dse::pareto::objective_values as value_set;
use maestro::dse::space::DesignSpace;
use maestro::dse::strategy::{SearchBudget, SearchStrategy};
use maestro::model::network::Network;
use maestro::model::zoo::vgg16;

/// Every point of `inner` is dominated-or-equal by some point of
/// `outer` (<= on both objectives).
fn dominated_or_equal(inner: &[DesignPoint], outer: &[DesignPoint]) -> bool {
    inner
        .iter()
        .all(|p| outer.iter().any(|q| q.runtime <= p.runtime && q.energy_pj <= p.energy_pj))
}

#[test]
fn guided_reaches_exhaustive_frontier_with_under_half_the_evaluations() {
    let net = Network::single(vgg16::conv13());
    let space = DesignSpace::ci_smoke("kc-p");
    let exhaustive = sweep(&net, &space, 2, &SweepConfig::serial()).unwrap();
    let guided = sweep(
        &net,
        &space,
        2,
        &SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::serial() },
    )
    .unwrap();
    assert!(!exhaustive.frontier.is_empty());
    assert_eq!(
        value_set(&guided.frontier),
        value_set(&exhaustive.frontier),
        "guided must reach the exhaustive frontier's objective values"
    );
    assert!(dominated_or_equal(&guided.frontier, &exhaustive.frontier));
    assert!(
        guided.stats.evaluated * 2 < exhaustive.stats.evaluated,
        "guided evaluated {} of the exhaustive {} — not under 50%",
        guided.stats.evaluated,
        exhaustive.stats.evaluated
    );
    assert!(guided.stats.waves > 1, "guided is iterative");
}

#[test]
fn guided_reaches_exhaustive_frontier_on_network_workload() {
    // Same acceptance contract on the CI-smoke *network* workload (the
    // one the DSE_SMOKE bench gates).
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let exhaustive = sweep(&net, &space, 2, &SweepConfig::serial()).unwrap();
    let guided = sweep(
        &net,
        &space,
        2,
        &SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::serial() },
    )
    .unwrap();
    assert_eq!(value_set(&guided.frontier), value_set(&exhaustive.frontier));
    assert!(
        guided.stats.evaluated * 2 < exhaustive.stats.evaluated,
        "guided evaluated {} of the exhaustive {}",
        guided.stats.evaluated,
        exhaustive.stats.evaluated
    );
}

#[test]
fn random_frontier_is_dominated_by_exhaustive() {
    let net = Network::single(vgg16::conv13());
    let space = DesignSpace::ci_smoke("kc-p");
    let exhaustive = sweep(&net, &space, 2, &SweepConfig::serial()).unwrap();
    for seed in [1u64, 7, 42] {
        let random = sweep(
            &net,
            &space,
            2,
            &SweepConfig {
                strategy: SearchStrategy::RandomSample { seed },
                budget: SearchBudget { max_designs: space.size() / 2, ..SearchBudget::default() },
                ..SweepConfig::serial()
            },
        )
        .unwrap();
        assert!(
            dominated_or_equal(&random.frontier, &exhaustive.frontier),
            "seed {seed}: a sampled frontier cannot beat the full sweep"
        );
        assert!(random.stats.evaluated <= space.size() / 2);
    }
}

#[test]
fn random_without_budget_is_rejected() {
    let net = Network::single(vgg16::conv13());
    let space = DesignSpace::ci_smoke("kc-p");
    let err = sweep(
        &net,
        &space,
        2,
        &SweepConfig { strategy: SearchStrategy::RandomSample { seed: 1 }, ..SweepConfig::default() },
    );
    assert!(err.is_err(), "random sampling needs max_designs");
    assert!(err.unwrap_err().to_string().contains("budget"));
}

#[test]
fn exhaustive_budget_accounts_every_candidate_and_is_a_prefix() {
    let net = Network::single(vgg16::conv13());
    let space = DesignSpace::ci_smoke("kc-p");
    let full = sweep(
        &net,
        &space,
        2,
        &SweepConfig { keep_all_points: true, ..SweepConfig::serial() },
    )
    .unwrap();
    let budget = 40u64;
    let cut = sweep(
        &net,
        &space,
        2,
        &SweepConfig {
            keep_all_points: true,
            budget: SearchBudget { max_designs: budget, ..SearchBudget::default() },
            ..SweepConfig::serial()
        },
    )
    .unwrap();
    let s = &cut.stats;
    assert_eq!(
        s.evaluated + s.pruned + s.unmappable + s.budget_skipped,
        s.total_designs,
        "every candidate lands in exactly one bucket under a budget"
    );
    assert_eq!(s.budget_skipped, space.size() - budget);
    // The admitted candidates are the serial-order prefix: the budgeted
    // point list replays the head of the unbudgeted one bit for bit.
    assert!(cut.points.len() <= full.points.len());
    assert_eq!(cut.points[..], full.points[..cut.points.len()]);
    assert!(dominated_or_equal(&cut.frontier, &full.frontier));
    // Determinism across thread counts holds under budgets too.
    let threaded = sweep(
        &net,
        &space,
        2,
        &SweepConfig {
            keep_all_points: true,
            threads: 4,
            budget: SearchBudget { max_designs: budget, ..SearchBudget::default() },
            ..SweepConfig::default()
        },
    )
    .unwrap();
    assert_eq!(threaded.points, cut.points);
    assert_eq!(threaded.frontier, cut.frontier);
}

#[test]
fn wall_clock_budget_stops_between_waves() {
    let net = Network::single(vgg16::conv13());
    let space = DesignSpace::ci_smoke("kc-p");
    let out = sweep(
        &net,
        &space,
        2,
        &SweepConfig {
            strategy: SearchStrategy::ParetoGuided,
            budget: SearchBudget { max_seconds: 1e-12, ..SearchBudget::default() },
            ..SweepConfig::serial()
        },
    )
    .unwrap();
    // The cutoff fires before the first or second wave; either way the
    // sweep ends early and cleanly instead of converging.
    assert!(out.stats.waves <= 1, "wall cutoff must stop the refinement loop");
}

#[test]
fn strategy_names_surface_in_summaries() {
    let net = Network::single(vgg16::conv13());
    let space = DesignSpace::ci_smoke("kc-p");
    let out = sweep(&net, &space, 2, &SweepConfig::serial()).unwrap();
    assert!(out.stats.summary().contains("strategy=exhaustive"), "{}", out.stats.summary());
    let guided = sweep(
        &net,
        &space,
        2,
        &SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::serial() },
    )
    .unwrap();
    assert!(guided.stats.summary().contains("strategy=guided"));
    assert!(guided.stats.summary().contains("waves="));
}
