//! The sharded sweep's determinism and accounting contract: the merged
//! frontier, point list, and statistics must be bit-identical for any
//! thread count / shard size — for single-layer *and* whole-network
//! workloads — and the counters must match a plain serial
//! reimplementation of the §5.2 pruned sweep.
//!
//! The Analyzer cache hit/miss/disk-hit counters are the one
//! exception: they follow the shard partition (each private-cache
//! shard owns its own map, so a shape straddling two shards is a miss
//! in both) and, for shared stores, the pre-warmed state; they carry
//! no result data and are zeroed by [`comparable`] before comparison.
//!
//! The shared-store contract extends this: a sweep pooling one
//! [`SharedStore`] — empty, pre-warmed by an earlier sweep, or loaded
//! from a cache file — must replay the serial private-cache reference
//! bit for bit at any thread count.

use std::sync::Arc;

use maestro::cache::SharedStore;
use maestro::dse::engine::{
    build_case_table, build_case_table_cached, eval_energy, eval_runtime, sweep, SweepConfig, SweepStats,
};
use maestro::dse::space::{kc_p_ct, DesignSpace};
use maestro::dse::strategy::{SearchBudget, SearchStrategy};
use maestro::engine::analysis::Analyzer;
use maestro::hw::area;
use maestro::model::layer::Layer;
use maestro::model::network::Network;
use maestro::model::zoo::vgg16;

/// Strip the fields excluded from the determinism contract: wall clock
/// and the partition/warmth-dependent cache counters.
fn comparable(stats: &SweepStats) -> SweepStats {
    SweepStats {
        seconds: 0.0,
        cache_hits: 0,
        cache_disk_hits: 0,
        cache_misses: 0,
        profile_hits: 0,
        ..stats.clone()
    }
}

#[test]
fn sweep_is_deterministic_across_thread_counts() {
    let net = Network::single(vgg16::conv13());
    let space = DesignSpace::fig13("kc-p", 6);
    let cfg = SweepConfig { keep_all_points: true, ..SweepConfig::serial() };
    let reference = sweep(&net, &space, 2, &cfg).unwrap();
    assert!(!reference.frontier.is_empty());
    for (threads, shard_size) in [(2usize, 0usize), (4, 1), (4, 3), (8, 2), (0, 0)] {
        let cfg = SweepConfig { threads, shard_size, keep_all_points: true, ..SweepConfig::default() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(
            out.frontier, reference.frontier,
            "frontier must be bit-identical (threads={threads}, shard_size={shard_size})"
        );
        assert_eq!(
            out.points, reference.points,
            "full point list must replay serial order (threads={threads}, shard_size={shard_size})"
        );
        assert_eq!(
            comparable(&out.stats),
            comparable(&reference.stats),
            "counts must match (threads={threads}, shard_size={shard_size})"
        );
    }
}

#[test]
fn network_sweep_is_deterministic_across_thread_counts() {
    // The network-level path: a repeated-shape workload (the VGG16 conv
    // stack) where the shard-local Analyzer caches actually engage.
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let cfg = SweepConfig { keep_all_points: true, ..SweepConfig::serial() };
    let reference = sweep(&net, &space, 2, &cfg).unwrap();
    assert!(reference.stats.cache_hits > 0, "repeated shapes must hit the shard caches");
    for (threads, shard_size) in [(2usize, 0usize), (4, 1), (0, 2)] {
        let cfg = SweepConfig { threads, shard_size, keep_all_points: true, ..SweepConfig::default() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(out.frontier, reference.frontier, "threads={threads}, shard_size={shard_size}");
        assert_eq!(out.points, reference.points, "threads={threads}, shard_size={shard_size}");
        assert_eq!(comparable(&out.stats), comparable(&reference.stats), "threads={threads}");
        assert_eq!(
            out.stats.cache_hits + out.stats.cache_misses,
            reference.stats.cache_hits + reference.stats.cache_misses,
            "total layer analyses requested is partition-independent"
        );
    }
}

#[test]
fn network_sweep_is_layer_name_independent() {
    // Shape memoization must key on shapes, never names: renaming every
    // layer cannot move a single bit of the outcome.
    let net = vgg16::conv_only();
    let mut renamed = net.clone();
    for (i, layer) in renamed.layers.iter_mut().enumerate() {
        layer.name = format!("anon_{i}");
    }
    let space = DesignSpace::ci_smoke("kc-p");
    let cfg = SweepConfig { keep_all_points: true, ..SweepConfig::default() };
    let a = sweep(&net, &space, 2, &cfg).unwrap();
    let b = sweep(&renamed, &space, 2, &cfg).unwrap();
    assert_eq!(a.frontier, b.frontier);
    assert_eq!(a.points, b.points);
    assert_eq!(comparable(&a.stats), comparable(&b.stats));
}

/// A from-scratch serial reimplementation of the pruned sweep's
/// accounting, independent of the sharded engine's code path. Tables
/// are built through the uncached one-shot path, so agreement here also
/// pins "memoized network sweep == per-layer aggregation".
fn serial_reference_counts(net: &Network, space: &DesignSpace, noc_hops: u64) -> SweepStats {
    let layers: Vec<&Layer> = net.layers.iter().collect();
    // The reference models what the engine reports for an unbudgeted
    // exhaustive sweep: one wave, nothing budget-skipped.
    let mut stats = SweepStats {
        total_designs: space.size(),
        strategy: "exhaustive".into(),
        waves: 1,
        ..SweepStats::default()
    };
    let min_bw = *space.bandwidths.iter().min().unwrap();
    for variant in &space.variants {
        for &pes in &space.pes {
            let Ok(table) = build_case_table(&layers, variant, pes) else {
                stats.unmappable += space.bandwidths.len() as u64;
                continue;
            };
            let min_ap = area::evaluate(pes, table.l1_req, table.l2_req, min_bw);
            if min_ap.area_mm2 > space.area_budget_mm2 || min_ap.power_mw > space.power_budget_mw {
                stats.pruned += space.bandwidths.len() as u64;
                continue;
            }
            let energy = eval_energy(&table.activity, table.l1_req, table.l2_req, noc_hops);
            for &bw in &space.bandwidths {
                stats.evaluated += 1;
                let ap = area::evaluate(pes, table.l1_req, table.l2_req, bw);
                let runtime = eval_runtime(&table, bw, space.noc_latency);
                let power = ap.power_mw + energy / runtime.max(1.0);
                if ap.area_mm2 <= space.area_budget_mm2 && power <= space.power_budget_mw {
                    stats.valid += 1;
                }
            }
        }
    }
    stats
}

#[test]
fn sweep_counts_match_serial_reference() {
    let net = Network::single(vgg16::conv2());
    let space = DesignSpace::ci_smoke("kc-p");
    let want = serial_reference_counts(&net, &space, 2);
    for threads in [1usize, 4] {
        let cfg = SweepConfig { threads, ..SweepConfig::default() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(comparable(&out.stats), comparable(&want), "threads={threads}");
    }
    assert_eq!(want.evaluated + want.pruned + want.unmappable, want.total_designs);
}

#[test]
fn network_sweep_counts_match_serial_reference() {
    // Same contract on a whole-network workload: the sharded memoized
    // path must agree with uncached per-layer table construction.
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let want = serial_reference_counts(&net, &space, 2);
    for threads in [1usize, 4] {
        let cfg = SweepConfig { threads, ..SweepConfig::default() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(comparable(&out.stats), comparable(&want), "threads={threads}");
    }
}

#[test]
fn warmed_analyzer_tables_replay_cold_tables() {
    // One shard-style Analyzer reused across (variant, PEs) pairs must
    // reproduce every cold-built table bit for bit.
    let net = vgg16::conv_only();
    let layers: Vec<&Layer> = net.layers.iter().collect();
    let mut analyzer = Analyzer::new();
    for variant in [kc_p_ct(8), kc_p_ct(32)] {
        for pes in [64u64, 512] {
            let warm = build_case_table_cached(&mut analyzer, &layers, &variant, pes).unwrap();
            let cold = build_case_table(&layers, &variant, pes).unwrap();
            assert_eq!(warm, cold, "{} pes={pes}", variant.name);
        }
    }
    assert!(analyzer.cache_hits() > 0);
}

#[test]
fn shared_store_sweep_is_bit_identical_for_any_thread_count_and_warmth() {
    // The acceptance contract of the cache subsystem: a sweep pooling
    // one SharedStore must replay the serial private-cache reference
    // exactly — for any thread count, and for ANY pre-warmed cache
    // state (cold, warmed by a previous sweep, or loaded from disk).
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let reference = sweep(&net, &space, 2, &SweepConfig { keep_all_points: true, ..SweepConfig::serial() }).unwrap();

    let store = Arc::new(SharedStore::new());
    for (round, threads) in [(0usize, 1usize), (1, 2), (2, 4), (3, 0)].into_iter() {
        // Round 0 runs cold; every later round re-sweeps an
        // increasingly warm store.
        let cfg = SweepConfig {
            threads,
            keep_all_points: true,
            cache: Some(Arc::clone(&store)),
            ..SweepConfig::default()
        };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(out.frontier, reference.frontier, "round {round}, threads={threads}");
        assert_eq!(out.points, reference.points, "round {round}, threads={threads}");
        assert_eq!(comparable(&out.stats), comparable(&reference.stats), "round {round}");
        if round > 0 {
            assert_eq!(out.stats.cache_misses, 0, "warm rounds must not re-analyze anything");
        }
    }

    // Disk warmth: flush the store, load into a fresh one, and sweep
    // again — still bit-identical, now with disk hits reported.
    let path = std::env::temp_dir().join(format!("maestro_dse_warm_{}.mcache", std::process::id()));
    store.flush(&path).unwrap();
    let from_disk = Arc::new(SharedStore::new());
    let report = from_disk.load(&path);
    assert!(report.warning.is_none(), "{:?}", report.warning);
    assert_eq!(report.loaded, store.len());
    let cfg = SweepConfig {
        threads: 4,
        keep_all_points: true,
        cache: Some(Arc::clone(&from_disk)),
        ..SweepConfig::default()
    };
    let warm = sweep(&net, &space, 2, &cfg).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(warm.frontier, reference.frontier);
    assert_eq!(warm.points, reference.points);
    assert_eq!(comparable(&warm.stats), comparable(&reference.stats));
    assert_eq!(warm.stats.cache_misses, 0, "disk-warm sweep must not re-analyze");
    assert!(warm.stats.cache_disk_hits > 0, "hits must be attributed to disk");
    assert_eq!(warm.stats.cache_hits, warm.stats.cache_disk_hits, "every hit came from disk");
}

#[test]
fn random_sample_is_deterministic_for_seed_and_any_thread_count() {
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let base = SweepConfig {
        keep_all_points: true,
        strategy: SearchStrategy::RandomSample { seed: 42 },
        budget: SearchBudget { max_designs: 60, ..SearchBudget::default() },
        ..SweepConfig::serial()
    };
    let reference = sweep(&net, &space, 2, &base).unwrap();
    // Every sampled candidate lands in exactly one accounting bucket.
    assert_eq!(
        reference.stats.evaluated + reference.stats.pruned + reference.stats.unmappable,
        60,
        "the sample is exactly the budget"
    );
    assert_eq!(reference.stats.budget_skipped, 0, "the plan never exceeds its own budget");
    assert_eq!(reference.stats.waves, 1);
    for (threads, shard_size) in [(2usize, 0usize), (4, 1), (0, 2)] {
        let cfg = SweepConfig { threads, shard_size, ..base.clone() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(out.frontier, reference.frontier, "threads={threads}, shard_size={shard_size}");
        assert_eq!(out.points, reference.points, "threads={threads}, shard_size={shard_size}");
        assert_eq!(comparable(&out.stats), comparable(&reference.stats), "threads={threads}");
    }
}

#[test]
fn guided_sweep_is_deterministic_across_thread_counts() {
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let base = SweepConfig {
        keep_all_points: true,
        strategy: SearchStrategy::ParetoGuided,
        ..SweepConfig::serial()
    };
    let reference = sweep(&net, &space, 2, &base).unwrap();
    assert!(!reference.frontier.is_empty());
    assert!(reference.stats.waves > 1, "guided refinement runs multiple waves");
    for (threads, shard_size) in [(2usize, 0usize), (4, 1), (0, 2)] {
        let cfg = SweepConfig { threads, shard_size, ..base.clone() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(out.frontier, reference.frontier, "threads={threads}, shard_size={shard_size}");
        assert_eq!(out.points, reference.points, "threads={threads}, shard_size={shard_size}");
        assert_eq!(comparable(&out.stats), comparable(&reference.stats), "threads={threads}");
    }
}

#[test]
fn guided_never_evaluates_a_design_twice() {
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let cfg = SweepConfig {
        keep_all_points: true,
        strategy: SearchStrategy::ParetoGuided,
        ..SweepConfig::serial()
    };
    let out = sweep(&net, &space, 2, &cfg).unwrap();
    assert_eq!(out.points.len() as u64, out.stats.evaluated, "keep_all_points records every evaluation");
    let mut seen = std::collections::HashSet::new();
    for p in &out.points {
        assert!(
            seen.insert((p.dataflow.clone(), p.pes, p.bandwidth)),
            "candidate ({}, {}, {}) evaluated twice",
            p.dataflow,
            p.pes,
            p.bandwidth
        );
    }
}

#[test]
fn guided_sweep_with_shared_store_replays_fully_warm() {
    // Shared-store caching must keep working for every strategy: the
    // guided strategy revisits the same candidates deterministically,
    // so a second run over one store replays every analysis and moves
    // no bits.
    let net = vgg16::conv_only();
    let space = DesignSpace::ci_smoke("kc-p");
    let store = Arc::new(SharedStore::new());
    let cfg = SweepConfig {
        keep_all_points: true,
        strategy: SearchStrategy::ParetoGuided,
        cache: Some(Arc::clone(&store)),
        ..SweepConfig::serial()
    };
    let cold = sweep(&net, &space, 2, &cfg).unwrap();
    assert!(cold.stats.cache_misses > 0);
    assert!(!store.is_empty());
    let warm = sweep(&net, &space, 2, &cfg).unwrap();
    assert_eq!(warm.stats.cache_misses, 0, "fully warm guided rerun must not re-analyze");
    assert_eq!(warm.frontier, cold.frontier);
    assert_eq!(warm.points, cold.points);
    assert_eq!(comparable(&warm.stats), comparable(&cold.stats));
}

#[test]
fn unmappable_and_pruned_pairs_are_distinguished() {
    let net = Network::single(vgg16::conv13());
    // kc_p_ct(64) needs a 64-PE cluster: pes=8 is unmappable, while
    // pes=4096 maps but exceeds the power budget at any bandwidth.
    let space = DesignSpace {
        pes: vec![8, 4096],
        bandwidths: vec![4, 64],
        noc_latency: 2,
        variants: vec![kc_p_ct(64)],
        variant_adjacency: Vec::new(),
        area_budget_mm2: 16.0,
        power_budget_mw: 450.0,
    };
    let out = sweep(&net, &space, 2, &SweepConfig::default()).unwrap();
    assert_eq!(out.stats.unmappable, 2);
    assert_eq!(out.stats.pruned, 2);
    assert_eq!(out.stats.evaluated, 0);
    assert!(out.frontier.is_empty());
    let summary = out.stats.summary();
    assert!(summary.contains("pruned=2") && summary.contains("unmappable=2"), "{summary}");
}

#[test]
fn streaming_frontier_without_points_matches_keep_all() {
    let net = Network::single(vgg16::conv2());
    let space = DesignSpace::ci_smoke("kc-p");
    let lean = sweep(&net, &space, 2, &SweepConfig::default()).unwrap();
    let keep = SweepConfig { keep_all_points: true, ..SweepConfig::default() };
    let full = sweep(&net, &space, 2, &keep).unwrap();
    assert!(lean.points.is_empty(), "keep_all_points=false must not materialize the space");
    assert_eq!(full.points.len() as u64, full.stats.evaluated);
    assert_eq!(lean.frontier, full.frontier);
}
