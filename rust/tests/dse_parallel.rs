//! The sharded sweep's determinism and accounting contract: the merged
//! frontier, point list, and statistics must be bit-identical for any
//! thread count / shard size, and the counters must match a plain
//! serial reimplementation of the §5.2 pruned sweep.

use maestro::dse::engine::{
    build_case_table, eval_energy, eval_runtime, sweep, SweepConfig, SweepStats,
};
use maestro::dse::space::{kc_p_ct, DesignSpace};
use maestro::hw::area;
use maestro::model::layer::Layer;
use maestro::model::zoo::vgg16;

fn without_wall_clock(stats: &SweepStats) -> SweepStats {
    SweepStats { seconds: 0.0, ..stats.clone() }
}

#[test]
fn sweep_is_deterministic_across_thread_counts() {
    let layer = vgg16::conv13();
    let space = DesignSpace::fig13("kc-p", 6);
    let reference = sweep(
        &[&layer],
        &space,
        2,
        &SweepConfig { keep_all_points: true, ..SweepConfig::serial() },
    )
    .unwrap();
    assert!(!reference.frontier.is_empty());
    for (threads, shard_size) in [(2usize, 0usize), (4, 1), (4, 3), (8, 2), (0, 0)] {
        let cfg = SweepConfig { threads, shard_size, keep_all_points: true };
        let out = sweep(&[&layer], &space, 2, &cfg).unwrap();
        assert_eq!(
            out.frontier, reference.frontier,
            "frontier must be bit-identical (threads={threads}, shard_size={shard_size})"
        );
        assert_eq!(
            out.points, reference.points,
            "full point list must replay serial order (threads={threads}, shard_size={shard_size})"
        );
        assert_eq!(
            without_wall_clock(&out.stats),
            without_wall_clock(&reference.stats),
            "counts must match (threads={threads}, shard_size={shard_size})"
        );
    }
}

/// A from-scratch serial reimplementation of the pruned sweep's
/// accounting, independent of the sharded engine's code path.
fn serial_reference_counts(layers: &[&Layer], space: &DesignSpace, noc_hops: u64) -> SweepStats {
    let mut stats = SweepStats { total_designs: space.size(), ..SweepStats::default() };
    let min_bw = *space.bandwidths.iter().min().unwrap();
    for variant in &space.variants {
        for &pes in &space.pes {
            let Ok(table) = build_case_table(layers, variant, pes) else {
                stats.unmappable += space.bandwidths.len() as u64;
                continue;
            };
            let min_ap = area::evaluate(pes, table.l1_req, table.l2_req, min_bw);
            if min_ap.area_mm2 > space.area_budget_mm2 || min_ap.power_mw > space.power_budget_mw {
                stats.pruned += space.bandwidths.len() as u64;
                continue;
            }
            let energy = eval_energy(&table.activity, table.l1_req, table.l2_req, noc_hops);
            for &bw in &space.bandwidths {
                stats.evaluated += 1;
                let ap = area::evaluate(pes, table.l1_req, table.l2_req, bw);
                let runtime = eval_runtime(&table, bw, space.noc_latency);
                let power = ap.power_mw + energy / runtime.max(1.0);
                if ap.area_mm2 <= space.area_budget_mm2 && power <= space.power_budget_mw {
                    stats.valid += 1;
                }
            }
        }
    }
    stats
}

#[test]
fn sweep_counts_match_serial_reference() {
    let layer = vgg16::conv2();
    let space = DesignSpace::ci_smoke("kc-p");
    let want = serial_reference_counts(&[&layer], &space, 2);
    for threads in [1usize, 4] {
        let cfg = SweepConfig { threads, ..SweepConfig::default() };
        let out = sweep(&[&layer], &space, 2, &cfg).unwrap();
        assert_eq!(without_wall_clock(&out.stats), without_wall_clock(&want), "threads={threads}");
    }
    assert_eq!(want.evaluated + want.pruned + want.unmappable, want.total_designs);
}

#[test]
fn unmappable_and_pruned_pairs_are_distinguished() {
    let layer = vgg16::conv13();
    // kc_p_ct(64) needs a 64-PE cluster: pes=8 is unmappable, while
    // pes=4096 maps but exceeds the power budget at any bandwidth.
    let space = DesignSpace {
        pes: vec![8, 4096],
        bandwidths: vec![4, 64],
        noc_latency: 2,
        variants: vec![kc_p_ct(64)],
        area_budget_mm2: 16.0,
        power_budget_mw: 450.0,
    };
    let out = sweep(&[&layer], &space, 2, &SweepConfig::default()).unwrap();
    assert_eq!(out.stats.unmappable, 2);
    assert_eq!(out.stats.pruned, 2);
    assert_eq!(out.stats.evaluated, 0);
    assert!(out.frontier.is_empty());
    let summary = out.stats.summary();
    assert!(summary.contains("pruned=2") && summary.contains("unmappable=2"), "{summary}");
}

#[test]
fn streaming_frontier_without_points_matches_keep_all() {
    let layer = vgg16::conv2();
    let space = DesignSpace::ci_smoke("kc-p");
    let lean = sweep(&[&layer], &space, 2, &SweepConfig::default()).unwrap();
    let full = sweep(
        &[&layer],
        &space,
        2,
        &SweepConfig { keep_all_points: true, ..SweepConfig::default() },
    )
    .unwrap();
    assert!(lean.points.is_empty(), "keep_all_points=false must not materialize the space");
    assert_eq!(full.points.len() as u64, full.stats.evaluated);
    assert_eq!(lean.frontier, full.frontier);
}
