//! Structural tests for the span tracer and its Chrome trace-event
//! validator (`obs::trace`).
//!
//! The trace buffers are process-global, so exactly **one** test here
//! records live spans — it owns every recording thread and runs its
//! phases (full, sampled, disable-mid-span) sequentially with
//! `clear()` between them. Every other test feeds the validator
//! hand-built JSON and never touches the recorder, so the default
//! parallel test harness cannot race the live test.
//!
//! (The end-to-end determinism contract — telemetry on/off/sampled
//! never changes a reply byte — is pinned in
//! `rust/tests/serve_concurrent.rs`.)

use maestro::obs::trace;
use maestro::util::json::Json;

fn parse(text: &str) -> Json {
    Json::parse(text).expect("test trace JSON parses")
}

fn trace_of(events: &str) -> Json {
    parse(&format!(r#"{{"traceEvents":[{events}]}}"#))
}

fn event(name: &str, ph: &str, ts: u64, tid: u64) -> String {
    format!(r#"{{"name":"{name}","ph":"{ph}","ts":{ts},"pid":1,"tid":{tid}}}"#)
}

/// The one live-recording test: nested spans on the test thread plus
/// worker threads, then a sampled phase, then an end-after-disable
/// phase. Each phase's export must pass the validator and carry
/// exactly the expected event count.
#[test]
fn recorded_spans_export_a_valid_chrome_trace() {
    // Phase 1: record everything — nesting on this thread, flat spans
    // on two workers.
    trace::enable(1);
    trace::clear();
    {
        let _outer = trace::span("test.outer");
        let _inner = trace::span("test.inner");
    }
    let workers: Vec<_> = (0..2)
        .map(|_| {
            std::thread::spawn(|| {
                for _ in 0..5 {
                    let _span = trace::span("test.worker");
                }
            })
        })
        .collect();
    for w in workers {
        w.join().expect("worker thread");
    }
    trace::disable();

    let exported = trace::export();
    let summary = trace::validate(&exported).expect("full trace validates");
    // 2 spans here + 5 on each of 2 workers, a B and an E apiece.
    assert_eq!(summary.events, (2 + 2 * 5) * 2);
    assert_eq!(summary.threads, 3, "this thread + 2 workers");
    assert_eq!(summary.max_depth, 2, "outer/inner nesting");
    assert_eq!(
        exported
            .get("otherData")
            .and_then(|o| o.get("dropped_spans"))
            .and_then(Json::as_u64),
        Some(0),
        "nothing hit the buffer cap"
    );

    // Phase 2: sampling keeps traces balanced. A fresh thread's
    // per-thread clock starts at 0, so every-3rd over 9 spans records
    // spans 0, 3, 6 — three B/E pairs.
    trace::clear();
    trace::enable(3);
    std::thread::spawn(|| {
        for _ in 0..9 {
            let _span = trace::span("test.sampled");
        }
    })
    .join()
    .expect("sampled thread");
    trace::disable();
    let sampled = trace::validate(&trace::export()).expect("sampled trace validates");
    assert_eq!(sampled.events, 3 * 2, "every 3rd of 9 spans, B+E each");

    // Phase 3: a span open across disable() still closes — the E lands
    // whenever the B was recorded, so the trace stays balanced.
    trace::clear();
    trace::enable(1);
    let straddle = trace::span("test.straddle");
    trace::disable();
    drop(straddle);
    let closed = trace::validate(&trace::export()).expect("straddling span still balances");
    assert_eq!(closed.events, 2);

    trace::clear();
}

#[test]
fn validator_accepts_interleaved_threads_with_per_thread_time() {
    // Global timestamps go backwards across tids (tid 2 starts before
    // tid 1's latest event) — legal, only per-tid order matters.
    let trace = trace_of(&[
        event("a", "B", 10, 1),
        event("b", "B", 5, 2),
        event("a", "E", 20, 1),
        event("b", "E", 6, 2),
    ]
    .join(","));
    let summary = trace::validate(&trace).expect("interleaved tids are valid");
    assert_eq!(summary.events, 4);
    assert_eq!(summary.threads, 2);
    assert_eq!(summary.max_depth, 1);
}

#[test]
fn validator_rejects_missing_trace_events_array() {
    let err = trace::validate(&parse(r#"{"otherData":{}}"#)).unwrap_err();
    assert!(err.to_string().contains("traceEvents"), "{err}");
}

#[test]
fn validator_rejects_unclosed_span() {
    let trace = trace_of(&event("a", "B", 1, 1));
    let err = trace::validate(&trace).unwrap_err();
    assert!(err.to_string().contains("open"), "{err}");
}

#[test]
fn validator_rejects_end_without_begin() {
    let trace = trace_of(&event("a", "E", 1, 1));
    let err = trace::validate(&trace).unwrap_err();
    assert!(err.to_string().contains("no span open"), "{err}");
}

#[test]
fn validator_rejects_mismatched_span_names() {
    let trace = trace_of(&[event("a", "B", 1, 1), event("b", "E", 2, 1)].join(","));
    let err = trace::validate(&trace).unwrap_err();
    assert!(err.to_string().contains("'a' is open"), "{err}");
}

#[test]
fn validator_rejects_backwards_time_within_a_thread() {
    let trace = trace_of(&[event("a", "B", 10, 1), event("a", "E", 9, 1)].join(","));
    let err = trace::validate(&trace).unwrap_err();
    assert!(err.to_string().contains("backwards"), "{err}");
}

#[test]
fn validator_rejects_unknown_phase() {
    let trace = trace_of(&event("a", "X", 1, 1));
    let err = trace::validate(&trace).unwrap_err();
    assert!(err.to_string().contains("phase"), "{err}");
}

#[test]
fn validator_rejects_events_missing_required_fields() {
    for (missing, text) in [
        ("name", r#"{"ph":"B","ts":1,"pid":1,"tid":1}"#),
        ("ph", r#"{"name":"a","ts":1,"pid":1,"tid":1}"#),
        ("ts", r#"{"name":"a","ph":"B","pid":1,"tid":1}"#),
        ("pid", r#"{"name":"a","ph":"B","ts":1,"tid":1}"#),
        ("tid", r#"{"name":"a","ph":"B","ts":1,"pid":1}"#),
    ] {
        let err = trace::validate(&trace_of(text)).unwrap_err();
        assert!(err.to_string().contains(missing), "missing {missing}: {err}");
    }
}

#[test]
fn validator_summarizes_empty_traces() {
    let summary = trace::validate(&trace_of("")).expect("empty trace is valid");
    assert_eq!(summary, trace::TraceSummary::default());
}
