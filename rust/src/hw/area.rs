//! Area and power regression models for the DSE (paper §5.2).
//!
//! "For the cost of building blocks, we implement float/fixed point
//! multiplier and adder, bus, bus arbiter, and global/local scratchpad in
//! RTL and synthesize them using 28nm technology. For bus and arbiter
//! cost, we fit the costs into a linear and quadratic model" — we
//! reproduce exactly those regression *forms* with representative 28 nm
//! constants (substitution documented in DESIGN.md §4):
//!
//! * 16-bit MAC PE (mult + adder + control): ~1600 um², ~0.12 mW static+
//!   dynamic at 1 GHz nominal activity.
//! * SRAM: ~0.35 um²/bit macro density plus periphery ≈ linear in bits.
//! * Bus: linear in width (wires). Arbiter: quadratic in requesters
//!   (matrix arbiter).
//!
//! The Fig 13 budget (Eyeriss chip: 16 mm², 450 mW) sits in the middle of
//! this model's reachable space, which is what the experiment needs.

/// Area/power of one candidate design. Units: mm² and mW.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaPower {
    pub area_mm2: f64,
    pub power_mw: f64,
}

/// Regression constants (28 nm).
pub mod consts {
    /// PE (MAC + pipeline registers + local control), mm².
    pub const PE_AREA_MM2: f64 = 0.0016;
    /// PE power at full utilization, mW.
    pub const PE_POWER_MW: f64 = 0.12;
    /// SRAM area per element (2 B = 16 bit at 0.35 um²/bit + periphery).
    pub const SRAM_AREA_MM2_PER_EL: f64 = 7.0e-6;
    /// SRAM leakage+dynamic power per element, mW.
    pub const SRAM_POWER_MW_PER_EL: f64 = 2.2e-4;
    /// Bus: linear in width (elements/cycle of bandwidth), mm² per lane.
    pub const BUS_AREA_MM2_PER_LANE: f64 = 0.004;
    /// Bus power per lane, mW.
    pub const BUS_POWER_MW_PER_LANE: f64 = 0.8;
    /// Matrix arbiter: quadratic in requesters. mm² per grant-pair.
    pub const ARB_AREA_MM2_PER_PAIR: f64 = 1.0e-7;
    /// Arbiter power per grant-pair, mW.
    pub const ARB_POWER_MW_PER_PAIR: f64 = 2.0e-5;
}

/// Evaluate the regression model for a design: `pes` PEs, per-PE L1 of
/// `l1_elements`, shared L2 of `l2_elements`, NoC of `bw` lanes.
pub fn evaluate(pes: u64, l1_elements: u64, l2_elements: u64, bw: u64) -> AreaPower {
    use consts::*;
    let pes_f = pes as f64;
    let l1_total = (l1_elements * pes) as f64;
    let l2_f = l2_elements as f64;
    let bw_f = bw as f64;
    // Arbiter arbitrates `pes` requesters onto the bus: quadratic.
    let arb_pairs = pes_f * pes_f;
    AreaPower {
        area_mm2: pes_f * PE_AREA_MM2
            + l1_total * SRAM_AREA_MM2_PER_EL
            + l2_f * SRAM_AREA_MM2_PER_EL
            + bw_f * BUS_AREA_MM2_PER_LANE
            + arb_pairs * ARB_AREA_MM2_PER_PAIR,
        power_mw: pes_f * PE_POWER_MW
            + l1_total * SRAM_POWER_MW_PER_EL
            + l2_f * SRAM_POWER_MW_PER_EL
            + bw_f * BUS_POWER_MW_PER_LANE
            + arb_pairs * ARB_POWER_MW_PER_PAIR,
    }
}

/// Kernel-facing coefficient vector for the AOT evaluator, ordered as
/// [pe_area, sram_area_per_el, bus_area_per_lane, arb_area_per_pair,
///  pe_power, sram_power_per_el, bus_power_per_lane, arb_power_per_pair].
pub fn coefficients() -> [f64; 8] {
    use consts::*;
    [
        PE_AREA_MM2,
        SRAM_AREA_MM2_PER_EL,
        BUS_AREA_MM2_PER_LANE,
        ARB_AREA_MM2_PER_PAIR,
        PE_POWER_MW,
        SRAM_POWER_MW_PER_EL,
        BUS_POWER_MW_PER_LANE,
        ARB_POWER_MW_PER_PAIR,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eyeriss_scale_design_fits_budget() {
        // 168 PEs, 0.5KB L1 each, 100KB L2, 12-lane NoC should sit well
        // inside 16 mm2 / 450 mW (Eyeriss-like).
        let ap = evaluate(168, 256, 51_200, 12);
        assert!(ap.area_mm2 < 16.0, "area {}", ap.area_mm2);
        assert!(ap.power_mw < 450.0, "power {}", ap.power_mw);
    }

    #[test]
    fn big_designs_exceed_budget() {
        let ap = evaluate(4096, 4096, 4_000_000, 256);
        assert!(ap.area_mm2 > 16.0 || ap.power_mw > 450.0);
    }

    #[test]
    fn monotone_in_every_parameter() {
        let base = evaluate(128, 512, 100_000, 16);
        assert!(evaluate(256, 512, 100_000, 16).area_mm2 > base.area_mm2);
        assert!(evaluate(128, 1024, 100_000, 16).area_mm2 > base.area_mm2);
        assert!(evaluate(128, 512, 200_000, 16).power_mw > base.power_mw);
        assert!(evaluate(128, 512, 100_000, 32).power_mw > base.power_mw);
    }

    #[test]
    fn arbiter_is_quadratic() {
        use consts::*;
        let a1 = evaluate(100, 1, 1, 1).area_mm2;
        let a2 = evaluate(200, 1, 1, 1).area_mm2;
        let arb1 = 100.0 * 100.0 * ARB_AREA_MM2_PER_PAIR;
        let arb2 = 200.0 * 200.0 * ARB_AREA_MM2_PER_PAIR;
        let lin = 100.0 * PE_AREA_MM2 + 100.0 * SRAM_AREA_MM2_PER_EL;
        assert!((a2 - a1 - (arb2 - arb1) - lin).abs() < 1e-9);
    }
}
