//! Energy model: per-access costs fitted to Cacti-style 28 nm SRAM
//! curves (substitution for the paper's Cacti 6.0 runs — DESIGN.md §4).
//!
//! Costs are in picojoules per 2-byte element access. Anchor points:
//!
//! * 16-bit MAC at 28 nm ≈ 0.2 pJ (Horowitz ISSCC'14 scaled).
//! * 2 KB L1 scratchpad read ≈ 1.2 pJ (the paper's L1 config).
//! * 1 MB L2 buffer read ≈ 12 pJ (the paper's L2 config).
//! * DRAM ≈ 160 pJ (not exercised by the per-layer model, reported for
//!   completeness).
//!
//! SRAM access energy grows ≈ √capacity for small arrays (wordline/
//! bitline growth), which we fit as `E(size) = a + b·√(size_el)`
//! calibrated to pass through the anchors above. Relative dataflow
//! rankings (Fig 10/12) depend only on the E_L2 ≫ E_L1 > E_MAC ordering,
//! which any Cacti run at this node reproduces.

/// Per-access energies for one hardware configuration, in pJ/element.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    pub mac_pj: f64,
    pub l1_read_pj: f64,
    pub l1_write_pj: f64,
    pub l2_read_pj: f64,
    pub l2_write_pj: f64,
    /// Per-element per-hop NoC wire/router energy.
    pub noc_hop_pj: f64,
    pub dram_pj: f64,
}

/// Fit constants: E = A + B * sqrt(elements). Writes cost ~10% more than
/// reads (bitline swing), matching Cacti's read/write asymmetry.
pub const L1_A: f64 = 0.35;
pub const L1_B: f64 = 0.0266; // 0.35 + 0.0266*sqrt(1024) ≈ 1.2 pJ at 2 KB
pub const L2_A: f64 = 2.0;
pub const L2_B: f64 = 0.0138; // 2.0 + 0.0138*sqrt(524288) ≈ 12 pJ at 1 MB
pub const WRITE_FACTOR: f64 = 1.1;

/// Energy per L1 read for a given capacity in elements.
pub fn l1_read_pj(l1_elements: u64) -> f64 {
    L1_A + L1_B * (l1_elements.max(1) as f64).sqrt()
}

/// Energy per L2 read for a given capacity in elements.
pub fn l2_read_pj(l2_elements: u64) -> f64 {
    L2_A + L2_B * (l2_elements.max(1) as f64).sqrt()
}

impl EnergyModel {
    /// Build the model for given buffer capacities (in elements).
    pub fn for_sizes(l1_elements: u64, l2_elements: u64) -> EnergyModel {
        let l1r = l1_read_pj(l1_elements);
        let l2r = l2_read_pj(l2_elements);
        EnergyModel {
            mac_pj: 0.2,
            l1_read_pj: l1r,
            l1_write_pj: l1r * WRITE_FACTOR,
            l2_read_pj: l2r,
            l2_write_pj: l2r * WRITE_FACTOR,
            noc_hop_pj: 0.06,
            dram_pj: 160.0,
        }
    }

    /// The paper's base configuration (2 KB L1, 1 MB L2 at 2B/element).
    pub fn paper_default() -> EnergyModel {
        EnergyModel::for_sizes(1024, 524_288)
    }

    /// Kernel-facing coefficient vector, ordered as the AOT artifact
    /// expects: [mac, l1r, l1w, l2r, l2w, noc_hop].
    pub fn coefficients(&self) -> [f64; 6] {
        [
            self.mac_pj,
            self.l1_read_pj,
            self.l1_write_pj,
            self.l2_read_pj,
            self.l2_write_pj,
            self.noc_hop_pj,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_hold() {
        let m = EnergyModel::paper_default();
        assert!((m.l1_read_pj - 1.2).abs() < 0.06, "L1 anchor: {}", m.l1_read_pj);
        assert!((m.l2_read_pj - 12.0).abs() < 0.6, "L2 anchor: {}", m.l2_read_pj);
    }

    #[test]
    fn ordering_l2_gg_l1_gt_mac() {
        let m = EnergyModel::paper_default();
        assert!(m.l2_read_pj > 5.0 * m.l1_read_pj);
        assert!(m.l1_read_pj > m.mac_pj);
    }

    #[test]
    fn monotone_in_capacity() {
        assert!(l1_read_pj(4096) > l1_read_pj(1024));
        assert!(l2_read_pj(1 << 21) > l2_read_pj(1 << 19));
    }

    #[test]
    fn writes_cost_more() {
        let m = EnergyModel::paper_default();
        assert!(m.l1_write_pj > m.l1_read_pj);
        assert!(m.l2_write_pj > m.l2_read_pj);
    }
}
