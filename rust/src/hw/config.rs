//! Abstract accelerator configuration (paper Fig 2): PE array + L1
//! scratchpads + shared L2 + NoC, with the reuse-support switches of
//! Table 2/5.

use anyhow::{ensure, Result};

/// How spatial reduction is implemented (Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReductionSupport {
    /// No hardware support: psums travel to the parent buffer and are
    /// merged by read-modify-write there.
    None,
    /// Adder tree: log2(fan-in) pipeline stages.
    Tree,
    /// Reduce-and-forward chain (systolic): fan-in - 1 forwarding hops.
    Forward,
}

/// One accelerator design point.
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    /// Total processing elements.
    pub num_pes: u64,
    /// Per-PE L1 scratchpad capacity, in data elements.
    pub l1_size: u64,
    /// Shared L2 buffer capacity, in data elements.
    pub l2_size: u64,
    /// NoC bandwidth: data elements per cycle deliverable from/to L2.
    pub noc_bandwidth: u64,
    /// NoC average latency in cycles (the pipe model's length, §4.2).
    pub noc_latency: u64,
    /// Spatial multicast support (fan-out NoC). Without it, multicast
    /// traffic is replicated per destination (Table 5 "No multicast").
    pub multicast: bool,
    /// Spatial reduction support (Table 5 "No Sp. reduction").
    pub reduction: ReductionSupport,
    /// MACs per PE per cycle.
    pub pe_throughput: u64,
    /// Clock, used only to convert cycles to seconds in reports.
    pub clock_ghz: f64,
}

impl HwConfig {
    /// The 256-PE / 32 GBps configuration of Fig 10 (32 GBps at 1 GHz and
    /// 2-byte elements = 16 elements/cycle).
    pub fn fig10_default() -> HwConfig {
        HwConfig {
            num_pes: 256,
            l1_size: 1024,     // 2 KB of 2-byte elements (paper's L1)
            l2_size: 524_288,  // 1 MB of 2-byte elements (paper's L2)
            noc_bandwidth: 16,
            noc_latency: 2,
            multicast: true,
            reduction: ReductionSupport::Tree,
            pe_throughput: 1,
            clock_ghz: 1.0,
        }
    }

    /// MAERI-like 64-PE config used by the Fig 9 validation.
    pub fn maeri_64() -> HwConfig {
        HwConfig { num_pes: 64, noc_bandwidth: 16, ..HwConfig::fig10_default() }
    }

    /// Eyeriss-like 168-PE config used by the Fig 9 validation.
    pub fn eyeriss_168() -> HwConfig {
        HwConfig {
            num_pes: 168,
            // Two-level hierarchical bus with dedicated channels per
            // tensor — §4.2 models it as ~3x bandwidth.
            noc_bandwidth: 12,
            ..HwConfig::fig10_default()
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.num_pes >= 1, "num_pes must be >= 1");
        ensure!(self.noc_bandwidth >= 1, "noc_bandwidth must be >= 1");
        ensure!(self.pe_throughput >= 1, "pe_throughput must be >= 1");
        ensure!(self.l1_size >= 1 && self.l2_size >= 1, "buffer sizes must be >= 1");
        Ok(())
    }

    /// Convert cycles to milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: f64) -> f64 {
        cycles / (self.clock_ghz * 1e9) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        HwConfig::fig10_default().validate().unwrap();
        HwConfig::maeri_64().validate().unwrap();
        HwConfig::eyeriss_168().validate().unwrap();
    }

    #[test]
    fn invalid_rejected() {
        let mut c = HwConfig::fig10_default();
        c.num_pes = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn cycle_conversion() {
        let c = HwConfig::fig10_default();
        assert!((c.cycles_to_ms(1e9) - 1000.0).abs() < 1e-9);
    }
}
