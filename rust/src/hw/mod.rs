//! Hardware models: accelerator configuration, the Cacti-fit energy
//! model, and the area/power regression models the DSE uses (§5.2).

pub mod area;
pub mod config;
pub mod energy;
