//! The resident `maestro serve` daemon: one warm [`SharedStore`],
//! newline-delimited JSON frames over TCP, bounded-queue backpressure,
//! and a shared-pool wave scheduler.
//!
//! ## Lifecycle
//!
//! [`serve`] (the CLI) or [`Daemon::spawn`] (in-process tests and
//! benches) binds a listener, loads `cache_file` into the store once,
//! and runs until a `shutdown` frame arrives. Every analyze/map/dse
//! request after the first reuses the same store, so repeated workloads
//! answer from memory (`warm_hits` in each reply's `stats`) instead of
//! re-running the analytical model. A flusher thread appends dirty
//! records back to `cache_file` every `flush_every` seconds and a final
//! flush runs on shutdown, so a crash loses at most one flush window.
//!
//! ## Request scheduling
//!
//! Work requests are `try_send`'d into a bounded [`JobQueue`] drained
//! by **one scheduler thread** that owns every in-flight request's wave
//! driver ([`SweepDriver`] / [`MapDriver`] / a prepared analyze). The
//! scheduler does no evaluation itself; it feeds one process-wide
//! [`WavePool`] of `workers` threads. Each round it pulls the next wave
//! from every in-flight request, interleaves their shard/chunk jobs
//! round-robin (so a long sweep cannot starve a short analyze — every
//! live request lands jobs in every wave), runs them as one pool wave,
//! and hands each request its results back in shard order. Absorption
//! and wave admission stay on the scheduler thread, so each request's
//! merge order — and therefore its reply — is bit-identical to the
//! in-process path for any worker count or concurrency level (the
//! cache/wall-clock counters in `stats` are diagnostic, as ever).
//! Under one request the pool sees that request's shards; under many,
//! it sees the union — a 2-worker daemon saturates both cores on
//! aggregate traffic instead of serializing requests behind each other.
//!
//! Overlapping requests also **coalesce work**, not just interleave it:
//! all evaluation flows through the shared store (identical
//! `(shape, dataflow, hw)` analyses replay as warm hits across
//! requests), and dse requests over the same design space share one
//! daemon-lifetime [`PairTables`] keyed by
//! [`table_identity`](crate::dse::table_identity), so the
//! bandwidth-invariant flattening work is done once per space, not once
//! per request.
//!
//! A full queue rejects immediately with an `overloaded` [`ApiError`];
//! its `retry_after_ms` scales with the observed drain rate (an EWMA of
//! per-request completion time times the backlog per worker) instead of
//! a constant. Control requests (`status`, `cancel`, `shutdown`) bypass
//! the queue entirely; `status` also reports queue depth, in-flight
//! count, and pool utilization.
//!
//! ## Streaming
//!
//! A `map`/`dse` request with `"stream": true` receives `progress`
//! frames on its connection before the final reply: one per absorbed
//! wave (dse) or shape (map), carrying the wave index, designs
//! evaluated, and — for dse — the frontier delta (points added /
//! dominated out) since the previous frame. Because waves absorb in the
//! same deterministic order as the in-process sweep, replaying the
//! deltas reconstructs the exact mid-sweep frontier after every wave,
//! and the final frame's accumulated set equals the final reply's
//! (sorted) frontier — a true prefix sequence of the deterministic
//! result, for any worker count and any concurrent traffic.
//!
//! ## Cancellation
//!
//! A work request carrying an `id` can be cancelled from **another**
//! connection (the submitting connection is blocked awaiting its
//! reply): `cancel` flips the request's scoped flag, which the sweep
//! driver checks between waves and the mapper between shapes. What the
//! client gets back depends on the request kind. `analyze`/`dse`
//! answer with a `cancelled` error (their partial output is
//! meaningless) — a streaming dse's frame sequence ends with that
//! well-formed error frame — and queued ones cancelled before starting
//! never execute. A cancelled `map` instead **degrades gracefully**:
//! shapes not yet searched fall back to the Table 3 default bindings —
//! the mapper's `max_seconds` semantics — so the reply is a complete,
//! well-formed mapping with `defaulted > 0`, never an error.
//!
//! [`SweepDriver`]: crate::dse::SweepDriver
//! [`MapDriver`]: crate::mapspace::MapDriver
//! [`WavePool`]: crate::util::pool::WavePool

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::SharedStore;
use crate::dse::engine::DesignPoint;
use crate::dse::{table_identity, PairTables, SweepDriver, SweepShard};
use crate::engine::analysis::NetworkStats;
use crate::mapspace::{MapChunk, MapDriver};
use crate::obs::{metrics, trace};
use crate::util::json::Json;
use crate::util::log;
use crate::util::pool::WavePool;
use crate::util::queue::JobQueue;

use super::api::{
    AnalyzeRequest, ApiError, DoneReply, DseRequest, MapRequest, MetricCounter, MetricGauge,
    MetricHistogram, MetricsReply, PointRow, ProgressReply, Request, RequestStats, Response,
    StatusReply,
};
use super::exec::{self, AnalyzeOutcome, AnalyzePrep, DsePrep, MapPrep};

/// Daemon knobs; [`ServeConfig::default`] matches the CLI defaults.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (see [`Daemon::addr`]).
    pub addr: String,
    /// Warm-store persistence: loaded at startup, flushed periodically
    /// and on shutdown. `None` = memory only.
    pub cache_file: Option<String>,
    /// Second-chance capacity cap on the resident store
    /// (0 = unbounded).
    pub cache_cap: usize,
    /// Shared-pool worker threads (the evaluation parallelism across
    /// **all** concurrent requests).
    pub workers: usize,
    /// Job-queue depth before `overloaded` rejections kick in.
    pub queue_cap: usize,
    /// Seconds between background store flushes (0 = shutdown only).
    pub flush_every: f64,
    /// Default worker threads for `dse` and `map` requests that leave
    /// `threads` 0 — affects only how finely their waves shard (0 =
    /// size for all cores); results are bit-identical for any value.
    pub threads: usize,
    /// Raise the log level to debug (one line per executed request).
    pub verbose: bool,
    /// Enable span tracing for the daemon's lifetime and write a
    /// Chrome trace-event JSON file here on shutdown. `None` = off.
    pub trace_out: Option<String>,
    /// Record every Nth span per thread (0/1 = all; only meaningful
    /// with `trace_out`).
    pub trace_sample: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7733".into(),
            cache_file: None,
            cache_cap: 0,
            workers: 2,
            queue_cap: 16,
            flush_every: 30.0,
            threads: 0,
            verbose: false,
            trace_out: None,
            trace_sample: 1,
        }
    }
}

// Fixed bucket layouts for the daemon's histograms (inclusive upper
// edges; one implicit overflow bucket). One constant per instrument so
// every call site agrees on the layout.
const SECONDS_BOUNDS: &[f64] = &[0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0];
const WAVE_JOBS_BOUNDS: &[f64] = &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
const DESIGNS_PER_SECOND_BOUNDS: &[f64] = &[1e2, 1e3, 1e4, 1e5, 1e6, 1e7];
const RETRY_MS_BOUNDS: &[f64] = &[100.0, 250.0, 500.0, 1_000.0, 2_500.0, 5_000.0, 10_000.0];

/// One queued unit of work: the decoded request, the channel its
/// frames go back on, and its cancellation flag.
struct Job {
    request: Request,
    reply: mpsc::Sender<Response>,
    cancel: Arc<AtomicBool>,
}

/// How many design-space identities keep their `PairTables` resident.
/// FIFO — a serving pattern cycling through more spaces than this
/// rebuilds tables on wrap, which costs work but never correctness.
const TABLE_CACHE_CAP: usize = 8;

/// Daemon-lifetime case-table cache: design-space identity
/// ([`table_identity`]) -> shared [`PairTables`]. Promoted from
/// sweep-lifetime so repeated and concurrent dse requests over the
/// same space flatten each (variant, PEs) pair once.
#[derive(Default)]
struct TableCache {
    map: HashMap<u64, Arc<PairTables>>,
    order: VecDeque<u64>,
}

/// State every daemon thread shares.
struct Shared {
    cfg: ServeConfig,
    store: Arc<SharedStore>,
    shutdown: AtomicBool,
    /// Daemon start time: `status`/`metrics` report uptime against it.
    started: Instant,
    /// Work requests concluded successfully since start (status field).
    requests_done: AtomicU64,
    /// Work requests concluded with an error reply since start.
    requests_failed: AtomicU64,
    /// Client-id -> cancel flag for queued/running work requests.
    inflight: Mutex<HashMap<u64, Arc<AtomicBool>>>,
    tables: Mutex<TableCache>,
    /// Requests accepted but not yet picked up by the scheduler.
    queue_depth: AtomicU64,
    /// Requests the scheduler is actively interleaving onto the pool.
    inflight_execs: AtomicU64,
    /// Job count of the most recent pool wave (utilization probe).
    last_wave_jobs: AtomicU64,
    /// EWMA of per-request dequeue-to-completion time in ms — the
    /// drain-rate estimate behind `overloaded.retry_after_ms`.
    drain_ms: AtomicU64,
}

impl Shared {
    /// The shared tables for one design-space identity (create on
    /// first use, FIFO-evict beyond [`TABLE_CACHE_CAP`]).
    fn tables_for(&self, key: u64) -> Arc<PairTables> {
        let mut cache = self.tables.lock().unwrap();
        if let Some(t) = cache.map.get(&key) {
            return Arc::clone(t);
        }
        let t = Arc::new(PairTables::new());
        cache.map.insert(key, Arc::clone(&t));
        cache.order.push_back(key);
        while cache.order.len() > TABLE_CACHE_CAP {
            if let Some(old) = cache.order.pop_front() {
                cache.map.remove(&old);
            }
        }
        t
    }

    /// Fold one finished request into the drain-rate EWMA
    /// (new = (3·old + sample) / 4; scheduler thread only).
    fn note_completion(&self, elapsed: Duration) {
        let sample = (elapsed.as_millis().min(u128::from(u64::MAX)) as u64).max(1);
        let old = self.drain_ms.load(Ordering::Relaxed);
        self.drain_ms.store((old * 3 + sample) / 4, Ordering::Relaxed);
    }

    /// Backpressure hint for a rejected request: the EWMA per-request
    /// drain time times the backlog rounds ahead of it, clamped to
    /// [100 ms, 10 s].
    fn retry_after_ms(&self) -> u64 {
        let per = self.drain_ms.load(Ordering::Relaxed).max(1);
        let waiting = self.queue_depth.load(Ordering::Relaxed)
            + self.inflight_execs.load(Ordering::Relaxed)
            + 1;
        let workers = self.cfg.workers.max(1) as u64;
        per.saturating_mul(waiting.div_ceil(workers)).clamp(100, 10_000)
    }
}

/// Run the daemon on `cfg.addr`, blocking until shutdown — the
/// `maestro serve` entry point.
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("serve: cannot bind {}", cfg.addr))?;
    serve_on(listener, cfg.clone())
}

/// A daemon running on a background thread — in-process clients (tests,
/// the serve bench) connect to [`Daemon::addr`].
pub struct Daemon {
    addr: SocketAddr,
    handle: std::thread::JoinHandle<Result<()>>,
}

impl Daemon {
    /// Bind (resolving port 0 to a concrete port) and serve on a
    /// background thread.
    pub fn spawn(cfg: ServeConfig) -> Result<Daemon> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("serve: cannot bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let handle = std::thread::spawn(move || serve_on(listener, cfg));
        Ok(Daemon { addr, handle })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait for the daemon to exit (send a `shutdown` frame first).
    pub fn join(self) -> Result<()> {
        match self.handle.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("serve: daemon thread panicked"),
        }
    }
}

fn serve_on(listener: TcpListener, cfg: ServeConfig) -> Result<()> {
    if cfg.verbose {
        log::set_level(log::Level::Debug);
    }
    if cfg.trace_out.is_some() {
        trace::enable(cfg.trace_sample);
    }
    let store = if cfg.cache_cap > 0 {
        Arc::new(SharedStore::with_max_entries(cfg.cache_cap))
    } else {
        Arc::new(SharedStore::new())
    };
    if let Some(path) = &cfg.cache_file {
        let report = store.load(Path::new(path));
        if let Some(w) = &report.warning {
            log::error("serve", w);
        }
        log::info(
            "serve",
            &format!("loaded {} cached analyses from {path}", report.loaded),
        );
    }
    let addr = listener.local_addr()?;
    log::info(
        "serve",
        &format!(
            "listening on {addr} ({} worker(s), queue cap {})",
            cfg.workers.max(1),
            cfg.queue_cap.max(1)
        ),
    );
    listener.set_nonblocking(true)?;

    let shared = Shared {
        store: Arc::clone(&store),
        shutdown: AtomicBool::new(false),
        started: Instant::now(),
        requests_done: AtomicU64::new(0),
        requests_failed: AtomicU64::new(0),
        inflight: Mutex::new(HashMap::new()),
        tables: Mutex::new(TableCache::default()),
        queue_depth: AtomicU64::new(0),
        inflight_execs: AtomicU64::new(0),
        last_wave_jobs: AtomicU64::new(0),
        drain_ms: AtomicU64::new(500),
        cfg,
    };
    let shared = &shared;

    std::thread::scope(|scope| {
        let (job_tx, queue) = JobQueue::<Job>::bounded(shared.cfg.queue_cap.max(1));
        scope.spawn(move || scheduler_loop(shared, queue));
        if shared.cfg.flush_every > 0.0 && shared.cfg.cache_file.is_some() {
            scope.spawn(move || flusher_loop(shared));
        }
        let mut conns = Vec::new();
        while !shared.shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let job_tx = job_tx.clone();
                    conns.push(scope.spawn(move || handle_conn(shared, job_tx, stream)));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    log::error("serve", &format!("accept failed: {e}"));
                    break;
                }
            }
        }
        shared.shutdown.store(true, Ordering::Relaxed);
        // Dropping the last sender closes the queue; connection threads
        // (each holding a clone) exit at their next read-poll tick, so
        // the scheduler drains whatever is queued, finishes its
        // in-flight requests, and then stops.
        drop(job_tx);
        for c in conns {
            let _ = c.join();
        }
    });

    if let Some(path) = &shared.cfg.cache_file {
        let report = store.flush(Path::new(path))?;
        log::info(
            "serve",
            &format!("flushed {} new record(s) ({} total) to {path}", report.written, report.total),
        );
    }
    if let Some(path) = &shared.cfg.trace_out {
        match trace::write_file(path) {
            Ok(summary) => log::info(
                "serve",
                &format!("wrote {} trace event(s) to {path}", summary.events),
            ),
            Err(e) => log::error("serve", &format!("trace export failed: {e}")),
        }
    }
    log::info("serve", "shutdown complete");
    Ok(())
}

/// Background store persistence: append dirty records every
/// `flush_every` seconds until shutdown (the final flush is the serve
/// loop's job, so nothing is lost if this thread never fires).
fn flusher_loop(shared: &Shared) {
    let period = Duration::from_secs_f64(shared.cfg.flush_every.max(0.1));
    let path = shared.cfg.cache_file.clone().expect("flusher requires a cache file");
    let mut last = Instant::now();
    while !shared.shutdown.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(100));
        if last.elapsed() < period {
            continue;
        }
        last = Instant::now();
        match shared.store.flush(Path::new(&path)) {
            Ok(r) if r.written > 0 => {
                metrics::counter("cache.flushes").inc();
                metrics::counter("cache.flush_records").add(r.written as u64);
                log::info(
                    "serve",
                    &format!("flushed {} new record(s) ({} total) to {path}", r.written, r.total),
                );
            }
            Ok(_) => {}
            Err(e) => log::error("serve", &format!("background flush failed: {e}")),
        }
    }
}

// ---------------------------------------------------------------------
// The shared-pool scheduler
// ---------------------------------------------------------------------

/// One evaluation job shipped to the shared pool. Boxed so jobs from
/// different request kinds ride in the same wave; each captures its
/// own `Arc`s (context + wave), so nothing borrows the scheduler.
type PoolJob = Box<dyn FnOnce() -> PoolResult + Send>;

/// What a pool job hands back; the scheduler routes each to its
/// request by the wave's slot tag. `Idle` is the panic-fill default —
/// seeing one routes an `internal` error to the request (and the
/// worker's re-raised panic takes the daemon down at scope join).
enum PoolResult {
    Idle,
    Sweep(SweepShard),
    Chunk(MapChunk),
    Fixed(Box<Result<(NetworkStats, RequestStats)>>),
    Analyzed(Box<Result<AnalyzeOutcome>>),
}

impl Default for PoolResult {
    fn default() -> PoolResult {
        PoolResult::Idle
    }
}

/// The map request's fixed-style baseline: one pool job, scheduled in
/// the request's first round, concurrent with its mapper waves.
enum FixedSlot {
    Unscheduled,
    Pending,
    Ready(NetworkStats, RequestStats),
}

/// Per-kind scheduler state for one in-flight request.
enum ActiveState {
    Analyze {
        req: AnalyzeRequest,
        prep: AnalyzePrep,
        running: bool,
    },
    Map {
        req: MapRequest,
        prep: MapPrep,
        driver: Option<MapDriver>,
        fixed: FixedSlot,
        waves_done: bool,
    },
    Dse {
        req: DseRequest,
        prep: DsePrep,
        driver: Option<SweepDriver>,
        /// Insertion-order frontier snapshot after the previous wave —
        /// the base the next streamed delta diffs against.
        prev_frontier: Vec<DesignPoint>,
    },
}

/// One in-flight request the scheduler is driving.
struct Active {
    id: Option<u64>,
    kind: &'static str,
    reply: mpsc::Sender<Response>,
    cancel: Arc<AtomicBool>,
    stream: bool,
    /// Dequeue time: the drain-rate EWMA sample and the map request's
    /// request-scoped wall clock.
    started: Instant,
    state: ActiveState,
}

/// Send the final frame and retire the request: inflight handle gone,
/// drain EWMA updated, telemetry folded in, debug log emitted. (A send
/// error means the submitting connection died; the result is simply
/// dropped.)
fn conclude(shared: &Shared, active: &Active, response: Response) {
    if let Some(id) = active.id {
        shared.inflight.lock().unwrap().remove(&id);
    }
    let elapsed = active.started.elapsed();
    shared.note_completion(elapsed);
    record_outcome(shared, active.kind, elapsed.as_secs_f64(), &response);
    let _ = active.reply.send(response);
}

/// The diagnostic cost accounting a successful reply carries.
fn reply_stats(response: &Response) -> Option<&RequestStats> {
    match response {
        Response::Analyze(r) => Some(&r.stats),
        Response::Map(r) => Some(&r.stats),
        Response::Dse(r) => Some(&r.stats),
        _ => None,
    }
}

/// Fold one retired request into the telemetry registry: outcome
/// counters, latency/throughput histograms, and the per-request cache
/// split from the reply's `stats`. Runs once per *request* — never per
/// design evaluation — so the evaluation hot path stays free of global
/// atomics.
fn record_outcome(shared: &Shared, kind: &str, wall: f64, response: &Response) {
    if matches!(response, Response::Error(_)) {
        shared.requests_failed.fetch_add(1, Ordering::Relaxed);
        metrics::counter("serve.requests_failed").inc();
    } else {
        shared.requests_done.fetch_add(1, Ordering::Relaxed);
        metrics::counter("serve.requests_done").inc();
    }
    metrics::histogram("serve.request_seconds", SECONDS_BOUNDS).observe(wall);
    if let Some(stats) = reply_stats(response) {
        metrics::counter("request.analyses").add(stats.analyses);
        metrics::counter("request.warm_hits").add(stats.warm_hits);
        metrics::counter("request.disk_hits").add(stats.disk_hits);
        metrics::counter("request.profile_hits").add(stats.profile_hits);
        metrics::counter("request.designs_evaluated").add(stats.designs_evaluated);
        if stats.designs_evaluated > 0 && wall > 0.0 {
            metrics::histogram("serve.designs_per_second", DESIGNS_PER_SECOND_BOUNDS)
                .observe(stats.designs_evaluated as f64 / wall);
        }
    }
    log::debug("serve", &format!("{kind} request handled in {wall:.3}s"));
}

/// The daemon's one scheduler: owns every in-flight request's driver,
/// feeds the process-wide pool, and keeps each request's absorb order
/// serial (module docs, "Request scheduling").
fn scheduler_loop(shared: &Shared, queue: JobQueue<Job>) {
    std::thread::scope(|scope| {
        let pool: WavePool<PoolJob, PoolResult> =
            WavePool::spawn(scope, shared.cfg.workers.max(1), |job: PoolJob| job());
        let mut actives: Vec<Active> = Vec::new();
        let mut open = true;
        loop {
            // Admit new work: block briefly when idle, then drain
            // whatever queued (admission prepares on this thread, so
            // `bad_request` errors reply without touching the pool).
            if actives.is_empty() && open {
                match queue.pop_timeout(Duration::from_millis(200)) {
                    Ok(job) => admit(shared, &mut actives, job),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => open = false,
                }
            }
            while open {
                match queue.try_pop() {
                    Ok(job) => admit(shared, &mut actives, job),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => open = false,
                }
            }
            shared.inflight_execs.store(actives.len() as u64, Ordering::Relaxed);
            if actives.is_empty() {
                if open {
                    continue;
                }
                break;
            }

            // One wave per request, interleaved round-robin into one
            // pool wave; `tags[i]` routes slot i back to its request.
            let mut done: Vec<usize> = Vec::new();
            let mut lanes: Vec<(usize, Vec<PoolJob>)> = Vec::new();
            for (i, active) in actives.iter_mut().enumerate() {
                let (jobs, response) = enqueue(shared, active);
                if let Some(response) = response {
                    conclude(shared, active, response);
                    done.push(i);
                } else if !jobs.is_empty() {
                    lanes.push((i, jobs));
                }
            }
            let mut wave_jobs: Vec<PoolJob> = Vec::new();
            let mut tags: Vec<usize> = Vec::new();
            let mut lanes: Vec<(usize, std::vec::IntoIter<PoolJob>)> =
                lanes.into_iter().map(|(i, v)| (i, v.into_iter())).collect();
            loop {
                let mut any = false;
                for (i, lane) in lanes.iter_mut() {
                    if let Some(job) = lane.next() {
                        tags.push(*i);
                        wave_jobs.push(job);
                        any = true;
                    }
                }
                if !any {
                    break;
                }
            }
            shared.last_wave_jobs.store(wave_jobs.len() as u64, Ordering::Relaxed);

            if wave_jobs.is_empty() {
                if done.is_empty() {
                    // Nothing runnable and nothing finished this round
                    // (e.g. a map waiting on its baseline): yield
                    // instead of spinning hot.
                    std::thread::sleep(Duration::from_millis(5));
                }
            } else {
                let njobs = wave_jobs.len();
                let wave_started = Instant::now();
                let results = {
                    let _span = trace::span("serve.wave");
                    pool.run_wave(wave_jobs)
                };
                metrics::histogram("serve.wave_jobs", WAVE_JOBS_BOUNDS).observe(njobs as f64);
                metrics::histogram("serve.wave_seconds", SECONDS_BOUNDS)
                    .observe(wave_started.elapsed().as_secs_f64());
                let mut per: Vec<Vec<PoolResult>> = Vec::new();
                per.resize_with(actives.len(), Vec::new);
                for (tag, result) in tags.into_iter().zip(results) {
                    per[tag].push(result);
                }
                for (i, batch) in per.into_iter().enumerate() {
                    if batch.is_empty() {
                        continue;
                    }
                    if let Some(response) = absorb(&mut actives[i], batch) {
                        conclude(shared, &actives[i], response);
                        done.push(i);
                    }
                }
            }
            done.sort_unstable();
            done.dedup();
            for i in done.into_iter().rev() {
                actives.remove(i);
            }
        }
        shared.last_wave_jobs.store(0, Ordering::Relaxed);
        shared.inflight_execs.store(0, Ordering::Relaxed);
    });
}

/// Turn a dequeued job into an in-flight request: prepare (replying
/// `bad_request` straight away on failure), build the wave driver, and
/// honor a cancel that landed while queued (analyze/dse never start;
/// map degrades gracefully, so it still runs).
fn admit(shared: &Shared, actives: &mut Vec<Active>, job: Job) {
    let _span = trace::span("serve.admit");
    shared.queue_depth.fetch_sub(1, Ordering::Relaxed);
    let Job { request, reply, cancel } = job;
    let id = request.id();
    let kind = request.kind();
    let started = Instant::now();
    let finish_now = |response: Response| {
        if let Some(id) = id {
            shared.inflight.lock().unwrap().remove(&id);
        }
        // Requests that never reach the pool (bad_request, queued-then-
        // cancelled) still count as retired.
        record_outcome(shared, kind, started.elapsed().as_secs_f64(), &response);
        let _ = reply.send(response);
    };
    if cancel.load(Ordering::Relaxed) && !matches!(request, Request::Map(_)) {
        finish_now(Response::error(id, ApiError::cancelled()));
        return;
    }
    let state = match request {
        Request::Analyze(r) => match exec::prepare_analyze(&r) {
            Ok(prep) => ActiveState::Analyze { req: r, prep, running: false },
            Err(e) => return finish_now(Response::error(id, to_api_error(&e))),
        },
        Request::Map(mut r) => {
            // Honor the request-scoped thread count with the daemon's
            // default as the fallback; it only sizes wave chunks.
            if r.threads == 0 {
                r.threads = shared.cfg.threads;
            }
            let built = exec::prepare_map(&r).and_then(|prep| {
                let driver =
                    exec::map_driver(&shared.store, &prep, &r, Some(Arc::clone(&cancel)))?;
                Ok((prep, driver))
            });
            match built {
                Ok((prep, driver)) => ActiveState::Map {
                    req: r,
                    prep,
                    driver: Some(driver),
                    fixed: FixedSlot::Unscheduled,
                    waves_done: false,
                },
                Err(e) => return finish_now(Response::error(id, to_api_error(&e))),
            }
        }
        Request::Dse(mut r) => {
            if r.threads == 0 {
                r.threads = shared.cfg.threads;
            }
            let built = exec::prepare_dse(&r).and_then(|prep| {
                let tables =
                    shared.tables_for(table_identity(&prep.workload, &prep.space));
                let driver = exec::dse_driver(
                    &shared.store,
                    &prep,
                    &r,
                    true,
                    Some(Arc::clone(&cancel)),
                    Some(tables),
                )?;
                Ok((prep, driver))
            });
            match built {
                Ok((prep, driver)) => ActiveState::Dse {
                    req: r,
                    prep,
                    driver: Some(driver),
                    prev_frontier: Vec::new(),
                },
                Err(e) => return finish_now(Response::error(id, to_api_error(&e))),
            }
        }
        // Control requests never reach the queue (handle_conn answers
        // them inline).
        _ => {
            return finish_now(Response::error(
                id,
                ApiError::internal("control request routed to scheduler"),
            ))
        }
    };
    let stream = match &state {
        ActiveState::Map { req, .. } => req.stream,
        ActiveState::Dse { req, .. } => req.stream,
        ActiveState::Analyze { .. } => false,
    };
    actives.push(Active { id, kind, reply, cancel, stream, started, state });
}

/// Pull one request's next wave of pool jobs, or its final response if
/// it has none left (`Some(response)` retires the request).
fn enqueue(shared: &Shared, active: &mut Active) -> (Vec<PoolJob>, Option<Response>) {
    let mut jobs: Vec<PoolJob> = Vec::new();
    let response = match &mut active.state {
        ActiveState::Analyze { req, prep, running } => {
            if !*running {
                *running = true;
                let store = Arc::clone(&shared.store);
                let prep = prep.clone();
                let req = req.clone();
                jobs.push(Box::new(move || {
                    PoolResult::Analyzed(Box::new(exec::run_prepared_analyze(&store, &prep, &req)))
                }));
            }
            None
        }
        ActiveState::Map { req, prep, driver, fixed, waves_done } => {
            if matches!(fixed, FixedSlot::Unscheduled) {
                *fixed = FixedSlot::Pending;
                let store = Arc::clone(&shared.store);
                let prep = prep.clone();
                let objective = req.objective;
                jobs.push(Box::new(move || {
                    PoolResult::Fixed(Box::new(exec::map_fixed_baseline(&store, &prep, objective)))
                }));
            }
            if !*waves_done {
                let drv = driver.as_mut().expect("map driver present until finish");
                loop {
                    match drv.next_wave() {
                        Some(wave) if wave.chunk_count() == 0 => {
                            // A shape admitting zero candidates absorbs
                            // immediately, exactly like the in-process
                            // loop; it still counts as a streamed shape.
                            drv.absorb_wave(Vec::new());
                            if active.stream {
                                let _ = active.reply.send(map_progress(active.id, drv));
                            }
                        }
                        Some(wave) => {
                            let ctx = drv.ctx();
                            for chunk in 0..wave.chunk_count() {
                                let ctx = Arc::clone(&ctx);
                                let wave = wave.clone();
                                jobs.push(Box::new(move || {
                                    PoolResult::Chunk(ctx.run_chunk(&wave, chunk))
                                }));
                            }
                            break;
                        }
                        None => {
                            *waves_done = true;
                            break;
                        }
                    }
                }
            }
            if *waves_done && jobs.is_empty() {
                if let FixedSlot::Ready(..) = fixed {
                    let FixedSlot::Ready(fx, fs) = std::mem::replace(fixed, FixedSlot::Pending)
                    else {
                        unreachable!()
                    };
                    let wall = active.started.elapsed().as_secs_f64();
                    let drv = driver.take().expect("map driver present until finish");
                    Some(match exec::finish_map(&shared.store, drv, (fx, fs), wall) {
                        Ok(out) => Response::Map(exec::map_reply(req, &out)),
                        Err(e) => Response::error(active.id, to_api_error(&e)),
                    })
                } else {
                    // Baseline still in flight; finalize next round.
                    None
                }
            } else {
                None
            }
        }
        ActiveState::Dse { req, prep, driver, .. } => {
            let drv = driver.as_mut().expect("dse driver present until finish");
            match drv.next_wave() {
                Some(wave) => {
                    let ctx = drv.ctx();
                    for shard in 0..wave.shard_count() {
                        let ctx = Arc::clone(&ctx);
                        let wave = wave.clone();
                        jobs.push(Box::new(move || {
                            PoolResult::Sweep(ctx.run_shard(&wave, shard))
                        }));
                    }
                    None
                }
                None => {
                    let out = exec::finish_dse(driver.take().expect("dse driver"));
                    // A cancel that raced a finishing dse still reports
                    // cancelled — the client asked for abandonment.
                    Some(if active.cancel.load(Ordering::Relaxed) {
                        Response::error(active.id, ApiError::cancelled())
                    } else {
                        Response::Dse(exec::dse_reply(req, prep, &out))
                    })
                }
            }
        }
    };
    (jobs, response)
}

/// Hand one request its slice of the finished pool wave (already in
/// shard order) and emit its streamed progress frame. `Some(response)`
/// retires the request.
fn absorb(active: &mut Active, results: Vec<PoolResult>) -> Option<Response> {
    match &mut active.state {
        ActiveState::Analyze { req, .. } => {
            let mut response = None;
            for result in results {
                response = Some(match result {
                    PoolResult::Analyzed(r) => match *r {
                        Ok(_) if active.cancel.load(Ordering::Relaxed) => {
                            Response::error(active.id, ApiError::cancelled())
                        }
                        Ok(out) => Response::Analyze(exec::analyze_reply(req, &out)),
                        Err(e) => Response::error(active.id, to_api_error(&e)),
                    },
                    _ => Response::error(active.id, ApiError::internal("analyze worker died")),
                });
            }
            response
        }
        ActiveState::Map { driver, fixed, .. } => {
            let mut chunks = Vec::new();
            let mut failure = None;
            for result in results {
                match result {
                    PoolResult::Chunk(c) => chunks.push(c),
                    PoolResult::Fixed(r) => match *r {
                        Ok((fx, fs)) => *fixed = FixedSlot::Ready(fx, fs),
                        Err(e) => failure = Some(Response::error(active.id, to_api_error(&e))),
                    },
                    _ => {
                        failure =
                            Some(Response::error(active.id, ApiError::internal("map worker died")))
                    }
                }
            }
            if failure.is_some() {
                return failure;
            }
            if !chunks.is_empty() {
                let drv = driver.as_mut().expect("map driver present until finish");
                drv.absorb_wave(chunks);
                if active.stream {
                    let _ = active.reply.send(map_progress(active.id, drv));
                }
            }
            None
        }
        ActiveState::Dse { driver, prev_frontier, .. } => {
            let mut shards = Vec::with_capacity(results.len());
            for result in results {
                match result {
                    PoolResult::Sweep(s) => shards.push(s),
                    _ => {
                        return Some(Response::error(
                            active.id,
                            ApiError::internal("sweep worker died"),
                        ))
                    }
                }
            }
            let drv = driver.as_mut().expect("dse driver present until finish");
            drv.absorb_wave(shards);
            if active.stream {
                let now = drv.frontier_points();
                let frontier_add: Vec<PointRow> = now
                    .iter()
                    .filter(|p| !prev_frontier.iter().any(|q| q == *p))
                    .map(exec::point_row)
                    .collect();
                let frontier_remove: Vec<PointRow> = prev_frontier
                    .iter()
                    .filter(|p| !now.iter().any(|q| q == *p))
                    .map(exec::point_row)
                    .collect();
                let frame = Response::Progress(ProgressReply {
                    id: active.id,
                    wave: drv.waves(),
                    evaluated: drv.evaluated(),
                    frontier_add,
                    frontier_remove,
                });
                *prev_frontier = now.to_vec();
                let _ = active.reply.send(frame);
            }
            None
        }
    }
}

/// The mapper's streamed frame: shapes searched so far + candidates
/// evaluated (frontier deltas are a dse concept; the lists stay empty).
fn map_progress(id: Option<u64>, drv: &MapDriver) -> Response {
    Response::Progress(ProgressReply {
        id,
        wave: drv.shapes_admitted() as u64,
        evaluated: drv.evaluated(),
        frontier_add: Vec::new(),
        frontier_remove: Vec::new(),
    })
}

/// Map an execution failure onto the wire error shape: the top-level
/// message plus the cause chain as diagnostics. Everything exec raises
/// is a request problem (unknown model/dataflow/layer, contradictory
/// flags), so the code is `bad_request`.
fn to_api_error(e: &anyhow::Error) -> ApiError {
    let diagnostics: Vec<String> = e.chain().skip(1).map(|c| c.to_string()).collect();
    ApiError::bad_request(e.to_string()).with_diagnostics(diagnostics)
}

// ---------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------

enum ReadEvent {
    Line(String),
    Idle,
    Closed,
}

/// Pull the next newline-terminated frame out of `stream`, keeping
/// partial reads in `acc` across timeout ticks (a 500 ms read timeout
/// lets the connection notice daemon shutdown while idle).
fn read_event(stream: &mut TcpStream, acc: &mut Vec<u8>) -> ReadEvent {
    loop {
        if let Some(pos) = acc.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = acc.drain(..=pos).collect();
            return ReadEvent::Line(String::from_utf8_lossy(&line).trim().to_string());
        }
        let mut chunk = [0u8; 4096];
        match stream.read(&mut chunk) {
            Ok(0) => return ReadEvent::Closed,
            Ok(n) => acc.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return ReadEvent::Idle
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return ReadEvent::Closed,
        }
    }
}

fn write_response(stream: &mut TcpStream, response: &Response) -> bool {
    let mut line = response.encode_line();
    line.push('\n');
    stream.write_all(line.as_bytes()).and_then(|_| stream.flush()).is_ok()
}

fn handle_conn(shared: &Shared, job_tx: SyncSender<Job>, mut stream: TcpStream) {
    if stream.set_read_timeout(Some(Duration::from_millis(500))).is_err() {
        return;
    }
    let mut acc = Vec::new();
    while !shared.shutdown.load(Ordering::Relaxed) {
        match read_event(&mut stream, &mut acc) {
            ReadEvent::Closed => break,
            ReadEvent::Idle => continue,
            ReadEvent::Line(text) => {
                if text.is_empty() {
                    continue;
                }
                if !handle_line(shared, &job_tx, &mut stream, &text) {
                    break;
                }
            }
        }
    }
}

/// Process one frame; returns false when the connection should close.
/// Malformed frames get a structured `bad_request` reply and the
/// connection (and daemon) stay up.
fn handle_line(shared: &Shared, job_tx: &SyncSender<Job>, stream: &mut TcpStream, text: &str) -> bool {
    let _span = trace::span("serve.request");
    let request = match Json::parse(text)
        .map_err(|e| ApiError::bad_request(format!("malformed frame: {e}")))
        .and_then(|v| Request::decode(&v))
    {
        Ok(r) => r,
        Err(err) => return write_response(stream, &Response::error(None, err)),
    };
    match request {
        Request::Status => {
            let mut reply: StatusReply = shared.store.metrics().into();
            let workers = shared.cfg.workers.max(1) as u64;
            reply.queue_depth = shared.queue_depth.load(Ordering::Relaxed);
            reply.inflight = shared.inflight_execs.load(Ordering::Relaxed);
            reply.workers = workers;
            let jobs = shared.last_wave_jobs.load(Ordering::Relaxed);
            reply.pool_utilization = jobs.min(workers) as f64 / workers as f64;
            reply.uptime_ms = shared.started.elapsed().as_millis() as u64;
            reply.requests_done = shared.requests_done.load(Ordering::Relaxed);
            reply.requests_failed = shared.requests_failed.load(Ordering::Relaxed);
            write_response(stream, &Response::Status(reply))
        }
        Request::Metrics => {
            // Sample point-in-time gauges now, at request granularity —
            // the evaluation hot path never touches the registry.
            let m = shared.store.metrics();
            metrics::gauge("cache.entries").set(m.entries as f64);
            metrics::gauge("cache.hits").set(m.hits as f64);
            metrics::gauge("cache.disk_hits").set(m.disk_hits as f64);
            metrics::gauge("cache.misses").set(m.misses as f64);
            metrics::gauge("cache.evictions").set(m.evictions as f64);
            metrics::gauge("serve.queue_depth")
                .set(shared.queue_depth.load(Ordering::Relaxed) as f64);
            metrics::gauge("serve.inflight")
                .set(shared.inflight_execs.load(Ordering::Relaxed) as f64);
            metrics::gauge("serve.workers").set(shared.cfg.workers.max(1) as f64);
            let workers = shared.cfg.workers.max(1) as u64;
            let jobs = shared.last_wave_jobs.load(Ordering::Relaxed);
            metrics::gauge("serve.pool_utilization")
                .set(jobs.min(workers) as f64 / workers as f64);
            let snap = metrics::snapshot();
            let reply = MetricsReply {
                uptime_ms: shared.started.elapsed().as_millis() as u64,
                counters: snap
                    .counters
                    .into_iter()
                    .map(|(name, value)| MetricCounter { name, value })
                    .collect(),
                gauges: snap
                    .gauges
                    .into_iter()
                    .map(|(name, value)| MetricGauge { name, value })
                    .collect(),
                histograms: snap
                    .histograms
                    .into_iter()
                    .map(|h| MetricHistogram {
                        name: h.name,
                        bounds: h.bounds,
                        buckets: h.buckets,
                        count: h.count,
                        sum: h.sum,
                    })
                    .collect(),
            };
            write_response(stream, &Response::Metrics(reply))
        }
        Request::Cancel { id } => {
            let flagged = {
                let inflight = shared.inflight.lock().unwrap();
                inflight.get(&id).map(|f| f.store(true, Ordering::Relaxed)).is_some()
            };
            let response = if flagged {
                Response::Done(DoneReply { id: Some(id), what: "cancel".into() })
            } else {
                Response::error(
                    Some(id),
                    ApiError::bad_request(format!("no in-flight request with id {id}")),
                )
            };
            write_response(stream, &response)
        }
        Request::Shutdown => {
            write_response(stream, &Response::Done(DoneReply { id: None, what: "shutdown".into() }));
            shared.shutdown.store(true, Ordering::Relaxed);
            false
        }
        work @ (Request::Analyze(_) | Request::Map(_) | Request::Dse(_)) => {
            let id = work.id();
            let cancel = Arc::new(AtomicBool::new(false));
            if let Some(id) = id {
                shared.inflight.lock().unwrap().insert(id, Arc::clone(&cancel));
            }
            let (reply_tx, reply_rx) = mpsc::channel();
            // Count the slot before offering it, so the scheduler's
            // matching decrement can never race this below zero.
            shared.queue_depth.fetch_add(1, Ordering::Relaxed);
            match job_tx.try_send(Job { request: work, reply: reply_tx, cancel }) {
                Ok(()) => {
                    // Forward frames until the final (non-progress) one;
                    // a non-streaming request gets exactly one.
                    loop {
                        match reply_rx.recv() {
                            Ok(response) => {
                                let done = !response.is_progress();
                                if !write_response(stream, &response) {
                                    return false;
                                }
                                if done {
                                    return true;
                                }
                            }
                            Err(_) => {
                                return write_response(
                                    stream,
                                    &Response::error(
                                        id,
                                        ApiError::internal("executor dropped the request"),
                                    ),
                                )
                            }
                        }
                    }
                }
                Err(TrySendError::Full(_)) => {
                    shared.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    if let Some(id) = id {
                        shared.inflight.lock().unwrap().remove(&id);
                    }
                    let retry_after = shared.retry_after_ms();
                    metrics::counter("serve.overloaded").inc();
                    metrics::histogram("serve.retry_after_ms", RETRY_MS_BOUNDS)
                        .observe(retry_after as f64);
                    write_response(
                        stream,
                        &Response::error(
                            id,
                            ApiError::overloaded(retry_after, shared.cfg.queue_cap.max(1)),
                        ),
                    )
                }
                Err(TrySendError::Disconnected(_)) => {
                    shared.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    write_response(
                        stream,
                        &Response::error(id, ApiError::internal("daemon is shutting down")),
                    );
                    false
                }
            }
        }
    }
}
