//! The resident `maestro serve` daemon: one warm [`SharedStore`],
//! newline-delimited JSON frames over TCP, bounded-queue backpressure.
//!
//! ## Lifecycle
//!
//! [`serve`] (the CLI) or [`Daemon::spawn`] (in-process tests and
//! benches) binds a listener, loads `cache_file` into the store once,
//! and runs until a `shutdown` frame arrives. Every analyze/map/dse
//! request after the first reuses the same store, so repeated workloads
//! answer from memory (`warm_hits` in each reply's `stats`) instead of
//! re-running the analytical model. A flusher thread appends dirty
//! records back to `cache_file` every `flush_every` seconds and a final
//! flush runs on shutdown, so a crash loses at most one flush window.
//!
//! ## Concurrency and backpressure
//!
//! Each connection gets a reader thread; work requests are `try_send`'d
//! into a bounded [`JobQueue`] drained by `workers` executor threads.
//! A full queue rejects immediately with an `overloaded` [`ApiError`]
//! carrying `retry_after_ms` — the daemon never buffers unboundedly and
//! never blocks one client on another's backlog. Control requests
//! (`status`, `cancel`, `shutdown`) bypass the queue entirely.
//!
//! ## Cancellation
//!
//! A work request carrying an `id` can be cancelled from **another**
//! connection (the submitting connection is blocked awaiting its
//! reply): `cancel` flips the request's scoped flag, which the sweep
//! engine checks between waves and the mapper between shapes. What the
//! client gets back depends on the request kind. `analyze`/`dse`
//! answer with a `cancelled` error (their partial output is
//! meaningless), and queued ones cancelled before starting never
//! execute. A cancelled `map` instead **degrades gracefully**: shapes
//! not yet searched fall back to the Table 3 default bindings — the
//! mapper's `max_seconds` semantics — so the reply is a complete,
//! well-formed mapping with `defaulted > 0`, never an error.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::SharedStore;
use crate::util::json::Json;
use crate::util::queue::JobQueue;

use super::api::{ApiError, DoneReply, Request, Response};
use super::exec;

/// Daemon knobs; [`ServeConfig::default`] matches the CLI defaults.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (see [`Daemon::addr`]).
    pub addr: String,
    /// Warm-store persistence: loaded at startup, flushed periodically
    /// and on shutdown. `None` = memory only.
    pub cache_file: Option<String>,
    /// Second-chance capacity cap on the resident store
    /// (0 = unbounded).
    pub cache_cap: usize,
    /// Executor threads draining the job queue (concurrent requests).
    pub workers: usize,
    /// Job-queue depth before `overloaded` rejections kick in.
    pub queue_cap: usize,
    /// Seconds between background store flushes (0 = shutdown only).
    pub flush_every: f64,
    /// Default worker threads for `dse` and `map` requests that leave
    /// `threads` 0 (0 = let the search use all cores).
    pub threads: usize,
    /// Log one line per executed request to stderr.
    pub verbose: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7733".into(),
            cache_file: None,
            cache_cap: 0,
            workers: 2,
            queue_cap: 16,
            flush_every: 30.0,
            threads: 0,
            verbose: false,
        }
    }
}

/// One queued unit of work: the decoded request, the channel its reply
/// goes back on, and its cancellation flag.
struct Job {
    request: Request,
    reply: mpsc::Sender<Response>,
    cancel: Arc<AtomicBool>,
}

/// State every daemon thread shares.
struct Shared {
    cfg: ServeConfig,
    store: Arc<SharedStore>,
    shutdown: AtomicBool,
    /// Client-id -> cancel flag for queued/running work requests.
    inflight: Mutex<HashMap<u64, Arc<AtomicBool>>>,
}

/// Run the daemon on `cfg.addr`, blocking until shutdown — the
/// `maestro serve` entry point.
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("serve: cannot bind {}", cfg.addr))?;
    serve_on(listener, cfg.clone())
}

/// A daemon running on a background thread — in-process clients (tests,
/// the serve bench) connect to [`Daemon::addr`].
pub struct Daemon {
    addr: SocketAddr,
    handle: std::thread::JoinHandle<Result<()>>,
}

impl Daemon {
    /// Bind (resolving port 0 to a concrete port) and serve on a
    /// background thread.
    pub fn spawn(cfg: ServeConfig) -> Result<Daemon> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("serve: cannot bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let handle = std::thread::spawn(move || serve_on(listener, cfg));
        Ok(Daemon { addr, handle })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait for the daemon to exit (send a `shutdown` frame first).
    pub fn join(self) -> Result<()> {
        match self.handle.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("serve: daemon thread panicked"),
        }
    }
}

fn serve_on(listener: TcpListener, cfg: ServeConfig) -> Result<()> {
    let store = if cfg.cache_cap > 0 {
        Arc::new(SharedStore::with_max_entries(cfg.cache_cap))
    } else {
        Arc::new(SharedStore::new())
    };
    if let Some(path) = &cfg.cache_file {
        let report = store.load(Path::new(path));
        if let Some(w) = &report.warning {
            eprintln!("serve: {w}");
        }
        println!("serve: loaded {} cached analyses from {path}", report.loaded);
    }
    let addr = listener.local_addr()?;
    println!(
        "serve: listening on {addr} ({} worker(s), queue cap {})",
        cfg.workers.max(1),
        cfg.queue_cap.max(1)
    );
    listener.set_nonblocking(true)?;

    let shared = Shared {
        store: Arc::clone(&store),
        shutdown: AtomicBool::new(false),
        inflight: Mutex::new(HashMap::new()),
        cfg,
    };
    let shared = &shared;

    std::thread::scope(|scope| {
        let (job_tx, queue) = JobQueue::<Job>::bounded(shared.cfg.queue_cap.max(1));
        for _ in 0..shared.cfg.workers.max(1) {
            let queue = queue.clone();
            scope.spawn(move || worker_loop(shared, queue));
        }
        if shared.cfg.flush_every > 0.0 && shared.cfg.cache_file.is_some() {
            scope.spawn(move || flusher_loop(shared));
        }
        let mut conns = Vec::new();
        while !shared.shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let job_tx = job_tx.clone();
                    conns.push(scope.spawn(move || handle_conn(shared, job_tx, stream)));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    eprintln!("serve: accept failed: {e}");
                    break;
                }
            }
        }
        shared.shutdown.store(true, Ordering::Relaxed);
        // Dropping the last sender closes the queue; connection threads
        // (each holding a clone) exit at their next read-poll tick, so
        // the workers drain whatever is queued and then stop.
        drop(job_tx);
        for c in conns {
            let _ = c.join();
        }
    });

    if let Some(path) = &shared.cfg.cache_file {
        let report = store.flush(Path::new(path))?;
        println!("serve: flushed {} new record(s) ({} total) to {path}", report.written, report.total);
    }
    println!("serve: shutdown complete");
    Ok(())
}

/// Background store persistence: append dirty records every
/// `flush_every` seconds until shutdown (the final flush is the serve
/// loop's job, so nothing is lost if this thread never fires).
fn flusher_loop(shared: &Shared) {
    let period = Duration::from_secs_f64(shared.cfg.flush_every.max(0.1));
    let path = shared.cfg.cache_file.clone().expect("flusher requires a cache file");
    let mut last = Instant::now();
    while !shared.shutdown.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(100));
        if last.elapsed() < period {
            continue;
        }
        last = Instant::now();
        match shared.store.flush(Path::new(&path)) {
            Ok(r) if r.written > 0 => {
                println!("serve: flushed {} new record(s) ({} total) to {path}", r.written, r.total);
            }
            Ok(_) => {}
            Err(e) => eprintln!("serve: background flush failed: {e}"),
        }
    }
}

/// Executor: drain the job queue until it closes.
fn worker_loop(shared: &Shared, queue: JobQueue<Job>) {
    while let Some(job) = queue.pop() {
        let t0 = Instant::now();
        let response = execute(shared, &job);
        if let Some(id) = job.request.id() {
            shared.inflight.lock().unwrap().remove(&id);
        }
        if shared.cfg.verbose {
            eprintln!(
                "serve: {} request handled in {:.3}s",
                job.request.kind(),
                t0.elapsed().as_secs_f64()
            );
        }
        // A send error means the submitting connection died; the result
        // is simply dropped.
        let _ = job.reply.send(response);
    }
}

/// Run one work request against the resident store.
fn execute(shared: &Shared, job: &Job) -> Response {
    let id = job.request.id();
    // `map` is exempt from the early-out: a cancelled map still runs
    // and degrades gracefully — every not-yet-searched shape drops to
    // the Table 3 defaults immediately, so the "run" is cheap and the
    // reply is a complete mapping, not an error (module docs,
    // "Cancellation").
    let graceful_cancel = matches!(job.request, Request::Map(_));
    if job.cancel.load(Ordering::Relaxed) && !graceful_cancel {
        return Response::error(id, ApiError::cancelled());
    }
    let store = &shared.store;
    let cancel = Some(Arc::clone(&job.cancel));
    let result = match &job.request {
        Request::Analyze(r) => exec::run_analyze(store, r).map(|out| Response::Analyze(exec::analyze_reply(r, &out))),
        Request::Map(r) => {
            // Honor the request-scoped thread count exactly like dse
            // below, with the daemon's default as the fallback.
            let mut r = r.clone();
            if r.threads == 0 {
                r.threads = shared.cfg.threads;
            }
            exec::run_map(store, &r, cancel).map(|out| Response::Map(exec::map_reply(&r, &out)))
        }
        Request::Dse(r) => {
            let mut r = r.clone();
            if r.threads == 0 {
                r.threads = shared.cfg.threads;
            }
            exec::prepare_dse(&r).and_then(|prep| {
                let out = exec::run_prepared_dse(store, &prep, &r, true, cancel)?;
                Ok(Response::Dse(exec::dse_reply(&r, &prep, &out)))
            })
        }
        // Control requests never reach the queue (handle_conn answers
        // them inline).
        _ => return Response::error(id, ApiError::internal("control request routed to executor")),
    };
    match result {
        // A cancel that raced a finishing analyze/dse still reports
        // cancelled — the client asked for abandonment. A cancelled map
        // is NOT converted: its outcome is a complete graceful
        // degradation (`defaulted > 0`), not a partial result.
        Ok(_) if job.cancel.load(Ordering::Relaxed) && !graceful_cancel => {
            Response::error(id, ApiError::cancelled())
        }
        Ok(resp) => resp,
        Err(e) => Response::error(id, to_api_error(&e)),
    }
}

/// Map an execution failure onto the wire error shape: the top-level
/// message plus the cause chain as diagnostics. Everything exec raises
/// is a request problem (unknown model/dataflow/layer, contradictory
/// flags), so the code is `bad_request`.
fn to_api_error(e: &anyhow::Error) -> ApiError {
    let diagnostics: Vec<String> = e.chain().skip(1).map(|c| c.to_string()).collect();
    ApiError::bad_request(e.to_string()).with_diagnostics(diagnostics)
}

enum ReadEvent {
    Line(String),
    Idle,
    Closed,
}

/// Pull the next newline-terminated frame out of `stream`, keeping
/// partial reads in `acc` across timeout ticks (a 500 ms read timeout
/// lets the connection notice daemon shutdown while idle).
fn read_event(stream: &mut TcpStream, acc: &mut Vec<u8>) -> ReadEvent {
    loop {
        if let Some(pos) = acc.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = acc.drain(..=pos).collect();
            return ReadEvent::Line(String::from_utf8_lossy(&line).trim().to_string());
        }
        let mut chunk = [0u8; 4096];
        match stream.read(&mut chunk) {
            Ok(0) => return ReadEvent::Closed,
            Ok(n) => acc.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return ReadEvent::Idle
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return ReadEvent::Closed,
        }
    }
}

fn write_response(stream: &mut TcpStream, response: &Response) -> bool {
    let mut line = response.encode_line();
    line.push('\n');
    stream.write_all(line.as_bytes()).and_then(|_| stream.flush()).is_ok()
}

fn handle_conn(shared: &Shared, job_tx: SyncSender<Job>, mut stream: TcpStream) {
    if stream.set_read_timeout(Some(Duration::from_millis(500))).is_err() {
        return;
    }
    let mut acc = Vec::new();
    while !shared.shutdown.load(Ordering::Relaxed) {
        match read_event(&mut stream, &mut acc) {
            ReadEvent::Closed => break,
            ReadEvent::Idle => continue,
            ReadEvent::Line(text) => {
                if text.is_empty() {
                    continue;
                }
                if !handle_line(shared, &job_tx, &mut stream, &text) {
                    break;
                }
            }
        }
    }
}

/// Process one frame; returns false when the connection should close.
/// Malformed frames get a structured `bad_request` reply and the
/// connection (and daemon) stay up.
fn handle_line(shared: &Shared, job_tx: &SyncSender<Job>, stream: &mut TcpStream, text: &str) -> bool {
    let request = match Json::parse(text)
        .map_err(|e| ApiError::bad_request(format!("malformed frame: {e}")))
        .and_then(|v| Request::decode(&v))
    {
        Ok(r) => r,
        Err(err) => return write_response(stream, &Response::error(None, err)),
    };
    match request {
        Request::Status => {
            write_response(stream, &Response::Status(shared.store.metrics().into()))
        }
        Request::Cancel { id } => {
            let flagged = {
                let inflight = shared.inflight.lock().unwrap();
                inflight.get(&id).map(|f| f.store(true, Ordering::Relaxed)).is_some()
            };
            let response = if flagged {
                Response::Done(DoneReply { id: Some(id), what: "cancel".into() })
            } else {
                Response::error(
                    Some(id),
                    ApiError::bad_request(format!("no in-flight request with id {id}")),
                )
            };
            write_response(stream, &response)
        }
        Request::Shutdown => {
            write_response(stream, &Response::Done(DoneReply { id: None, what: "shutdown".into() }));
            shared.shutdown.store(true, Ordering::Relaxed);
            false
        }
        work @ (Request::Analyze(_) | Request::Map(_) | Request::Dse(_)) => {
            let id = work.id();
            let cancel = Arc::new(AtomicBool::new(false));
            if let Some(id) = id {
                shared.inflight.lock().unwrap().insert(id, Arc::clone(&cancel));
            }
            let (reply_tx, reply_rx) = mpsc::channel();
            match job_tx.try_send(Job { request: work, reply: reply_tx, cancel }) {
                Ok(()) => match reply_rx.recv() {
                    Ok(response) => write_response(stream, &response),
                    Err(_) => write_response(
                        stream,
                        &Response::error(id, ApiError::internal("executor dropped the request")),
                    ),
                },
                Err(TrySendError::Full(_)) => {
                    if let Some(id) = id {
                        shared.inflight.lock().unwrap().remove(&id);
                    }
                    write_response(
                        stream,
                        &Response::error(
                            id,
                            ApiError::overloaded(500, shared.cfg.queue_cap.max(1)),
                        ),
                    )
                }
                Err(TrySendError::Disconnected(_)) => {
                    write_response(
                        stream,
                        &Response::error(id, ApiError::internal("daemon is shutting down")),
                    );
                    false
                }
            }
        }
    }
}
