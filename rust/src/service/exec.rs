//! Request execution: the one implementation of analyze / map / dse
//! that every surface shares.
//!
//! The CLI's `network`, `map`, and `dse` subcommands and the `serve`
//! daemon all funnel through these functions, so a request computes the
//! same numbers whichever door it came in. The split per request kind:
//!
//! * **analyze / map** — [`run_analyze`] / [`run_map`] do the whole
//!   job and return a rich outcome (the engine's native structs plus
//!   [`RequestStats`]); callers render it (human tables, `--json`, or a
//!   daemon reply frame via [`analyze_reply`] / [`map_reply`]).
//! * **dse** — two steps, because the CLI narrates between them:
//!   [`prepare_dse`] builds the space/strategy/workload (and the
//!   `search:` / `workload:` description lines), then
//!   [`run_prepared_dse`] runs the sweep. The daemon calls both
//!   back-to-back and encodes with [`dse_reply`].
//!
//! Every kind also has a **wave-granular** surface for the daemon's
//! shared-pool scheduler: the same prepare/run split extends to
//! analyze ([`prepare_analyze`] / [`run_prepared_analyze`]) and map
//! ([`prepare_map`] / [`map_driver`] / [`map_fixed_baseline`] /
//! [`finish_map`]), and dse gains [`dse_driver`] / [`finish_dse`]
//! returning the engine's externalized
//! [`SweepDriver`](crate::dse::SweepDriver) /
//! [`MapDriver`](crate::mapspace::MapDriver) so the scheduler can pull
//! waves from many requests and interleave their shards onto one
//! process-wide pool. Preparation validates everything that can fail
//! from bad input, so `bad_request` errors surface before a request is
//! ever scheduled.
//!
//! Every function takes the caller's [`SharedStore`] — a per-run store
//! for the CLI, the resident warm store for the daemon — and the
//! returned [`RequestStats`] are strictly request-scoped (computed from
//! the request's own analyzer/sweep counters, never from global store
//! deltas, so concurrent daemon requests don't pollute each other).

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::cache::SharedStore;
use crate::dse::engine::{sweep, DesignPoint, PairTables, SweepConfig, SweepDriver, SweepOutcome};
use crate::dse::pareto::{best, Optimize};
use crate::dse::space::DesignSpace;
use crate::dse::strategy::{SearchBudget, SearchStrategy};
use crate::engine::analysis::{
    adaptive_network_with, analyze_network_with, Analyzer, NetworkStats, Objective,
};
use crate::hw::config::HwConfig;
use crate::ir::dataflow::Dataflow;
use crate::ir::styles;
use crate::mapspace::{enumerate_all, MapDriver, Mapper, MapperConfig, MappingOutcome, StyleTemplate};
use crate::model::layer::Layer;
use crate::model::network::Network;
use crate::model::zoo;

use super::api::{
    AnalyzeReply, AnalyzeRequest, DseReply, DseRequest, DseSearch, LayerRow, MapReply, MapRequest,
    MapSearch, PointRow, Ratios, RequestStats, ShapeRow, SideTotals, SkippedRow,
};

/// Build the analysis hardware config the way the CLI's `--pes`/`--bw`
/// flags always have: Fig 10 defaults with the two knobs overridden.
pub fn hw_from(pes: u64, bw: u64) -> Result<HwConfig> {
    let mut hw = HwConfig::fig10_default();
    hw.num_pes = pes;
    hw.noc_bandwidth = bw;
    hw.validate()?;
    Ok(hw)
}

/// Resolve a `(model, layer-name)` pair into a concrete layer (empty
/// name = the model's first layer — VGG16 conv1_1 under the defaults).
/// The CLI's `pick_layer` and the daemon both resolve through here, so
/// the not-found diagnostic is identical everywhere.
pub fn pick_layer_named(model: &str, lname: &str) -> Result<(Layer, String)> {
    let net = zoo::by_name(model)?;
    let layer = if lname.is_empty() {
        net.layers[0].clone()
    } else {
        net.layers
            .iter()
            .find(|l| l.name == lname)
            .with_context(|| {
                format!(
                    "layer '{lname}' not in {model}; first few: {}",
                    net.layers.iter().take(8).map(|l| l.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })?
            .clone()
    };
    Ok((layer, model.to_string()))
}

fn stats_from_analyzer(a: &Analyzer, designs_evaluated: u64, wall_seconds: f64) -> RequestStats {
    RequestStats {
        analyses: a.cache_misses(),
        disk_hits: a.disk_hits(),
        warm_hits: a.cache_hits().saturating_sub(a.disk_hits()),
        profile_hits: a.profile_hits(),
        designs_evaluated,
        wall_seconds,
    }
}

// ---------------------------------------------------------------------
// analyze
// ---------------------------------------------------------------------

/// What [`run_analyze`] hands back: the engine's [`NetworkStats`] plus
/// the context a renderer needs (shape count, mapspace note, request
/// accounting).
#[derive(Debug, Clone)]
pub struct AnalyzeOutcome {
    pub network: NetworkStats,
    /// Unique shapes in the model (the CLI table's `shapes` column).
    pub shapes: usize,
    /// Total layers in the model (analyzed + skipped).
    pub layers_total: usize,
    /// The `mapspace: N candidate mapping(s) ...` narration line
    /// (`dataflow == "mapped"` only); the CLI prints it verbatim.
    pub mapspace_note: Option<String>,
    pub mapspace_candidates: Option<u64>,
    pub stats: RequestStats,
}

/// How a prepared analyze request evaluates — resolved up front so a
/// bad `dataflow` string is rejected before the request is scheduled.
#[derive(Debug, Clone)]
enum AnalyzeMode {
    /// Adaptive over the five fixed Table 3 styles.
    Adaptive,
    /// Adaptive over a mapspace-enumerated candidate set.
    Mapped { candidates: Vec<Dataflow> },
    /// One named fixed style.
    Fixed(Dataflow),
}

/// Everything an analyze request resolves to before evaluation: the
/// network, hardware config, and candidate set. The analyze half of
/// the prepare/run split ([`prepare_dse`]'s pattern, extended).
#[derive(Debug, Clone)]
pub struct AnalyzePrep {
    pub net: Network,
    pub hw: HwConfig,
    mode: AnalyzeMode,
    pub mapspace_note: Option<String>,
    pub mapspace_candidates: Option<u64>,
}

/// Resolve an [`AnalyzeRequest`]: model lookup, hardware validation,
/// dataflow-mode resolution (including the `mapped` candidate
/// enumeration). Everything that can fail from bad input fails here.
pub fn prepare_analyze(req: &AnalyzeRequest) -> Result<AnalyzePrep> {
    let net = zoo::by_name(&req.model)?;
    let hw = hw_from(req.pes, req.bw)?;
    let mut mapspace_note = None;
    let mut mapspace_candidates = None;
    let mode = if req.dataflow == "adaptive" {
        AnalyzeMode::Adaptive
    } else if req.dataflow == "mapped" {
        // Mapspace-backed adaptivity: the candidate set is the
        // fingerprint-deduped union of every style template's tiling
        // enumeration over the network's unique shapes (see the
        // `network` CLI docs for the cross-shape trade-off).
        let templates = StyleTemplate::all();
        let groups = net.unique_shapes();
        let n_shapes = groups.len();
        let mut candidates = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for group in &groups {
            let en = enumerate_all(&templates, group.layer, hw.num_pes, req.tile_resolution);
            for df in en.dataflows {
                if seen.insert(df.fingerprint()) {
                    candidates.push(df);
                }
            }
        }
        mapspace_note = Some(format!(
            "mapspace: {} candidate mapping(s) across {n_shapes} unique shape(s)",
            candidates.len()
        ));
        mapspace_candidates = Some(candidates.len() as u64);
        AnalyzeMode::Mapped { candidates }
    } else {
        let df = styles::by_name(&req.dataflow)
            .with_context(|| format!("unknown dataflow {}", req.dataflow))?;
        AnalyzeMode::Fixed(df)
    };
    Ok(AnalyzePrep { net, hw, mode, mapspace_note, mapspace_candidates })
}

/// Evaluate a prepared analyze request over the caller's store. Pure
/// with respect to shared state: any thread may run it (the daemon
/// runs it as a single shared-pool job), and the resulting
/// [`NetworkStats`] are bit-identical to the in-process path for any
/// store warmth (values are pure functions of keys).
pub fn run_prepared_analyze(
    store: &Arc<SharedStore>,
    prep: &AnalyzePrep,
    req: &AnalyzeRequest,
) -> Result<AnalyzeOutcome> {
    let t0 = std::time::Instant::now();
    let mut analyzer = Analyzer::with_store(Arc::clone(store));
    let network = match &prep.mode {
        AnalyzeMode::Adaptive => {
            adaptive_network_with(&mut analyzer, &prep.net, &styles::all_styles(), &prep.hw, req.objective)?
        }
        AnalyzeMode::Mapped { candidates } => {
            adaptive_network_with(&mut analyzer, &prep.net, candidates, &prep.hw, req.objective)?
        }
        AnalyzeMode::Fixed(df) => analyze_network_with(&mut analyzer, &prep.net, df, &prep.hw, true)?,
    };
    let stats = stats_from_analyzer(&analyzer, 0, t0.elapsed().as_secs_f64());
    Ok(AnalyzeOutcome {
        network,
        shapes: prep.net.unique_shapes().len(),
        layers_total: prep.net.layers.len(),
        mapspace_note: prep.mapspace_note.clone(),
        mapspace_candidates: prep.mapspace_candidates,
        stats,
    })
}

/// Whole-network analysis over the caller's store — the engine behind
/// `maestro network` and the daemon's `analyze` requests
/// ([`prepare_analyze`] + [`run_prepared_analyze`] back-to-back).
pub fn run_analyze(store: &Arc<SharedStore>, req: &AnalyzeRequest) -> Result<AnalyzeOutcome> {
    let prep = prepare_analyze(req)?;
    run_prepared_analyze(store, &prep, req)
}

/// Encode an [`AnalyzeOutcome`] as the wire reply.
pub fn analyze_reply(req: &AnalyzeRequest, out: &AnalyzeOutcome) -> AnalyzeReply {
    AnalyzeReply {
        id: req.id,
        network: out.network.network.clone(),
        dataflow: out.network.dataflow.clone(),
        layers: out.network.per_layer.len() as u64,
        shapes: out.shapes as u64,
        runtime_cycles: out.network.runtime,
        energy_uj: out.network.energy.total() / 1e6,
        gmacs: out.network.macs / 1e9,
        mapspace_candidates: out.mapspace_candidates,
        per_layer: if req.per_layer {
            out.network
                .per_layer
                .iter()
                .map(|s| LayerRow {
                    layer: s.layer.clone(),
                    dataflow: s.dataflow.clone(),
                    runtime: s.runtime,
                    energy_uj: s.energy.total() / 1e6,
                    util: s.util,
                })
                .collect()
        } else {
            Vec::new()
        },
        skipped: skipped_rows(&out.network),
        stats: out.stats.clone(),
    }
}

fn skipped_rows(n: &NetworkStats) -> Vec<SkippedRow> {
    n.skipped
        .iter()
        .map(|s| SkippedRow { layer: s.layer.clone(), reason: s.reason.clone() })
        .collect()
}

// ---------------------------------------------------------------------
// map
// ---------------------------------------------------------------------

/// What [`run_map`] hands back: the mapper's native outcome, the
/// fixed-style baseline it is compared against, and request accounting.
#[derive(Debug, Clone)]
pub struct MapOutcome {
    pub mapping: MappingOutcome,
    /// Adaptive-over-Table-3 baseline through the same store.
    pub fixed: NetworkStats,
    pub stats: RequestStats,
}

/// Everything a map request resolves to before the search runs: the
/// network and hardware config. The map half of the prepare/run split.
#[derive(Debug, Clone)]
pub struct MapPrep {
    pub net: Network,
    pub hw: HwConfig,
}

/// Resolve a [`MapRequest`]: model lookup + hardware validation.
pub fn prepare_map(req: &MapRequest) -> Result<MapPrep> {
    Ok(MapPrep { net: zoo::by_name(&req.model)?, hw: hw_from(req.pes, req.bw)? })
}

/// The [`MapperConfig`] a map request implies (the one mapping both
/// the in-process path and the daemon's driver use, so knob defaults
/// can never drift between the two).
fn map_config(req: &MapRequest, cancel: Option<Arc<AtomicBool>>) -> MapperConfig {
    MapperConfig {
        tile_resolution: req.tile_resolution,
        objective: req.objective,
        budget: SearchBudget { max_designs: req.budget, max_seconds: req.budget_seconds },
        cancel,
        threads: req.threads,
        ..MapperConfig::default()
    }
}

/// Build the externalized per-shape wave driver for a prepared map
/// request — the daemon's scheduler pulls [`MapWave`]s from it and
/// runs their chunks on the shared pool.
///
/// [`MapWave`]: crate::mapspace::MapWave
pub fn map_driver(
    store: &Arc<SharedStore>,
    prep: &MapPrep,
    req: &MapRequest,
    cancel: Option<Arc<AtomicBool>>,
) -> Result<MapDriver> {
    MapDriver::new(&prep.net, &prep.hw, &map_config(req, cancel), Arc::clone(store))
}

/// The fixed-style comparison every map reply carries: adaptive over
/// the five Table 3 styles through the same store (template defaults
/// replay from it). Independent of the mapper waves — the daemon runs
/// it as one shared-pool job concurrent with them; results are
/// bit-identical either way (pure functions of keys). The returned
/// [`RequestStats`] carry this baseline's analyzer counters
/// (`designs_evaluated` / `wall_seconds` zero — the caller folds them).
pub fn map_fixed_baseline(
    store: &Arc<SharedStore>,
    prep: &MapPrep,
    objective: Objective,
) -> Result<(NetworkStats, RequestStats)> {
    let mut analyzer = Analyzer::with_store(Arc::clone(store));
    let fixed =
        adaptive_network_with(&mut analyzer, &prep.net, &styles::all_styles(), &prep.hw, objective)?;
    let counters = stats_from_analyzer(&analyzer, 0, 0.0);
    Ok((fixed, counters))
}

/// Fold a finished mapper search and its fixed baseline into a
/// [`MapOutcome`]: assembles the network view through a fresh analyzer
/// on the same store and merges the two counter sets exactly the way
/// the in-process path always has. `wall_seconds` is the caller's
/// request-scoped measurement.
pub fn finish_map(
    store: &Arc<SharedStore>,
    driver: MapDriver,
    fixed: (NetworkStats, RequestStats),
    wall_seconds: f64,
) -> Result<MapOutcome> {
    let mut analyzer = Analyzer::with_store(Arc::clone(store));
    let mapping = driver.finish(&mut analyzer)?;
    let (fixed, fs) = fixed;
    let ms = &mapping.stats;
    let stats = RequestStats {
        analyses: ms.cache_misses + fs.analyses,
        disk_hits: ms.cache_disk_hits + fs.disk_hits,
        warm_hits: ms.cache_hits.saturating_sub(ms.cache_disk_hits) + fs.warm_hits,
        profile_hits: ms.profile_hits + fs.profile_hits,
        designs_evaluated: ms.evaluated,
        wall_seconds,
    };
    Ok(MapOutcome { mapping, fixed, stats })
}

/// Layer-wise mapper search + fixed-style baseline — the engine behind
/// `maestro map` and the daemon's `map` requests. `cancel` (daemon:
/// one flag per request) degrades unsearched shapes to Table 3
/// defaults, exactly like an expired `budget_seconds`. `req.threads`
/// sizes the mapper's worker pool (0 = all cores) — winners and
/// counters are bit-identical for any value.
pub fn run_map(
    store: &Arc<SharedStore>,
    req: &MapRequest,
    cancel: Option<Arc<AtomicBool>>,
) -> Result<MapOutcome> {
    let t0 = std::time::Instant::now();
    let prep = prepare_map(req)?;
    let mut mapper = Mapper::with_store(Arc::clone(store));
    let mapping = mapper.map_network(&prep.net, &prep.hw, &map_config(req, cancel))?;
    let (fixed, fs) = map_fixed_baseline(store, &prep, req.objective)?;
    let ms = &mapping.stats;
    let stats = RequestStats {
        analyses: ms.cache_misses + fs.analyses,
        disk_hits: ms.cache_disk_hits + fs.disk_hits,
        warm_hits: ms.cache_hits.saturating_sub(ms.cache_disk_hits) + fs.warm_hits,
        profile_hits: ms.profile_hits + fs.profile_hits,
        designs_evaluated: ms.evaluated,
        wall_seconds: t0.elapsed().as_secs_f64(),
    };
    Ok(MapOutcome { mapping, fixed, stats })
}

/// Encode a [`MapOutcome`] as the wire reply.
pub fn map_reply(req: &MapRequest, out: &MapOutcome) -> MapReply {
    let m = &out.mapping;
    let ratios = if out.fixed.per_layer.len() == m.network.per_layer.len() {
        Some(Ratios {
            runtime: out.fixed.runtime / m.network.runtime.max(1e-12),
            energy: out.fixed.energy.total() / m.network.energy.total().max(1e-12),
            edp: (out.fixed.runtime * out.fixed.energy.total())
                / (m.network.runtime * m.network.energy.total()).max(1e-12),
        })
    } else {
        None
    };
    MapReply {
        id: req.id,
        network: m.network.network.clone(),
        objective: req.objective.name().to_string(),
        per_shape: m
            .per_shape
            .iter()
            .map(|s| ShapeRow {
                representative: s.representative.clone(),
                members: s.members,
                mapping: s.dataflow.name.clone(),
                runtime: s.stats.runtime,
                energy_uj: s.stats.energy.total() / 1e6,
                util: s.stats.util,
            })
            .collect(),
        skipped: skipped_rows(&m.network),
        mapper: SideTotals {
            layers: m.network.per_layer.len() as u64,
            runtime: m.network.runtime,
            energy_uj: m.network.energy.total() / 1e6,
        },
        fixed: SideTotals {
            layers: out.fixed.per_layer.len() as u64,
            runtime: out.fixed.runtime,
            energy_uj: out.fixed.energy.total() / 1e6,
        },
        ratios,
        search: MapSearch {
            shapes: m.stats.shapes,
            combos: m.stats.combos,
            candidates: m.stats.candidates,
            evaluated: m.stats.evaluated,
            budget_skipped: m.stats.budget_skipped,
            defaulted: m.stats.shapes_defaulted,
        },
        stats: out.stats.clone(),
    }
}

// ---------------------------------------------------------------------
// dse
// ---------------------------------------------------------------------

/// Everything a dse request resolves to before the sweep runs: the
/// design space, strategy, budget, and workload. Split from
/// [`run_prepared_dse`] because the CLI narrates (`search:` /
/// `workload:` lines, cache opening) between preparation and sweep.
#[derive(Debug, Clone)]
pub struct DsePrep {
    pub space: DesignSpace,
    pub strategy: SearchStrategy,
    pub budget: SearchBudget,
    pub workload: Network,
    /// The `mapspace: generated ...` narration line (`--mapspace` only).
    pub mapspace_note: Option<String>,
    pub macs: f64,
    pub shapes: usize,
}

impl DsePrep {
    /// The CLI's `search: strategy=... budget=... wall=...` line.
    pub fn search_line(&self) -> String {
        format!(
            "search: strategy={} budget={} wall={}",
            self.strategy.name(),
            if self.budget.max_designs > 0 {
                self.budget.max_designs.to_string()
            } else {
                "unlimited".into()
            },
            if self.budget.max_seconds > 0.0 {
                format!("{}s", self.budget.max_seconds)
            } else {
                "off".into()
            },
        )
    }

    /// The CLI's `workload: ...` line.
    pub fn workload_line(&self) -> String {
        format!(
            "workload: {} ({} layer(s), {} unique shape(s), {:.2} GMACs)",
            self.workload.name,
            self.workload.layers.len(),
            self.shapes,
            self.macs / 1e9
        )
    }
}

/// Resolve a [`DseRequest`] into a [`DsePrep`]: build the design space
/// (generated variant axis under `mapspace`), parse the strategy,
/// assemble the workload. Rejects the contradictory `network` + named
/// `layer` combination, exactly like the CLI always has.
pub fn prepare_dse(req: &DseRequest) -> Result<DsePrep> {
    let mut mapspace_note = None;
    let space = if req.mapspace {
        let (layer, _) = pick_layer_named(&req.model, &req.layer)?;
        let space = DesignSpace::mapspace(
            &req.family,
            &layer,
            req.tile_resolution,
            req.resolution,
            req.bw_resolution,
        )?;
        mapspace_note = Some(format!(
            "mapspace: generated {} variant(s) for family {} against layer '{}' (tile resolution {})",
            space.variants.len(),
            req.family,
            layer.name,
            req.tile_resolution
        ));
        space
    } else {
        DesignSpace::fig13_axes(&req.family, req.resolution, req.bw_resolution)
    };
    let strategy = SearchStrategy::parse(&req.strategy, req.seed)?;
    let budget = SearchBudget { max_designs: req.budget, max_seconds: req.budget_seconds };
    let workload = if req.network {
        ensure!(req.layer.is_empty(), "--network sweeps every layer of the model; drop --layer");
        zoo::by_name(&req.model)?
    } else {
        Network::single(pick_layer_named(&req.model, &req.layer)?.0)
    };
    let macs = workload.macs() as f64;
    let shapes = workload.unique_shapes().len();
    Ok(DsePrep { space, strategy, budget, workload, mapspace_note, macs, shapes })
}

/// What [`run_prepared_dse`] hands back: the sweep's native outcome
/// plus request accounting.
#[derive(Debug, Clone)]
pub struct DseOutcome {
    pub sweep: SweepOutcome,
    pub stats: RequestStats,
}

/// Run the sharded sweep over a prepared design space. `use_store`
/// hands the caller's store to the sweep shards (the daemon always
/// does; the CLI only under `--cache-file`, preserving its historical
/// cache counters). `cancel` stops at the next wave boundary.
pub fn run_prepared_dse(
    store: &Arc<SharedStore>,
    prep: &DsePrep,
    req: &DseRequest,
    use_store: bool,
    cancel: Option<Arc<AtomicBool>>,
) -> Result<DseOutcome> {
    let t0 = std::time::Instant::now();
    let cfg = SweepConfig {
        threads: req.threads,
        keep_all_points: req.keep_points,
        cache: if use_store { Some(Arc::clone(store)) } else { None },
        strategy: prep.strategy.clone(),
        budget: prep.budget,
        cancel,
        ..SweepConfig::default()
    };
    let sweep_out = sweep(&prep.workload, &prep.space, prep.space.noc_latency, &cfg)?;
    let s = &sweep_out.stats;
    let stats = RequestStats {
        analyses: s.cache_misses,
        disk_hits: s.cache_disk_hits,
        warm_hits: s.cache_hits.saturating_sub(s.cache_disk_hits),
        profile_hits: s.profile_hits,
        designs_evaluated: s.evaluated,
        wall_seconds: t0.elapsed().as_secs_f64(),
    };
    Ok(DseOutcome { sweep: sweep_out, stats })
}

/// Build the externalized wave driver for a prepared dse request — the
/// daemon's scheduler pulls [`SweepWave`]s from it and runs their
/// shards on the shared pool. `shared_tables` is the daemon-lifetime
/// per-pair case-table cache (keyed by
/// [`table_identity`](crate::dse::table_identity) upstream), so two
/// clients sweeping the same space share tables; tables never affect
/// results, only the work to produce them.
///
/// [`SweepWave`]: crate::dse::SweepWave
pub fn dse_driver(
    store: &Arc<SharedStore>,
    prep: &DsePrep,
    req: &DseRequest,
    use_store: bool,
    cancel: Option<Arc<AtomicBool>>,
    shared_tables: Option<Arc<PairTables>>,
) -> Result<SweepDriver> {
    let cfg = SweepConfig {
        threads: req.threads,
        keep_all_points: req.keep_points,
        cache: if use_store { Some(Arc::clone(store)) } else { None },
        strategy: prep.strategy.clone(),
        budget: prep.budget,
        cancel,
        shared_tables,
        ..SweepConfig::default()
    };
    SweepDriver::new(&prep.workload, &prep.space, prep.space.noc_latency, &cfg)
}

/// Finalize a driven sweep into a [`DseOutcome`] — the counters fold
/// exactly as [`run_prepared_dse`]'s do (`wall_seconds` is the sweep's
/// own prep-to-finish clock).
pub fn finish_dse(driver: SweepDriver) -> DseOutcome {
    let sweep_out = driver.finish();
    let stats = {
        let s = &sweep_out.stats;
        RequestStats {
            analyses: s.cache_misses,
            disk_hits: s.cache_disk_hits,
            warm_hits: s.cache_hits.saturating_sub(s.cache_disk_hits),
            profile_hits: s.profile_hits,
            designs_evaluated: s.evaluated,
            wall_seconds: s.seconds,
        }
    };
    DseOutcome { sweep: sweep_out, stats }
}

/// Encode one design point as its wire row (shared by the final
/// reply's frontier/optima and the streamed frontier deltas, so the
/// two can never disagree on a point's encoding).
pub fn point_row(p: &DesignPoint) -> PointRow {
    PointRow {
        dataflow: p.dataflow.clone(),
        pes: p.pes,
        bandwidth: p.bandwidth,
        l1: p.l1,
        l2: p.l2,
        runtime: p.runtime,
        energy_pj: p.energy_pj,
        area_mm2: p.area_mm2,
        power_mw: p.power_mw,
    }
}

/// Encode a [`DseOutcome`] as the wire reply. Optima are extracted from
/// the full point set when the sweep kept it, else from the frontier
/// (optima are always frontier members, so the answer is the same).
pub fn dse_reply(req: &DseRequest, prep: &DsePrep, out: &DseOutcome) -> DseReply {
    let s = &out.sweep.stats;
    let pts: &[DesignPoint] =
        if out.sweep.points.is_empty() { &out.sweep.frontier } else { &out.sweep.points };
    DseReply {
        id: req.id,
        family: req.family.clone(),
        workload: prep.workload.name.clone(),
        layers: prep.workload.layers.len() as u64,
        shapes: prep.shapes as u64,
        gmacs: prep.macs / 1e9,
        search: DseSearch {
            strategy: if s.strategy.is_empty() { "exhaustive".into() } else { s.strategy.clone() },
            total_designs: s.total_designs,
            evaluated: s.evaluated,
            valid: s.valid,
            pruned: s.pruned,
            unmappable: s.unmappable,
            budget_skipped: s.budget_skipped,
            waves: s.waves,
        },
        frontier: out.sweep.frontier.iter().map(point_row).collect(),
        throughput_opt: best(pts, Optimize::Throughput, prep.macs).map(point_row),
        energy_opt: best(pts, Optimize::Energy, prep.macs).map(point_row),
        stats: out.stats.clone(),
    }
}
