//! The typed, versioned request/response schema — the **single** wire
//! surface of the DSE service.
//!
//! Every entry point speaks these types: the `maestro serve` daemon
//! decodes one [`Request`] per newline-delimited frame and encodes one
//! [`Response`] per reply line, and the CLI's `network`/`map`/`dse`
//! subcommands build the *same* request structs from their flags
//! ([`AnalyzeRequest::from_args`] & co.) and — under `--json` — emit
//! the *same* response encoding, so scripts scrape one schema whether
//! they shell out or connect to a daemon.
//!
//! Versioning: every frame carries `"v": 1` ([`WIRE_VERSION`]).
//! Decoders reject other versions with a structured [`ApiError`]
//! instead of guessing. Optional fields are omitted (never `null`) and
//! unknown request fields are ignored, so the schema can grow
//! compatibly; the golden tests in `rust/tests/service_api.rs` pin the
//! exact encodings.
//!
//! Streaming: `map` / `dse` requests may set `"stream": true` (omitted
//! when false, so non-streaming frames are unchanged). The daemon then
//! interleaves [`ProgressReply`] frames (`"kind": "progress"` — wave
//! index, designs evaluated, frontier delta as add/remove point lists)
//! before the final reply on the same connection; the final frame is
//! any non-progress kind. Progress frames are wave-granular and
//! deterministic: replaying the deltas reconstructs the sweep's
//! frontier after every wave, and the last state's point set equals
//! the final reply's (sorted) frontier.
//!
//! Errors: [`ApiError`] is the one failure shape — a stable `code`
//! (`bad_request` | `overloaded` | `cancelled` | `internal`), a human
//! message, `retry_after_ms` for backpressure rejections, and a
//! `diagnostics` list for multi-line context.

use anyhow::Result;

use crate::cache::StoreMetrics;
use crate::engine::analysis::Objective;
use crate::hw::config::HwConfig;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Wire protocol version stamped on (and required in) every frame.
pub const WIRE_VERSION: u64 = 1;

// ---------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------

/// One decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Whole-network analysis (the `network` subcommand's work).
    Analyze(AnalyzeRequest),
    /// Layer-wise mapper search + fixed-style baseline (`map`).
    Map(MapRequest),
    /// Design-space sweep (`dse`).
    Dse(DseRequest),
    /// Resident-store counters (daemon only; cheap, never queued).
    Status,
    /// Full telemetry snapshot — every registered counter, gauge, and
    /// histogram (daemon only; cheap, never queued).
    Metrics,
    /// Cooperatively cancel the in-flight request with this client id.
    Cancel { id: u64 },
    /// Flush the store and stop the daemon.
    Shutdown,
}

/// `network`: analyze every layer of a zoo model under a dataflow
/// policy. `dataflow` is a Table 3 style name, `"adaptive"` (best fixed
/// style per layer), or `"mapped"` (adaptive over the mapspace union —
/// see the `network` CLI docs).
#[derive(Debug, Clone, PartialEq)]
pub struct AnalyzeRequest {
    /// Client-chosen id echoed in the reply; also the handle
    /// [`Request::Cancel`] targets.
    pub id: Option<u64>,
    pub model: String,
    pub dataflow: String,
    pub pes: u64,
    pub bw: u64,
    pub objective: Objective,
    /// Tile resolution for `dataflow == "mapped"`.
    pub tile_resolution: usize,
    /// Include the per-layer breakdown in the reply.
    pub per_layer: bool,
}

/// `map`: per-shape mapper search plus the fixed-style baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct MapRequest {
    pub id: Option<u64>,
    pub model: String,
    pub pes: u64,
    pub bw: u64,
    pub objective: Objective,
    pub tile_resolution: usize,
    /// Max candidates evaluated per shape (0 = unlimited).
    pub budget: u64,
    /// Whole-run wall cutoff in seconds (0 = off).
    pub budget_seconds: f64,
    /// Mapper worker threads (0 = all cores; results are bit-identical
    /// for any value).
    pub threads: usize,
    /// Stream per-shape progress frames before the final reply
    /// (daemon connections only; ignored in-process).
    pub stream: bool,
}

/// `dse`: a budgeted, strategy-driven sweep over a design space.
#[derive(Debug, Clone, PartialEq)]
pub struct DseRequest {
    pub id: Option<u64>,
    pub family: String,
    pub model: String,
    /// Layer name within the model; empty = the model's first layer.
    pub layer: String,
    /// Sweep the whole (shape-deduplicated) model instead of one layer.
    pub network: bool,
    pub resolution: usize,
    pub bw_resolution: usize,
    /// Generate the variant axis from the family's style template.
    pub mapspace: bool,
    pub tile_resolution: usize,
    /// `exhaustive` | `random` | `guided`.
    pub strategy: String,
    pub seed: u64,
    /// Max designs admitted to evaluation (0 = unlimited).
    pub budget: u64,
    pub budget_seconds: f64,
    /// Sweep worker threads (0 = all cores).
    pub threads: usize,
    /// Return every evaluated point, not just the frontier (the CLI's
    /// scatter needs them; daemon clients should leave this off).
    pub keep_points: bool,
    /// Stream per-wave progress frames (frontier deltas) before the
    /// final reply (daemon connections only; ignored in-process).
    pub stream: bool,
}

// ---------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------

/// One encoded response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Analyze(AnalyzeReply),
    Map(MapReply),
    Dse(DseReply),
    Status(StatusReply),
    Metrics(MetricsReply),
    /// Incremental progress on a streaming `map`/`dse` request; more
    /// frames follow on the same connection until a non-progress kind.
    Progress(ProgressReply),
    /// Acknowledgement for `cancel` / `shutdown`.
    Done(DoneReply),
    Error(ErrorReply),
}

/// Per-request cost accounting, shipped in **every** successful reply:
/// the cold / disk / warm split of analysis work plus designs evaluated
/// and wall time — how a client observes the resident store paying off.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestStats {
    /// Full layer analyses this request actually ran (cold misses).
    pub analyses: u64,
    /// Analyses replayed from entries a cache file loaded (disk-warm).
    pub disk_hits: u64,
    /// Analyses replayed from entries already resident in memory.
    pub warm_hits: u64,
    /// The subset of `analyses` that skipped the bandwidth-invariant
    /// phase by replaying a memoized reuse profile (two-phase split;
    /// diagnostic only).
    pub profile_hits: u64,
    /// Design/candidate evaluations the request performed.
    pub designs_evaluated: u64,
    pub wall_seconds: f64,
}

/// One per-layer row of an [`AnalyzeReply`].
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRow {
    pub layer: String,
    pub dataflow: String,
    pub runtime: f64,
    pub energy_uj: f64,
    pub util: f64,
}

/// A layer dropped from analysis, with its diagnostic.
#[derive(Debug, Clone, PartialEq)]
pub struct SkippedRow {
    pub layer: String,
    pub reason: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct AnalyzeReply {
    pub id: Option<u64>,
    pub network: String,
    pub dataflow: String,
    /// Layers analyzed / unique shapes in the model.
    pub layers: u64,
    pub shapes: u64,
    pub runtime_cycles: f64,
    pub energy_uj: f64,
    pub gmacs: f64,
    /// Size of the mapspace candidate union (`dataflow == "mapped"`).
    pub mapspace_candidates: Option<u64>,
    /// Per-layer breakdown; empty unless the request set `per_layer`.
    pub per_layer: Vec<LayerRow>,
    pub skipped: Vec<SkippedRow>,
    pub stats: RequestStats,
}

/// One per-shape row of a [`MapReply`].
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeRow {
    pub representative: String,
    pub members: u64,
    pub mapping: String,
    pub runtime: f64,
    pub energy_uj: f64,
    pub util: f64,
}

/// Network totals for one side of the mapper-vs-fixed comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct SideTotals {
    pub layers: u64,
    pub runtime: f64,
    pub energy_uj: f64,
}

/// Fixed-over-mapper improvement ratios (>1 = mapper wins); present
/// only when both sides cover the same layers.
#[derive(Debug, Clone, PartialEq)]
pub struct Ratios {
    pub runtime: f64,
    pub energy: f64,
    pub edp: f64,
}

/// Mapper search counters (the structured form of
/// `MapperStats::summary`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MapSearch {
    pub shapes: u64,
    pub combos: u64,
    pub candidates: u64,
    pub evaluated: u64,
    pub budget_skipped: u64,
    pub defaulted: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct MapReply {
    pub id: Option<u64>,
    pub network: String,
    pub objective: String,
    pub per_shape: Vec<ShapeRow>,
    pub skipped: Vec<SkippedRow>,
    pub mapper: SideTotals,
    pub fixed: SideTotals,
    pub ratios: Option<Ratios>,
    pub search: MapSearch,
    pub stats: RequestStats,
}

/// One design point (frontier row / optimum) of a [`DseReply`].
#[derive(Debug, Clone, PartialEq)]
pub struct PointRow {
    pub dataflow: String,
    pub pes: u64,
    pub bandwidth: u64,
    pub l1: u64,
    pub l2: u64,
    pub runtime: f64,
    pub energy_pj: f64,
    pub area_mm2: f64,
    pub power_mw: f64,
}

/// Sweep counters (the structured form of `SweepStats::summary`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DseSearch {
    pub strategy: String,
    pub total_designs: u64,
    pub evaluated: u64,
    pub valid: u64,
    pub pruned: u64,
    pub unmappable: u64,
    pub budget_skipped: u64,
    pub waves: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct DseReply {
    pub id: Option<u64>,
    pub family: String,
    pub workload: String,
    pub layers: u64,
    pub shapes: u64,
    pub gmacs: f64,
    pub search: DseSearch,
    pub frontier: Vec<PointRow>,
    pub throughput_opt: Option<PointRow>,
    pub energy_opt: Option<PointRow>,
    pub stats: RequestStats,
}

/// One streamed progress frame on a `"stream": true` request. `dse`
/// emits one per absorbed sweep wave with the frontier's change as
/// add/remove point lists (apply removes, then adds, to mirror the
/// deterministic mid-sweep frontier); `map` emits one per searched
/// shape with empty delta lists (the mapper has no frontier).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProgressReply {
    pub id: Option<u64>,
    /// Waves (dse) or shapes (map) absorbed so far, 1-based.
    pub wave: u64,
    /// Designs/candidates evaluated so far.
    pub evaluated: u64,
    /// Points that entered the frontier this wave.
    pub frontier_add: Vec<PointRow>,
    /// Points this wave's additions dominated out of the frontier.
    pub frontier_remove: Vec<PointRow>,
}

/// Resident-store counters plus scheduler load (`status`) — the probe
/// surface a load balancer watches.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatusReply {
    pub entries: u64,
    pub max_entries: u64,
    pub hits: u64,
    pub disk_hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Requests accepted but not yet picked up by the scheduler.
    pub queue_depth: u64,
    /// Requests the scheduler is actively interleaving onto the pool.
    pub inflight: u64,
    /// Shared-pool worker threads.
    pub workers: u64,
    /// Fraction of pool workers occupied by the most recent wave
    /// (`min(jobs, workers) / workers`; 0.0 when idle).
    pub pool_utilization: f64,
    /// Milliseconds since the daemon started (monotonic clock).
    pub uptime_ms: u64,
    /// Work requests concluded successfully over the daemon's lifetime.
    pub requests_done: u64,
    /// Work requests concluded with an error frame (bad requests,
    /// cancellations, overload rejections, worker failures).
    pub requests_failed: u64,
}

impl From<StoreMetrics> for StatusReply {
    fn from(m: StoreMetrics) -> StatusReply {
        StatusReply {
            entries: m.entries,
            max_entries: m.max_entries,
            hits: m.hits,
            disk_hits: m.disk_hits,
            misses: m.misses,
            evictions: m.evictions,
            queue_depth: 0,
            inflight: 0,
            workers: 0,
            pool_utilization: 0.0,
            uptime_ms: 0,
            requests_done: 0,
            requests_failed: 0,
        }
    }
}

/// One counter in a [`MetricsReply`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricCounter {
    pub name: String,
    pub value: u64,
}

/// One gauge in a [`MetricsReply`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricGauge {
    pub name: String,
    pub value: f64,
}

/// One fixed-bucket histogram in a [`MetricsReply`]: `bounds` are
/// inclusive upper edges; `buckets` has one extra overflow slot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricHistogram {
    pub name: String,
    pub bounds: Vec<f64>,
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

/// The full telemetry snapshot (`metrics`): every registered
/// instrument, names sorted, plus daemon uptime. Purely diagnostic —
/// values depend on traffic history and timing, never the other way
/// around.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsReply {
    pub uptime_ms: u64,
    pub counters: Vec<MetricCounter>,
    pub gauges: Vec<MetricGauge>,
    pub histograms: Vec<MetricHistogram>,
}

/// Acknowledgement frame for control requests.
#[derive(Debug, Clone, PartialEq)]
pub struct DoneReply {
    pub id: Option<u64>,
    /// What was acknowledged: `"cancel"` or `"shutdown"`.
    pub what: String,
}

/// The one failure shape, shared by every entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    /// Stable machine-readable code: `bad_request` | `overloaded` |
    /// `cancelled` | `internal`.
    pub code: String,
    pub message: String,
    /// Backpressure hint (`overloaded` only): retry after this delay.
    pub retry_after_ms: Option<u64>,
    /// Extra context lines (never required to act on the error).
    pub diagnostics: Vec<String>,
}

impl ApiError {
    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError { code: "bad_request".into(), message: message.into(), retry_after_ms: None, diagnostics: Vec::new() }
    }

    pub fn overloaded(retry_after_ms: u64, backlog: usize) -> ApiError {
        ApiError {
            code: "overloaded".into(),
            message: format!("job queue full ({backlog} request(s) queued); retry later"),
            retry_after_ms: Some(retry_after_ms),
            diagnostics: Vec::new(),
        }
    }

    pub fn cancelled() -> ApiError {
        ApiError {
            code: "cancelled".into(),
            message: "request cancelled".into(),
            retry_after_ms: None,
            diagnostics: Vec::new(),
        }
    }

    pub fn internal(message: impl Into<String>) -> ApiError {
        ApiError { code: "internal".into(), message: message.into(), retry_after_ms: None, diagnostics: Vec::new() }
    }

    pub fn with_diagnostics(mut self, diagnostics: Vec<String>) -> ApiError {
        self.diagnostics = diagnostics;
        self
    }
}

/// Error frame: the failed request's id (when known) plus the error.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorReply {
    pub id: Option<u64>,
    pub error: ApiError,
}

// ---------------------------------------------------------------------
// CLI bridges (flags -> requests; one source for defaults)
// ---------------------------------------------------------------------

impl AnalyzeRequest {
    /// Build from parsed CLI flags — the `network` subcommand's half of
    /// the "CLI and daemon are one API" contract. Defaults here *are*
    /// the CLI defaults.
    pub fn from_args(args: &Args) -> Result<AnalyzeRequest> {
        let hw = HwConfig::fig10_default();
        Ok(AnalyzeRequest {
            id: None,
            model: args.opt_required("model")?,
            dataflow: args.opt("dataflow", "adaptive"),
            pes: args.opt_u64("pes", hw.num_pes)?,
            bw: args.opt_u64("bw", hw.noc_bandwidth)?,
            objective: Objective::parse(&args.opt("objective", "runtime")),
            tile_resolution: args.opt_u64("tile-resolution", 6)? as usize,
            per_layer: args.has("per-layer"),
        })
    }
}

impl MapRequest {
    pub fn from_args(args: &Args) -> Result<MapRequest> {
        let hw = HwConfig::fig10_default();
        Ok(MapRequest {
            id: None,
            model: args.opt_required("model")?,
            pes: args.opt_u64("pes", hw.num_pes)?,
            bw: args.opt_u64("bw", hw.noc_bandwidth)?,
            objective: Objective::parse(&args.opt("objective", "runtime")),
            tile_resolution: args.opt_u64("tile-resolution", 6)? as usize,
            budget: args.opt_u64("budget", 0)?,
            budget_seconds: args.opt_f64("budget-seconds", 0.0)?,
            // --workers (the coordinator-era spelling) still caps map
            // parallelism when --threads is absent, as for dse.
            threads: args.opt_u64("threads", args.opt_u64("workers", 0)?)? as usize,
            stream: args.has("stream"),
        })
    }
}

impl DseRequest {
    pub fn from_args(args: &Args) -> Result<DseRequest> {
        let resolution = args.opt_u64("resolution", 12)? as usize;
        Ok(DseRequest {
            id: None,
            family: args.opt("family", "kc-p"),
            // --layer-model is a deprecated alias the parser rewrites
            // to --model, so one lookup covers both spellings.
            model: args.opt("model", "vgg16"),
            layer: args.opt("layer", ""),
            network: args.has("network"),
            resolution,
            bw_resolution: args.opt_u64("bw-resolution", resolution as u64)? as usize,
            mapspace: args.has("mapspace"),
            tile_resolution: args.opt_u64("tile-resolution", 6)? as usize,
            strategy: args.opt("strategy", "exhaustive"),
            seed: args.opt_u64("seed", 1)?,
            budget: args.opt_u64("budget", 0)?,
            budget_seconds: args.opt_f64("budget-seconds", 0.0)?,
            // --workers (the coordinator-era spelling) still caps sweep
            // parallelism when --threads is absent.
            threads: args.opt_u64("threads", args.opt_u64("workers", 0)?)? as usize,
            keep_points: false,
            stream: args.has("stream"),
        })
    }
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn envelope(kind: &str, id: Option<u64>) -> Json {
    Json::obj()
        .set("v", Json::int(WIRE_VERSION))
        .set("kind", Json::str(kind))
        .set_opt("id", id.map(Json::int))
}

fn stats_json(s: &RequestStats) -> Json {
    Json::obj()
        .set("analyses", Json::int(s.analyses))
        .set("disk_hits", Json::int(s.disk_hits))
        .set("warm_hits", Json::int(s.warm_hits))
        .set("profile_hits", Json::int(s.profile_hits))
        .set("designs_evaluated", Json::int(s.designs_evaluated))
        .set("wall_seconds", Json::num(s.wall_seconds))
}

fn skipped_json(rows: &[SkippedRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj()
                    .set("layer", Json::str(&r.layer))
                    .set("reason", Json::str(&r.reason))
            })
            .collect(),
    )
}

fn point_json(p: &PointRow) -> Json {
    Json::obj()
        .set("dataflow", Json::str(&p.dataflow))
        .set("pes", Json::int(p.pes))
        .set("bandwidth", Json::int(p.bandwidth))
        .set("l1", Json::int(p.l1))
        .set("l2", Json::int(p.l2))
        .set("runtime", Json::num(p.runtime))
        .set("energy_pj", Json::num(p.energy_pj))
        .set("area_mm2", Json::num(p.area_mm2))
        .set("power_mw", Json::num(p.power_mw))
}

impl Request {
    pub fn kind(&self) -> &'static str {
        match self {
            Request::Analyze(_) => "analyze",
            Request::Map(_) => "map",
            Request::Dse(_) => "dse",
            Request::Status => "status",
            Request::Metrics => "metrics",
            Request::Cancel { .. } => "cancel",
            Request::Shutdown => "shutdown",
        }
    }

    /// The client-chosen correlation id, when the variant carries one.
    pub fn id(&self) -> Option<u64> {
        match self {
            Request::Analyze(r) => r.id,
            Request::Map(r) => r.id,
            Request::Dse(r) => r.id,
            _ => None,
        }
    }

    pub fn encode(&self) -> Json {
        match self {
            Request::Analyze(r) => envelope("analyze", r.id)
                .set("model", Json::str(&r.model))
                .set("dataflow", Json::str(&r.dataflow))
                .set("pes", Json::int(r.pes))
                .set("bw", Json::int(r.bw))
                .set("objective", Json::str(r.objective.name()))
                .set("tile_resolution", Json::int(r.tile_resolution as u64))
                .set("per_layer", Json::Bool(r.per_layer)),
            Request::Map(r) => envelope("map", r.id)
                .set("model", Json::str(&r.model))
                .set("pes", Json::int(r.pes))
                .set("bw", Json::int(r.bw))
                .set("objective", Json::str(r.objective.name()))
                .set("tile_resolution", Json::int(r.tile_resolution as u64))
                .set("budget", Json::int(r.budget))
                .set("budget_seconds", Json::num(r.budget_seconds))
                .set("threads", Json::int(r.threads as u64))
                // Omitted when false, so pre-streaming frames are
                // byte-stable (the goldens pin them).
                .set_opt("stream", r.stream.then(|| Json::Bool(true))),
            Request::Dse(r) => envelope("dse", r.id)
                .set("family", Json::str(&r.family))
                .set("model", Json::str(&r.model))
                .set_opt("layer", (!r.layer.is_empty()).then(|| Json::str(&r.layer)))
                .set("network", Json::Bool(r.network))
                .set("resolution", Json::int(r.resolution as u64))
                .set("bw_resolution", Json::int(r.bw_resolution as u64))
                .set("mapspace", Json::Bool(r.mapspace))
                .set("tile_resolution", Json::int(r.tile_resolution as u64))
                .set("strategy", Json::str(&r.strategy))
                .set("seed", Json::int(r.seed))
                .set("budget", Json::int(r.budget))
                .set("budget_seconds", Json::num(r.budget_seconds))
                .set("threads", Json::int(r.threads as u64))
                .set("keep_points", Json::Bool(r.keep_points))
                .set_opt("stream", r.stream.then(|| Json::Bool(true))),
            Request::Status => envelope("status", None),
            Request::Metrics => envelope("metrics", None),
            Request::Cancel { id } => envelope("cancel", None).set("id", Json::int(*id)),
            Request::Shutdown => envelope("shutdown", None),
        }
    }

    /// Decode a request frame. Failures are [`ApiError`]s so the daemon
    /// replies structurally instead of dropping the connection.
    pub fn decode(v: &Json) -> std::result::Result<Request, ApiError> {
        check_version(v)?;
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| ApiError::bad_request("missing 'kind'"))?;
        let id = opt_u64(v, "id")?;
        match kind {
            "analyze" => {
                let hw = HwConfig::fig10_default();
                Ok(Request::Analyze(AnalyzeRequest {
                    id,
                    model: need_str(v, "model")?,
                    dataflow: get_str(v, "dataflow", "adaptive")?,
                    pes: get_u64(v, "pes", hw.num_pes)?,
                    bw: get_u64(v, "bw", hw.noc_bandwidth)?,
                    objective: Objective::parse(&get_str(v, "objective", "runtime")?),
                    tile_resolution: get_u64(v, "tile_resolution", 6)? as usize,
                    per_layer: get_bool(v, "per_layer", false)?,
                }))
            }
            "map" => {
                let hw = HwConfig::fig10_default();
                Ok(Request::Map(MapRequest {
                    id,
                    model: need_str(v, "model")?,
                    pes: get_u64(v, "pes", hw.num_pes)?,
                    bw: get_u64(v, "bw", hw.noc_bandwidth)?,
                    objective: Objective::parse(&get_str(v, "objective", "runtime")?),
                    tile_resolution: get_u64(v, "tile_resolution", 6)? as usize,
                    budget: get_u64(v, "budget", 0)?,
                    budget_seconds: get_f64(v, "budget_seconds", 0.0)?,
                    threads: get_u64(v, "threads", 0)? as usize,
                    stream: get_bool(v, "stream", false)?,
                }))
            }
            "dse" => {
                let resolution = get_u64(v, "resolution", 12)? as usize;
                Ok(Request::Dse(DseRequest {
                    id,
                    family: get_str(v, "family", "kc-p")?,
                    model: get_str(v, "model", "vgg16")?,
                    layer: get_str(v, "layer", "")?,
                    network: get_bool(v, "network", false)?,
                    resolution,
                    bw_resolution: get_u64(v, "bw_resolution", resolution as u64)? as usize,
                    mapspace: get_bool(v, "mapspace", false)?,
                    tile_resolution: get_u64(v, "tile_resolution", 6)? as usize,
                    strategy: get_str(v, "strategy", "exhaustive")?,
                    seed: get_u64(v, "seed", 1)?,
                    budget: get_u64(v, "budget", 0)?,
                    budget_seconds: get_f64(v, "budget_seconds", 0.0)?,
                    threads: get_u64(v, "threads", 0)? as usize,
                    keep_points: get_bool(v, "keep_points", false)?,
                    stream: get_bool(v, "stream", false)?,
                }))
            }
            "status" => Ok(Request::Status),
            "metrics" => Ok(Request::Metrics),
            "cancel" => {
                let id = opt_u64(v, "id")?
                    .ok_or_else(|| ApiError::bad_request("cancel: missing 'id'"))?;
                Ok(Request::Cancel { id })
            }
            "shutdown" => Ok(Request::Shutdown),
            other => Err(ApiError::bad_request(format!(
                "unknown request kind '{other}' (analyze | map | dse | status | metrics | cancel | shutdown)"
            ))),
        }
    }
}

impl Response {
    /// The failure constructor every layer funnels through.
    pub fn error(id: Option<u64>, error: ApiError) -> Response {
        Response::Error(ErrorReply { id, error })
    }

    /// Whether more frames follow this one on the same connection
    /// (clients read until the first non-progress frame).
    pub fn is_progress(&self) -> bool {
        matches!(self, Response::Progress(_))
    }

    pub fn encode(&self) -> Json {
        match self {
            Response::Analyze(r) => envelope("analyze", r.id)
                .set("ok", Json::Bool(true))
                .set("network", Json::str(&r.network))
                .set("dataflow", Json::str(&r.dataflow))
                .set("layers", Json::int(r.layers))
                .set("shapes", Json::int(r.shapes))
                .set("runtime_cycles", Json::num(r.runtime_cycles))
                .set("energy_uj", Json::num(r.energy_uj))
                .set("gmacs", Json::num(r.gmacs))
                .set_opt("mapspace_candidates", r.mapspace_candidates.map(Json::int))
                .set(
                    "per_layer",
                    Json::Arr(
                        r.per_layer
                            .iter()
                            .map(|l| {
                                Json::obj()
                                    .set("layer", Json::str(&l.layer))
                                    .set("dataflow", Json::str(&l.dataflow))
                                    .set("runtime", Json::num(l.runtime))
                                    .set("energy_uj", Json::num(l.energy_uj))
                                    .set("util", Json::num(l.util))
                            })
                            .collect(),
                    ),
                )
                .set("skipped", skipped_json(&r.skipped))
                .set("stats", stats_json(&r.stats)),
            Response::Map(r) => envelope("map", r.id)
                .set("ok", Json::Bool(true))
                .set("network", Json::str(&r.network))
                .set("objective", Json::str(&r.objective))
                .set(
                    "per_shape",
                    Json::Arr(
                        r.per_shape
                            .iter()
                            .map(|s| {
                                Json::obj()
                                    .set("representative", Json::str(&s.representative))
                                    .set("members", Json::int(s.members))
                                    .set("mapping", Json::str(&s.mapping))
                                    .set("runtime", Json::num(s.runtime))
                                    .set("energy_uj", Json::num(s.energy_uj))
                                    .set("util", Json::num(s.util))
                            })
                            .collect(),
                    ),
                )
                .set("skipped", skipped_json(&r.skipped))
                .set("mapper", side_json(&r.mapper))
                .set("fixed", side_json(&r.fixed))
                .set_opt(
                    "ratios",
                    r.ratios.as_ref().map(|x| {
                        Json::obj()
                            .set("runtime", Json::num(x.runtime))
                            .set("energy", Json::num(x.energy))
                            .set("edp", Json::num(x.edp))
                    }),
                )
                .set(
                    "search",
                    Json::obj()
                        .set("shapes", Json::int(r.search.shapes))
                        .set("combos", Json::int(r.search.combos))
                        .set("candidates", Json::int(r.search.candidates))
                        .set("evaluated", Json::int(r.search.evaluated))
                        .set("budget_skipped", Json::int(r.search.budget_skipped))
                        .set("defaulted", Json::int(r.search.defaulted)),
                )
                .set("stats", stats_json(&r.stats)),
            Response::Dse(r) => envelope("dse", r.id)
                .set("ok", Json::Bool(true))
                .set("family", Json::str(&r.family))
                .set("workload", Json::str(&r.workload))
                .set("layers", Json::int(r.layers))
                .set("shapes", Json::int(r.shapes))
                .set("gmacs", Json::num(r.gmacs))
                .set(
                    "search",
                    Json::obj()
                        .set("strategy", Json::str(&r.search.strategy))
                        .set("total_designs", Json::int(r.search.total_designs))
                        .set("evaluated", Json::int(r.search.evaluated))
                        .set("valid", Json::int(r.search.valid))
                        .set("pruned", Json::int(r.search.pruned))
                        .set("unmappable", Json::int(r.search.unmappable))
                        .set("budget_skipped", Json::int(r.search.budget_skipped))
                        .set("waves", Json::int(r.search.waves)),
                )
                .set("frontier", Json::Arr(r.frontier.iter().map(point_json).collect()))
                .set_opt("throughput_opt", r.throughput_opt.as_ref().map(point_json))
                .set_opt("energy_opt", r.energy_opt.as_ref().map(point_json))
                .set("stats", stats_json(&r.stats)),
            Response::Status(r) => envelope("status", None)
                .set("ok", Json::Bool(true))
                .set("entries", Json::int(r.entries))
                .set("max_entries", Json::int(r.max_entries))
                .set("hits", Json::int(r.hits))
                .set("disk_hits", Json::int(r.disk_hits))
                .set("misses", Json::int(r.misses))
                .set("evictions", Json::int(r.evictions))
                .set("queue_depth", Json::int(r.queue_depth))
                .set("inflight", Json::int(r.inflight))
                .set("workers", Json::int(r.workers))
                .set("pool_utilization", Json::num(r.pool_utilization))
                // Appended in PR 10 (v1-compatible growth: decoders
                // default absent fields to zero).
                .set("uptime_ms", Json::int(r.uptime_ms))
                .set("requests_done", Json::int(r.requests_done))
                .set("requests_failed", Json::int(r.requests_failed)),
            Response::Metrics(r) => envelope("metrics", None)
                .set("ok", Json::Bool(true))
                .set("uptime_ms", Json::int(r.uptime_ms))
                .set(
                    "counters",
                    Json::Arr(
                        r.counters
                            .iter()
                            .map(|c| {
                                Json::obj()
                                    .set("name", Json::str(&c.name))
                                    .set("value", Json::int(c.value))
                            })
                            .collect(),
                    ),
                )
                .set(
                    "gauges",
                    Json::Arr(
                        r.gauges
                            .iter()
                            .map(|g| {
                                Json::obj()
                                    .set("name", Json::str(&g.name))
                                    .set("value", Json::num(g.value))
                            })
                            .collect(),
                    ),
                )
                .set(
                    "histograms",
                    Json::Arr(
                        r.histograms
                            .iter()
                            .map(|h| {
                                Json::obj()
                                    .set("name", Json::str(&h.name))
                                    .set(
                                        "bounds",
                                        Json::Arr(h.bounds.iter().map(|b| Json::num(*b)).collect()),
                                    )
                                    .set(
                                        "buckets",
                                        Json::Arr(h.buckets.iter().map(|b| Json::int(*b)).collect()),
                                    )
                                    .set("count", Json::int(h.count))
                                    .set("sum", Json::num(h.sum))
                            })
                            .collect(),
                    ),
                ),
            Response::Progress(r) => envelope("progress", r.id)
                .set("ok", Json::Bool(true))
                .set("wave", Json::int(r.wave))
                .set("evaluated", Json::int(r.evaluated))
                .set("frontier_add", Json::Arr(r.frontier_add.iter().map(point_json).collect()))
                .set(
                    "frontier_remove",
                    Json::Arr(r.frontier_remove.iter().map(point_json).collect()),
                ),
            Response::Done(r) => envelope("done", r.id)
                .set("ok", Json::Bool(true))
                .set("what", Json::str(&r.what)),
            Response::Error(r) => envelope("error", r.id).set("ok", Json::Bool(false)).set(
                "error",
                Json::obj()
                    .set("code", Json::str(&r.error.code))
                    .set("message", Json::str(&r.error.message))
                    .set_opt("retry_after_ms", r.error.retry_after_ms.map(Json::int))
                    .set(
                        "diagnostics",
                        Json::Arr(r.error.diagnostics.iter().map(|d| Json::str(d)).collect()),
                    ),
            ),
        }
    }

    /// One frame on the wire: the compact encoding (always a single
    /// line — the codec escapes every raw newline).
    pub fn encode_line(&self) -> String {
        self.encode().dump()
    }

    /// Decode a response frame (clients, round-trip tests).
    pub fn decode(v: &Json) -> std::result::Result<Response, ApiError> {
        check_version(v)?;
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| ApiError::bad_request("missing 'kind'"))?;
        let id = opt_u64(v, "id")?;
        match kind {
            "analyze" => Ok(Response::Analyze(AnalyzeReply {
                id,
                network: need_str(v, "network")?,
                dataflow: need_str(v, "dataflow")?,
                layers: get_u64(v, "layers", 0)?,
                shapes: get_u64(v, "shapes", 0)?,
                runtime_cycles: get_f64(v, "runtime_cycles", 0.0)?,
                energy_uj: get_f64(v, "energy_uj", 0.0)?,
                gmacs: get_f64(v, "gmacs", 0.0)?,
                mapspace_candidates: opt_u64(v, "mapspace_candidates")?,
                per_layer: arr(v, "per_layer")?
                    .iter()
                    .map(|l| {
                        Ok(LayerRow {
                            layer: need_str(l, "layer")?,
                            dataflow: need_str(l, "dataflow")?,
                            runtime: get_f64(l, "runtime", 0.0)?,
                            energy_uj: get_f64(l, "energy_uj", 0.0)?,
                            util: get_f64(l, "util", 0.0)?,
                        })
                    })
                    .collect::<std::result::Result<_, ApiError>>()?,
                skipped: decode_skipped(v)?,
                stats: decode_stats(v)?,
            })),
            "map" => Ok(Response::Map(MapReply {
                id,
                network: need_str(v, "network")?,
                objective: get_str(v, "objective", "runtime")?,
                per_shape: arr(v, "per_shape")?
                    .iter()
                    .map(|s| {
                        Ok(ShapeRow {
                            representative: need_str(s, "representative")?,
                            members: get_u64(s, "members", 0)?,
                            mapping: need_str(s, "mapping")?,
                            runtime: get_f64(s, "runtime", 0.0)?,
                            energy_uj: get_f64(s, "energy_uj", 0.0)?,
                            util: get_f64(s, "util", 0.0)?,
                        })
                    })
                    .collect::<std::result::Result<_, ApiError>>()?,
                skipped: decode_skipped(v)?,
                mapper: decode_side(v, "mapper")?,
                fixed: decode_side(v, "fixed")?,
                ratios: match v.get("ratios") {
                    None => None,
                    Some(x) => Some(Ratios {
                        runtime: get_f64(x, "runtime", 0.0)?,
                        energy: get_f64(x, "energy", 0.0)?,
                        edp: get_f64(x, "edp", 0.0)?,
                    }),
                },
                search: {
                    let s = v
                        .get("search")
                        .ok_or_else(|| ApiError::bad_request("map: missing 'search'"))?;
                    MapSearch {
                        shapes: get_u64(s, "shapes", 0)?,
                        combos: get_u64(s, "combos", 0)?,
                        candidates: get_u64(s, "candidates", 0)?,
                        evaluated: get_u64(s, "evaluated", 0)?,
                        budget_skipped: get_u64(s, "budget_skipped", 0)?,
                        defaulted: get_u64(s, "defaulted", 0)?,
                    }
                },
                stats: decode_stats(v)?,
            })),
            "dse" => Ok(Response::Dse(DseReply {
                id,
                family: need_str(v, "family")?,
                workload: need_str(v, "workload")?,
                layers: get_u64(v, "layers", 0)?,
                shapes: get_u64(v, "shapes", 0)?,
                gmacs: get_f64(v, "gmacs", 0.0)?,
                search: {
                    let s = v
                        .get("search")
                        .ok_or_else(|| ApiError::bad_request("dse: missing 'search'"))?;
                    DseSearch {
                        strategy: get_str(s, "strategy", "exhaustive")?,
                        total_designs: get_u64(s, "total_designs", 0)?,
                        evaluated: get_u64(s, "evaluated", 0)?,
                        valid: get_u64(s, "valid", 0)?,
                        pruned: get_u64(s, "pruned", 0)?,
                        unmappable: get_u64(s, "unmappable", 0)?,
                        budget_skipped: get_u64(s, "budget_skipped", 0)?,
                        waves: get_u64(s, "waves", 0)?,
                    }
                },
                frontier: arr(v, "frontier")?
                    .iter()
                    .map(decode_point)
                    .collect::<std::result::Result<_, ApiError>>()?,
                throughput_opt: v.get("throughput_opt").map(decode_point).transpose()?,
                energy_opt: v.get("energy_opt").map(decode_point).transpose()?,
                stats: decode_stats(v)?,
            })),
            "status" => Ok(Response::Status(StatusReply {
                entries: get_u64(v, "entries", 0)?,
                max_entries: get_u64(v, "max_entries", 0)?,
                hits: get_u64(v, "hits", 0)?,
                disk_hits: get_u64(v, "disk_hits", 0)?,
                misses: get_u64(v, "misses", 0)?,
                evictions: get_u64(v, "evictions", 0)?,
                queue_depth: get_u64(v, "queue_depth", 0)?,
                inflight: get_u64(v, "inflight", 0)?,
                workers: get_u64(v, "workers", 0)?,
                pool_utilization: get_f64(v, "pool_utilization", 0.0)?,
                uptime_ms: get_u64(v, "uptime_ms", 0)?,
                requests_done: get_u64(v, "requests_done", 0)?,
                requests_failed: get_u64(v, "requests_failed", 0)?,
            })),
            "metrics" => Ok(Response::Metrics(MetricsReply {
                uptime_ms: get_u64(v, "uptime_ms", 0)?,
                counters: arr(v, "counters")?
                    .iter()
                    .map(|c| {
                        Ok(MetricCounter {
                            name: need_str(c, "name")?,
                            value: get_u64(c, "value", 0)?,
                        })
                    })
                    .collect::<std::result::Result<_, ApiError>>()?,
                gauges: arr(v, "gauges")?
                    .iter()
                    .map(|g| {
                        Ok(MetricGauge {
                            name: need_str(g, "name")?,
                            value: get_f64(g, "value", 0.0)?,
                        })
                    })
                    .collect::<std::result::Result<_, ApiError>>()?,
                histograms: arr(v, "histograms")?
                    .iter()
                    .map(|h| {
                        Ok(MetricHistogram {
                            name: need_str(h, "name")?,
                            bounds: arr(h, "bounds")?
                                .iter()
                                .map(|b| {
                                    b.as_f64().ok_or_else(|| {
                                        ApiError::bad_request("histogram bounds must be numbers")
                                    })
                                })
                                .collect::<std::result::Result<_, ApiError>>()?,
                            buckets: arr(h, "buckets")?
                                .iter()
                                .map(|b| {
                                    b.as_u64().ok_or_else(|| {
                                        ApiError::bad_request("histogram buckets must be counts")
                                    })
                                })
                                .collect::<std::result::Result<_, ApiError>>()?,
                            count: get_u64(h, "count", 0)?,
                            sum: get_f64(h, "sum", 0.0)?,
                        })
                    })
                    .collect::<std::result::Result<_, ApiError>>()?,
            })),
            "progress" => Ok(Response::Progress(ProgressReply {
                id,
                wave: get_u64(v, "wave", 0)?,
                evaluated: get_u64(v, "evaluated", 0)?,
                frontier_add: arr(v, "frontier_add")?
                    .iter()
                    .map(decode_point)
                    .collect::<std::result::Result<_, ApiError>>()?,
                frontier_remove: arr(v, "frontier_remove")?
                    .iter()
                    .map(decode_point)
                    .collect::<std::result::Result<_, ApiError>>()?,
            })),
            "done" => Ok(Response::Done(DoneReply { id, what: get_str(v, "what", "")? })),
            "error" => {
                let e = v.get("error").ok_or_else(|| ApiError::bad_request("missing 'error'"))?;
                Ok(Response::Error(ErrorReply {
                    id,
                    error: ApiError {
                        code: get_str(e, "code", "internal")?,
                        message: get_str(e, "message", "")?,
                        retry_after_ms: opt_u64(e, "retry_after_ms")?,
                        diagnostics: arr(e, "diagnostics")?
                            .iter()
                            .map(|d| {
                                d.as_str().map(str::to_string).ok_or_else(|| {
                                    ApiError::bad_request("diagnostics must be strings")
                                })
                            })
                            .collect::<std::result::Result<_, ApiError>>()?,
                    },
                }))
            }
            other => Err(ApiError::bad_request(format!("unknown response kind '{other}'"))),
        }
    }
}

fn side_json(s: &SideTotals) -> Json {
    Json::obj()
        .set("layers", Json::int(s.layers))
        .set("runtime", Json::num(s.runtime))
        .set("energy_uj", Json::num(s.energy_uj))
}

fn decode_side(v: &Json, key: &str) -> std::result::Result<SideTotals, ApiError> {
    let s = v.get(key).ok_or_else(|| ApiError::bad_request(format!("map: missing '{key}'")))?;
    Ok(SideTotals {
        layers: get_u64(s, "layers", 0)?,
        runtime: get_f64(s, "runtime", 0.0)?,
        energy_uj: get_f64(s, "energy_uj", 0.0)?,
    })
}

fn decode_point(p: &Json) -> std::result::Result<PointRow, ApiError> {
    Ok(PointRow {
        dataflow: need_str(p, "dataflow")?,
        pes: get_u64(p, "pes", 0)?,
        bandwidth: get_u64(p, "bandwidth", 0)?,
        l1: get_u64(p, "l1", 0)?,
        l2: get_u64(p, "l2", 0)?,
        runtime: get_f64(p, "runtime", 0.0)?,
        energy_pj: get_f64(p, "energy_pj", 0.0)?,
        area_mm2: get_f64(p, "area_mm2", 0.0)?,
        power_mw: get_f64(p, "power_mw", 0.0)?,
    })
}

fn decode_skipped(v: &Json) -> std::result::Result<Vec<SkippedRow>, ApiError> {
    arr(v, "skipped")?
        .iter()
        .map(|r| Ok(SkippedRow { layer: need_str(r, "layer")?, reason: need_str(r, "reason")? }))
        .collect()
}

fn decode_stats(v: &Json) -> std::result::Result<RequestStats, ApiError> {
    let s = v.get("stats").ok_or_else(|| ApiError::bad_request("missing 'stats'"))?;
    Ok(RequestStats {
        analyses: get_u64(s, "analyses", 0)?,
        disk_hits: get_u64(s, "disk_hits", 0)?,
        warm_hits: get_u64(s, "warm_hits", 0)?,
        profile_hits: get_u64(s, "profile_hits", 0)?,
        designs_evaluated: get_u64(s, "designs_evaluated", 0)?,
        wall_seconds: get_f64(s, "wall_seconds", 0.0)?,
    })
}

fn arr<'a>(v: &'a Json, key: &str) -> std::result::Result<&'a [Json], ApiError> {
    match v.get(key) {
        None => Ok(&[]),
        Some(x) => x
            .as_arr()
            .ok_or_else(|| ApiError::bad_request(format!("'{key}' must be an array"))),
    }
}

fn check_version(v: &Json) -> std::result::Result<(), ApiError> {
    match v.get("v").and_then(Json::as_u64) {
        Some(WIRE_VERSION) => Ok(()),
        Some(other) => Err(ApiError::bad_request(format!(
            "unsupported wire version {other} (this build speaks v{WIRE_VERSION})"
        ))),
        None => Err(ApiError::bad_request("missing wire version field 'v'")),
    }
}

fn need_str(v: &Json, key: &str) -> std::result::Result<String, ApiError> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| ApiError::bad_request(format!("missing or non-string '{key}'")))
}

fn get_str(v: &Json, key: &str, default: &str) -> std::result::Result<String, ApiError> {
    match v.get(key) {
        None => Ok(default.to_string()),
        Some(x) => x
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| ApiError::bad_request(format!("'{key}' must be a string"))),
    }
}

fn get_u64(v: &Json, key: &str, default: u64) -> std::result::Result<u64, ApiError> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x
            .as_u64()
            .ok_or_else(|| ApiError::bad_request(format!("'{key}' must be a non-negative integer"))),
    }
}

fn opt_u64(v: &Json, key: &str) -> std::result::Result<Option<u64>, ApiError> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_u64()
            .map(Some)
            .ok_or_else(|| ApiError::bad_request(format!("'{key}' must be a non-negative integer"))),
    }
}

fn get_f64(v: &Json, key: &str, default: f64) -> std::result::Result<f64, ApiError> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x
            .as_f64()
            .ok_or_else(|| ApiError::bad_request(format!("'{key}' must be a number"))),
    }
}

fn get_bool(v: &Json, key: &str, default: bool) -> std::result::Result<bool, ApiError> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x
            .as_bool()
            .ok_or_else(|| ApiError::bad_request(format!("'{key}' must be a boolean"))),
    }
}
