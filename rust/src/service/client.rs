//! Persistent-connection TCP client for the serve daemon.
//!
//! Two modes:
//!
//! * [`call`] — one request over a fresh connection: send the frame,
//!   collect every reply line (streamed `progress` frames included)
//!   until the final non-progress frame. Lines come back as the
//!   daemon's exact bytes, so `--remote` output is byte-identical to
//!   what a raw socket client would see.
//! * [`repl`] — `maestro client --addr HOST:PORT`: a long-lived
//!   connection piping JSON request lines from stdin to the daemon and
//!   every reply frame back to stdout. One connection across many
//!   requests, so the daemon's resident store warmth accrues to the
//!   whole session and per-request connect cost disappears.
//!
//! Frame framing matches the daemon (`service::daemon`): one JSON
//! object per newline-terminated line; a streaming request's reply is
//! zero or more `"kind":"progress"` frames followed by exactly one
//! final frame of any other kind.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::api::{Request, Response};

/// A reply line ends its request unless it is a `progress` frame.
/// Unparseable lines count as final so a broken peer can't hang us.
fn is_final_frame(line: &str) -> bool {
    match Json::parse(line) {
        Ok(v) => v.get("kind").and_then(|k| k.as_str()) != Some("progress"),
        Err(_) => true,
    }
}

fn connect(addr: &str) -> Result<(TcpStream, BufReader<TcpStream>)> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("client: cannot connect to {addr}"))?;
    let reader = BufReader::new(stream.try_clone()?);
    Ok((stream, reader))
}

fn send_line(writer: &mut TcpStream, text: &str) -> Result<()> {
    let mut line = text.to_string();
    line.push('\n');
    writer.write_all(line.as_bytes())?;
    writer.flush()?;
    Ok(())
}

/// Send one request and collect its reply frames (in arrival order,
/// final frame last). The CLI's `--remote` path prints these verbatim.
pub fn call(addr: &str, request: &Request) -> Result<Vec<String>> {
    let (mut writer, mut reader) = connect(addr)?;
    send_line(&mut writer, &request.encode().dump())?;
    let mut frames = Vec::new();
    loop {
        let mut reply = String::new();
        if reader.read_line(&mut reply)? == 0 {
            bail!("client: connection closed before the final reply");
        }
        let reply = reply.trim_end();
        if reply.is_empty() {
            continue;
        }
        let done = is_final_frame(reply);
        frames.push(reply.to_string());
        if done {
            return Ok(frames);
        }
    }
}

/// `maestro client --metrics`: fetch one telemetry snapshot frame from
/// the daemon and print it. The frame is decoded into the typed
/// [`Response`] and re-encoded before printing — a genuine round-trip
/// through the versioned API, so a daemon/client codec drift fails
/// here instead of printing bytes the client cannot actually parse.
pub fn metrics(addr: &str) -> Result<()> {
    for frame in call(addr, &Request::Metrics)? {
        let parsed = Json::parse(&frame)
            .map_err(|e| anyhow::anyhow!("client: malformed metrics frame: {e}"))?;
        let response = Response::decode(&parsed).map_err(|e| {
            anyhow::anyhow!("client: bad metrics frame ({}): {}", e.code, e.message)
        })?;
        println!("{}", response.encode_line());
    }
    Ok(())
}

/// The `maestro client` loop: forward each non-empty stdin line as a
/// request frame and print every reply frame to stdout as it arrives.
/// Returns on stdin EOF or when the daemon closes the connection
/// (e.g. after acknowledging a `shutdown` frame). Lines are passed
/// through unvalidated — a malformed one earns a structured
/// `bad_request` frame from the daemon, exactly like a raw socket.
pub fn repl(addr: &str) -> Result<()> {
    let (mut writer, mut reader) = connect(addr)?;
    let stdin = std::io::stdin();
    for input in stdin.lock().lines() {
        let input = input?;
        let text = input.trim();
        if text.is_empty() {
            continue;
        }
        send_line(&mut writer, text)?;
        loop {
            let mut reply = String::new();
            if reader.read_line(&mut reply)? == 0 {
                return Ok(());
            }
            let reply = reply.trim_end();
            if reply.is_empty() {
                continue;
            }
            println!("{reply}");
            if is_final_frame(reply) {
                break;
            }
        }
    }
    Ok(())
}
