//! DSE-as-a-service: the unified request/response API and the resident
//! `maestro serve` daemon behind it.
//!
//! PR 5's cache subsystem made warm starts a file-level concern — every
//! CLI invocation still paid process startup plus a disk load before
//! its first analysis. This subsystem keeps the warm state *resident*:
//!
//! * [`api`] — the typed, versioned wire schema ([`api::Request`] /
//!   [`api::Response`] with a shared [`api::ApiError`]). One schema for
//!   every surface: the daemon's TCP frames, the CLI's `--json` output,
//!   and the `from_args` builders that turn CLI flags into requests.
//! * [`exec`] — the single implementation of analyze / map / dse that
//!   both the CLI subcommands and the daemon executor call, returning
//!   engine-native outcomes plus per-request [`api::RequestStats`]
//!   (cold-vs-disk-vs-warm cache split, designs evaluated, wall time).
//! * [`daemon`] — the resident server: one warm [`SharedStore`] for
//!   the process lifetime, newline-delimited JSON over TCP, bounded
//!   job-queue backpressure (`overloaded` with drain-rate-scaled
//!   `retry_after_ms`), per-request cooperative cancellation, periodic
//!   + shutdown store flushes. Concurrent requests share **one**
//!   process-wide wave pool: a scheduler interleaves every in-flight
//!   request's shards into coalesced waves (see the daemon docs), and
//!   `map`/`dse` requests may stream per-wave `progress` frames.
//! * [`client`] — the persistent-connection client: `maestro client`
//!   (stdin request lines, stdout reply frames) and the `--remote`
//!   path of `network`/`map`/`dse`.
//!
//! [`SharedStore`]: crate::cache::SharedStore

pub mod api;
pub mod client;
pub mod daemon;
pub mod exec;

pub use api::{ApiError, Request, RequestStats, Response, WIRE_VERSION};
pub use daemon::{serve, Daemon, ServeConfig};
