//! Cycle-level schedule simulator — the ground-truth substitute for the
//! paper's RTL validation (Fig 9; substitution documented in DESIGN.md
//! §4).
//!
//! Unlike the analytical engine (closed-form transition classes with
//! amortized double buffering), this simulator *walks every step* of the
//! schedule with an explicit three-stage transfer pipeline:
//!
//! ```text
//! fetch[i]   starts when the fetch channel frees   (fetch_done[i-1])
//! compute[i] starts at max(fetch_done[i], compute_done[i-1])
//! drain[i]   starts at max(compute_done[i], drain_done[i-1])
//! ```
//!
//! Data movement is derived from *explicit per-step index intervals* and
//! interval set-difference against the previous step's resident data —
//! no fresh-fraction formulas, no iteration-case merging. The two models
//! share only the schedule semantics (`engine::mapping::build_schedule`),
//! which is the specification both implement.

use anyhow::{ensure, Result};

use crate::engine::mapping::{build_schedule, LevelSchedule, PosState};
use crate::engine::noc::{level_bandwidth, pipe_delay, reduction_delay};
use crate::hw::config::{HwConfig, ReductionSupport};
use crate::ir::dataflow::Dataflow;
use crate::ir::dims::{Dim, DimMap};
use crate::model::layer::Layer;
use crate::model::tensor::{couplings, Coupling, TensorDim, ALL_TENSORS};

/// Result of a cycle-level simulation.
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    pub cycles: f64,
    /// Unique L2 fetches per tensor [F, I, O-psum-reingress].
    pub l2_reads: [f64; 3],
    /// L2 writes (output psums + finals).
    pub l2_writes: f64,
    pub steps: u64,
    pub macs: f64,
}

/// Per-dimension index interval `[start, start+len)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
struct Iv {
    start: u64,
    len: u64,
}

impl Iv {
    fn end(&self) -> u64 {
        self.start + self.len
    }
    fn overlap(&self, o: &Iv) -> u64 {
        let lo = self.start.max(o.start);
        let hi = self.end().min(o.end());
        hi.saturating_sub(lo)
    }
}

/// Axis-aligned box footprint of a tensor (one interval per tensor dim).
#[derive(Debug, Clone, Default, PartialEq)]
struct Box_ {
    ivs: Vec<Iv>,
}

impl Box_ {
    fn volume(&self) -> u64 {
        self.ivs.iter().map(|iv| iv.len).product()
    }
    /// |self \ prev| for axis-aligned boxes.
    fn new_vs(&self, prev: &Box_) -> u64 {
        if prev.ivs.len() != self.ivs.len() {
            return self.volume();
        }
        let overlap: u64 = self.ivs.iter().zip(&prev.ivs).map(|(a, b)| a.overlap(b)).product();
        self.volume() - overlap.min(self.volume())
    }
}

/// Simulate a (layer, dataflow, hardware) triple. `max_steps` bounds the
/// walk (error if exceeded) so tests cannot hang.
pub fn simulate(layer: &Layer, dataflow: &Dataflow, hw: &HwConfig, max_steps: u64) -> Result<SimResult> {
    let resolved = dataflow.resolve(layer, hw.num_pes)?;
    ensure!(resolved.levels.len() <= 2, "simulator supports <= 2 cluster levels");
    let top = build_schedule(&resolved.levels[0], &resolved.levels[0].parent_tile, layer)?;
    let inner_level = resolved.levels.get(1);

    let mut sim = LevelSim::new(&top, layer, hw, level_bandwidth(hw, 1), DimMap::default());
    let mut res = SimResult::default();
    let mut fetch_done = 0.0f64;
    let mut compute_done = 0.0f64;
    let mut drain_done = 0.0f64;

    // Persistent inner-cluster buffer state (global coordinates): data a
    // PE retained across outer steps is not re-streamed inside the
    // cluster. Outputs reset per outer step (psums re-commit upward).
    let mut inner_state: [Option<Box_>; 3] = [None, None, None];

    let mut odo = Odometer::new(&top);
    loop {
        res.steps += 1;
        ensure!(res.steps <= max_steps, "simulation exceeded {max_steps} steps");

        let step = sim.step_footprints(&odo);
        // Unique fetch: union box across units minus previous union box.
        let mut fetch_elems = 0.0;
        for (ti, _) in ALL_TENSORS.iter().enumerate() {
            if ti == 2 {
                continue; // outputs handled on the drain side
            }
            let newly = if hw.multicast {
                step.union_new[ti] as f64
            } else {
                step.per_unit_new[ti] as f64
            };
            res.l2_reads[ti] += newly;
            fetch_elems += newly;
        }
        // Partial-sum re-ingress: when the output tile entering this
        // step was visited in an earlier reduction sweep (an outer
        // reduction loop is mid-flight), its psums come back down for
        // further accumulation (parent read-modify-write).
        if step.out_new_union > 0 && sim.psum_revisit_active(&odo) {
            let psum = if step.out_reduced && hw.reduction == ReductionSupport::None {
                step.out_new_per_unit as f64 * step.active as f64
            } else {
                step.out_new_union as f64
            };
            res.l2_reads[2] += psum;
            fetch_elems += psum;
        }

        // Compute time: inner level (if any) or PE MACs.
        let (ct, macs) = match inner_level {
            None => {
                let m = step.macs_per_unit as f64 * layer.sparsity_macs_scale();
                ((m / hw.pe_throughput as f64).ceil().max(1.0), step.macs_per_unit as f64 * step.active as f64)
            }
            Some(level) => {
                let origin = sim.origins(&odo);
                let (t, m) = simulate_inner(
                    level,
                    &step.tile,
                    origin,
                    layer,
                    hw,
                    top.units,
                    &mut inner_state,
                    max_steps,
                )?;
                (t, m * step.active as f64)
            }
        };
        res.macs += macs * layer.sparsity_macs_scale();

        // Drain: output tile leaves when its footprint shifts; simulate by
        // draining the *newly produced* output volume each step (the
        // non-fresh steps produce psum updates that stay local).
        let mut drain_elems = step.out_new_union as f64;
        let mut red = 0.0;
        if step.out_reduced {
            if hw.reduction == ReductionSupport::None {
                drain_elems = step.out_new_per_unit as f64 * step.active as f64;
            }
            red = reduction_delay(hw.reduction, step.active);
        }
        res.l2_writes += drain_elems;

        // Three-stage pipeline bookkeeping.
        let f_start = fetch_done;
        fetch_done = f_start + pipe_delay(fetch_elems, sim.bw, hw.noc_latency);
        let c_start = fetch_done.max(compute_done);
        compute_done = c_start + ct + red;
        let d_start = compute_done.max(drain_done);
        drain_done = d_start + pipe_delay(drain_elems, sim.bw, hw.noc_latency);

        sim.retire(step);
        if !odo.advance() {
            break;
        }
    }
    res.cycles = drain_done.max(compute_done);
    Ok(res)
}

/// Simulate the inner level over a fixed parent tile at a global-space
/// `origin`; `state` persists PE-retained data across outer steps.
/// Returns (cycles, macs per one cluster execution).
#[allow(clippy::too_many_arguments)]
fn simulate_inner(
    level: &crate::ir::dataflow::ResolvedLevel,
    parent_tile: &DimMap<u64>,
    origin: DimMap<u64>,
    layer: &Layer,
    hw: &HwConfig,
    outer_units: u64,
    state: &mut [Option<Box_>; 3],
    max_steps: u64,
) -> Result<(f64, f64)> {
    let sched = build_schedule(level, parent_tile, layer)?;
    let bw = level_bandwidth(hw, outer_units);
    let mut sim = LevelSim::new(&sched, layer, hw, bw, origin);
    // Retained filter/input data carries over; psums re-commit upward.
    sim.prev_union[0] = state[0].take();
    sim.prev_union[1] = state[1].take();
    let mut odo = Odometer::new(&sched);
    let mut fetch_done = 0.0f64;
    let mut compute_done = 0.0f64;
    let mut drain_done = 0.0f64;
    let mut macs_total = 0.0;
    let mut steps = 0u64;
    loop {
        steps += 1;
        ensure!(steps <= max_steps, "inner simulation exceeded {max_steps} steps");
        let step = sim.step_footprints(&odo);
        let mut fetch_elems = 0.0;
        for ti in 0..2 {
            fetch_elems += if hw.multicast { step.union_new[ti] as f64 } else { step.per_unit_new[ti] as f64 };
        }
        if step.out_new_union > 0 && sim.psum_revisit_active(&odo) {
            fetch_elems += if step.out_reduced && hw.reduction == ReductionSupport::None {
                step.out_new_per_unit as f64 * step.active as f64
            } else {
                step.out_new_union as f64
            };
        }
        let m = step.macs_per_unit as f64;
        let ct = (m * layer.sparsity_macs_scale() / hw.pe_throughput as f64).ceil().max(1.0);
        macs_total += m * step.active as f64;
        let mut drain_elems = step.out_new_union as f64;
        let mut red = 0.0;
        if step.out_reduced {
            if hw.reduction == ReductionSupport::None {
                drain_elems = step.out_new_per_unit as f64 * step.active as f64;
            }
            red = reduction_delay(hw.reduction, step.active);
        }
        let f_start = fetch_done;
        fetch_done = f_start + pipe_delay(fetch_elems, bw, hw.noc_latency);
        let c_start = fetch_done.max(compute_done);
        compute_done = c_start + ct + red;
        let d_start = compute_done.max(drain_done);
        drain_done = d_start + pipe_delay(drain_elems, bw, hw.noc_latency);
        sim.retire(step);
        if !odo.advance() {
            break;
        }
    }
    state[0] = sim.prev_union[0].take();
    state[1] = sim.prev_union[1].take();
    Ok((drain_done.max(compute_done), macs_total))
}

/// The nested-loop odometer over a level schedule (temporal loops +
/// spatial fold, in directive order).
struct Odometer {
    /// (is_fold, dim index, total positions), outermost first.
    loops: Vec<(bool, usize, u64)>,
    pos: Vec<u64>,
}

impl Odometer {
    fn new(s: &LevelSchedule) -> Odometer {
        let mut loops = Vec::new();
        for (i, d) in s.dims.iter().enumerate() {
            if Some(i) == s.fold_order_idx {
                loops.push((true, usize::MAX, s.fold_total()));
            }
            if !d.spatial {
                loops.push((false, i, d.total_positions()));
            }
        }
        if s.fold_order_idx.is_some() && !loops.iter().any(|l| l.0) {
            loops.push((true, usize::MAX, s.fold_total()));
        }
        let pos = vec![0; loops.len()];
        Odometer { loops, pos }
    }

    /// Advance the innermost loop; returns false when the walk is done.
    fn advance(&mut self) -> bool {
        for i in (0..self.loops.len()).rev() {
            self.pos[i] += 1;
            if self.pos[i] < self.loops[i].2 {
                return true;
            }
            self.pos[i] = 0;
        }
        false
    }

    fn fold_pos(&self) -> u64 {
        self.loops
            .iter()
            .zip(&self.pos)
            .find(|((is_fold, _, _), _)| *is_fold)
            .map(|(_, &p)| p)
            .unwrap_or(0)
    }

    fn dim_pos(&self, dim_idx: usize) -> u64 {
        self.loops
            .iter()
            .zip(&self.pos)
            .find(|((is_fold, di, _), _)| !*is_fold && *di == dim_idx)
            .map(|(_, &p)| p)
            .unwrap_or(0)
    }
}

/// One step's concrete footprints.
struct StepFootprints {
    tile: DimMap<u64>,
    active: u64,
    macs_per_unit: u64,
    /// New elements per tensor, summed over units (no multicast collapse).
    per_unit_new: [u64; 3],
    /// New elements in the union box across units.
    union_new: [u64; 3],
    out_new_union: u64,
    out_new_per_unit: u64,
    out_reduced: bool,
    /// Union boxes to retire into `prev`.
    union_boxes: [Box_; 3],
}

/// Per-level simulation state: previous resident boxes.
struct LevelSim<'a> {
    s: &'a LevelSchedule,
    layer: &'a Layer,
    coup: [Coupling; 3],
    prev_union: [Option<Box_>; 3],
    bw: u64,
    /// Global-space offset of this level's iteration (inner levels
    /// iterate within the outer level's current tile).
    origin: DimMap<u64>,
}

impl<'a> LevelSim<'a> {
    fn new(
        s: &'a LevelSchedule,
        layer: &'a Layer,
        _hw: &HwConfig,
        bw: u64,
        origin: DimMap<u64>,
    ) -> LevelSim<'a> {
        LevelSim { s, layer, coup: couplings(layer), prev_union: [None, None, None], bw, origin }
    }

    /// Global origins of the current step's unit-0 tile (handed to the
    /// inner level so its intervals live in the same coordinate space).
    fn origins(&self, odo: &Odometer) -> DimMap<u64> {
        let mut o: DimMap<u64> = DimMap::default();
        for d in &self.s.dims {
            o.set(d.dim, self.dim_iv(odo, d.dim, 0).start);
        }
        o
    }

    /// Interval of a loop dim at the odometer's position, for unit `u`.
    fn dim_iv(&self, odo: &Odometer, dim: Dim, unit: u64) -> Iv {
        let idx = self.s.dims.iter().position(|d| d.dim == dim).unwrap();
        let d = &self.s.dims[idx];
        let base = self.origin.get(dim);
        if d.spatial {
            let joint_pos = odo.fold_pos() * self.s.units + unit;
            let pos = joint_pos.min(d.total_positions().saturating_sub(1));
            Iv { start: base + pos * d.offset, len: d.size }
        } else {
            let pos = odo.dim_pos(idx);
            let state = if pos >= d.positions_full { PosState::Edge } else { PosState::Normal };
            Iv { start: base + pos * d.offset, len: d.in_size(state) }
        }
    }

    /// Output-space interval derived from act/win intervals.
    fn out_iv(&self, act: Iv, win_dim: Dim, odo: &Odometer, unit: u64) -> Iv {
        let w = self.dim_iv(odo, win_dim, unit);
        let stride = self.layer.stride.max(1);
        // Window semantics: outputs whose full window lies inside `act`,
        // relative to the window's current start.
        if act.len >= w.len {
            // y' = (y - r) / stride over y in act, r in the *full* parent
            // window for this level; use the windowed dim's `win` field.
            let dsched = self.s.sched_of(if win_dim == Dim::R { Dim::Y } else { Dim::X });
            let winlen = dsched.win.max(1);
            if act.len < winlen {
                // Joint diagonal: single output coordinate.
                return Iv { start: act.start.saturating_sub(w.start) / stride, len: 1 };
            }
            let rows = (act.len - winlen) / stride + 1;
            Iv { start: act.start / stride, len: rows.max(1) }
        } else {
            Iv { start: act.start.saturating_sub(w.start) / stride, len: 1 }
        }
    }

    fn tensor_box(&self, odo: &Odometer, coupling: &Coupling, unit: u64) -> Box_ {
        let mut ivs = Vec::with_capacity(coupling.dims.len());
        for td in &coupling.dims {
            let iv = match td {
                TensorDim::Direct(d) => self.dim_iv(odo, *d, unit),
                TensorDim::Windowed { act, win } => {
                    let a = self.dim_iv(odo, *act, unit);
                    self.out_iv(a, *win, odo, unit)
                }
            };
            ivs.push(iv);
        }
        Box_ { ivs }
    }

    /// Union box across active units (footprints are consecutive along
    /// spatial dims, so the union of boxes is a box).
    fn union_box(&self, odo: &Odometer, coupling: &Coupling, active: u64) -> Box_ {
        if active <= 1 {
            return self.tensor_box(odo, coupling, 0);
        }
        let first = self.tensor_box(odo, coupling, 0);
        let last = self.tensor_box(odo, coupling, active - 1);
        let ivs = first
            .ivs
            .iter()
            .zip(&last.ivs)
            .map(|(a, b)| {
                let start = a.start.min(b.start);
                let end = a.end().max(b.end());
                Iv { start, len: end - start }
            })
            .collect();
        Box_ { ivs }
    }

    fn step_footprints(&mut self, odo: &Odometer) -> StepFootprints {
        let fold_pos = odo.fold_pos();
        let active = if self.s.spatial_positions <= self.s.units {
            self.s.spatial_positions.max(1)
        } else if fold_pos < self.s.folds_full {
            self.s.units
        } else {
            self.s.fold_edge_units.max(1)
        };

        // Tile handed to each unit.
        let mut tile: DimMap<u64> = DimMap::filled(1);
        for d in &self.s.dims {
            let iv = self.dim_iv(odo, d.dim, 0);
            tile.set(d.dim, iv.len);
        }

        // MACs per unit from concrete intervals.
        let macs_per_unit = self.macs_from_tile(odo);

        let mut per_unit_new = [0u64; 3];
        let mut union_new = [0u64; 3];
        let mut union_boxes: [Box_; 3] = [Box_::default(), Box_::default(), Box_::default()];
        for (ti, _) in ALL_TENSORS.iter().enumerate() {
            if self.coup[ti].dims.is_empty() {
                continue;
            }
            // Per-unit sum of new elements.
            let mut sum_new = 0u64;
            let mut prev_unit_box: Option<Box_> = None;
            for u in 0..active {
                let b = self.tensor_box(odo, &self.coup[ti], u);
                // Against the same step's previous unit (halo share) and
                // the previous step's union (temporal reuse).
                let mut newv = match &self.prev_union[ti] {
                    Some(p) => b.new_vs(p),
                    None => b.volume(),
                };
                if let Some(pu) = &prev_unit_box {
                    newv = newv.min(b.new_vs(pu));
                }
                sum_new += newv;
                prev_unit_box = Some(b);
            }
            per_unit_new[ti] = sum_new;
            let ub = self.union_box(odo, &self.coup[ti], active);
            union_new[ti] = match &self.prev_union[ti] {
                Some(p) => ub.new_vs(p),
                None => ub.volume(),
            };
            union_boxes[ti] = ub;
        }

        // Output spatial reduction: unit boxes identical while some
        // spatial dim varies.
        let out_reduced = active > 1 && {
            let b0 = self.tensor_box(odo, &self.coup[2], 0);
            let b1 = self.tensor_box(odo, &self.coup[2], active - 1);
            b0 == b1 && self.s.dims.iter().any(|d| d.spatial && !self.coup[2].couples_directly(d.dim))
        };
        let out_new_per_unit = {
            let b = self.tensor_box(odo, &self.coup[2], 0);
            match &self.prev_union[2] {
                Some(p) => b.new_vs(p),
                None => b.volume(),
            }
        };

        StepFootprints {
            tile,
            active,
            macs_per_unit,
            per_unit_new,
            union_new,
            out_new_union: union_new[2],
            out_new_per_unit,
            out_reduced,
            union_boxes,
        }
    }

    /// Is the current step re-visiting previously retired output tiles?
    /// True when any reduction-dim loop *outer* to the innermost
    /// output-advancing loop is past its first position (mirrors
    /// `engine::reuse::psum_revisits`, which the analytical model uses
    /// to amortize the same traffic).
    fn psum_revisit_active(&self, odo: &Odometer) -> bool {
        let o = &self.coup[2];
        let advancing = |l: &(bool, usize, u64)| -> bool {
            if l.0 {
                self.s
                    .dims
                    .iter()
                    .filter(|d| d.spatial)
                    .any(|d| crate::engine::reuse::output_advancing(o, d.dim))
            } else {
                crate::engine::reuse::output_advancing(o, self.s.dims[l.1].dim)
            }
        };
        let reduction = |l: &(bool, usize, u64)| -> bool {
            if l.0 {
                self.s
                    .dims
                    .iter()
                    .filter(|d| d.spatial)
                    .any(|d| crate::engine::reuse::is_reduction_dim(self.layer, d.dim))
            } else {
                crate::engine::reuse::is_reduction_dim(self.layer, self.s.dims[l.1].dim)
            }
        };
        let innermost_adv = odo
            .loops
            .iter()
            .rposition(|l| advancing(l) && l.2 > 1)
            .unwrap_or(0);
        odo.loops[..innermost_adv]
            .iter()
            .zip(&odo.pos)
            .any(|(l, &p)| reduction(l) && l.2 > 1 && p > 0)
    }

    fn retire(&mut self, step: StepFootprints) {
        for (ti, b) in step.union_boxes.into_iter().enumerate() {
            if !b.ivs.is_empty() {
                self.prev_union[ti] = Some(b);
            }
        }
    }

    /// Exact MAC count for one unit's tile at the current position.
    fn macs_from_tile(&self, odo: &Odometer) -> u64 {
        let mut macs: u64 = 1;
        for d in &self.s.dims {
            let iv = self.dim_iv(odo, d.dim, 0);
            match d.dim {
                Dim::Y | Dim::X => {
                    if d.joint_spatial {
                        macs *= 1;
                    } else if d.windowed {
                        let winlen = d.win.max(1);
                        let rows = if iv.len >= winlen { (iv.len - winlen) / d.stride.max(1) + 1 } else { 1 };
                        macs *= rows;
                    } else {
                        macs *= iv.len;
                    }
                }
                Dim::R | Dim::S => {
                    if d.joint_spatial {
                        macs *= 1;
                    } else {
                        macs *= iv.len;
                    }
                }
                _ => macs *= iv.len,
            }
        }
        macs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::styles;
    use crate::model::tensor::TensorKind;

    fn small_layer() -> Layer {
        Layer::conv2d("small", 1, 8, 6, 12, 12, 3, 3, 1)
    }

    fn hw(pes: u64) -> HwConfig {
        HwConfig { num_pes: pes, ..HwConfig::fig10_default() }
    }

    #[test]
    fn sim_mac_conservation() {
        let layer = small_layer();
        for df in [styles::c_p(), styles::x_p(), styles::yx_p(), styles::yr_p()] {
            let r = simulate(&layer, &df, &hw(16), 10_000_000).unwrap_or_else(|e| panic!("{}: {e}", df.name));
            assert!(
                (r.macs - layer.macs() as f64).abs() < 1e-6 * layer.macs() as f64,
                "{}: {} != {}",
                df.name,
                r.macs,
                layer.macs()
            );
        }
    }

    #[test]
    fn sim_fetches_cover_tensors() {
        use crate::model::tensor::tensor_elements;
        let layer = small_layer();
        let r = simulate(&layer, &styles::x_p(), &hw(16), 10_000_000).unwrap();
        assert!(r.l2_reads[0] >= tensor_elements(&layer, TensorKind::Filter) as f64 * 0.999);
        assert!(r.l2_reads[1] >= tensor_elements(&layer, TensorKind::Input) as f64 * 0.999);
        assert!(r.l2_writes >= tensor_elements(&layer, TensorKind::Output) as f64 * 0.999);
    }

    #[test]
    fn sim_respects_compute_roofline() {
        let layer = small_layer();
        let h = hw(16);
        let r = simulate(&layer, &styles::yx_p(), &h, 10_000_000).unwrap();
        let roofline = layer.macs() as f64 / (h.num_pes * h.pe_throughput) as f64;
        assert!(r.cycles >= roofline, "{} < {roofline}", r.cycles);
    }

    #[test]
    fn sim_step_budget_enforced() {
        let layer = small_layer();
        assert!(simulate(&layer, &styles::x_p(), &hw(16), 3).is_err());
    }

    #[test]
    fn analytical_model_matches_simulator_within_tolerance() {
        // The Fig 9 claim: analytical runtime within a few % of the
        // step-walking ground truth. Use a moderate layer so the test is
        // fast; the bench runs the full VGG16/AlexNet validation.
        use crate::engine::analysis::analyze_layer;
        let layer = Layer::conv2d("v", 1, 16, 16, 18, 18, 3, 3, 1);
        let h = hw(32);
        for df in [styles::x_p(), styles::kc_p(), styles::yx_p()] {
            let sim = match simulate(&layer, &df, &h, 50_000_000) {
                Ok(r) => r,
                Err(_) => continue, // dataflow invalid at this PE count
            };
            let ana = analyze_layer(&layer, &df, &h).unwrap();
            let err = (ana.runtime - sim.cycles).abs() / sim.cycles;
            assert!(
                err < 0.15,
                "{}: analytical {} vs sim {} ({}%)",
                df.name,
                ana.runtime,
                sim.cycles,
                err * 100.0
            );
        }
    }
}
