//! Cycle-level reference simulator — the RTL-simulation substitute used
//! to validate the analytical model (Fig 9). See [`cycle`].

pub mod cycle;
