//! Experiment report emitters shared by the benches and examples.

pub mod experiments;
