//! Shared experiment drivers: the benches and examples call these to
//! regenerate the paper's tables/figures, so the logic is tested once
//! here and formatted consistently.

use anyhow::Result;

use crate::dse::engine::DesignPoint;
use crate::dse::pareto::{best, Optimize};
use crate::engine::analysis::{analyze_layer, LayerStats, NetworkStats};
use crate::hw::config::HwConfig;

use crate::ir::styles;
use crate::model::layer::Layer;
use crate::util::table::{num, Scatter, Table};

/// Fig 10-style row: one (model/layer, dataflow) runtime+energy pair.
pub fn dataflow_comparison(layer: &Layer, hw: &HwConfig) -> Result<Vec<LayerStats>> {
    let mut out = Vec::new();
    for df in styles::all_styles() {
        if let Ok(s) = analyze_layer(layer, &df, hw) {
            out.push(s);
        }
    }
    Ok(out)
}

/// Render per-dataflow stats as a table.
pub fn stats_table(stats: &[LayerStats]) -> Table {
    let mut t = Table::new(&[
        "dataflow", "runtime(cyc)", "energy(uJ)", "util", "L2 rd", "L2 wr", "peak BW", "L1 req", "L2 req",
    ]);
    for s in stats {
        t.row(&[
            s.dataflow.clone(),
            num(s.runtime),
            num(s.energy.total() / 1e6),
            format!("{:.3}", s.util),
            num(s.l2_reads.iter().sum::<f64>()),
            num(s.l2_writes.iter().sum::<f64>()),
            num(s.peak_bw_need),
            s.l1_req.to_string(),
            s.l2_req.to_string(),
        ]);
    }
    t
}

/// Per-layer breakdown of a whole-network analysis (the CLI `network
/// --per-layer` view): winning dataflow, runtime, energy and
/// utilization per layer, plus one row per skipped layer with its
/// diagnostic.
pub fn network_layers_table(stats: &NetworkStats) -> Table {
    let mut t = Table::new(&["layer", "dataflow", "runtime(cyc)", "energy(uJ)", "util"]);
    for s in &stats.per_layer {
        t.row(&[
            s.layer.clone(),
            s.dataflow.clone(),
            num(s.runtime),
            num(s.energy.total() / 1e6),
            format!("{:.3}", s.util),
        ]);
    }
    for s in &stats.skipped {
        t.row(&[s.layer.clone(), "(skipped)".into(), "-".into(), "-".into(), "-".into()]);
    }
    t
}

/// Render a Pareto frontier (or any design-point list) as a table —
/// shared by the CLI `dse` subcommand and the DSE examples.
pub fn frontier_table(points: &[DesignPoint], macs: f64) -> Table {
    let mut t = Table::new(&[
        "variant", "PEs", "BW", "L1 (el)", "L2 (el)", "thrpt (MAC/cyc)", "energy (uJ)", "area (mm2)", "power (mW)",
    ]);
    for p in points {
        t.row(&[
            p.dataflow.clone(),
            p.pes.to_string(),
            p.bandwidth.to_string(),
            p.l1.to_string(),
            p.l2.to_string(),
            format!("{:.1}", p.throughput(macs)),
            format!("{:.1}", p.energy_pj / 1e6),
            format!("{:.2}", p.area_mm2),
            format!("{:.0}", p.power_mw),
        ]);
    }
    t
}

/// Fig 13-style scatter: area vs throughput, with optima marked.
pub fn design_space_scatter(points: &[DesignPoint], macs: f64, title: &str) -> String {
    let mut sc = Scatter::new(title, "area (mm2)", "throughput (MACs/cycle)");
    for p in points.iter().filter(|p| p.valid) {
        sc.point(p.area_mm2, p.throughput(macs), '.');
    }
    if let Some(t) = best(points, Optimize::Throughput, macs) {
        sc.point(t.area_mm2, t.throughput(macs), '*');
    }
    if let Some(e) = best(points, Optimize::Energy, macs) {
        sc.point(e.area_mm2, e.throughput(macs), '+');
    }
    sc.render(72, 18)
}

/// Buffer-vs-throughput scatter (Fig 13 second column).
pub fn buffer_scatter(points: &[DesignPoint], macs: f64, title: &str) -> String {
    let mut sc = Scatter::new(title, "total buffer (KB)", "throughput (MACs/cycle)");
    for p in points.iter().filter(|p| p.valid) {
        let kb = (p.l1 * p.pes + p.l2) as f64 * 2.0 / 1024.0;
        sc.point(kb, p.throughput(macs), '.');
    }
    sc.render(72, 18)
}

/// The energy-vs-throughput optimized comparison of §1 / §5.2.
pub struct OptimaComparison {
    pub throughput_opt: DesignPoint,
    pub energy_opt: DesignPoint,
    pub power_ratio: f64,
    pub sram_ratio: f64,
    pub pe_ratio: f64,
    pub edp_improvement: f64,
    pub throughput_fraction: f64,
}

/// Compare the throughput- and energy-optimized design points.
pub fn compare_optima(points: &[DesignPoint], macs: f64) -> Option<OptimaComparison> {
    let t = best(points, Optimize::Throughput, macs)?.clone();
    let e = best(points, Optimize::Energy, macs)?.clone();
    let sram = |p: &DesignPoint| (p.l1 * p.pes + p.l2) as f64;
    Some(OptimaComparison {
        power_ratio: t.power_mw / e.power_mw.max(1e-9),
        sram_ratio: sram(&e) / sram(&t).max(1e-9),
        pe_ratio: e.pes as f64 / t.pes as f64,
        edp_improvement: 1.0 - e.edp() / t.edp().max(1e-9),
        throughput_fraction: e.throughput(macs) / t.throughput(macs).max(1e-9),
        throughput_opt: t,
        energy_opt: e,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::vgg16;

    #[test]
    fn comparison_runs_all_styles() {
        let stats = dataflow_comparison(&vgg16::conv13(), &HwConfig::fig10_default()).unwrap();
        assert!(stats.len() >= 4, "most styles must analyze conv13");
        let t = stats_table(&stats);
        assert!(t.render().contains("KC-P"));
    }

    #[test]
    fn frontier_table_renders_points() {
        use crate::dse::engine::{sweep, SweepConfig};
        use crate::dse::space::DesignSpace;
        use crate::model::network::Network;
        let layer = vgg16::conv13();
        let net = Network::single(layer.clone());
        let out = sweep(&net, &DesignSpace::ci_smoke("kc-p"), 2, &SweepConfig::serial()).unwrap();
        assert!(!out.frontier.is_empty());
        let rendered = frontier_table(&out.frontier, layer.macs() as f64).render();
        assert!(rendered.contains("KC-P"));
        assert!(rendered.contains("thrpt"));
    }

    #[test]
    fn network_layers_table_lists_skips() {
        use crate::engine::analysis::analyze_network;
        use crate::ir::styles;
        use crate::model::layer::Layer;
        use crate::model::network::Network;
        let net = Network::new(
            "mixed",
            vec![
                Layer::conv2d("ok", 1, 64, 16, 30, 30, 3, 3, 1),
                Layer::conv2d("bad", 1, 8, 4, 2, 2, 3, 3, 1),
            ],
        );
        let hw = HwConfig::fig10_default();
        let stats = analyze_network(&net, &styles::kc_p(), &hw, true).unwrap();
        let rendered = network_layers_table(&stats).render();
        assert!(rendered.contains("ok"));
        assert!(rendered.contains("bad") && rendered.contains("(skipped)"), "{rendered}");
    }

    #[test]
    fn optima_comparison_on_synthetic_points() {
        use crate::dse::engine::DesignPoint;
        let mk = |pes, runtime: f64, energy: f64, power, l1, l2| DesignPoint {
            dataflow: "t".into(),
            pes,
            bandwidth: 16,
            l1,
            l2,
            runtime,
            energy_pj: energy,
            area_mm2: 10.0,
            power_mw: power,
            valid: true,
        };
        let pts = vec![mk(256, 100.0, 1000.0, 400.0, 512, 100_000), mk(200, 160.0, 500.0, 200.0, 4096, 500_000)];
        let c = compare_optima(&pts, 1e6).unwrap();
        assert!(c.power_ratio > 1.0);
        assert!(c.sram_ratio > 1.0);
        assert!(c.throughput_fraction < 1.0);
    }
}
