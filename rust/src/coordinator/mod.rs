//! The L3 coordinator: orchestrates DSE jobs across preparation workers
//! (case-table construction — CPU-bound Rust) and a dedicated evaluator
//! thread owning the PJRT executable (which is not `Send`), with bounded
//! channels for backpressure and a metrics sink.
//!
//! ```text
//!   jobs ──> [prep worker]──┐
//!   jobs ──> [prep worker]──┼──(bounded queue)──> [eval thread: PJRT] ──> results
//!   jobs ──> [prep worker]──┘       (or scalar eval inline per worker)
//! ```

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cache::SharedStore;
use crate::dse::engine::{build_case_table_cached, CaseTable, DesignPoint};
use crate::dse::space::DesignSpace;
use crate::dse::strategy::PairBatch;
use crate::engine::analysis::Analyzer;
use crate::ir::dataflow::Dataflow;
use crate::model::layer::Layer;
use crate::model::network::Network;
use crate::runtime::{evaluate_scalar, BatchEvaluator, DesignIn, EvalOut, D_MAX};
// Re-exported where it was proven: the prep workers below and the
// sharded DSE sweep share this bounded-queue idiom.
pub use crate::util::queue::JobQueue;

/// Which evaluation backend executes design batches.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Pure-Rust scalar evaluation (always available).
    Scalar,
    /// The AOT-compiled PJRT artifact at this path.
    Pjrt(std::path::PathBuf),
}

/// One DSE job: a whole-network workload + mapping variant + PE count,
/// with the design points (bandwidth/latency/buffers) to evaluate.
/// Single-layer workloads wrap with [`Network::single`].
#[derive(Debug, Clone)]
pub struct DseJob {
    pub id: u64,
    pub network: Network,
    pub variant: Dataflow,
    pub pes: u64,
    pub designs: Vec<DesignIn>,
    pub noc_hops: u64,
    pub area_budget: f64,
    pub power_budget: f64,
}

/// A finished job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub dataflow: String,
    pub pes: u64,
    /// Parallel to the job's `designs`; empty when the (variant, pes)
    /// pair is unmappable.
    pub outputs: Vec<(DesignIn, EvalOut)>,
    pub macs: f64,
}

impl JobResult {
    /// Convert to flat design points.
    pub fn points(&self) -> Vec<DesignPoint> {
        self.outputs
            .iter()
            .map(|(d, o)| DesignPoint {
                dataflow: self.dataflow.clone(),
                pes: self.pes,
                bandwidth: d.bandwidth as u64,
                l1: d.l1 as u64,
                l2: d.l2 as u64,
                runtime: o.runtime,
                energy_pj: o.energy_pj,
                area_mm2: o.area_mm2,
                power_mw: o.power_mw,
                valid: o.valid,
            })
            .collect()
    }
}

/// Run metrics (designs/second is the paper's headline DSE number).
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_done: AtomicUsize,
    pub jobs_skipped: AtomicUsize,
    pub designs_evaluated: AtomicU64,
    pub prep_nanos: AtomicU64,
    pub eval_nanos: AtomicU64,
}

impl Metrics {
    pub fn summary(&self, wall_seconds: f64) -> String {
        let d = self.designs_evaluated.load(Ordering::Relaxed);
        format!(
            "jobs={} skipped={} designs={} rate={:.0}/s prep={:.2}s eval={:.2}s wall={wall_seconds:.2}s",
            self.jobs_done.load(Ordering::Relaxed),
            self.jobs_skipped.load(Ordering::Relaxed),
            d,
            d as f64 / wall_seconds.max(1e-9),
            self.prep_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            self.eval_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }
}

/// Evaluate one prepared job through the PJRT artifact, chunking designs
/// into artifact-sized batches.
fn eval_with_pjrt(
    evaluator: &BatchEvaluator,
    job: &DseJob,
    table: &CaseTable,
) -> Result<Vec<EvalOut>> {
    let mut outs = Vec::with_capacity(job.designs.len());
    for chunk in job.designs.chunks(D_MAX) {
        let o = evaluator.evaluate(table, chunk, job.noc_hops, job.area_budget, job.power_budget)?;
        outs.extend(o);
    }
    Ok(outs)
}

/// Turn strategy batches ([`PairBatch`], e.g. from
/// [`crate::dse::strategy::plan_single_wave`]) into coordinator jobs:
/// one job per batch, one design per batch bandwidth, with the "place
/// required buffers" sentinel (`l1`/`l2` = 0) so the prep worker sizes
/// L1/L2 from the case table — the coordinator's shards come from the
/// same candidate generation as the in-process sweep engine's.
pub fn jobs_from_batches(net: &Network, space: &DesignSpace, batches: &[PairBatch]) -> Vec<DseJob> {
    batches
        .iter()
        .enumerate()
        .map(|(i, batch)| {
            let (variant_idx, pes_idx) = space.pair_coords(batch.pair);
            DseJob {
                id: i as u64 + 1,
                network: net.clone(),
                variant: space.variants[variant_idx].clone(),
                pes: space.pes[pes_idx],
                designs: batch
                    .bws
                    .iter()
                    .map(|&bwi| DesignIn {
                        bandwidth: space.bandwidths[bwi] as f64,
                        latency: space.noc_latency as f64,
                        l1: 0.0,
                        l2: 0.0,
                    })
                    .collect(),
                noc_hops: space.noc_latency,
                area_budget: space.area_budget_mm2,
                power_budget: space.power_budget_mw,
            }
        })
        .collect()
}

/// Run a set of DSE jobs on `workers` preparation threads with the given
/// backend. Returns results (completion order) and the metrics.
pub fn run_jobs(
    jobs: Vec<DseJob>,
    backend: Backend,
    workers: usize,
) -> Result<(Vec<JobResult>, Arc<Metrics>)> {
    run_jobs_with_store(jobs, backend, workers, None)
}

/// [`run_jobs`] with an optional shared analysis cache: every prep
/// worker's [`Analyzer`] fronts the same [`SharedStore`], so duplicate
/// (shape, variant, hardware) triples across jobs — and entries
/// pre-warmed from a `--cache-file` — replay instead of re-analyzing.
/// `None` keeps the PR 2 per-worker private caches (cleared per job to
/// bound memory). Results are identical either way: cached values are
/// pure functions of their keys.
pub fn run_jobs_with_store(
    jobs: Vec<DseJob>,
    backend: Backend,
    workers: usize,
    cache: Option<Arc<SharedStore>>,
) -> Result<(Vec<JobResult>, Arc<Metrics>)> {
    let metrics = Arc::new(Metrics::default());
    let workers = workers.max(1);
    let n_jobs = jobs.len();
    let use_pjrt = matches!(backend, Backend::Pjrt(_));

    let (job_tx, job_queue) = JobQueue::<DseJob>::bounded(workers * 2);
    let (prep_tx, prep_rx) = sync_channel::<(DseJob, CaseTable)>(workers * 2);
    let (res_tx, res_rx) = sync_channel::<JobResult>(n_jobs.max(1));

    let results = std::thread::scope(|scope| -> Result<Vec<JobResult>> {
        // ---- Prep workers ------------------------------------------
        for _ in 0..workers {
            let queue = job_queue.clone();
            let prep_tx = prep_tx.clone();
            let res_tx = res_tx.clone();
            let metrics = Arc::clone(&metrics);
            let cache = cache.clone();
            scope.spawn(move || {
                // One Analyzer per prep worker: a job's repeated layer
                // shapes are analyzed once. With a private cache it is
                // cleared per job — keys include (variant, pes), so
                // cross-job hits only exist for duplicate jobs and
                // holding entries would grow memory with the job count
                // — while the scratch allocation amortizes across the
                // worker's life. With a shared store the clear is a
                // no-op: entries pool across workers and jobs (and
                // feed `--cache-file` persistence), which is exactly
                // where duplicate-job replays come from.
                let mut analyzer = match cache {
                    Some(store) => Analyzer::with_store(store),
                    None => Analyzer::new(),
                };
                loop {
                    let Some(job) = queue.pop() else { break };
                    analyzer.clear_cache();
                    let t0 = std::time::Instant::now();
                    let layer_refs: Vec<&Layer> = job.network.layers.iter().collect();
                    let table = build_case_table_cached(&mut analyzer, &layer_refs, &job.variant, job.pes);
                    metrics.prep_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    // Buffer placement (§5.2: "the DSE tool places the
                    // exact amount buffers MAESTRO reported"): a
                    // non-positive L1/L2 in a design is the "place
                    // required" sentinel.
                    let mut job = job;
                    if let Ok(t) = &table {
                        for d in &mut job.designs {
                            if d.l1 <= 0.0 {
                                d.l1 = t.l1_req.max(1) as f64;
                            }
                            if d.l2 <= 0.0 {
                                d.l2 = t.l2_req.max(1) as f64;
                            }
                        }
                    }
                    match table {
                        Ok(table) if use_pjrt => {
                            if prep_tx.send((job, table)).is_err() {
                                break;
                            }
                        }
                        Ok(table) => {
                            let t1 = std::time::Instant::now();
                            let outs = evaluate_scalar(
                                &table,
                                &job.designs,
                                job.noc_hops,
                                job.area_budget,
                                job.power_budget,
                            );
                            metrics.eval_nanos.fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            metrics.designs_evaluated.fetch_add(job.designs.len() as u64, Ordering::Relaxed);
                            metrics.jobs_done.fetch_add(1, Ordering::Relaxed);
                            let _ = res_tx.send(JobResult {
                                id: job.id,
                                dataflow: job.variant.name.clone(),
                                pes: job.pes,
                                outputs: job.designs.iter().copied().zip(outs).collect(),
                                macs: table.activity.macs,
                            });
                        }
                        Err(_) => {
                            metrics.jobs_skipped.fetch_add(1, Ordering::Relaxed);
                            let _ = res_tx.send(JobResult {
                                id: job.id,
                                dataflow: job.variant.name.clone(),
                                pes: job.pes,
                                outputs: Vec::new(),
                                macs: 0.0,
                            });
                        }
                    }
                }
            });
        }
        drop(prep_tx);

        // ---- Evaluator thread (owns the PJRT executable) -------------
        if let Backend::Pjrt(path) = backend.clone() {
            let res_tx = res_tx.clone();
            let metrics = Arc::clone(&metrics);
            scope.spawn(move || {
                let evaluator = match BatchEvaluator::load(&path) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("coordinator: PJRT load failed ({e:#}); dropping to scalar");
                        for (job, table) in prep_rx.iter() {
                            let outs = evaluate_scalar(
                                &table,
                                &job.designs,
                                job.noc_hops,
                                job.area_budget,
                                job.power_budget,
                            );
                            metrics.designs_evaluated.fetch_add(job.designs.len() as u64, Ordering::Relaxed);
                            metrics.jobs_done.fetch_add(1, Ordering::Relaxed);
                            let _ = res_tx.send(JobResult {
                                id: job.id,
                                dataflow: job.variant.name.clone(),
                                pes: job.pes,
                                outputs: job.designs.iter().copied().zip(outs).collect(),
                                macs: table.activity.macs,
                            });
                        }
                        return;
                    }
                };
                for (job, table) in prep_rx.iter() {
                    let t1 = std::time::Instant::now();
                    let outs = match eval_with_pjrt(&evaluator, &job, &table) {
                        Ok(o) => o,
                        Err(e) => {
                            eprintln!("coordinator: eval failed for job {}: {e:#}", job.id);
                            metrics.jobs_skipped.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    };
                    metrics.eval_nanos.fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    metrics.designs_evaluated.fetch_add(job.designs.len() as u64, Ordering::Relaxed);
                    metrics.jobs_done.fetch_add(1, Ordering::Relaxed);
                    let _ = res_tx.send(JobResult {
                        id: job.id,
                        dataflow: job.variant.name.clone(),
                        pes: job.pes,
                        outputs: job.designs.iter().copied().zip(outs).collect(),
                        macs: table.activity.macs,
                    });
                }
            });
        } else {
            drop(prep_rx);
        }
        drop(res_tx);

        // ---- Feed jobs ----------------------------------------------
        for job in jobs {
            job_tx.send(job).context("job queue closed")?;
        }
        drop(job_tx);

        // ---- Collect ---------------------------------------------------
        Ok(res_rx.iter().collect())
    })?;

    Ok((results, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::kc_p_ct;
    use crate::model::zoo::vgg16;

    fn designs() -> Vec<DesignIn> {
        [4u64, 16, 64]
            .iter()
            .map(|&bw| DesignIn { bandwidth: bw as f64, latency: 2.0, l1: 1024.0, l2: 200_000.0 })
            .collect()
    }

    fn jobs() -> Vec<DseJob> {
        let layer = vgg16::conv13();
        [64u64, 128, 256]
            .iter()
            .enumerate()
            .map(|(i, &pes)| DseJob {
                id: i as u64,
                network: Network::single(layer.clone()),
                variant: kc_p_ct(16),
                pes,
                designs: designs(),
                noc_hops: 2,
                area_budget: 16.0,
                power_budget: 450.0,
            })
            .collect()
    }

    #[test]
    fn scalar_backend_runs_jobs() {
        let (results, metrics) = run_jobs(jobs(), Backend::Scalar, 2).unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(metrics.designs_evaluated.load(Ordering::Relaxed), 9);
        for r in &results {
            assert_eq!(r.outputs.len(), 3);
            assert!(r.outputs.iter().all(|(_, o)| o.runtime > 0.0));
        }
    }

    #[test]
    fn unmappable_jobs_are_skipped_not_fatal() {
        let layer = vgg16::conv13();
        let job = DseJob {
            id: 9,
            network: Network::single(layer),
            variant: kc_p_ct(64),
            pes: 8, // cluster 64 > 8 PEs -> unmappable
            designs: designs(),
            noc_hops: 2,
            area_budget: 16.0,
            power_budget: 450.0,
        };
        let (results, metrics) = run_jobs(vec![job], Backend::Scalar, 1).unwrap();
        assert_eq!(results.len(), 1);
        assert!(results[0].outputs.is_empty());
        assert_eq!(metrics.jobs_skipped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shared_store_pools_across_duplicate_jobs() {
        // The same job set twice through one store: the second copies'
        // analyses must replay (store hits) and the outputs per job id
        // must be identical to the first copies'.
        let store = Arc::new(SharedStore::new());
        let mut doubled = jobs();
        doubled.extend(jobs());
        let (results, _m) =
            run_jobs_with_store(doubled, Backend::Scalar, 2, Some(Arc::clone(&store))).unwrap();
        assert_eq!(results.len(), 6);
        assert!(store.hits() > 0, "duplicate jobs must replay from the shared store");
        assert!(!store.is_empty());
        for id in 0..3u64 {
            let outs: Vec<_> = results.iter().filter(|r| r.id == id).collect();
            assert_eq!(outs.len(), 2);
            assert_eq!(outs[0].outputs, outs[1].outputs, "replayed job {id} must match");
        }
    }

    #[test]
    fn jobs_from_batches_mirror_the_strategy_plan() {
        use crate::dse::strategy::{plan_single_wave, SearchBudget, SearchStrategy};
        let space = crate::dse::space::DesignSpace::ci_smoke("kc-p");
        let net = Network::single(vgg16::conv13());
        let (batches, skipped) =
            plan_single_wave(&space, &SearchStrategy::Exhaustive, &SearchBudget::default()).unwrap();
        assert_eq!(skipped, 0);
        let jobs = jobs_from_batches(&net, &space, &batches);
        assert_eq!(jobs.len(), space.pairs());
        let total: usize = jobs.iter().map(|j| j.designs.len()).sum();
        assert_eq!(total as u64, space.size());
        for (job, batch) in jobs.iter().zip(&batches) {
            let (vi, pi) = space.pair_coords(batch.pair);
            assert_eq!(job.variant.name, space.variants[vi].name);
            assert_eq!(job.pes, space.pes[pi]);
            for (d, &bwi) in job.designs.iter().zip(&batch.bws) {
                assert_eq!(d.bandwidth, space.bandwidths[bwi] as f64);
                assert_eq!(d.l1, 0.0, "place-required-buffers sentinel");
            }
        }
        // A budgeted random plan flows through the same constructor.
        let (sampled, _) = plan_single_wave(
            &space,
            &SearchStrategy::RandomSample { seed: 3 },
            &SearchBudget { max_designs: 17, ..SearchBudget::default() },
        )
        .unwrap();
        let jobs = jobs_from_batches(&net, &space, &sampled);
        let total: usize = jobs.iter().map(|j| j.designs.len()).sum();
        assert_eq!(total, 17);
    }

    #[test]
    fn pjrt_backend_falls_back_when_artifact_missing() {
        let (results, _m) =
            run_jobs(jobs(), Backend::Pjrt("/nonexistent/dse.hlo.txt".into()), 2).unwrap();
        assert_eq!(results.len(), 3);
        for r in &results {
            assert_eq!(r.outputs.len(), 3);
        }
    }
}
