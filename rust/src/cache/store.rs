//! The shared concurrent analysis store: a sharded `RwLock` map from
//! [`CacheKey`] to analysis outcomes, safe to consult and populate from
//! any number of sweep shards / coordinator prep workers at once.
//!
//! # Why racing writers are benign
//!
//! Every value is a pure function of its key (pinned by the analysis
//! determinism tests), so two workers that miss the same key compute
//! bit-identical results; whichever insert lands first wins and the
//! loser's copy is dropped. No entry is ever mutated in place, so
//! readers can never observe a torn or stale value — the store needs no
//! cross-shard coordination beyond the per-shard lock.
//!
//! # Memory
//!
//! By default the store never evicts: that is what makes warm-start
//! persistence and cross-sweep reuse possible, and it means a
//! shared-store DSE sweep grows O((variant, PEs) pairs x unique
//! shapes) — every pair contributes its own keys, which is exactly the
//! growth the private caches' per-pair `clear_cache` avoids. Entries
//! are small (a [`LayerStats`] plus two short strings, ~300 bytes), so
//! zoo networks over CLI-scale spaces stay modest.
//!
//! For mapspace-scale sweeps, [`SharedStore::with_max_entries`] bounds
//! the store with **coarse per-shard second-chance (clock) eviction**
//! (the CLI's `--cache-cap`): each shard keeps its own insertion-order
//! queue, every hit sets the entry's referenced bit, and when the
//! shard fills the rotation pops queue-front entries — a referenced
//! entry has its bit cleared and goes to the back (its second chance),
//! an unreferenced one is evicted. Hot entries therefore survive cap
//! pressure that drops cold ones, at one atomic bit per hit — no
//! recency list to maintain under the read lock. Coarse on purpose —
//! the bound is enforced per shard (so the global cap is approximate,
//! up to the shard rounding), recency is one bit (not an exact LRU),
//! and an evicted entry that was never flushed is simply gone (a later
//! `flush` will not write it — combine `--cache-cap` with
//! `--cache-file` only when losing cold entries from the file is
//! acceptable). Results are unaffected either way: cached values are
//! pure functions of their keys, so an eviction only turns a future
//! hit into a recompute (the determinism tests in
//! `rust/tests/dse_parallel.rs` hold for any warmth, including
//! post-eviction).

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use anyhow::Result;

use crate::engine::analysis::LayerStats;

use super::key::CacheKey;
use super::persist;

/// One cached analysis outcome. Failures are first-class values: a
/// shape that cannot map under a dataflow is diagnosed once and the
/// diagnostic replays (re-attributed to the caller's layer/dataflow by
/// the `Analyzer`).
#[derive(Debug, Clone, PartialEq)]
pub enum CacheValue {
    Stats(LayerStats),
    Failure {
        /// Layer the diagnosis was produced on (error chains embed
        /// layer names; replays for same-shape siblings say so).
        layer: String,
        /// Dataflow *name* the diagnosis was produced under (the key
        /// only knows the structural fingerprint).
        dataflow: String,
        message: String,
    },
}

/// A successful lookup: the value plus whether the entry originated
/// from a cache file (drives the mem-hit vs disk-hit split).
#[derive(Debug, Clone)]
pub struct CacheHit {
    pub value: CacheValue,
    pub from_disk: bool,
}

#[derive(Debug)]
struct Slot {
    value: CacheValue,
    /// Entry came in via [`SharedStore::load`] (vs computed here).
    from_disk: bool,
    /// Second-chance bit: set on every hit (atomically, so the read
    /// lock suffices), consumed by the eviction rotation in
    /// [`SharedStore::insert_slot`]. Only meaningful on capped stores.
    referenced: std::sync::atomic::AtomicBool,
}

impl Slot {
    fn new(value: CacheValue, from_disk: bool) -> Slot {
        Slot { value, from_disk, referenced: std::sync::atomic::AtomicBool::new(false) }
    }
}

/// Result of [`SharedStore::load`]. Corruption never fails the load:
/// the valid prefix is kept, the bad tail dropped, and `warning` says
/// what happened.
#[derive(Debug, Default)]
pub struct LoadReport {
    /// Records inserted into the store.
    pub loaded: usize,
    /// Trailing bytes ignored as truncated/corrupt.
    pub dropped_bytes: u64,
    pub warning: Option<String>,
}

/// Result of [`SharedStore::flush`].
#[derive(Debug)]
pub struct FlushReport {
    /// Records written by this flush.
    pub written: usize,
    /// Entries in the store after the flush.
    pub total: usize,
}

/// Snapshot of a store's size and lifetime counters
/// ([`SharedStore::metrics`]) — the `serve` daemon's status payload.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreMetrics {
    pub entries: u64,
    /// Second-chance capacity cap (0 = unbounded).
    pub max_entries: u64,
    pub hits: u64,
    pub disk_hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// One lock shard: the key map plus (for capped stores) the clock
/// queue backing second-chance eviction.
#[derive(Debug, Default)]
struct Shard {
    map: HashMap<CacheKey, Slot>,
    /// The clock rotation order (insertion order until hits rotate
    /// entries to the back); maintained only when the store is capped.
    /// A key appears at most once (inserts are first-wins and eviction
    /// removes the map entry together with its queue slot).
    order: std::collections::VecDeque<CacheKey>,
}

/// The shared concurrent analysis cache. See the module docs for the
/// concurrency and memory story; see [`super::persist`] for the on-disk
/// format behind [`SharedStore::load`] / [`SharedStore::flush`].
pub struct SharedStore {
    shards: Vec<RwLock<Shard>>,
    /// Per-shard entry cap; 0 = unbounded (the default).
    shard_cap: usize,
    hits: AtomicU64,
    disk_hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// Serializes flushes from *this* store (the daemon's periodic
    /// flusher vs its shutdown flush); cross-process coordination is
    /// the read-diff-append protocol in [`SharedStore::flush`].
    flush_lock: Mutex<()>,
}

impl Default for SharedStore {
    fn default() -> SharedStore {
        SharedStore::new()
    }
}

impl std::fmt::Debug for SharedStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedStore")
            .field("entries", &self.len())
            .field("hits", &self.hits())
            .field("disk_hits", &self.disk_hits())
            .field("misses", &self.misses())
            .finish()
    }
}

impl SharedStore {
    /// A store with the default shard count (16 — enough that a worker
    /// pool rarely contends on one lock, few enough that iteration
    /// stays trivial).
    pub fn new() -> SharedStore {
        SharedStore::with_shards(16)
    }

    /// A store with `n` shards (rounded up to a power of two, min 1).
    pub fn with_shards(n: usize) -> SharedStore {
        SharedStore::build(n, 0)
    }

    /// A store bounded to roughly `max_entries` with coarse per-shard
    /// second-chance (clock) eviction (see the module docs for exactly
    /// how coarse). Small caps get fewer shards so the bound stays
    /// meaningful; the effective global bound is `shard count x
    /// per-shard cap`, within rounding of `max_entries`.
    pub fn with_max_entries(max_entries: usize) -> SharedStore {
        let max_entries = max_entries.max(1);
        // Largest power of two <= min(16, max_entries).
        let mut n_shards = 1usize;
        while n_shards * 2 <= max_entries.min(16) {
            n_shards *= 2;
        }
        SharedStore::build(n_shards, max_entries.div_ceil(n_shards))
    }

    fn build(n_shards: usize, shard_cap: usize) -> SharedStore {
        let n = n_shards.max(1).next_power_of_two();
        SharedStore {
            shards: (0..n).map(|_| RwLock::new(Shard::default())).collect(),
            shard_cap,
            hits: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            flush_lock: Mutex::new(()),
        }
    }

    /// The effective entry bound (0 = unbounded).
    pub fn max_entries(&self) -> usize {
        self.shard_cap * self.shards.len()
    }

    /// Insert a slot into a locked shard, running the second-chance
    /// rotation first when the shard is at its cap: a queue-front entry
    /// whose referenced bit is set gets the bit cleared and moves to
    /// the back; an unreferenced one is evicted. The rotation
    /// terminates — each bit is cleared at most once per pass, so after
    /// at most one full lap an unreferenced entry surfaces. Callers
    /// guarantee the key is vacant.
    fn insert_slot(&self, shard: &mut Shard, key: CacheKey, slot: Slot) {
        if self.shard_cap > 0 {
            while shard.map.len() >= self.shard_cap {
                let Some(front) = shard.order.pop_front() else { break };
                match shard.map.get(&front) {
                    Some(s) if s.referenced.swap(false, Ordering::Relaxed) => {
                        // Hit since it last reached the front: spared,
                        // rotated to the back.
                        shard.order.push_back(front);
                    }
                    Some(_) => {
                        shard.map.remove(&front);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    // Queue entry with no map entry cannot happen (they
                    // are maintained together), but tolerate it.
                    None => {}
                }
            }
            shard.order.push_back(key);
        }
        shard.map.insert(key, slot);
    }

    fn shard_of(&self, key: &CacheKey) -> usize {
        // Shard selection is in-memory only (load() re-inserts through
        // this same function), so it needs no cross-process stability —
        // hash the Copy key directly instead of serializing it, keeping
        // the hit path allocation-free.
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) & (self.shards.len() - 1)
    }

    /// Look up a key, counting the hit/miss (and its disk/mem origin).
    pub fn get(&self, key: &CacheKey) -> Option<CacheHit> {
        let shard = self.shards[self.shard_of(key)].read().unwrap();
        match shard.map.get(key) {
            Some(slot) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if slot.from_disk {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                }
                if self.shard_cap > 0 {
                    // Second chance: mark the entry hot so the next
                    // eviction rotation spares it once.
                    slot.referenced.store(true, Ordering::Relaxed);
                }
                Some(CacheHit { value: slot.value.clone(), from_disk: slot.from_disk })
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a freshly computed value. If the key is already present
    /// (a racing writer got there first, or the entry was loaded from
    /// disk) the existing slot is kept — values are pure functions of
    /// the key, so both copies are bit-identical and keeping the first
    /// preserves its origin/persistence flags.
    pub fn insert(&self, key: CacheKey, value: CacheValue) {
        let mut shard = self.shards[self.shard_of(&key)].write().unwrap();
        if shard.map.contains_key(&key) {
            return;
        }
        self.insert_slot(&mut shard, key, Slot::new(value, false));
    }

    /// Entries currently held.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate lookup counters (across every consumer of this store).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Hits served by entries that came from a cache file.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped by the second-chance cap (always 0 for
    /// unbounded stores).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// One coherent-enough snapshot of size + lifetime counters — what
    /// the `serve` daemon reports per status request and logs around
    /// flushes. Counters are independent relaxed atomics, so the fields
    /// are each exact but not mutually atomic under concurrent traffic.
    pub fn metrics(&self) -> StoreMetrics {
        StoreMetrics {
            entries: self.len() as u64,
            max_entries: self.max_entries() as u64,
            hits: self.hits(),
            disk_hits: self.disk_hits(),
            misses: self.misses(),
            evictions: self.evictions(),
        }
    }

    /// Drop every entry (counters and persistence bookkeeping survive).
    pub fn clear(&self) {
        for s in &self.shards {
            let mut shard = s.write().unwrap();
            shard.map.clear();
            shard.order.clear();
        }
    }

    /// Load a cache file into the store. Never fails: a missing file is
    /// a clean cold start, and a truncated or corrupt file contributes
    /// its valid record prefix with the bad tail dropped (see
    /// [`LoadReport::warning`]). Keys already in the store keep their
    /// in-memory value (it is bit-identical by construction).
    pub fn load(&self, path: &Path) -> LoadReport {
        let parsed = persist::read_file(path);
        let mut loaded = 0;
        for (key, value) in parsed.entries {
            let mut shard = self.shards[self.shard_of(&key)].write().unwrap();
            if shard.map.contains_key(&key) {
                // The key exists in memory AND in the file; values are
                // pure functions of keys, so the in-memory copy is
                // already what the file holds — keep it.
                continue;
            }
            // Loads respect the capacity cap too: a capped store
            // keeps roughly the newest `max_entries` records of the
            // file (entries hit since loading get their second
            // chance like any other).
            self.insert_slot(&mut shard, key, Slot::new(value, true));
            loaded += 1;
        }
        LoadReport { loaded, dropped_bytes: parsed.dropped_bytes, warning: parsed.warning }
    }

    /// Write the store to `path` as an append-only record log.
    ///
    /// The file is **re-read first** and only records it currently
    /// lacks are appended (after truncating any corrupt tail); a
    /// missing file gets a fresh write (header + every entry) via a
    /// per-process temporary sibling and an atomic rename.
    ///
    /// Computing dirtiness against the file's *current* contents —
    /// rather than against state remembered from an earlier load —
    /// makes concurrent writers union-safe: records another process
    /// (a second daemon, or a CLI run sharing the `--cache-file`)
    /// appended since this store last looked are left in place, and
    /// both sides converge on the union of their entries instead of
    /// last-writer-wins. There is no cross-process file lock, so an
    /// append that lands in the narrow window between this flush's
    /// re-read and its write can still be clipped — but the loser's
    /// next flush re-reads, finds its records missing, and re-appends
    /// them, so nothing is lost while either process keeps flushing.
    ///
    /// Records are written in sorted key order, so flushing the same
    /// contents always produces the same bytes.
    pub fn flush(&self, path: &Path) -> Result<FlushReport> {
        let _span = crate::obs::trace::span("cache.flush");
        // One flush of this store at a time — the daemon's periodic
        // flusher and its shutdown flush must not interleave their
        // read-diff-append sequences on the same file.
        let _guard = self.flush_lock.lock().unwrap();

        let parsed = persist::read_file(path);
        let on_disk: HashSet<CacheKey> = parsed.entries.iter().map(|(key, _)| *key).collect();

        // Snapshot the records the file lacks. An entry a racing
        // worker inserts mid-flush may miss this snapshot; the next
        // flush's re-read will not find it on disk and appends it then.
        let mut records: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
        for s in &self.shards {
            let shard = s.read().unwrap();
            for (key, slot) in shard.map.iter() {
                if on_disk.contains(key) {
                    continue;
                }
                records.push((key.to_bytes(), persist::encode_record(key, &slot.value)));
            }
        }
        records.sort_by(|a, b| a.0.cmp(&b.0));

        if path.exists() {
            persist::append_records(path, parsed.valid_len, records.iter().map(|(_, r)| r.as_slice()))?;
        } else {
            persist::write_fresh(path, records.iter().map(|(_, r)| r.as_slice()))?;
        }
        Ok(FlushReport { written: records.len(), total: self.len() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::config::HwConfig;
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    fn key_of(layer: &crate::model::layer::Layer, df: &crate::ir::dataflow::Dataflow) -> CacheKey {
        CacheKey::new(layer.shape_key(), df.fingerprint(), &HwConfig::fig10_default())
    }

    fn failure(tag: &str) -> CacheValue {
        CacheValue::Failure {
            layer: format!("layer-{tag}"),
            dataflow: "df".into(),
            message: format!("message-{tag}"),
        }
    }

    #[test]
    fn get_insert_roundtrip_with_counters() {
        let store = SharedStore::new();
        let k = key_of(&vgg16::conv2(), &styles::kc_p());
        assert!(store.get(&k).is_none());
        store.insert(k, failure("a"));
        let hit = store.get(&k).expect("inserted");
        assert_eq!(hit.value, failure("a"));
        assert!(!hit.from_disk);
        assert_eq!((store.hits(), store.misses(), store.disk_hits()), (1, 1, 0));
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn first_insert_wins() {
        let store = SharedStore::new();
        let k = key_of(&vgg16::conv2(), &styles::kc_p());
        store.insert(k, failure("first"));
        store.insert(k, failure("second"));
        assert_eq!(store.get(&k).unwrap().value, failure("first"));
        assert_eq!(store.len(), 1);
    }

    fn distinct_keys(n: u64) -> Vec<CacheKey> {
        // Vary K: every key gets a distinct ShapeKey.
        (1..=n)
            .map(|k| {
                let layer = crate::model::layer::Layer::conv2d("k", 1, k, 8, 16, 16, 3, 3, 1);
                key_of(&layer, &styles::kc_p())
            })
            .collect()
    }

    #[test]
    fn capped_store_evicts_cold_entries_and_stays_bounded() {
        let store = SharedStore::with_max_entries(8);
        assert_eq!(store.max_entries(), 8);
        let keys = distinct_keys(50);
        // A pure insert workload: nothing is ever hit, so no entry
        // earns a second chance and every overflow evicts exactly one.
        for (i, k) in keys.iter().enumerate() {
            store.insert(*k, failure(&i.to_string()));
        }
        assert!(store.len() <= store.max_entries(), "len {} over cap", store.len());
        assert_eq!(store.evictions() as usize, 50 - store.len(), "every overflow was evicted");
        // An evicted key is a clean miss and can be re-inserted.
        let evicted = keys.iter().find(|k| store.get(k).is_none()).expect("something was evicted");
        store.insert(*evicted, failure("again"));
        assert_eq!(store.get(evicted).unwrap().value, failure("again"));
        assert!(store.len() <= store.max_entries());
    }

    #[test]
    fn second_chance_keeps_a_rehit_entry_and_evicts_a_cold_one() {
        // One shard, cap 4, so the rotation order is fully observable.
        let store = SharedStore::build(1, 4);
        let keys = distinct_keys(5);
        for (i, k) in keys[..4].iter().enumerate() {
            store.insert(*k, failure(&i.to_string()));
        }
        // Re-hit the oldest entry: its referenced bit spares it from
        // the next rotation.
        assert!(store.get(&keys[0]).is_some());
        // Cap-pressure insert: the rotation pops keys[0] (referenced —
        // bit cleared, rotated to the back), then keys[1] (cold —
        // evicted).
        store.insert(keys[4], failure("4"));
        assert_eq!(store.len(), 4);
        assert_eq!(store.evictions(), 1);
        assert!(store.get(&keys[0]).is_some(), "the re-hit entry survived cap pressure");
        assert!(store.get(&keys[1]).is_none(), "the oldest cold entry was the one evicted");
        assert!(store.get(&keys[4]).is_some(), "the new entry landed");
    }

    #[test]
    fn unbounded_store_never_evicts() {
        let store = SharedStore::new();
        assert_eq!(store.max_entries(), 0);
        for (i, k) in distinct_keys(50).iter().enumerate() {
            store.insert(*k, failure(&i.to_string()));
        }
        assert_eq!(store.len(), 50);
        assert_eq!(store.evictions(), 0);
    }

    #[test]
    fn tiny_cap_uses_fewer_shards_for_a_meaningful_bound() {
        let store = SharedStore::with_max_entries(2);
        for (i, k) in distinct_keys(20).iter().enumerate() {
            store.insert(*k, failure(&i.to_string()));
        }
        assert!(store.len() <= store.max_entries());
        assert!(store.max_entries() <= 4, "a cap of 2 must not balloon to 16 shards");
    }

    #[test]
    fn concurrent_readers_and_writers_converge() {
        // Racing writers over one key set: every thread computes the
        // same pure value per key, so the surviving store must hold
        // exactly one value per key regardless of interleaving.
        let store = std::sync::Arc::new(SharedStore::with_shards(4));
        let layers = [vgg16::conv2(), vgg16::conv13()];
        let dfs = styles::all_styles();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let store = std::sync::Arc::clone(&store);
                let layers = &layers;
                let dfs = &dfs;
                scope.spawn(move || {
                    for _ in 0..50 {
                        for layer in layers {
                            for df in dfs {
                                let k = key_of(layer, df);
                                if store.get(&k).is_none() {
                                    store.insert(
                                        k,
                                        CacheValue::Failure {
                                            layer: layer.name.clone(),
                                            dataflow: df.name.clone(),
                                            message: format!("{}+{}", layer.name, df.name),
                                        },
                                    );
                                }
                            }
                        }
                    }
                });
            }
        });
        assert_eq!(store.len(), layers.len() * dfs.len());
        for layer in &layers {
            for df in &dfs {
                match store.get(&key_of(layer, df)).unwrap().value {
                    CacheValue::Failure { message, .. } => {
                        assert_eq!(message, format!("{}+{}", layer.name, df.name));
                    }
                    other => panic!("unexpected value {other:?}"),
                }
            }
        }
    }
}
