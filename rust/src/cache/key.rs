//! Cache identity: what makes two analyses "the same computation".
//!
//! Every analysis result in this crate is a pure function of three
//! inputs — a layer's canonical [`ShapeKey`], a dataflow's *structure*,
//! and the hardware configuration — so the cache key is exactly that
//! triple, with each component reduced to a stable, name-free value:
//!
//! * [`DataflowFingerprint`] — a 128-bit FNV-1a hash over the ordered
//!   directive list (map kinds, dims, sizes, offsets, and cluster
//!   structure). Names never enter the hash, so two hand-built
//!   dataflows that share a name but differ structurally get distinct
//!   keys (no aliasing), while differently-named but structurally
//!   identical dataflows share one entry. The encoding each directive
//!   feeds is tag-prefixed and fixed-width per field, so the byte
//!   stream is prefix-free: distinct directive lists cannot collide by
//!   concatenation.
//! * [`HwKey`] — the hardware config flattened to integers (floats via
//!   `to_bits`) with an exhaustive destructure, so adding a field to
//!   `HwConfig` fails to compile here instead of silently aliasing.
//! * [`ShapeKey`] — already canonical and name-independent
//!   (`model::layer`).
//!
//! The fingerprint is computed from the *unresolved* directives; that
//! is complete because resolution is itself a pure function of
//! (directives, layer shape, PE count) and the key already carries the
//! shape and the PE count (inside [`HwKey`]).

use crate::hw::config::{HwConfig, ReductionSupport};
use crate::ir::dataflow::Dataflow;
use crate::model::layer::ShapeKey;
use crate::util::stablehash::Fnv128;

/// Structural identity of a dataflow: a process-stable 128-bit hash of
/// its directive list. See the module docs for what it does and does
/// not capture. Construct via [`Dataflow::fingerprint`] or
/// [`DataflowFingerprint::of`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataflowFingerprint(u128);

impl DataflowFingerprint {
    /// Fingerprint a dataflow's structure (its name is ignored).
    pub fn of(df: &Dataflow) -> DataflowFingerprint {
        let mut h = Fnv128::new();
        for d in &df.directives {
            d.fingerprint_into(&mut h);
        }
        DataflowFingerprint(h.finish())
    }

    pub fn as_u128(&self) -> u128 {
        self.0
    }

    /// Rebuild from a persisted value (cache file records).
    pub fn from_u128(v: u128) -> DataflowFingerprint {
        DataflowFingerprint(v)
    }
}

impl std::fmt::Display for DataflowFingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

/// Cache identity of a hardware config (f64 fields via `to_bits` so the
/// key stays `Eq + Hash` and serializes exactly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HwKey {
    /// num_pes, l1_size, l2_size, noc_bandwidth, noc_latency,
    /// pe_throughput — in that order.
    pub scalars: [u64; 6],
    pub multicast: bool,
    pub reduction: u8,
    pub clock_bits: u64,
}

impl HwKey {
    pub fn of(hw: &HwConfig) -> HwKey {
        // Exhaustive destructuring (no `..` rest pattern): adding a
        // field to HwConfig must fail to compile here, not silently
        // alias cache keys and serve stale stats.
        let &HwConfig {
            num_pes,
            l1_size,
            l2_size,
            noc_bandwidth,
            noc_latency,
            multicast,
            reduction,
            pe_throughput,
            clock_ghz,
        } = hw;
        HwKey {
            scalars: [num_pes, l1_size, l2_size, noc_bandwidth, noc_latency, pe_throughput],
            multicast,
            reduction: match reduction {
                ReductionSupport::None => 0,
                ReductionSupport::Tree => 1,
                ReductionSupport::Forward => 2,
            },
            clock_bits: clock_ghz.to_bits(),
        }
    }
}

/// [`HwKey`] minus `noc_bandwidth`: the hardware identity of a
/// *bandwidth-invariant* analysis profile
/// ([`crate::engine::profile::ReuseProfile`]). Two configs that differ
/// only in NoC bandwidth share one profile — the bandwidth enters the
/// analysis only through `pipe_delay` replays at finalize time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HwProfileKey {
    /// num_pes, l1_size, l2_size, noc_latency, pe_throughput — in that
    /// order (the [`HwKey`] scalars minus noc_bandwidth).
    pub scalars: [u64; 5],
    pub multicast: bool,
    pub reduction: u8,
    pub clock_bits: u64,
}

impl HwProfileKey {
    pub fn of(hw: &HwConfig) -> HwProfileKey {
        // Exhaustive destructuring, like `HwKey::of`: a new HwConfig
        // field must fail to compile here, not silently alias profiles.
        // `noc_bandwidth` is named (not dropped through `..`) and then
        // deliberately discarded — its exclusion is the whole point.
        let &HwConfig {
            num_pes,
            l1_size,
            l2_size,
            noc_bandwidth,
            noc_latency,
            multicast,
            reduction,
            pe_throughput,
            clock_ghz,
        } = hw;
        let _ = noc_bandwidth; // bandwidth-invariant by construction
        HwProfileKey {
            scalars: [num_pes, l1_size, l2_size, noc_latency, pe_throughput],
            multicast,
            reduction: match reduction {
                ReductionSupport::None => 0,
                ReductionSupport::Tree => 1,
                ReductionSupport::Forward => 2,
            },
            clock_bits: clock_ghz.to_bits(),
        }
    }
}

/// Memoization key of a bandwidth-invariant [`ReuseProfile`]
/// (`crate::engine::profile`): the [`CacheKey`] triple with the
/// hardware reduced to [`HwProfileKey`]. Layered *under* the full-key
/// [`CacheKey`] store — profiles are in-memory per-Analyzer state and
/// never persist to disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ProfileKey {
    pub shape: ShapeKey,
    pub dataflow: DataflowFingerprint,
    pub hw: HwProfileKey,
}

impl ProfileKey {
    pub fn new(shape: ShapeKey, dataflow: DataflowFingerprint, hw: &HwConfig) -> ProfileKey {
        ProfileKey { shape, dataflow, hw: HwProfileKey::of(hw) }
    }
}

/// The full memoization key: canonical layer shape x structural
/// dataflow identity x hardware. Everything an analysis reads, nothing
/// it does not (names of layers and dataflows are diagnostics, not
/// identity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub shape: ShapeKey,
    pub dataflow: DataflowFingerprint,
    pub hw: HwKey,
}

impl CacheKey {
    pub fn new(shape: ShapeKey, dataflow: DataflowFingerprint, hw: &HwConfig) -> CacheKey {
        CacheKey { shape, dataflow, hw: HwKey::of(hw) }
    }

    /// Stable byte encoding: shard selection, record serialization, and
    /// deterministic flush ordering all read this.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(160);
        b.push(self.shape.op.tag());
        for v in [
            self.shape.n,
            self.shape.k,
            self.shape.c,
            self.shape.y,
            self.shape.x,
            self.shape.r,
            self.shape.s,
            self.shape.stride,
            self.shape.sparsity_bits(),
        ] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&self.dataflow.as_u128().to_le_bytes());
        for v in self.hw.scalars {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.push(self.hw.multicast as u8);
        b.push(self.hw.reduction);
        b.extend_from_slice(&self.hw.clock_bits.to_le_bytes());
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::styles;

    #[test]
    fn fingerprint_ignores_names() {
        let a = styles::kc_p();
        let mut b = a.clone();
        b.name = "renamed".into();
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn fingerprint_separates_structures() {
        // Every pair of built-in styles must fingerprint apart.
        let all = styles::all_styles();
        for (i, x) in all.iter().enumerate() {
            for y in &all[i + 1..] {
                assert_ne!(
                    x.fingerprint(),
                    y.fingerprint(),
                    "{} vs {} must not collide",
                    x.name,
                    y.name
                );
            }
        }
    }

    #[test]
    fn fingerprint_is_order_sensitive() {
        use crate::ir::dims::Dim;
        use crate::ir::directive::{Directive, Extent};
        let fwd = Dataflow::new(
            "fwd",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::temporal(Extent::lit(2), Extent::lit(2), Dim::C),
            ],
        );
        let rev = Dataflow::new(
            "rev",
            vec![
                Directive::temporal(Extent::lit(2), Extent::lit(2), Dim::C),
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
            ],
        );
        assert_ne!(fwd.fingerprint(), rev.fingerprint());
    }

    #[test]
    fn fingerprint_sees_extent_kind_and_cluster_structure() {
        use crate::ir::dims::Dim;
        use crate::ir::directive::{Directive, Extent};
        // Lit(3) vs Sz(R) (which may also resolve to 3) are distinct
        // structures: they adapt differently to other layers.
        let lit = Dataflow::new(
            "a",
            vec![Directive::temporal(Extent::lit(3), Extent::lit(1), Dim::Y)],
        );
        let sym = Dataflow::new(
            "a",
            vec![Directive::temporal(Extent::sz(Dim::R), Extent::lit(1), Dim::Y)],
        );
        assert_ne!(lit.fingerprint(), sym.fingerprint());

        let flat = Dataflow::new(
            "f",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::C),
            ],
        );
        let clustered = Dataflow::new(
            "f",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::cluster(Extent::lit(4)),
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::C),
            ],
        );
        assert_ne!(flat.fingerprint(), clustered.fingerprint());
    }

    #[test]
    fn hw_key_distinguishes_every_field() {
        let base = HwConfig::fig10_default();
        let k0 = HwKey::of(&base);
        let mut pes = base.clone();
        pes.num_pes += 1;
        assert_ne!(HwKey::of(&pes), k0);
        let mut mc = base.clone();
        mc.multicast = !mc.multicast;
        assert_ne!(HwKey::of(&mc), k0);
        let mut clk = base;
        clk.clock_ghz += 0.5;
        assert_ne!(HwKey::of(&clk), k0);
    }

    #[test]
    fn profile_key_ignores_bandwidth_only() {
        let base = HwConfig::fig10_default();
        let k0 = HwProfileKey::of(&base);
        // Bandwidth-only changes share one profile key...
        let mut bw = base.clone();
        bw.noc_bandwidth = 1;
        assert_eq!(HwProfileKey::of(&bw), k0);
        assert_ne!(HwKey::of(&bw), HwKey::of(&base));
        // ...while every other field still distinguishes.
        let mut pes = base.clone();
        pes.num_pes += 1;
        assert_ne!(HwProfileKey::of(&pes), k0);
        let mut lat = base.clone();
        lat.noc_latency += 1;
        assert_ne!(HwProfileKey::of(&lat), k0);
        let mut mc = base.clone();
        mc.multicast = !mc.multicast;
        assert_ne!(HwProfileKey::of(&mc), k0);
        let mut red = base.clone();
        red.reduction = ReductionSupport::None;
        assert_ne!(HwProfileKey::of(&red), k0);
        let mut clk = base;
        clk.clock_ghz += 0.5;
        assert_ne!(HwProfileKey::of(&clk), k0);
    }

    #[test]
    fn key_bytes_are_injective_over_components() {
        use crate::model::zoo::vgg16;
        let hw = HwConfig::fig10_default();
        let a = CacheKey::new(vgg16::conv2().shape_key(), styles::kc_p().fingerprint(), &hw);
        let b = CacheKey::new(vgg16::conv13().shape_key(), styles::kc_p().fingerprint(), &hw);
        let c = CacheKey::new(vgg16::conv2().shape_key(), styles::x_p().fingerprint(), &hw);
        assert_ne!(a.to_bytes(), b.to_bytes());
        assert_ne!(a.to_bytes(), c.to_bytes());
        assert_eq!(a.to_bytes(), a.to_bytes());
    }
}
