//! On-disk cache format: an append-only record log behind
//! [`SharedStore::load`](super::SharedStore::load) /
//! [`SharedStore::flush`](super::SharedStore::flush).
//!
//! ```text
//! file   := header record*
//! header := magic[8] format_version:u32le analysis_version:u32le
//! record := payload_len:u32le checksum:u64le payload[payload_len]
//! ```
//!
//! * `checksum` is FNV-1a 64 over the payload, so a torn append or a
//!   flipped bit invalidates exactly the records it touches.
//! * The payload is the [`CacheKey`] byte encoding followed by a
//!   tagged [`CacheValue`] (strings as `u32le` length + UTF-8, floats
//!   as `f64::to_bits` little-endian) — every field fixed-order and
//!   explicitly sized, so records written by one build parse bit-
//!   identically in another.
//! * Readers keep the longest valid record prefix: a bad header means
//!   a cold start, a bad tail is dropped (and truncated away by the
//!   next flush). Nothing in this module panics on foreign bytes.
//!
//! # Invalidation
//!
//! Cached values are functions of the key *and of the analysis
//! formulas*. [`ANALYSIS_VERSION`] is baked into the header; bump it in
//! the same commit as any change to `engine::analysis` /
//! `engine::reuse` / `engine::mapping` / `engine::noc` / `hw` outputs,
//! and every stale file self-invalidates into a cold start.

use std::fs::{self, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::engine::analysis::{EnergyBreakdown, LayerStats};
use crate::model::layer::{Op, ShapeKey};
use crate::util::stablehash::Fnv64;

use super::key::{CacheKey, DataflowFingerprint, HwKey};
use super::store::CacheValue;

/// File magic: "maestro cache" + a format generation letter.
pub const MAGIC: [u8; 8] = *b"MSTROCSA";
/// Bump on any change to the record encoding itself.
pub const FORMAT_VERSION: u32 = 1;
/// Bump whenever analysis outputs change for an unchanged key, so old
/// files are discarded instead of replaying stale numbers.
pub const ANALYSIS_VERSION: u32 = 1;

const HEADER_LEN: u64 = 16;
const FRAME_LEN: usize = 12; // payload_len + checksum
/// Sanity cap: no legitimate record (one LayerStats + short strings)
/// approaches this; a larger length field means corruption.
const MAX_PAYLOAD: u32 = 1 << 20;

fn header_bytes() -> [u8; HEADER_LEN as usize] {
    let mut h = [0u8; HEADER_LEN as usize];
    h[..8].copy_from_slice(&MAGIC);
    h[8..12].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&ANALYSIS_VERSION.to_le_bytes());
    h
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize one (key, value) pair as a framed record (frame + payload).
pub(crate) fn encode_record(key: &CacheKey, value: &CacheValue) -> Vec<u8> {
    let mut payload = key.to_bytes();
    match value {
        CacheValue::Stats(s) => {
            payload.push(0);
            put_str(&mut payload, &s.layer);
            put_str(&mut payload, &s.dataflow);
            for v in [s.runtime, s.macs, s.util] {
                put_f64(&mut payload, v);
            }
            for v in s.l2_reads {
                put_f64(&mut payload, v);
            }
            for v in s.l2_writes {
                put_f64(&mut payload, v);
            }
            for v in [s.l1_fills, s.l1_reads, s.l1_writes, s.noc_delivered, s.peak_bw_need] {
                put_f64(&mut payload, v);
            }
            put_u64(&mut payload, s.l1_req);
            put_u64(&mut payload, s.l2_req);
            for v in [s.energy.mac, s.energy.l1, s.energy.l2, s.energy.noc] {
                put_f64(&mut payload, v);
            }
        }
        CacheValue::Failure { layer, dataflow, message } => {
            payload.push(1);
            put_str(&mut payload, layer);
            put_str(&mut payload, dataflow);
            put_str(&mut payload, message);
        }
    }
    let mut rec = Vec::with_capacity(FRAME_LEN + payload.len());
    rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    rec.extend_from_slice(&Fnv64::hash(&payload).to_le_bytes());
    rec.extend_from_slice(&payload);
    rec
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// Bounds-checked little-endian cursor; every read is `Option` so a
/// short or garbled payload unwinds into "drop the tail", never a
/// panic.
struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(data: &'a [u8]) -> Cursor<'a> {
        Cursor { data, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        if end > self.data.len() {
            return None;
        }
        let s = &self.data[self.pos..end];
        self.pos = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn u128(&mut self) -> Option<u128> {
        Some(u128::from_le_bytes(self.take(16)?.try_into().ok()?))
    }

    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Option<String> {
        let len = self.u32()? as usize;
        if len > MAX_PAYLOAD as usize {
            return None;
        }
        String::from_utf8(self.take(len)?.to_vec()).ok()
    }

    fn done(&self) -> bool {
        self.pos == self.data.len()
    }
}

fn decode_key(c: &mut Cursor) -> Option<CacheKey> {
    let op = Op::from_tag(c.u8()?)?;
    let n = c.u64()?;
    let k = c.u64()?;
    let ch = c.u64()?;
    let y = c.u64()?;
    let x = c.u64()?;
    let r = c.u64()?;
    let s = c.u64()?;
    let stride = c.u64()?;
    let sparsity_bits = c.u64()?;
    let shape = ShapeKey::from_raw(op, [n, k, ch, y, x, r, s], stride, sparsity_bits);
    let dataflow = DataflowFingerprint::from_u128(c.u128()?);
    let mut scalars = [0u64; 6];
    for slot in &mut scalars {
        *slot = c.u64()?;
    }
    let multicast = match c.u8()? {
        0 => false,
        1 => true,
        _ => return None,
    };
    let reduction = c.u8()?;
    if reduction > 2 {
        return None;
    }
    let clock_bits = c.u64()?;
    Some(CacheKey { shape, dataflow, hw: HwKey { scalars, multicast, reduction, clock_bits } })
}

fn decode_value(c: &mut Cursor) -> Option<CacheValue> {
    match c.u8()? {
        0 => {
            let layer = c.string()?;
            let dataflow = c.string()?;
            let runtime = c.f64()?;
            let macs = c.f64()?;
            let util = c.f64()?;
            let l2_reads = [c.f64()?, c.f64()?, c.f64()?];
            let l2_writes = [c.f64()?, c.f64()?, c.f64()?];
            let l1_fills = c.f64()?;
            let l1_reads = c.f64()?;
            let l1_writes = c.f64()?;
            let noc_delivered = c.f64()?;
            let peak_bw_need = c.f64()?;
            let l1_req = c.u64()?;
            let l2_req = c.u64()?;
            let energy = EnergyBreakdown { mac: c.f64()?, l1: c.f64()?, l2: c.f64()?, noc: c.f64()? };
            Some(CacheValue::Stats(LayerStats {
                layer,
                dataflow,
                runtime,
                macs,
                util,
                l2_reads,
                l2_writes,
                l1_fills,
                l1_reads,
                l1_writes,
                noc_delivered,
                l1_req,
                l2_req,
                peak_bw_need,
                energy,
            }))
        }
        1 => {
            let layer = c.string()?;
            let dataflow = c.string()?;
            let message = c.string()?;
            Some(CacheValue::Failure { layer, dataflow, message })
        }
        _ => None,
    }
}

fn decode_payload(payload: &[u8]) -> Option<(CacheKey, CacheValue)> {
    let mut c = Cursor::new(payload);
    let key = decode_key(&mut c)?;
    let value = decode_value(&mut c)?;
    if !c.done() {
        // Trailing bytes mean a framing/version confusion — reject the
        // record rather than trusting a partial parse.
        return None;
    }
    Some((key, value))
}

// ---------------------------------------------------------------------
// File I/O
// ---------------------------------------------------------------------

/// What a read of a cache file yields: the decodable entries, the byte
/// length of the valid prefix (header + intact records), how much tail
/// was dropped, and a human-readable warning when anything was wrong.
pub(crate) struct ParsedFile {
    pub entries: Vec<(CacheKey, CacheValue)>,
    pub valid_len: u64,
    pub dropped_bytes: u64,
    pub warning: Option<String>,
}

impl ParsedFile {
    fn cold(warning: Option<String>, dropped_bytes: u64) -> ParsedFile {
        ParsedFile { entries: Vec::new(), valid_len: 0, dropped_bytes, warning }
    }
}

/// Read and validate a cache file. Infallible by design: every failure
/// mode degrades to "fewer entries + a warning".
pub(crate) fn read_file(path: &Path) -> ParsedFile {
    let data = match fs::read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return ParsedFile::cold(None, 0),
        Err(e) => {
            return ParsedFile::cold(Some(format!("cache file {} unreadable ({e}); starting cold", path.display())), 0)
        }
    };
    if data.is_empty() {
        return ParsedFile::cold(None, 0);
    }
    if data.len() < HEADER_LEN as usize || data[..8] != MAGIC {
        return ParsedFile::cold(
            Some(format!("cache file {} has no valid header; starting cold", path.display())),
            data.len() as u64,
        );
    }
    let format = u32::from_le_bytes(data[8..12].try_into().unwrap());
    let analysis = u32::from_le_bytes(data[12..16].try_into().unwrap());
    if format != FORMAT_VERSION || analysis != ANALYSIS_VERSION {
        return ParsedFile::cold(
            Some(format!(
                "cache file {} is version {format}/{analysis} (want {FORMAT_VERSION}/{ANALYSIS_VERSION}); starting cold",
                path.display()
            )),
            data.len() as u64,
        );
    }

    let mut entries = Vec::new();
    let mut off = HEADER_LEN as usize;
    let mut warning = None;
    while off < data.len() {
        let Some(rest) = data.get(off..) else { break };
        if rest.len() < FRAME_LEN {
            warning = Some(format!("cache file {}: truncated record frame; dropping tail", path.display()));
            break;
        }
        let len = u32::from_le_bytes(rest[0..4].try_into().unwrap());
        let checksum = u64::from_le_bytes(rest[4..12].try_into().unwrap());
        if len > MAX_PAYLOAD {
            warning = Some(format!("cache file {}: implausible record length; dropping tail", path.display()));
            break;
        }
        let end = off + FRAME_LEN + len as usize;
        if end > data.len() {
            warning = Some(format!("cache file {}: truncated record payload; dropping tail", path.display()));
            break;
        }
        let payload = &data[off + FRAME_LEN..end];
        if Fnv64::hash(payload) != checksum {
            warning = Some(format!("cache file {}: record checksum mismatch; dropping tail", path.display()));
            break;
        }
        match decode_payload(payload) {
            Some(kv) => entries.push(kv),
            None => {
                warning = Some(format!("cache file {}: undecodable record; dropping tail", path.display()));
                break;
            }
        }
        off = end;
    }
    ParsedFile {
        entries,
        valid_len: off as u64,
        dropped_bytes: (data.len() - off) as u64,
        warning,
    }
}

/// Append records after the valid prefix of an existing file (the tail
/// beyond `valid_len` — corrupt by definition — is truncated first). If
/// the valid prefix does not even cover a header (e.g. the file was
/// empty), the header is rewritten. Returns the new valid length.
pub(crate) fn append_records<'a>(
    path: &Path,
    valid_len: u64,
    records: impl Iterator<Item = &'a [u8]>,
) -> Result<u64> {
    let mut f = OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("open cache file {}", path.display()))?;
    let mut base = valid_len;
    if base < HEADER_LEN {
        f.set_len(0)?;
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&header_bytes())?;
        base = HEADER_LEN;
    } else {
        f.set_len(base)?;
        f.seek(SeekFrom::Start(base))?;
    }
    let mut written = 0u64;
    for rec in records {
        f.write_all(rec)?;
        written += rec.len() as u64;
    }
    f.flush()?;
    Ok(base + written)
}

/// Result of [`compact_file`].
#[derive(Debug)]
pub struct CompactReport {
    /// Decodable records in the file before compaction (duplicates
    /// included).
    pub records_before: usize,
    /// Unique-key records written back.
    pub records_after: usize,
    /// Corrupt/truncated tail bytes dropped by the rewrite.
    pub dropped_bytes: u64,
    /// What, if anything, was wrong with the input file.
    pub warning: Option<String>,
}

/// Rewrite a cache file with unique keys: the append-only log tolerates
/// duplicate records across sessions (e.g. a store re-bound between
/// `--cache-file` paths flushes its full contents again), which wastes
/// bytes and load time. Compaction keeps the **first** record per key —
/// the same first-wins rule [`SharedStore::load`](super::SharedStore::load)
/// applies — sorts by key (the flush convention, so compacting the same
/// contents always produces the same bytes), drops any corrupt tail,
/// and rewrites atomically. Refuses to touch a nonempty file that is
/// not a compatible cache file (wrong magic/version): rewriting one
/// would destroy data this code cannot read.
pub fn compact_file(path: &Path) -> Result<CompactReport> {
    use anyhow::{bail, ensure};
    ensure!(path.exists(), "cache file {} does not exist", path.display());
    let parsed = read_file(path);
    let file_len = fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    if parsed.valid_len < HEADER_LEN && file_len > 0 {
        bail!(
            "{}",
            parsed.warning.clone().unwrap_or_else(|| format!(
                "{} is not a compatible cache file; not rewritten",
                path.display()
            ))
        );
    }
    let records_before = parsed.entries.len();
    let mut seen = std::collections::HashSet::new();
    let mut records: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
    for (key, value) in &parsed.entries {
        if seen.insert(*key) {
            records.push((key.to_bytes(), encode_record(key, value)));
        }
    }
    records.sort_by(|a, b| a.0.cmp(&b.0));
    write_fresh(path, records.iter().map(|(_, r)| r.as_slice()))?;
    Ok(CompactReport {
        records_before,
        records_after: records.len(),
        dropped_bytes: parsed.dropped_bytes,
        warning: parsed.warning,
    })
}

/// Write a complete fresh file (header + records) via a temporary
/// sibling and an atomic rename, so readers never observe a half-
/// written file. The temp name carries the process id: two processes
/// fresh-writing the same path race only on the final rename (where
/// either complete file is a valid outcome), never on the temp bytes.
pub(crate) fn write_fresh<'a>(path: &Path, records: impl Iterator<Item = &'a [u8]>) -> Result<()> {
    let mut bytes = header_bytes().to_vec();
    for rec in records {
        bytes.extend_from_slice(rec);
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(format!(".tmp.{}", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    fs::write(&tmp, &bytes).with_context(|| format!("write cache file {}", tmp.display()))?;
    fs::rename(&tmp, path).with_context(|| format!("rename cache file into {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::analysis::analyze_layer;
    use crate::hw::config::HwConfig;
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    fn sample() -> (CacheKey, CacheValue) {
        let layer = vgg16::conv2();
        let df = styles::kc_p();
        let hw = HwConfig::fig10_default();
        let stats = analyze_layer(&layer, &df, &hw).unwrap();
        (CacheKey::new(layer.shape_key(), df.fingerprint(), &hw), CacheValue::Stats(stats))
    }

    #[test]
    fn record_roundtrip_is_bit_exact() {
        let (key, value) = sample();
        let rec = encode_record(&key, &value);
        let (got_key, got_value) = decode_payload(&rec[FRAME_LEN..]).expect("decodes");
        assert_eq!(got_key, key);
        assert_eq!(got_value, value);

        let failure = CacheValue::Failure {
            layer: "bad".into(),
            dataflow: "kc-p".into(),
            message: "cluster sizes exceed total PEs".into(),
        };
        let rec = encode_record(&key, &failure);
        let (_, got) = decode_payload(&rec[FRAME_LEN..]).expect("decodes");
        assert_eq!(got, failure);
    }

    #[test]
    fn flipped_payload_bit_fails_checksum() {
        let (key, value) = sample();
        let mut rec = encode_record(&key, &value);
        let last = rec.len() - 1;
        rec[last] ^= 0x40;
        let len = u32::from_le_bytes(rec[0..4].try_into().unwrap());
        let checksum = u64::from_le_bytes(rec[4..12].try_into().unwrap());
        assert_eq!(len as usize, rec.len() - FRAME_LEN);
        assert_ne!(Fnv64::hash(&rec[FRAME_LEN..]), checksum);
    }

    #[test]
    fn decoder_survives_arbitrary_truncation() {
        // Every proper prefix of a valid payload must decode to None,
        // never panic.
        let (key, value) = sample();
        let rec = encode_record(&key, &value);
        let payload = &rec[FRAME_LEN..];
        for cut in 0..payload.len() {
            assert!(decode_payload(&payload[..cut]).is_none(), "prefix of {cut} bytes must not decode");
        }
        assert!(decode_payload(payload).is_some());
    }
}
