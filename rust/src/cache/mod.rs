//! The analysis cache subsystem: structural identity, concurrent
//! sharing, and on-disk warm starts for `(shape, dataflow, hardware) ->
//! LayerStats` memoization.
//!
//! MAESTRO's headline result is the throughput of the cost model itself
//! (480M designs at 0.17M designs/s); the dominant lever behind that
//! rate is never evaluating the same `(shape, dataflow, hardware)`
//! triple twice. PR 2's `Analyzer` proved the lever within one thread
//! and one process; this module promotes it to a subsystem with three
//! layers:
//!
//! * **Identity** ([`key`]) — [`DataflowFingerprint`] replaces the
//!   dataflow *name* in every cache key: a stable 128-bit structural
//!   hash over the ordered directive list, so hand-built same-name
//!   dataflows can no longer alias and identical structures under
//!   different names share one entry. Names survive only as
//!   diagnostics. [`HwKey`] and `ShapeKey` complete the triple.
//! * **Sharing** ([`store`]) — [`SharedStore`], a sharded-`RwLock`
//!   concurrent map that DSE sweep shards and coordinator prep workers
//!   consult and populate together. Values are pure functions of their
//!   keys, so racing writers are benign and the sweep's bit-identical
//!   deterministic merge is untouched (pinned in
//!   `rust/tests/dse_parallel.rs`).
//! * **Persistence** ([`persist`]) — an append-only, checksummed,
//!   corrupt-tail-tolerant record log behind [`SharedStore::load`] /
//!   [`SharedStore::flush`], wired through the `network`/`dse` CLI
//!   `--cache-file` flags so repeated runs on zoo networks start warm
//!   (hits split into mem vs disk everywhere they surface). Duplicate
//!   records accumulated across sessions are tolerated on load and
//!   reclaimed by [`compact_file`] (`maestro cache compact`).
//!   Concurrent writers sharing one path — two daemons, or a daemon
//!   plus a CLI run — are union-safe: every flush re-reads the file
//!   and appends only records it lacks (so nobody truncates away
//!   another process's appends), and fresh writes stage through
//!   per-process temp names before their atomic rename. See
//!   [`SharedStore::flush`] for the exact guarantee and its one
//!   narrow (self-healing) race window.
//!
//! Consumers rarely touch this module directly: construct an
//! [`crate::engine::analysis::Analyzer`] over a store with
//! `Analyzer::with_store`, or hand a store to
//! [`crate::dse::SweepConfig::cache`] / the coordinator's
//! `run_jobs_with_store`.

pub mod key;
pub mod persist;
pub mod store;

pub use key::{CacheKey, DataflowFingerprint, HwKey, HwProfileKey, ProfileKey};
pub use persist::{compact_file, CompactReport};
pub use store::{CacheHit, CacheValue, FlushReport, LoadReport, SharedStore, StoreMetrics};
