//! ResNeXt-50 (32x4d) (Xie et al., CVPR'17): aggregated residual blocks.
//!
//! The 32-branch grouped 3x3 conv is expressed the way Table 4 lists it —
//! "more data parallelism via branching structure": each group is a
//! separate conv layer with C = K = width/32, followed by concatenation
//! (data movement only) and the residual add. To keep layer counts
//! tractable we emit one representative group layer plus a `groups`
//! repetition via batching the N dimension of that layer — MACs and data
//! volumes are identical to materializing 32 copies.

use crate::model::layer::Layer;
use crate::model::network::Network;

fn block(layers: &mut Vec<Layer>, stage: &str, idx: usize, in_c: u64, width: u64, out_c: u64, hw_in: u64, stride: u64) -> u64 {
    let p = format!("{stage}_{idx}");
    let hw_out = hw_in / stride;
    let group_w = width / 32;
    layers.push(Layer::conv2d(&format!("{p}_pw1"), 1, width, in_c, hw_in, hw_in, 1, 1, 1));
    // Grouped conv: 32 groups of (group_w -> group_w); batch the groups on N.
    layers.push(Layer::conv2d(&format!("{p}_gconv3"), 32, group_w, group_w, hw_in + 2, hw_in + 2, 3, 3, stride));
    layers.push(Layer::conv2d(&format!("{p}_pw2"), 1, out_c, width, hw_out, hw_out, 1, 1, 1));
    layers.push(Layer::residual(&format!("{p}_add"), 1, out_c, hw_out, hw_out));
    hw_out
}

/// ResNeXt-50 32x4d.
pub fn network() -> Network {
    let mut layers = Vec::new();
    layers.push(Layer::conv2d("conv1", 1, 64, 3, 230, 230, 7, 7, 2));
    layers.push(Layer::pooling("pool1", 1, 64, 113, 113, 3, 2));
    let stages: [(&str, usize, u64, u64, u64, u64); 4] = [
        ("conv2", 3, 64, 128, 256, 56),
        ("conv3", 4, 256, 256, 512, 56),
        ("conv4", 6, 512, 512, 1024, 28),
        ("conv5", 3, 1024, 1024, 2048, 14),
    ];
    for (name, blocks, first_in, width, out, hw) in stages {
        let mut hw_cur = hw;
        let mut in_c = first_in;
        for b in 0..blocks {
            let stride = if b == 0 && name != "conv2" { 2 } else { 1 };
            hw_cur = block(&mut layers, name, b + 1, in_c, width, out, hw_cur, stride);
            in_c = out;
        }
    }
    layers.push(Layer::fully_connected("fc1000", 1, 1000, 2048));
    Network::new("resnext50", layers)
}

/// The DWCONV exemplar of Fig 11 ("DWCONV of CONV2 in ResNeXt50") — the
/// grouped conv of the first conv2 block (group width 4, the closest
/// depthwise-like operator in ResNeXt).
pub fn conv2_grouped() -> Layer {
    network()
        .layers
        .iter()
        .find(|l| l.name == "conv2_1_gconv3")
        .expect("conv2_1_gconv3 present")
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_conv_shape() {
        let l = conv2_grouped();
        assert_eq!(l.n, 32);
        assert_eq!(l.c, 4);
        assert_eq!(l.k, 4);
    }

    #[test]
    fn macs_magnitude() {
        // ResNeXt-50 ~4.2 GMACs.
        let g = network().macs() as f64 / 1e9;
        assert!((3.0..5.5).contains(&g), "resnext50 GMACs = {g}");
    }
}
