//! Model zoo: the networks used in the paper's evaluation (§5, Fig 9-13).
//!
//! Shapes use batch 1 and the standard ImageNet-era configurations; each
//! network file documents its source. `Y`/`X` are input extents with the
//! original padding folded in (input-centric convention: a padded 3x3/s1
//! conv over a 56x56 map is recorded as Y = X = 58 so that Y' = 56 —
//! MAESTRO models data movement, and the padded halo is data that is
//! staged like any other).

pub mod alexnet;
pub mod dcgan;
pub mod mobilenet_v2;
pub mod resnet50;
pub mod resnext50;
pub mod unet;
pub mod vgg16;

use anyhow::{bail, Result};

use crate::model::network::Network;

/// Look a zoo network up by name.
pub fn by_name(name: &str) -> Result<Network> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "vgg16" => vgg16::network(),
        "vgg16-conv" => vgg16::conv_only(),
        "alexnet" => alexnet::network(),
        "resnet50" => resnet50::network(),
        "resnext50" => resnext50::network(),
        "mobilenetv2" | "mobilenet_v2" => mobilenet_v2::network(),
        "unet" => unet::network(),
        "dcgan" => dcgan::network(),
        other => bail!("unknown zoo network '{other}' (try vgg16, alexnet, resnet50, resnext50, mobilenetv2, unet, dcgan)"),
    })
}

/// All zoo names (for CLI help and audit tests).
pub const ALL: [&str; 7] = [
    "vgg16", "alexnet", "resnet50", "resnext50", "mobilenetv2", "unet", "dcgan",
];

/// The five models of Fig 10.
pub const FIG10_MODELS: [&str; 5] = ["resnet50", "vgg16", "resnext50", "mobilenetv2", "unet"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_networks_validate() {
        for name in ALL {
            let n = by_name(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            n.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!n.layers.is_empty(), "{name} empty");
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(by_name("lenet-9000").is_err());
    }

    #[test]
    fn vgg16_macs_magnitude() {
        // VGG16 conv stack is ~15.3 GMACs at 224x224; accept 14-17 G.
        let n = by_name("vgg16-conv").unwrap();
        let g = n.macs() as f64 / 1e9;
        assert!((14.0..17.0).contains(&g), "vgg16 conv GMACs = {g}");
    }

    #[test]
    fn alexnet_macs_magnitude() {
        // AlexNet conv stack ~0.66 GMACs (single-GPU variant ~1.07); accept 0.5-1.3 G.
        let n = by_name("alexnet").unwrap();
        let conv_macs: u64 = n
            .layers
            .iter()
            .filter(|l| matches!(l.op, crate::model::layer::Op::Conv2d | crate::model::layer::Op::PointwiseConv))
            .map(|l| l.macs())
            .sum();
        let g = conv_macs as f64 / 1e9;
        assert!((0.5..1.3).contains(&g), "alexnet conv GMACs = {g}");
    }

    #[test]
    fn mobilenet_has_depthwise_and_pointwise() {
        let n = by_name("mobilenetv2").unwrap();
        use crate::model::layer::OpClass;
        assert!(!n.layers_of(OpClass::Depthwise).is_empty());
        assert!(!n.layers_of(OpClass::Pointwise).is_empty());
    }

    #[test]
    fn unet_has_transposed() {
        let n = by_name("unet").unwrap();
        use crate::model::layer::OpClass;
        assert!(!n.layers_of(OpClass::Transposed).is_empty());
    }

    #[test]
    fn resnet_residual_links_present() {
        let n = by_name("resnet50").unwrap();
        use crate::model::layer::OpClass;
        assert!(!n.layers_of(OpClass::Residual).is_empty());
    }
}
