//! DCGAN generator (Radford et al., 2015) — Table 4's other transposed-
//! convolution exemplar: project + four fractionally-strided convs
//! 4x4 kernels, doubling spatial extent 4 -> 64.

use crate::model::layer::Layer;
use crate::model::network::Network;

/// DCGAN generator for 64x64 output.
pub fn network() -> Network {
    let layers = vec![
        // Project z(100) -> 4x4x1024 as an FC.
        Layer::fully_connected("project", 1, 1024 * 4 * 4, 100),
        Layer::transposed_conv("tconv1", 1, 512, 1024, 4, 4, 4, 4, 2),
        Layer::transposed_conv("tconv2", 1, 256, 512, 8, 8, 4, 4, 2),
        Layer::transposed_conv("tconv3", 1, 128, 256, 16, 16, 4, 4, 2),
        Layer::transposed_conv("tconv4", 1, 3, 128, 32, 32, 4, 4, 2),
    ];
    Network::new("dcgan", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_tconvs() {
        let n = network();
        use crate::model::layer::Op;
        assert_eq!(n.layers.iter().filter(|l| l.op == Op::TransposedConv).count(), 4);
    }

    #[test]
    fn upsampled_extents() {
        let n = network();
        let t1 = n.layers.iter().find(|l| l.name == "tconv1").unwrap();
        assert_eq!(t1.y, 8); // 4 * up(2)
    }
}
