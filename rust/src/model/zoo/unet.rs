//! U-Net (Ronneberger et al., MICCAI'15): the 572x572 biomedical
//! segmentation network — wide shallow activations, contracting path,
//! and transposed-conv up-path (Table 4's TRCONV exemplar).

use crate::model::layer::Layer;
use crate::model::network::Network;

/// Classic U-Net (valid convs, 572x572 input).
pub fn network() -> Network {
    let mut layers = Vec::new();
    // Contracting path: double 3x3 valid convs, then 2x2 maxpool.
    let down: [(u64, u64, u64); 5] = [
        // (in_c, out_c, input hw)
        (1, 64, 572),
        (64, 128, 284),
        (128, 256, 140),
        (256, 512, 68),
        (512, 1024, 32),
    ];
    for (i, (in_c, out_c, hw)) in down.iter().enumerate() {
        let lvl = i + 1;
        layers.push(Layer::conv2d(&format!("down{lvl}_conv1"), 1, *out_c, *in_c, *hw, *hw, 3, 3, 1));
        layers.push(Layer::conv2d(&format!("down{lvl}_conv2"), 1, *out_c, *out_c, hw - 2, hw - 2, 3, 3, 1));
        if lvl < 5 {
            layers.push(Layer::pooling(&format!("pool{lvl}"), 1, *out_c, hw - 4, hw - 4, 2, 2));
        }
    }
    // Expanding path: 2x2 up-conv (transposed), concat, double 3x3 convs.
    let up: [(u64, u64, u64); 4] = [
        // (in_c, out_c, pre-upsample hw)
        (1024, 512, 28),
        (512, 256, 52),
        (256, 128, 100),
        (128, 64, 196),
    ];
    for (i, (in_c, out_c, hw)) in up.iter().enumerate() {
        let lvl = i + 1;
        layers.push(Layer::transposed_conv(&format!("up{lvl}_upconv"), 1, *out_c, *in_c, *hw, *hw, 2, 2, 2));
        let hw2 = hw * 2;
        // After concat, channels double.
        layers.push(Layer::conv2d(&format!("up{lvl}_conv1"), 1, *out_c, *in_c, hw2, hw2, 3, 3, 1));
        layers.push(Layer::conv2d(&format!("up{lvl}_conv2"), 1, *out_c, *out_c, hw2 - 2, hw2 - 2, 3, 3, 1));
    }
    // Final 1x1 conv to 2 classes.
    layers.push(Layer::conv2d("out_conv", 1, 2, 64, 388, 388, 1, 1, 1));
    Network::new("unet", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_is_572_wide() {
        let n = network();
        assert_eq!(n.layers[0].y, 572);
        // Output segmentation map is 388x388 in the classic config.
        let last = n.layers.last().unwrap();
        assert_eq!(last.y_out(), 388);
    }

    #[test]
    fn has_four_upconvs() {
        let n = network();
        let ups = n.layers.iter().filter(|l| l.name.contains("upconv")).count();
        assert_eq!(ups, 4);
    }

    #[test]
    fn macs_magnitude() {
        // U-Net 572x572 is heavy: ~170 GMACs dense (the up-path runs on
        // the upsampled grids).
        let g = network().macs() as f64 / 1e9;
        assert!((100.0..250.0).contains(&g), "unet GMACs = {g}");
    }
}
