//! VGG16 (Simonyan & Zisserman, ICLR'15) — configuration D.
//!
//! 13 3x3/s1 same-padded conv layers + 3 FC layers. Input-centric
//! convention: padded inputs (Y = out + 2) so Y' matches the published
//! output sizes.

use crate::model::layer::Layer;
use crate::model::network::Network;

/// Full VGG16: conv1_1 .. conv5_3 + fc6/fc7/fc8.
pub fn network() -> Network {
    let mut layers = conv_only().layers;
    layers.push(Layer::fully_connected("fc6", 1, 4096, 25088)); // 512*7*7
    layers.push(Layer::fully_connected("fc7", 1, 4096, 4096));
    layers.push(Layer::fully_connected("fc8", 1, 1000, 4096));
    Network::new("vgg16", layers)
}

/// The 13-layer conv stack (what Fig 9's MAERI validation and Fig 13's
/// DSE use).
pub fn conv_only() -> Network {
    // (name, K, C, out_hw): 3x3/s1 pad-1 conv => input extent out_hw + 2.
    let cfg: [(&str, u64, u64, u64); 13] = [
        ("conv1_1", 64, 3, 224),
        ("conv1_2", 64, 64, 224),
        ("conv2_1", 128, 64, 112),
        ("conv2_2", 128, 128, 112),
        ("conv3_1", 256, 128, 56),
        ("conv3_2", 256, 256, 56),
        ("conv3_3", 256, 256, 56),
        ("conv4_1", 512, 256, 28),
        ("conv4_2", 512, 512, 28),
        ("conv4_3", 512, 512, 28),
        ("conv5_1", 512, 512, 14),
        ("conv5_2", 512, 512, 14),
        ("conv5_3", 512, 512, 14),
    ];
    let layers = cfg
        .iter()
        .map(|&(name, k, c, out)| Layer::conv2d(name, 1, k, c, out + 2, out + 2, 3, 3, 1))
        .collect();
    Network::new("vgg16-conv", layers)
}

/// The early/late exemplar layers used throughout §5.2 (Fig 13, Table 5):
/// CONV2 (early: wide & shallow) and CONV13 (late: narrow & deep).
pub fn conv2() -> Layer {
    conv_only().layers[1].clone()
}
pub fn conv13() -> Layer {
    conv_only().layers[12].clone()
}
/// CONV11 — the intro's NVDLA-style DSE example layer.
pub fn conv11() -> Layer {
    conv_only().layers[10].clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_convs_three_fcs() {
        let n = network();
        assert_eq!(n.layers.len(), 16);
        assert_eq!(conv_only().layers.len(), 13);
    }

    #[test]
    fn output_sizes_match_published() {
        for l in conv_only().layers {
            // Same-padded 3x3/s1: Y' = Y - 2.
            assert_eq!(l.y_out(), l.y - 2, "{}", l.name);
        }
        assert_eq!(conv2().y_out(), 224);
        assert_eq!(conv13().y_out(), 14);
    }

    #[test]
    fn early_late_exemplars() {
        use crate::model::layer::OpClass;
        assert_eq!(conv2().class(), OpClass::ConvEarly);
        assert_eq!(conv13().class(), OpClass::ConvLate);
    }
}
