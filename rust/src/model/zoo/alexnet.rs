//! AlexNet (Krizhevsky et al., 2012) — single-tower variant used in the
//! Eyeriss papers (what Fig 9's Eyeriss validation runs).

use crate::model::layer::Layer;
use crate::model::network::Network;

/// AlexNet conv1-conv5 + fc6-fc8, batch 1.
pub fn network() -> Network {
    let layers = vec![
        // conv1: 96 x 3 x 11x11 / s4 over 227x227 -> 55x55.
        Layer::conv2d("conv1", 1, 96, 3, 227, 227, 11, 11, 4),
        // conv2: 256 x 96 x 5x5 / s1 pad 2 over 27x27 -> 27x27 (post-pool input 31).
        Layer::conv2d("conv2", 1, 256, 96, 31, 31, 5, 5, 1),
        // conv3: 384 x 256 x 3x3 / s1 pad 1 over 13x13 -> 13x13.
        Layer::conv2d("conv3", 1, 384, 256, 15, 15, 3, 3, 1),
        // conv4: 384 x 384 x 3x3 / s1 pad 1.
        Layer::conv2d("conv4", 1, 384, 384, 15, 15, 3, 3, 1),
        // conv5: 256 x 384 x 3x3 / s1 pad 1.
        Layer::conv2d("conv5", 1, 256, 384, 15, 15, 3, 3, 1),
        Layer::fully_connected("fc6", 1, 4096, 9216), // 256*6*6
        Layer::fully_connected("fc7", 1, 4096, 4096),
        Layer::fully_connected("fc8", 1, 1000, 4096),
    ];
    Network::new("alexnet", layers)
}

/// The conv stack only (Eyeriss reports conv-layer processing delay).
pub fn conv_only() -> Network {
    let mut n = network();
    n.layers.truncate(5);
    n.name = "alexnet-conv".into();
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1_output_is_55() {
        let l = &network().layers[0];
        assert_eq!(l.y_out(), 55);
        assert_eq!(l.x_out(), 55);
    }

    #[test]
    fn conv_stack_is_five() {
        assert_eq!(conv_only().layers.len(), 5);
    }
}
