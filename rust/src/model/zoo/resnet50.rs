//! ResNet-50 (He et al., CVPR'16): bottleneck blocks expressed as the
//! fine-grained operators of Table 4 (1x1 pointwise, 3x3 conv, 1x1
//! pointwise, residual add).

use crate::model::layer::Layer;
use crate::model::network::Network;

/// Append one bottleneck block: in_c -> mid_c (1x1) -> mid_c (3x3/stride)
/// -> out_c (1x1) + residual.
fn bottleneck(layers: &mut Vec<Layer>, stage: &str, idx: usize, in_c: u64, mid_c: u64, out_c: u64, hw_in: u64, stride: u64) -> u64 {
    let p = format!("{stage}_{idx}");
    let hw_out = hw_in / stride;
    layers.push(Layer::conv2d(&format!("{p}_pw1"), 1, mid_c, in_c, hw_in, hw_in, 1, 1, 1));
    // 3x3 pad-1: input extent hw_in + 2 so output = hw_in / stride.
    layers.push(Layer::conv2d(&format!("{p}_conv3"), 1, mid_c, mid_c, hw_in + 2, hw_in + 2, 3, 3, stride));
    layers.push(Layer::conv2d(&format!("{p}_pw2"), 1, out_c, mid_c, hw_out, hw_out, 1, 1, 1));
    layers.push(Layer::residual(&format!("{p}_add"), 1, out_c, hw_out, hw_out));
    hw_out
}

/// ResNet-50: conv1, 4 stages of [3, 4, 6, 3] bottlenecks, fc.
pub fn network() -> Network {
    let mut layers = Vec::new();
    // conv1: 7x7/s2 pad 3 over 224 -> 112 (input extent 224+6=230).
    layers.push(Layer::conv2d("conv1", 1, 64, 3, 230, 230, 7, 7, 2));
    // (after 3x3/s2 maxpool -> 56x56)
    layers.push(Layer::pooling("pool1", 1, 64, 113, 113, 3, 2));
    let stages: [(&str, usize, u64, u64, u64, u64); 4] = [
        // (name, blocks, in_c of first block, mid, out, input hw)
        ("conv2", 3, 64, 64, 256, 56),
        ("conv3", 4, 256, 128, 512, 56),
        ("conv4", 6, 512, 256, 1024, 28),
        ("conv5", 3, 1024, 512, 2048, 14),
    ];
    for (name, blocks, first_in, mid, out, hw) in stages {
        let mut hw_cur = hw;
        let mut in_c = first_in;
        for b in 0..blocks {
            // First block of conv3/4/5 downsamples.
            let stride = if b == 0 && name != "conv2" { 2 } else { 1 };
            hw_cur = bottleneck(&mut layers, name, b + 1, in_c, mid, out, hw_cur, stride);
            in_c = out;
        }
    }
    layers.push(Layer::fully_connected("fc1000", 1, 1000, 2048));
    Network::new("resnet50", layers)
}

/// CONV1 — the "early layer" exemplar of Fig 11.
pub fn conv1() -> Layer {
    network().layers[0].clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1_matches_published() {
        let l = conv1();
        assert_eq!(l.y_out(), 112);
        assert_eq!(l.k, 64);
    }

    #[test]
    fn block_counts() {
        let n = network();
        // 16 bottlenecks x 4 ops + conv1 + pool1 + fc = 67 layers.
        assert_eq!(n.layers.len(), 16 * 4 + 3);
    }

    #[test]
    fn total_macs_magnitude() {
        // ~3.8-4.1 GMACs for ResNet-50.
        let g = network().macs() as f64 / 1e9;
        assert!((3.0..5.0).contains(&g), "resnet50 GMACs = {g}");
    }

    #[test]
    fn stage_output_sizes() {
        let n = network();
        let last = n.layers.iter().rfind(|l| l.name.contains("conv5") && l.name.contains("pw2")).unwrap();
        assert_eq!(last.y_out(), 7);
        assert_eq!(last.k, 2048);
    }
}
