//! MobileNetV2 (Sandler et al., 2018): inverted residual bottlenecks as
//! fine-grained operators — expand pointwise, depthwise 3x3, project
//! pointwise (+ residual when stride 1 and shapes match).

use crate::model::layer::Layer;
use crate::model::network::Network;

/// One inverted residual: in_c --t*--> depthwise/s --> out_c.
fn inverted_residual(
    layers: &mut Vec<Layer>,
    name: &str,
    in_c: u64,
    out_c: u64,
    hw_in: u64,
    stride: u64,
    expand: u64,
) -> u64 {
    let mid = in_c * expand;
    let hw_out = hw_in / stride;
    if expand > 1 {
        layers.push(Layer::conv2d(&format!("{name}_expand"), 1, mid, in_c, hw_in, hw_in, 1, 1, 1));
    }
    layers.push(Layer::depthwise(&format!("{name}_dw"), 1, mid, hw_in + 2, hw_in + 2, 3, 3, stride));
    layers.push(Layer::conv2d(&format!("{name}_project"), 1, out_c, mid, hw_out, hw_out, 1, 1, 1));
    if stride == 1 && in_c == out_c {
        layers.push(Layer::residual(&format!("{name}_add"), 1, out_c, hw_out, hw_out));
    }
    hw_out
}

/// MobileNetV2 1.0x at 224x224.
pub fn network() -> Network {
    let mut layers = Vec::new();
    // conv1: 32 x 3 x 3x3 / s2 pad 1 over 224 -> 112.
    layers.push(Layer::conv2d("conv1", 1, 32, 3, 226, 226, 3, 3, 2));
    // (t, c, n, s) rows from the paper.
    let cfg: [(u64, u64, usize, u64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut in_c = 32u64;
    let mut hw = 112u64;
    for (bi, (t, c, n, s)) in cfg.iter().enumerate() {
        for rep in 0..*n {
            let stride = if rep == 0 { *s } else { 1 };
            let name = format!("bneck{}_{}", bi + 1, rep + 1);
            hw = inverted_residual(&mut layers, &name, in_c, *c, hw, stride, *t);
            in_c = *c;
        }
    }
    // Final 1x1 conv to 1280 + classifier.
    layers.push(Layer::conv2d("conv_last", 1, 1280, 320, 7, 7, 1, 1, 1));
    layers.push(Layer::fully_connected("fc", 1, 1000, 1280));
    Network::new("mobilenetv2", layers)
}

/// The PWCONV exemplar of Fig 11: "first conv of bottleneck1 in
/// MobileNetV2" — bneck2_1's expand (bottleneck1 has expand 1, so the
/// first *pointwise* conv of the bottleneck sequence is bneck2_1_expand).
pub fn bottleneck1_pw() -> Layer {
    network()
        .layers
        .iter()
        .find(|l| l.name == "bneck2_1_expand")
        .expect("bneck2_1_expand present")
        .clone()
}

/// A representative depthwise layer (for the DWCONV column).
pub fn dwconv_exemplar() -> Layer {
    network()
        .layers
        .iter()
        .find(|l| l.name == "bneck2_1_dw")
        .expect("bneck2_1_dw present")
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_magnitude() {
        // MobileNetV2 ~0.3 GMACs.
        let g = network().macs() as f64 / 1e9;
        assert!((0.2..0.5).contains(&g), "mobilenetv2 GMACs = {g}");
    }

    #[test]
    fn final_spatial_is_7() {
        let last_conv = network().layers.iter().rfind(|l| l.name == "conv_last").unwrap().clone();
        assert_eq!(last_conv.y_out(), 7);
    }

    #[test]
    fn exemplars_exist() {
        assert_eq!(bottleneck1_pw().r, 1);
        assert_eq!(dwconv_exemplar().op, crate::model::layer::Op::DepthwiseConv);
    }
}
