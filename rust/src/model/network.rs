//! Whole-network descriptions and a small text format for user models.
//!
//! The text format mirrors the layer constructors:
//!
//! ```text
//! network vgg16-tiny
//! # name      op         N K   C  Y   X   R S stride
//! conv1:      conv2d     1 64  3  224 224 3 3 1
//! fc1:        fc         1 1000 4096
//! dw3:        depthwise  1 32 112 112 3 3 1     # N C Y X R S stride
//! up1:        transposed 1 64 128 28 28 2 2 2   # last = upscale
//! ```

use anyhow::{bail, ensure, Context, Result};

use crate::model::layer::{Layer, OpClass};

/// An ordered list of layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn new(name: &str, layers: Vec<Layer>) -> Network {
        Network { name: name.into(), layers }
    }

    /// Total dense MACs.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Layers of a given operator class.
    pub fn layers_of(&self, class: OpClass) -> Vec<&Layer> {
        self.layers.iter().filter(|l| l.class() == class).collect()
    }

    /// Validate all layers.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.layers.is_empty(), "network {} has no layers", self.name);
        for l in &self.layers {
            l.validate()?;
        }
        Ok(())
    }

    /// Parse the text model format (see module docs). `#` starts a
    /// comment; blank lines are skipped.
    pub fn parse(text: &str) -> Result<Network> {
        let mut name = String::from("unnamed");
        let mut layers = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let err = |m: &str| format!("model line {}: {m}: '{line}'", lineno + 1);
            if let Some(rest) = line.strip_prefix("network ") {
                name = rest.trim().to_string();
                continue;
            }
            let (lname, rest) = line
                .split_once(':')
                .with_context(|| err("expected 'name: op dims...'"))?;
            let toks: Vec<&str> = rest.split_whitespace().collect();
            ensure!(!toks.is_empty(), err("missing op"));
            let nums: Result<Vec<u64>> = toks[1..]
                .iter()
                .map(|t| t.parse::<u64>().with_context(|| err("bad integer")))
                .collect();
            let nums = nums?;
            let lname = lname.trim();
            let need = |n: usize| -> Result<()> {
                ensure!(nums.len() == n, err(&format!("op {} expects {n} integers, got {}", toks[0], nums.len())));
                Ok(())
            };
            let layer = match toks[0] {
                "conv2d" => {
                    need(8)?;
                    Layer::conv2d(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5], nums[6], nums[7])
                }
                "depthwise" => {
                    need(7)?;
                    Layer::depthwise(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5], nums[6])
                }
                "fc" => {
                    need(3)?;
                    Layer::fully_connected(lname, nums[0], nums[1], nums[2])
                }
                "pooling" => {
                    need(6)?;
                    Layer::pooling(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5])
                }
                "residual" => {
                    need(4)?;
                    Layer::residual(lname, nums[0], nums[1], nums[2], nums[3])
                }
                "transposed" => {
                    need(8)?;
                    Layer::transposed_conv(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5], nums[6], nums[7])
                }
                "lstm-gate" => {
                    need(3)?;
                    Layer::lstm_gate(lname, nums[0], nums[1], nums[2])
                }
                other => bail!(err(&format!("unknown op '{other}'"))),
            };
            layers.push(layer);
        }
        let net = Network { name, layers };
        net.validate()?;
        Ok(net)
    }

    /// Emit the text format (round-trips through [`Network::parse`]).
    pub fn emit(&self) -> String {
        let mut out = format!("network {}\n", self.name);
        for l in &self.layers {
            use crate::model::layer::Op::*;
            let line = match l.op {
                Conv2d | PointwiseConv => format!(
                    "{}: conv2d {} {} {} {} {} {} {} {}",
                    l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s, l.stride
                ),
                DepthwiseConv => format!(
                    "{}: depthwise {} {} {} {} {} {} {}",
                    l.name, l.n, l.c, l.y, l.x, l.r, l.s, l.stride
                ),
                FullyConnected => format!("{}: fc {} {} {}", l.name, l.n, l.k, l.c),
                Pooling => format!("{}: pooling {} {} {} {} {} {}", l.name, l.n, l.c, l.y, l.x, l.r, l.stride),
                ResidualAdd => format!("{}: residual {} {} {} {}", l.name, l.n, l.k, l.y, l.x),
                TransposedConv => format!(
                    // Upscale already folded into y/x; emit with up=1.
                    "{}: transposed {} {} {} {} {} {} {} 1",
                    l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s
                ),
                LstmGate => format!("{}: lstm-gate {} {} {}", l.name, l.n, l.k, l.c),
            };
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
network tiny
# comment line
conv1: conv2d 1 64 3 224 224 3 3 1
pw1: conv2d 1 128 64 56 56 1 1 1
dw1: depthwise 1 64 56 56 3 3 1
fc1: fc 1 1000 4096
";

    #[test]
    fn parse_sample() {
        let n = Network::parse(SAMPLE).unwrap();
        assert_eq!(n.name, "tiny");
        assert_eq!(n.layers.len(), 4);
        assert_eq!(n.layers[0].k, 64);
        assert_eq!(n.layers[1].op, crate::model::layer::Op::PointwiseConv);
    }

    #[test]
    fn parse_rejects_bad_arity() {
        assert!(Network::parse("network x\nc: conv2d 1 2 3\n").is_err());
    }

    #[test]
    fn parse_rejects_unknown_op() {
        assert!(Network::parse("network x\nc: warp 1 2 3\n").is_err());
    }

    #[test]
    fn emit_parse_roundtrip() {
        let n = Network::parse(SAMPLE).unwrap();
        let n2 = Network::parse(&n.emit()).unwrap();
        assert_eq!(n, n2);
    }

    #[test]
    fn macs_sum() {
        let n = Network::parse(SAMPLE).unwrap();
        assert_eq!(n.macs(), n.layers.iter().map(|l| l.macs()).sum::<u64>());
    }
}
