//! Whole-network descriptions and a small text format for user models.
//!
//! The text format mirrors the layer constructors:
//!
//! ```text
//! network vgg16-tiny
//! # name      op         N K   C  Y   X   R S stride
//! conv1:      conv2d     1 64  3  224 224 3 3 1
//! fc1:        fc         1 1000 4096
//! dw3:        depthwise  1 32 112 112 3 3 1     # N C Y X R S stride
//! up1:        transposed 1 64 128 28 28 2 2 2   # last = upscale
//! ```

use anyhow::{bail, ensure, Context, Result};

use crate::model::layer::{Layer, OpClass, ShapeKey};

/// An ordered list of layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

/// One distinct layer shape of a network: a representative layer (the
/// first occurrence), the member layer names, and the multiplicity.
/// Produced by [`Network::unique_shapes`] — the accounting/display view
/// of the dedup the `engine::analysis::Analyzer` performs implicitly
/// (every layer is replayed through its shape-keyed cache, so each
/// group costs one analysis; per-layer results are kept, not scaled).
#[derive(Debug, Clone)]
pub struct ShapeGroup<'a> {
    pub key: ShapeKey,
    /// First layer in network order with this shape.
    pub layer: &'a Layer,
    /// Names of every member layer, in network order.
    pub members: Vec<&'a str>,
}

impl ShapeGroup<'_> {
    /// Multiplicity of the shape within the network.
    pub fn count(&self) -> u64 {
        self.members.len() as u64
    }
}

impl Network {
    pub fn new(name: &str, layers: Vec<Layer>) -> Network {
        Network { name: name.into(), layers }
    }

    /// A single-layer network (the DSE's historical unit of work, now a
    /// special case of the network-level pipeline).
    pub fn single(layer: Layer) -> Network {
        Network { name: layer.name.clone(), layers: vec![layer] }
    }

    /// Total dense MACs.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Group layers by canonical [`ShapeKey`], in first-occurrence
    /// order. Repeated shapes (ResNet bottlenecks, VGG conv stacks) are
    /// what make memoized whole-network analysis cheap: the Analyzer
    /// computes each group once and replays cache hits for the rest.
    pub fn unique_shapes(&self) -> Vec<ShapeGroup<'_>> {
        let mut groups: Vec<ShapeGroup<'_>> = Vec::new();
        let mut index: std::collections::HashMap<ShapeKey, usize> = std::collections::HashMap::new();
        for layer in &self.layers {
            let key = layer.shape_key();
            match index.get(&key).copied() {
                Some(i) => groups[i].members.push(&layer.name),
                None => {
                    index.insert(key, groups.len());
                    groups.push(ShapeGroup { key, layer, members: vec![&layer.name] });
                }
            }
        }
        groups
    }

    /// Layers of a given operator class.
    pub fn layers_of(&self, class: OpClass) -> Vec<&Layer> {
        self.layers.iter().filter(|l| l.class() == class).collect()
    }

    /// Validate all layers.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.layers.is_empty(), "network {} has no layers", self.name);
        for l in &self.layers {
            l.validate()?;
        }
        Ok(())
    }

    /// Parse the text model format (see module docs). `#` starts a
    /// comment; blank lines are skipped.
    pub fn parse(text: &str) -> Result<Network> {
        let mut name = String::from("unnamed");
        let mut layers = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let err = |m: &str| format!("model line {}: {m}: '{line}'", lineno + 1);
            if let Some(rest) = line.strip_prefix("network ") {
                name = rest.trim().to_string();
                continue;
            }
            let (lname, rest) = line
                .split_once(':')
                .with_context(|| err("expected 'name: op dims...'"))?;
            let toks: Vec<&str> = rest.split_whitespace().collect();
            ensure!(!toks.is_empty(), err("missing op"));
            let nums: Result<Vec<u64>> = toks[1..]
                .iter()
                .map(|t| t.parse::<u64>().with_context(|| err("bad integer")))
                .collect();
            let nums = nums?;
            let lname = lname.trim();
            let need = |n: usize| -> Result<()> {
                ensure!(nums.len() == n, err(&format!("op {} expects {n} integers, got {}", toks[0], nums.len())));
                Ok(())
            };
            let layer = match toks[0] {
                "conv2d" => {
                    need(8)?;
                    Layer::conv2d(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5], nums[6], nums[7])
                }
                "depthwise" => {
                    need(7)?;
                    Layer::depthwise(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5], nums[6])
                }
                "fc" => {
                    need(3)?;
                    Layer::fully_connected(lname, nums[0], nums[1], nums[2])
                }
                "pooling" => {
                    need(6)?;
                    Layer::pooling(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5])
                }
                "residual" => {
                    need(4)?;
                    Layer::residual(lname, nums[0], nums[1], nums[2], nums[3])
                }
                "transposed" => {
                    need(8)?;
                    Layer::transposed_conv(lname, nums[0], nums[1], nums[2], nums[3], nums[4], nums[5], nums[6], nums[7])
                }
                "lstm-gate" => {
                    need(3)?;
                    Layer::lstm_gate(lname, nums[0], nums[1], nums[2])
                }
                other => bail!(err(&format!("unknown op '{other}'"))),
            };
            layers.push(layer);
        }
        let net = Network { name, layers };
        net.validate()?;
        Ok(net)
    }

    /// Emit the text format (round-trips through [`Network::parse`]).
    pub fn emit(&self) -> String {
        let mut out = format!("network {}\n", self.name);
        for l in &self.layers {
            use crate::model::layer::Op::*;
            let line = match l.op {
                Conv2d | PointwiseConv => format!(
                    "{}: conv2d {} {} {} {} {} {} {} {}",
                    l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s, l.stride
                ),
                DepthwiseConv => format!(
                    "{}: depthwise {} {} {} {} {} {} {}",
                    l.name, l.n, l.c, l.y, l.x, l.r, l.s, l.stride
                ),
                FullyConnected => format!("{}: fc {} {} {}", l.name, l.n, l.k, l.c),
                Pooling => format!("{}: pooling {} {} {} {} {} {}", l.name, l.n, l.c, l.y, l.x, l.r, l.stride),
                ResidualAdd => format!("{}: residual {} {} {} {}", l.name, l.n, l.k, l.y, l.x),
                TransposedConv => format!(
                    // Upscale already folded into y/x; emit with up=1.
                    "{}: transposed {} {} {} {} {} {} {} 1",
                    l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s
                ),
                LstmGate => format!("{}: lstm-gate {} {} {}", l.name, l.n, l.k, l.c),
            };
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
network tiny
# comment line
conv1: conv2d 1 64 3 224 224 3 3 1
pw1: conv2d 1 128 64 56 56 1 1 1
dw1: depthwise 1 64 56 56 3 3 1
fc1: fc 1 1000 4096
";

    #[test]
    fn parse_sample() {
        let n = Network::parse(SAMPLE).unwrap();
        assert_eq!(n.name, "tiny");
        assert_eq!(n.layers.len(), 4);
        assert_eq!(n.layers[0].k, 64);
        assert_eq!(n.layers[1].op, crate::model::layer::Op::PointwiseConv);
    }

    #[test]
    fn parse_rejects_bad_arity() {
        assert!(Network::parse("network x\nc: conv2d 1 2 3\n").is_err());
    }

    #[test]
    fn parse_rejects_unknown_op() {
        assert!(Network::parse("network x\nc: warp 1 2 3\n").is_err());
    }

    #[test]
    fn emit_parse_roundtrip() {
        let n = Network::parse(SAMPLE).unwrap();
        let n2 = Network::parse(&n.emit()).unwrap();
        assert_eq!(n, n2);
    }

    #[test]
    fn macs_sum() {
        let n = Network::parse(SAMPLE).unwrap();
        assert_eq!(n.macs(), n.layers.iter().map(|l| l.macs()).sum::<u64>());
    }

    #[test]
    fn unique_shapes_group_and_preserve_order() {
        // Four layers, two of them (conv2d 64ch) shape-identical despite
        // distinct names.
        let text = "\
network dup
a: conv2d 1 64 3 224 224 3 3 1
b: conv2d 1 128 64 58 58 3 3 1
c: conv2d 1 128 64 58 58 3 3 1
d: fc 1 1000 4096
";
        let n = Network::parse(text).unwrap();
        let groups = n.unique_shapes();
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].members, vec!["a"]);
        assert_eq!(groups[1].members, vec!["b", "c"]);
        assert_eq!(groups[1].count(), 2);
        assert_eq!(groups[1].layer.name, "b", "representative is the first occurrence");
        assert_eq!(groups[2].members, vec!["d"]);
        let total: u64 = groups.iter().map(|g| g.count()).sum();
        assert_eq!(total, n.layers.len() as u64, "every layer lands in exactly one group");
    }

    #[test]
    fn zoo_networks_have_repeated_shapes() {
        // The premise of the memoized pipeline: real networks repeat
        // shapes heavily (ResNet-50's bottleneck blocks).
        let n = crate::model::zoo::by_name("resnet50").unwrap();
        let unique = n.unique_shapes().len();
        assert!(
            unique * 2 <= n.layers.len(),
            "resnet50: expected >=2x shape reuse, got {unique} unique of {} layers",
            n.layers.len()
        );
    }

    #[test]
    fn single_wraps_one_layer() {
        let l = Layer::conv2d("only", 1, 8, 4, 10, 10, 3, 3, 1);
        let n = Network::single(l.clone());
        assert_eq!(n.name, "only");
        assert_eq!(n.layers, vec![l]);
    }
}
