//! DNN model descriptions: layers, tensors with dimension coupling (the
//! paper's *tensor analysis engine*, §4.1), whole networks, and a model
//! zoo covering every network the evaluation uses (§5).

pub mod layer;
pub mod network;
pub mod tensor;
pub mod zoo;
