//! Tensor dimension coupling — the *tensor analysis engine* (paper §4.1).
//!
//! "The tensor analysis engine identifies dimension coupling for each
//! tensor based on specified layer operations." A dimension is *coupled*
//! to a tensor when changing its index moves the tensor footprint. The
//! activation dims Y/X couple to the output through the sliding window
//! `y' = (y − r)/stride`, which the engines handle via
//! [`TensorDim::Windowed`].

use crate::ir::dims::Dim;
use crate::model::layer::{Layer, Op};

/// The three operand roles of the supported operations (two inputs, one
/// output — §4.4 "all the operations represented as the loop nest with
/// two input tensors and one output tensor").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    /// Weights.
    Filter,
    /// Input activation.
    Input,
    /// Output activation (partial sums until reduction completes).
    Output,
}

pub const ALL_TENSORS: [TensorKind; 3] = [TensorKind::Filter, TensorKind::Input, TensorKind::Output];

impl TensorKind {
    pub fn name(&self) -> &'static str {
        match self {
            TensorKind::Filter => "filter",
            TensorKind::Input => "input",
            TensorKind::Output => "output",
        }
    }
}

/// How one loop dimension addresses a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorDim {
    /// The tensor is indexed directly by this dim.
    Direct(Dim),
    /// The tensor is indexed by the *difference* of an activation dim and
    /// its window dim (`y' = y − r`), divided by stride.
    Windowed { act: Dim, win: Dim },
}

/// The coupling signature of one tensor of one layer: the list of tensor
/// dimensions in canonical order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Coupling {
    pub kind: TensorKind,
    pub dims: Vec<TensorDim>,
}

impl Coupling {
    /// Is a loop dim coupled to this tensor (directly or through a
    /// window)?
    pub fn couples(&self, d: Dim) -> bool {
        self.dims.iter().any(|td| match td {
            TensorDim::Direct(x) => *x == d,
            TensorDim::Windowed { act, win } => *act == d || *win == d,
        })
    }

    /// Direct coupling only (Table 1's checkmarks use this distinction:
    /// outputs couple to Y/X as Y'/X').
    pub fn couples_directly(&self, d: Dim) -> bool {
        self.dims.iter().any(|td| matches!(td, TensorDim::Direct(x) if *x == d))
    }
}

/// Compute the coupling of all three tensors for a layer — the tensor
/// analysis engine. Users with exotic operators can construct `Coupling`
/// values directly; everything downstream consumes only this signature,
/// which is what gives MAESTRO its generality (§4.1).
pub fn couplings(layer: &Layer) -> [Coupling; 3] {
    use Dim::*;
    use TensorDim::*;
    match layer.op {
        Op::Conv2d | Op::PointwiseConv | Op::FullyConnected | Op::TransposedConv => [
            Coupling { kind: TensorKind::Filter, dims: vec![Direct(K), Direct(C), Direct(R), Direct(S)] },
            Coupling { kind: TensorKind::Input, dims: vec![Direct(N), Direct(C), Direct(Y), Direct(X)] },
            Coupling {
                kind: TensorKind::Output,
                dims: vec![
                    Direct(N),
                    Direct(K),
                    Windowed { act: Y, win: R },
                    Windowed { act: X, win: S },
                ],
            },
        ],
        // Depth-wise convolution: output couples the *input* channel dim,
        // not K (paper §4.1's depth-wise example). K carries the channel
        // multiplier (usually 1).
        Op::DepthwiseConv => [
            Coupling { kind: TensorKind::Filter, dims: vec![Direct(K), Direct(C), Direct(R), Direct(S)] },
            Coupling { kind: TensorKind::Input, dims: vec![Direct(N), Direct(C), Direct(Y), Direct(X)] },
            Coupling {
                kind: TensorKind::Output,
                dims: vec![
                    Direct(N),
                    Direct(K),
                    Direct(C),
                    Windowed { act: Y, win: R },
                    Windowed { act: X, win: S },
                ],
            },
        ],
        // Pooling has no filter tensor; model the window as a weightless
        // filter so the same engines apply (filter footprint 0 is handled
        // by `tensor_bytes`).
        Op::Pooling => [
            Coupling { kind: TensorKind::Filter, dims: vec![] },
            Coupling { kind: TensorKind::Input, dims: vec![Direct(N), Direct(C), Direct(Y), Direct(X)] },
            Coupling {
                kind: TensorKind::Output,
                dims: vec![
                    Direct(N),
                    Direct(C),
                    Windowed { act: Y, win: R },
                    Windowed { act: X, win: S },
                ],
            },
        ],
        // Residual add: elementwise over (N, C/K, Y, X); both inputs have
        // the output's shape. We give the second operand the Filter role.
        Op::ResidualAdd => [
            Coupling { kind: TensorKind::Filter, dims: vec![Direct(N), Direct(K), Direct(Y), Direct(X)] },
            Coupling { kind: TensorKind::Input, dims: vec![Direct(N), Direct(K), Direct(Y), Direct(X)] },
            Coupling { kind: TensorKind::Output, dims: vec![Direct(N), Direct(K), Direct(Y), Direct(X)] },
        ],
        // LSTM gates are GEMMs (hidden x weight); modeled like FC.
        Op::LstmGate => [
            Coupling { kind: TensorKind::Filter, dims: vec![Direct(K), Direct(C)] },
            Coupling { kind: TensorKind::Input, dims: vec![Direct(N), Direct(C)] },
            Coupling { kind: TensorKind::Output, dims: vec![Direct(N), Direct(K)] },
        ],
    }
}

/// Number of elements of one tensor of a layer.
pub fn tensor_elements(layer: &Layer, kind: TensorKind) -> u64 {
    let c = &couplings(layer)[match kind {
        TensorKind::Filter => 0,
        TensorKind::Input => 1,
        TensorKind::Output => 2,
    }];
    if c.dims.is_empty() {
        return 0;
    }
    c.dims
        .iter()
        .map(|td| match td {
            TensorDim::Direct(d) => layer.dim(*d),
            TensorDim::Windowed { act, win } => layer.out_extent(*act, *win),
        })
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::Layer;

    #[test]
    fn conv_coupling_matches_paper() {
        let l = Layer::conv2d("c", 1, 64, 32, 56, 56, 3, 3, 1);
        let [f, i, o] = couplings(&l);
        // Filter couples K, C, R, S but not N, Y, X.
        assert!(f.couples(Dim::K) && f.couples(Dim::C) && f.couples(Dim::R) && f.couples(Dim::S));
        assert!(!f.couples(Dim::N) && !f.couples(Dim::Y) && !f.couples(Dim::X));
        // Input couples N, C, Y, X but not K, R, S.
        assert!(i.couples(Dim::N) && i.couples(Dim::C) && i.couples(Dim::Y) && i.couples(Dim::X));
        assert!(!i.couples(Dim::K) && !i.couples(Dim::R) && !i.couples(Dim::S));
        // Output couples N, K and (via window) Y, X, R, S; not C.
        assert!(o.couples(Dim::N) && o.couples(Dim::K));
        assert!(o.couples(Dim::Y) && o.couples(Dim::R));
        assert!(!o.couples(Dim::C));
        // But Y couples the output only through the window.
        assert!(!o.couples_directly(Dim::Y));
        assert!(o.couples_directly(Dim::K));
    }

    #[test]
    fn depthwise_output_couples_c_not_k_parallelism() {
        let l = Layer::depthwise("dw", 1, 32, 56, 56, 3, 3, 1);
        let [_, _, o] = couplings(&l);
        assert!(o.couples(Dim::C));
    }

    #[test]
    fn fc_tensor_sizes() {
        // FC 4096 -> 1000 as conv with Y=R=1, X=S=1.
        let l = Layer::fully_connected("fc", 1, 1000, 4096);
        assert_eq!(tensor_elements(&l, TensorKind::Filter), 4096 * 1000);
        assert_eq!(tensor_elements(&l, TensorKind::Input), 4096);
        assert_eq!(tensor_elements(&l, TensorKind::Output), 1000);
    }

    #[test]
    fn conv_tensor_sizes() {
        let l = Layer::conv2d("c", 2, 8, 4, 10, 12, 3, 3, 1);
        assert_eq!(tensor_elements(&l, TensorKind::Filter), 8 * 4 * 3 * 3);
        assert_eq!(tensor_elements(&l, TensorKind::Input), 2 * 4 * 10 * 12);
        assert_eq!(tensor_elements(&l, TensorKind::Output), 2 * 8 * 8 * 10);
    }

    #[test]
    fn pooling_has_no_filter() {
        let l = Layer::pooling("p", 1, 32, 56, 56, 2, 2);
        assert_eq!(tensor_elements(&l, TensorKind::Filter), 0);
        assert!(tensor_elements(&l, TensorKind::Output) > 0);
    }
}
