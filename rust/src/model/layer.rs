//! Layer descriptions: the seven-dimensional shapes of Figure 1 plus the
//! operator taxonomy of Table 4.

use std::fmt;

use anyhow::{ensure, Result};

use crate::ir::dims::Dim;

/// Supported operator types (Table 4 + §4.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Dense 2D convolution (possibly strided).
    Conv2d,
    /// 1x1 convolution — no filter-plane parallelism, no convolutional
    /// reuse (Table 4).
    PointwiseConv,
    /// Depth-wise convolution — output couples C, not K.
    DepthwiseConv,
    /// Fully-connected / GEMM (also LSTM projections).
    FullyConnected,
    /// Transposed convolution (UNet up-conv, DCGAN). Modeled on the
    /// zero-up-sampled input grid — see [`Layer::transposed_conv`].
    TransposedConv,
    /// Max/avg pooling (weightless window op).
    Pooling,
    /// Residual (skip connection) elementwise add.
    ResidualAdd,
    /// One LSTM gate GEMM (i/f/g/o).
    LstmGate,
}

impl Op {
    /// Stable numeric tag for cache-file serialization. Append-only:
    /// never renumber existing variants, only add new ones.
    pub fn tag(&self) -> u8 {
        match self {
            Op::Conv2d => 0,
            Op::PointwiseConv => 1,
            Op::DepthwiseConv => 2,
            Op::FullyConnected => 3,
            Op::TransposedConv => 4,
            Op::Pooling => 5,
            Op::ResidualAdd => 6,
            Op::LstmGate => 7,
        }
    }

    /// Inverse of [`Op::tag`]; `None` for tags from a future build.
    pub fn from_tag(tag: u8) -> Option<Op> {
        Some(match tag {
            0 => Op::Conv2d,
            1 => Op::PointwiseConv,
            2 => Op::DepthwiseConv,
            3 => Op::FullyConnected,
            4 => Op::TransposedConv,
            5 => Op::Pooling,
            6 => Op::ResidualAdd,
            7 => Op::LstmGate,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Op::Conv2d => "conv2d",
            Op::PointwiseConv => "pointwise",
            Op::DepthwiseConv => "depthwise",
            Op::FullyConnected => "fc",
            Op::TransposedConv => "transposed",
            Op::Pooling => "pooling",
            Op::ResidualAdd => "residual",
            Op::LstmGate => "lstm-gate",
        }
    }
}

/// Operator classes used by the case studies (Table 4 / Fig 10f). The
/// early/late split follows the paper's footnote: `C > Y ⇒ late layer`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    ConvEarly,
    ConvLate,
    FullyConnected,
    Pointwise,
    Depthwise,
    Residual,
    Transposed,
    Other,
}

impl OpClass {
    pub fn name(&self) -> &'static str {
        match self {
            OpClass::ConvEarly => "CONV2D-early",
            OpClass::ConvLate => "CONV2D-late",
            OpClass::FullyConnected => "FC",
            OpClass::Pointwise => "PWCONV",
            OpClass::Depthwise => "DWCONV",
            OpClass::Residual => "Residual",
            OpClass::Transposed => "TRCONV",
            OpClass::Other => "Other",
        }
    }

    pub fn all() -> [OpClass; 7] {
        [
            OpClass::ConvEarly,
            OpClass::ConvLate,
            OpClass::FullyConnected,
            OpClass::Pointwise,
            OpClass::Depthwise,
            OpClass::Residual,
            OpClass::Transposed,
        ]
    }
}

/// Canonical, name-independent identity of a layer's compute shape.
///
/// Two layers with equal `ShapeKey`s are indistinguishable to every
/// analysis in this crate: the dataflow resolver, schedule builder,
/// reuse/performance/cost engines and the DSE case tables read only the
/// fields captured here (operator, the seven dimension extents, stride,
/// and the structured-sparsity discount), never the layer's `name`.
/// That makes the key the memoization unit for whole-network analysis —
/// ResNet-50's repeated bottleneck blocks or VGG's conv stacks collapse
/// to one evaluation per distinct key (see `engine::analysis::Analyzer`).
///
/// The sparsity discount is stored as `f64::to_bits` so the key stays
/// `Eq + Hash`; it is derived state today (a function of `op`) but is
/// included so future per-layer sparsity annotations cannot alias.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    pub op: Op,
    pub n: u64,
    pub k: u64,
    pub c: u64,
    pub y: u64,
    pub x: u64,
    pub r: u64,
    pub s: u64,
    pub stride: u64,
    sparsity_bits: u64,
}

impl ShapeKey {
    /// The sparsity discount as raw bits (kept private as a field so
    /// only [`Layer::shape_key`] computes it; exposed read-only for the
    /// cache subsystem's stable serialization).
    pub fn sparsity_bits(&self) -> u64 {
        self.sparsity_bits
    }

    /// Rebuild a key from persisted raw parts (`dims` in canonical
    /// N, K, C, Y, X, R, S order). Cache deserialization only — new
    /// keys come from [`Layer::shape_key`].
    pub fn from_raw(op: Op, dims: [u64; 7], stride: u64, sparsity_bits: u64) -> ShapeKey {
        let [n, k, c, y, x, r, s] = dims;
        ShapeKey { op, n, k, c, y, x, r, s, stride, sparsity_bits }
    }

    /// Materialize a layer with this shape. Round-trips exactly:
    /// `layer.shape_key().to_layer(name).shape_key() == layer.shape_key()`
    /// (the sparsity discount is a pure function of `op`). For
    /// consumers that hold only a shape — e.g. replaying a persisted
    /// cache key, or enumerating mapspace tilings against a `ShapeKey`
    /// (`rust/tests/mapspace.rs` pins that the enumeration over a
    /// rebuilt layer is bit-identical to the original's).
    pub fn to_layer(&self, name: &str) -> Layer {
        Layer {
            name: name.into(),
            op: self.op,
            n: self.n,
            k: self.k,
            c: self.c,
            y: self.y,
            x: self.x,
            r: self.r,
            s: self.s,
            stride: self.stride,
        }
    }
}

/// One DNN layer with concrete dimensions. `Y`/`X` are *input* activation
/// extents (input-centric convention, §4.1); output extents are derived.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub name: String,
    pub op: Op,
    /// Batch.
    pub n: u64,
    /// Output channels (channel multiplier for depthwise; = C for residual).
    pub k: u64,
    /// Input channels.
    pub c: u64,
    /// Input rows.
    pub y: u64,
    /// Input columns.
    pub x: u64,
    /// Filter rows.
    pub r: u64,
    /// Filter columns.
    pub s: u64,
    /// Convolution stride (1 for FC/residual).
    pub stride: u64,
}

impl Layer {
    pub fn conv2d(name: &str, n: u64, k: u64, c: u64, y: u64, x: u64, r: u64, s: u64, stride: u64) -> Layer {
        let op = if r == 1 && s == 1 { Op::PointwiseConv } else { Op::Conv2d };
        Layer { name: name.into(), op, n, k, c, y, x, r, s, stride }
    }

    pub fn depthwise(name: &str, n: u64, c: u64, y: u64, x: u64, r: u64, s: u64, stride: u64) -> Layer {
        Layer { name: name.into(), op: Op::DepthwiseConv, n, k: 1, c, y, x, r, s, stride }
    }

    pub fn fully_connected(name: &str, n: u64, k: u64, c: u64) -> Layer {
        Layer { name: name.into(), op: Op::FullyConnected, n, k, c, y: 1, x: 1, r: 1, s: 1, stride: 1 }
    }

    pub fn pooling(name: &str, n: u64, c: u64, y: u64, x: u64, r: u64, stride: u64) -> Layer {
        Layer { name: name.into(), op: Op::Pooling, n, k: 1, c, y, x, r, s: r, stride }
    }

    pub fn residual(name: &str, n: u64, k: u64, y: u64, x: u64) -> Layer {
        Layer { name: name.into(), op: Op::ResidualAdd, n, k, c: 1, y, x, r: 1, s: 1, stride: 1 }
    }

    pub fn lstm_gate(name: &str, n: u64, hidden: u64, input: u64) -> Layer {
        Layer { name: name.into(), op: Op::LstmGate, n, k: hidden, c: input, y: 1, x: 1, r: 1, s: 1, stride: 1 }
    }

    /// Transposed convolution producing `up × ` upscaled outputs. We model
    /// it on the zero-up-sampled input grid (input extent × up), which
    /// preserves the data-movement pattern and exposes the structured
    /// output sparsity Table 4 mentions; MAC counting discounts the zero
    /// rows via [`Layer::sparsity_macs_scale`].
    pub fn transposed_conv(name: &str, n: u64, k: u64, c: u64, y: u64, x: u64, r: u64, s: u64, up: u64) -> Layer {
        Layer {
            name: name.into(),
            op: Op::TransposedConv,
            n,
            k,
            c,
            y: y * up,
            x: x * up,
            r,
            s,
            stride: 1,
        }
    }

    /// Fraction of MACs that are non-trivial (zero-skipping on the
    /// up-sampled grid of a transposed conv; 1.0 elsewhere).
    pub fn sparsity_macs_scale(&self) -> f64 {
        match self.op {
            // 1 in up^2 input points is non-zero; up is recoverable from
            // nothing here, so we use the common up=2 of UNet/DCGAN.
            Op::TransposedConv => 0.25,
            _ => 1.0,
        }
    }

    /// The canonical shape identity of this layer (everything the
    /// analysis engines read except the name). Layers sharing a key
    /// produce bit-identical analysis results under any (dataflow,
    /// hardware) pair.
    pub fn shape_key(&self) -> ShapeKey {
        ShapeKey {
            op: self.op,
            n: self.n,
            k: self.k,
            c: self.c,
            y: self.y,
            x: self.x,
            r: self.r,
            s: self.s,
            stride: self.stride,
            sparsity_bits: self.sparsity_macs_scale().to_bits(),
        }
    }

    /// Extent of a loop dimension.
    pub fn dim(&self, d: Dim) -> u64 {
        match d {
            Dim::N => self.n,
            Dim::K => self.k,
            Dim::C => self.c,
            Dim::Y => self.y,
            Dim::X => self.x,
            Dim::R => self.r,
            Dim::S => self.s,
        }
    }

    /// Output extent for a windowed activation dim: `(act − win)/stride + 1`.
    pub fn out_extent(&self, act: Dim, win: Dim) -> u64 {
        let a = self.dim(act);
        let w = self.dim(win);
        if a < w {
            0
        } else {
            (a - w) / self.stride + 1
        }
    }

    /// Output rows / columns.
    pub fn y_out(&self) -> u64 {
        self.out_extent(Dim::Y, Dim::R)
    }
    pub fn x_out(&self) -> u64 {
        self.out_extent(Dim::X, Dim::S)
    }

    /// Whether an activation dim slides a window for this op.
    pub fn windowed(&self, d: Dim) -> bool {
        matches!(d, Dim::Y | Dim::X)
            && !matches!(self.op, Op::FullyConnected | Op::ResidualAdd | Op::LstmGate)
    }

    /// Total multiply-accumulates (dense; transposed conv reports the
    /// dense count — use [`Layer::effective_macs`] for the sparsity-aware
    /// number).
    pub fn macs(&self) -> u64 {
        let base = self.n * self.y_out() * self.x_out() * self.r * self.s * self.c;
        match self.op {
            Op::DepthwiseConv => base * self.k, // k = channel multiplier
            Op::Pooling | Op::ResidualAdd => {
                // One op per output element.
                self.n * self.k.max(1) * self.c.max(1) * self.y_out() * self.x_out()
            }
            _ => base * self.k,
        }
    }

    /// MACs after structured-sparsity discounting (§4.4 — uniformly
    /// distributed sparsity model).
    pub fn effective_macs(&self) -> f64 {
        self.macs() as f64 * self.sparsity_macs_scale()
    }

    /// Operator classification for the case studies. Paper footnote 2:
    /// "If C > Y, late layer. Else, early layer."
    pub fn class(&self) -> OpClass {
        match self.op {
            Op::PointwiseConv => OpClass::Pointwise,
            Op::DepthwiseConv => OpClass::Depthwise,
            Op::FullyConnected | Op::LstmGate => OpClass::FullyConnected,
            Op::ResidualAdd => OpClass::Residual,
            Op::TransposedConv => OpClass::Transposed,
            Op::Pooling => OpClass::Other,
            Op::Conv2d => {
                if self.c > self.y {
                    OpClass::ConvLate
                } else {
                    OpClass::ConvEarly
                }
            }
        }
    }

    /// Basic sanity checks used by parsers and the zoo audit test.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.n >= 1 && self.k >= 1 && self.c >= 1, "layer {}: channel/batch dims must be >= 1", self.name);
        ensure!(self.y >= self.r && self.x >= self.s, "layer {}: activation smaller than filter", self.name);
        ensure!(self.stride >= 1, "layer {}: stride must be >= 1", self.name);
        ensure!(self.y_out() >= 1 && self.x_out() >= 1, "layer {}: empty output", self.name);
        Ok(())
    }
}

impl fmt::Display for Layer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} [{}] N{} K{} C{} Y{} X{} R{} S{} /{}",
            self.name, self.op.name(), self.n, self.k, self.c, self.y, self.x, self.r, self.s, self.stride
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims() {
        let l = Layer::conv2d("c", 1, 64, 3, 224, 224, 3, 3, 1);
        assert_eq!(l.y_out(), 222);
        assert_eq!(l.x_out(), 222);
        let s2 = Layer::conv2d("c2", 1, 64, 3, 224, 224, 7, 7, 2);
        assert_eq!(s2.y_out(), (224 - 7) / 2 + 1);
    }

    #[test]
    fn pointwise_autodetected() {
        let l = Layer::conv2d("pw", 1, 256, 64, 56, 56, 1, 1, 1);
        assert_eq!(l.op, Op::PointwiseConv);
        assert_eq!(l.class(), OpClass::Pointwise);
    }

    #[test]
    fn macs_closed_form() {
        let l = Layer::conv2d("c", 2, 8, 4, 10, 12, 3, 3, 1);
        // N*K*C*Y'*X'*R*S = 2*8*4*8*10*9
        assert_eq!(l.macs(), 2 * 8 * 4 * 8 * 10 * 9);
    }

    #[test]
    fn depthwise_macs_drop_k() {
        let l = Layer::depthwise("dw", 1, 32, 10, 10, 3, 3, 1);
        assert_eq!(l.macs(), 32 * 8 * 8 * 9);
        assert_eq!(l.class(), OpClass::Depthwise);
    }

    #[test]
    fn early_late_classification() {
        // VGG16 conv1: C=3, Y=224 -> early.
        assert_eq!(Layer::conv2d("c1", 1, 64, 3, 224, 224, 3, 3, 1).class(), OpClass::ConvEarly);
        // VGG16 conv13: C=512, Y=14 -> late.
        assert_eq!(Layer::conv2d("c13", 1, 512, 512, 16, 16, 3, 3, 1).class(), OpClass::ConvLate);
    }

    #[test]
    fn fc_is_degenerate_conv() {
        let l = Layer::fully_connected("fc", 1, 1000, 4096);
        assert_eq!(l.y_out(), 1);
        assert_eq!(l.macs(), 1000 * 4096);
        assert!(!l.windowed(Dim::Y));
    }

    #[test]
    fn transposed_upsamples_and_discounts() {
        let l = Layer::transposed_conv("up", 1, 64, 128, 28, 28, 2, 2, 2);
        assert_eq!(l.y, 56);
        assert!(l.effective_macs() < l.macs() as f64);
    }

    #[test]
    fn shape_key_ignores_names() {
        let a = Layer::conv2d("res2a_branch2b", 1, 64, 64, 58, 58, 3, 3, 1);
        let b = Layer::conv2d("res2c_branch2b", 1, 64, 64, 58, 58, 3, 3, 1);
        assert_ne!(a.name, b.name);
        assert_eq!(a.shape_key(), b.shape_key());
    }

    #[test]
    fn shape_key_separates_stride_and_op_class() {
        let base = Layer::conv2d("a", 1, 64, 64, 58, 58, 3, 3, 1);
        let strided = Layer::conv2d("a", 1, 64, 64, 58, 58, 3, 3, 2);
        assert_ne!(base.shape_key(), strided.shape_key(), "stride must be part of the key");
        // Same seven dims, different operator: depthwise K=1/C=64 vs a
        // pointwise-free conv with identical extents.
        let dw = Layer::depthwise("a", 1, 64, 58, 58, 3, 3, 1);
        let cv = Layer::conv2d("a", 1, 1, 64, 58, 58, 3, 3, 1);
        assert_eq!((dw.n, dw.k, dw.c, dw.y, dw.x, dw.r, dw.s), (cv.n, cv.k, cv.c, cv.y, cv.x, cv.r, cv.s));
        assert_ne!(dw.shape_key(), cv.shape_key(), "op class must be part of the key");
    }

    #[test]
    fn shape_key_separates_sparsity() {
        // Transposed conv carries the structured-sparsity discount; a
        // dense conv with the same geometry must not collide.
        let dense = Layer::conv2d("d", 1, 64, 128, 56, 56, 2, 2, 1);
        let sparse = Layer::transposed_conv("u", 1, 64, 128, 28, 28, 2, 2, 2);
        assert_eq!(dense.macs(), sparse.macs());
        assert_ne!(dense.shape_key(), sparse.shape_key());
    }

    #[test]
    fn shape_key_to_layer_roundtrips() {
        for layer in [
            Layer::conv2d("a", 2, 64, 3, 224, 224, 7, 7, 2),
            Layer::depthwise("b", 1, 32, 28, 28, 3, 3, 1),
            Layer::fully_connected("c", 1, 1000, 4096),
            Layer::transposed_conv("d", 1, 64, 128, 28, 28, 2, 2, 2),
        ] {
            let key = layer.shape_key();
            assert_eq!(key.to_layer("rebuilt").shape_key(), key, "{}", layer.name);
        }
    }

    #[test]
    fn validate_catches_bad_shapes() {
        assert!(Layer::conv2d("bad", 1, 8, 4, 2, 2, 3, 3, 1).validate().is_err());
        assert!(Layer::conv2d("ok", 1, 8, 4, 8, 8, 3, 3, 1).validate().is_ok());
    }
}
