//! `maestro` CLI — the leader entrypoint.
//!
//! ```text
//! maestro analyze  --model vgg16 --layer conv2_2 --dataflow kc-p [--pes 256 --bw 16]
//! maestro network  --model mobilenetv2 --dataflow adaptive [--objective runtime --per-layer]
//! maestro map      --model vgg16 [--objective edp --tile-resolution 6]  # layer-wise mapper
//! maestro validate --model vgg16 --dataflow yr-p --pes 64      # model vs cycle sim
//! maestro dse      --family kc-p --model vgg16 --layer conv2_2 [--resolution 12 --threads 0]
//! maestro dse      --family kc-p --model resnet50 --network   # whole-network sweep
//! maestro dse      --family kc-p --strategy guided                  # frontier without the full sweep
//! maestro dse      --family kc-p --strategy random --budget 50000 --seed 7
//! maestro dse      --family kc-p --mapspace                         # generated variant axis
//! maestro serve    --cache-file warm.mcache [--addr 127.0.0.1:7733] # resident DSE daemon
//! maestro client   --addr 127.0.0.1:7733    # persistent connection: stdin frames -> daemon
//! maestro dse      --family kc-p --remote 127.0.0.1:7733 [--stream] # run on a daemon instead
//! maestro cache    compact --cache-file warm.mcache   # rewrite with unique keys
//! maestro table1
//! maestro zoo
//! ```
//!
//! `network`, `map`, and `dse` are thin clients of the same entry
//! points the `serve` daemon executes (`maestro::service::exec`); give
//! any of them `--json` to emit the daemon's versioned response frame
//! instead of tables.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use maestro::cache::SharedStore;
use maestro::coordinator::{jobs_from_batches, run_jobs_with_store, Backend};
use maestro::dse::engine::DesignPoint;
use maestro::dse::pareto::{best, Optimize};
use maestro::dse::strategy::plan_single_wave;
use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::zoo;
use maestro::report::experiments;
use maestro::runtime::BatchEvaluator;
use maestro::service::api::{AnalyzeRequest, DseRequest, MapRequest};
use maestro::service::exec::{
    analyze_reply, dse_reply, map_reply, pick_layer_named, prepare_dse, run_analyze, run_map,
    run_prepared_dse,
};
use maestro::service::{Request, Response, ServeConfig};
use maestro::sim::cycle::simulate;
use maestro::util::cli::{common_flags, usage, Args, FlagSpec};
use maestro::util::table::{num, Table};

fn flags() -> Vec<FlagSpec> {
    let mut spec = vec![
        FlagSpec { name: "model", takes_value: true, help: "zoo network name (see `maestro zoo`)" },
        FlagSpec { name: "layer", takes_value: true, help: "layer name within the model" },
        FlagSpec { name: "dataflow", takes_value: true, help: "c-p | x-p | yx-p | yr-p | kc-p | adaptive | mapped (network: mapspace-backed adaptive)" },
        FlagSpec { name: "pes", takes_value: true, help: "number of PEs (default 256)" },
        FlagSpec { name: "bw", takes_value: true, help: "NoC bandwidth, elements/cycle (default 16)" },
        FlagSpec { name: "family", takes_value: true, help: "DSE dataflow family: kc-p | yr-p | yx-p" },
        FlagSpec { name: "resolution", takes_value: true, help: "DSE sweep resolution per axis (default 12)" },
        FlagSpec {
            name: "bw-resolution",
            takes_value: true,
            help: "dse: bandwidth-axis resolution (default: --resolution)",
        },
        FlagSpec {
            name: "strategy",
            takes_value: true,
            help: "dse: search strategy: exhaustive | random | guided (default exhaustive)",
        },
        FlagSpec { name: "network", takes_value: false, help: "dse: sweep the whole model (shape-deduped)" },
        FlagSpec { name: "per-layer", takes_value: false, help: "network: print the per-layer breakdown" },
        FlagSpec { name: "pjrt", takes_value: false, help: "use the AOT PJRT evaluator for DSE" },
        FlagSpec { name: "workers", takes_value: true, help: "coordinator workers for --pjrt (default 4); serve: executor threads (default 2); without --pjrt, caps sweep threads when --threads is absent" },
        FlagSpec { name: "max-steps", takes_value: true, help: "simulator step budget (default 200M)" },
        FlagSpec { name: "csv", takes_value: false, help: "emit CSV instead of aligned tables" },
        FlagSpec {
            name: "tile-resolution",
            takes_value: true,
            help: "map/dse --mapspace: candidate tile sizes per knob (default 6; Table-3 default always kept)",
        },
        FlagSpec {
            name: "mapspace",
            takes_value: false,
            help: "dse: generate the variant axis from the family's style template on the picked layer",
        },
        FlagSpec {
            name: "json",
            takes_value: false,
            help: "network/map/dse: emit the service API's versioned JSON frame instead of tables",
        },
        FlagSpec { name: "addr", takes_value: true, help: "serve/client: daemon address (default 127.0.0.1:7733)" },
        FlagSpec {
            name: "remote",
            takes_value: true,
            help: "network/map/dse: send the request to a serve daemon at ADDR and print its frames",
        },
        FlagSpec {
            name: "stream",
            takes_value: false,
            help: "map/dse with --remote: stream progress frames before the final reply",
        },
        FlagSpec {
            name: "queue-cap",
            takes_value: true,
            help: "serve: job-queue depth before overloaded rejections (default 16)",
        },
        FlagSpec {
            name: "flush-every",
            takes_value: true,
            help: "serve: seconds between background store flushes (default 30; 0 = shutdown only)",
        },
        FlagSpec { name: "verbose", takes_value: false, help: "serve: raise the log level to debug (per-request lines)" },
        FlagSpec {
            name: "metrics",
            takes_value: false,
            help: "client: fetch one telemetry snapshot frame from the daemon and exit",
        },
    ];
    spec.extend(common_flags());
    spec
}

/// Load `--cache-file` (when given) into a fresh [`SharedStore`],
/// bounded by `--cache-cap` (second-chance eviction) when set. Returns
/// the store and the path to flush back to. Corrupt or stale files
/// warn and start cold — never fail the run. `quiet` (--json) keeps
/// stdout to the single response frame.
fn open_cache(args: &Args, quiet: bool) -> Result<(Arc<SharedStore>, Option<String>)> {
    let cap = args.opt_u64("cache-cap", 0)? as usize;
    let store = if cap > 0 {
        Arc::new(SharedStore::with_max_entries(cap))
    } else {
        Arc::new(SharedStore::new())
    };
    let path = args.opt("cache-file", "");
    if path.is_empty() {
        return Ok((store, None));
    }
    let report = store.load(std::path::Path::new(&path));
    if let Some(w) = &report.warning {
        eprintln!("cache-file: {w}");
    }
    if !quiet {
        println!("cache-file: loaded {} cached analyses from {path}", report.loaded);
        if cap > 0 && store.evictions() > 0 {
            println!(
                "cache-cap: kept the newest {} of the file's records ({} evicted)",
                store.len(),
                store.evictions()
            );
        }
    }
    Ok((store, Some(path)))
}

/// Flush the store back to its `--cache-file` (if one was given).
fn close_cache(store: &SharedStore, path: &Option<String>, quiet: bool) -> Result<()> {
    if let Some(path) = path {
        let report = store.flush(std::path::Path::new(path))?;
        if !quiet {
            println!(
                "cache-file: wrote {} new record(s) ({} total) to {path}",
                report.written, report.total
            );
        }
    }
    Ok(())
}

/// Enable span tracing when `--trace-out FILE` is given (with the
/// `--trace-sample` rate); returns the path to export to on completion.
fn trace_setup(args: &Args) -> Result<Option<String>> {
    let path = args.opt("trace-out", "");
    if path.is_empty() {
        return Ok(None);
    }
    maestro::obs::trace::enable(args.opt_u64("trace-sample", 1)?);
    Ok(Some(path))
}

/// Validate and write the Chrome trace file `trace_setup` armed.
fn trace_finish(path: &Option<String>, quiet: bool) -> Result<()> {
    if let Some(path) = path {
        let summary = maestro::obs::trace::write_file(path)?;
        if !quiet {
            println!("trace: wrote {} event(s) to {path}", summary.events);
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = flags();
    let args = Args::parse(&argv, &spec, true)?;
    for w in &args.warnings {
        eprintln!("warning: {w}");
    }
    let Some(cmd) = args.subcommand.clone() else {
        println!("maestro — data-centric DNN dataflow cost model (MICRO-52 reproduction)");
        println!("subcommands: analyze | network | map | validate | dse | serve | client | cache | table1 | zoo");
        println!("{}", usage(&spec));
        return Ok(());
    };

    match cmd.as_str() {
        "zoo" => {
            let mut t = Table::new(&["network", "layers", "GMACs"]);
            for name in zoo::ALL {
                let n = zoo::by_name(name)?;
                t.row(&[name.to_string(), n.layers.len().to_string(), format!("{:.2}", n.macs() as f64 / 1e9)]);
            }
            print!("{}", t.render());
        }
        "analyze" => {
            let (layer, _) = pick_layer(&args)?;
            let hw = pick_hw(&args)?;
            let dfname = args.opt("dataflow", "all");
            println!("layer: {layer}");
            if dfname == "all" {
                let stats = experiments::dataflow_comparison(&layer, &hw)?;
                let t = experiments::stats_table(&stats);
                print!("{}", if args.has("csv") { t.to_csv() } else { t.render() });
            } else {
                let df = styles::by_name(&dfname).with_context(|| format!("unknown dataflow {dfname}"))?;
                let s = analyze_layer(&layer, &df, &hw)?;
                let t = experiments::stats_table(&[s]);
                print!("{}", if args.has("csv") { t.to_csv() } else { t.render() });
            }
        }
        "network" => {
            let req = AnalyzeRequest::from_args(&args)?;
            if run_remote(&args, Request::Analyze(req.clone()))? {
                return Ok(());
            }
            let json = args.has("json");
            let trace_path = trace_setup(&args)?;
            let (store, cache_path) = open_cache(&args, json)?;
            let out = run_analyze(&store, &req)?;
            if json {
                println!("{}", Response::Analyze(analyze_reply(&req, &out)).encode_line());
            } else {
                if let Some(note) = &out.mapspace_note {
                    println!("{note}");
                }
                let stats = &out.network;
                let cols = ["network", "dataflow", "layers", "shapes", "runtime(cyc)", "energy(uJ)", "GMACs"];
                let mut t = Table::new(&cols);
                t.row(&[
                    stats.network.clone(),
                    stats.dataflow.clone(),
                    stats.per_layer.len().to_string(),
                    out.shapes.to_string(),
                    num(stats.runtime),
                    num(stats.energy.total() / 1e6),
                    format!("{:.2}", stats.macs / 1e9),
                ]);
                print!("{}", if args.has("csv") { t.to_csv() } else { t.render() });
                if args.has("per-layer") {
                    let pl = experiments::network_layers_table(stats);
                    print!("{}", if args.has("csv") { pl.to_csv() } else { pl.render() });
                }
                if !stats.skipped.is_empty() {
                    println!("skipped {} layer(s):", stats.skipped.len());
                    for s in &stats.skipped {
                        println!("  {}: {}", s.layer, s.reason);
                    }
                }
                println!(
                    "analyzer cache: {} hits ({} from disk) / {} misses across {} layers",
                    out.stats.warm_hits + out.stats.disk_hits,
                    out.stats.disk_hits,
                    out.stats.analyses,
                    out.layers_total
                );
            }
            close_cache(&store, &cache_path, json)?;
            trace_finish(&trace_path, json)?;
        }
        "map" => {
            // The layer-wise mapper (mapspace subsystem): per unique
            // layer shape, search the enumerated tiling space of every
            // Table 3 style template for the best mapping, then compare
            // against the fixed-style adaptive baseline (§5.1) through
            // the same shared analysis store.
            let req = MapRequest::from_args(&args)?;
            if run_remote(&args, Request::Map(req.clone()))? {
                return Ok(());
            }
            let json = args.has("json");
            let trace_path = trace_setup(&args)?;
            let (store, cache_path) = open_cache(&args, json)?;
            let out = run_map(&store, &req, None)?;
            if json {
                println!("{}", Response::Map(map_reply(&req, &out)).encode_line());
            } else {
                let outcome = &out.mapping;
                let mut t = Table::new(&["shape (rep. layer)", "x", "mapping", "runtime(cyc)", "energy(uJ)", "util"]);
                for s in &outcome.per_shape {
                    t.row(&[
                        s.representative.clone(),
                        s.members.to_string(),
                        s.dataflow.name.clone(),
                        num(s.stats.runtime),
                        num(s.stats.energy.total() / 1e6),
                        format!("{:.3}", s.stats.util),
                    ]);
                }
                print!("{}", if args.has("csv") { t.to_csv() } else { t.render() });
                if !outcome.network.skipped.is_empty() {
                    println!("skipped {} layer(s):", outcome.network.skipped.len());
                    for s in &outcome.network.skipped {
                        println!("  {}: {}", s.layer, s.reason);
                    }
                }
                println!("{}", outcome.stats.summary());
                let fixed = &out.fixed;
                println!(
                    "mapper:       {} layer(s), runtime={} cyc, energy={} uJ",
                    outcome.network.per_layer.len(),
                    num(outcome.network.runtime),
                    num(outcome.network.energy.total() / 1e6),
                );
                println!(
                    "fixed styles: {} layer(s), runtime={} cyc, energy={} uJ (adaptive over Table 3)",
                    fixed.per_layer.len(),
                    num(fixed.runtime),
                    num(fixed.energy.total() / 1e6),
                );
                if fixed.per_layer.len() == outcome.network.per_layer.len() {
                    println!(
                        "mapper-vs-fixed ({}): runtime x{:.4}, energy x{:.4}, edp x{:.4}",
                        req.objective.name(),
                        fixed.runtime / outcome.network.runtime.max(1e-12),
                        fixed.energy.total() / outcome.network.energy.total().max(1e-12),
                        (fixed.runtime * fixed.energy.total())
                            / (outcome.network.runtime * outcome.network.energy.total()).max(1e-12),
                    );
                } else {
                    println!("mapper-vs-fixed: layer coverage differs; no ratio printed");
                }
            }
            close_cache(&store, &cache_path, json)?;
            trace_finish(&trace_path, json)?;
        }
        "validate" => {
            let (layer, _) = pick_layer(&args)?;
            let hw = pick_hw(&args)?;
            let dfname = args.opt("dataflow", "x-p");
            let df = styles::by_name(&dfname).with_context(|| format!("unknown dataflow {dfname}"))?;
            let max_steps = args.opt_u64("max-steps", 200_000_000)?;
            let sim = simulate(&layer, &df, &hw, max_steps)?;
            let ana = analyze_layer(&layer, &df, &hw)?;
            let err = (ana.runtime - sim.cycles).abs() / sim.cycles * 100.0;
            let mut t = Table::new(&["what", "cycles", "L2 reads", "L2 writes"]);
            t.row(&["analytical".into(), num(ana.runtime), num(ana.l2_reads.iter().sum::<f64>()), num(ana.l2_writes.iter().sum::<f64>())]);
            t.row(&["cycle-sim".into(), num(sim.cycles), num(sim.l2_reads.iter().sum::<f64>()), num(sim.l2_writes)]);
            print!("{}", t.render());
            println!("runtime error: {err:.2}%  (sim walked {} steps)", sim.steps);
        }
        "dse" => {
            let req = DseRequest::from_args(&args)?;
            if run_remote(&args, Request::Dse(req.clone()))? {
                return Ok(());
            }
            let json = args.has("json");
            let trace_path = trace_setup(&args)?;
            let prep = prepare_dse(&req)?;
            if !json {
                if let Some(note) = &prep.mapspace_note {
                    println!("{note}");
                }
                println!("{}", prep.search_line());
                println!("{}", prep.workload_line());
            }
            let (store, cache_path) = open_cache(&args, json)?;
            if args.has("pjrt") {
                // The PJRT backend goes through the coordinator (the
                // evaluator thread owns the executable). Jobs come from
                // the strategy's (single-wave) candidate plan: one job
                // per batch, designs = the batch's bandwidths. Guided
                // refinement needs per-wave frontier feedback and is
                // rejected by plan_single_wave with a pointer back to
                // the in-process engine.
                let workers = args.opt_u64("workers", 4)? as usize;
                let backend = Backend::Pjrt(BatchEvaluator::default_path());
                let (batches, budget_cut) = plan_single_wave(&prep.space, &prep.strategy, &prep.budget)?;
                if budget_cut > 0 {
                    println!("budget: {budget_cut} candidate design(s) cut by --budget");
                }
                let jobs = jobs_from_batches(&prep.workload, &prep.space, &batches);
                let t0 = std::time::Instant::now();
                let cache = cache_path.as_ref().map(|_| Arc::clone(&store));
                let (results, metrics) = run_jobs_with_store(jobs, backend, workers, cache)?;
                let wall = t0.elapsed().as_secs_f64();
                let macs = results.iter().map(|r| r.macs).fold(0.0, f64::max);
                let mut points = Vec::new();
                for r in &results {
                    points.extend(r.points());
                }
                println!("{}", metrics.summary(wall));
                println!("designs: {} total, {} valid", points.len(), points.iter().filter(|p| p.valid).count());
                let title = format!("{} design space ({})", req.family, prep.workload.name);
                print!("{}", experiments::design_space_scatter(&points, macs, &title));
                print_optima(&points, macs);
            } else {
                // Default path: the sharded scalar sweep engine. With
                // --cache-file the shards pool one persistent store
                // (disk hits surface in the summary's cache= field).
                // The shared store never evicts unless --cache-cap is
                // set, so a cached sweep holds one entry per (variant,
                // PEs) pair per unique shape — warn when that departs
                // meaningfully from the memory-bounded default.
                if cache_path.is_some() && store.max_entries() == 0 {
                    let pairs = prep.space.pairs();
                    if pairs > 10_000 {
                        eprintln!(
                            "cache-file: warning — this space has {pairs} (variant, PEs) pairs; the shared \
                             store retains ~{} entries (one per pair per unique shape) for the whole sweep. \
                             Bound it with --cache-cap N, or drop --cache-file for the memory-bounded default.",
                            pairs * prep.shapes
                        );
                    }
                }
                let mut req = req.clone();
                req.keep_points = true;
                let out = run_prepared_dse(&store, &prep, &req, cache_path.is_some(), None)?;
                if json {
                    println!("{}", Response::Dse(dse_reply(&req, &prep, &out)).encode_line());
                } else {
                    println!("{}", out.sweep.stats.summary());
                    let title = format!("{} design space ({})", req.family, prep.workload.name);
                    print!("{}", experiments::design_space_scatter(&out.sweep.points, prep.macs, &title));
                    println!("runtime-energy Pareto frontier: {} points", out.sweep.frontier.len());
                    let head = &out.sweep.frontier[..out.sweep.frontier.len().min(12)];
                    let t = experiments::frontier_table(head, prep.macs);
                    print!("{}", if args.has("csv") { t.to_csv() } else { t.render() });
                    print_optima(&out.sweep.points, prep.macs);
                }
            }
            close_cache(&store, &cache_path, json)?;
            trace_finish(&trace_path, json)?;
        }
        "serve" => {
            let cache_file = {
                let p = args.opt("cache-file", "");
                if p.is_empty() {
                    None
                } else {
                    Some(p)
                }
            };
            let trace_out = {
                let p = args.opt("trace-out", "");
                if p.is_empty() {
                    None
                } else {
                    Some(p)
                }
            };
            let cfg = ServeConfig {
                addr: args.opt("addr", "127.0.0.1:7733"),
                cache_file,
                cache_cap: args.opt_u64("cache-cap", 0)? as usize,
                workers: args.opt_u64("workers", 2)? as usize,
                queue_cap: args.opt_u64("queue-cap", 16)? as usize,
                flush_every: args.opt_f64("flush-every", 30.0)?,
                threads: args.opt_u64("threads", 0)? as usize,
                verbose: args.has("verbose"),
                trace_out,
                trace_sample: args.opt_u64("trace-sample", 1)?,
            };
            maestro::service::serve(&cfg)?;
        }
        "client" => {
            let addr = args.opt("addr", "127.0.0.1:7733");
            if args.has("metrics") {
                maestro::service::client::metrics(&addr)?;
            } else {
                maestro::service::client::repl(&addr)?;
            }
        }
        "cache" => {
            let action = args.positional.first().map(String::as_str).unwrap_or("");
            match action {
                "compact" => {
                    let path = args.opt_required("cache-file")?;
                    let report = maestro::cache::compact_file(std::path::Path::new(&path))?;
                    if let Some(w) = &report.warning {
                        eprintln!("cache compact: {w}");
                    }
                    println!(
                        "cache compact: {} -> {} record(s) in {path} ({} duplicate(s) removed, {} corrupt byte(s) dropped)",
                        report.records_before,
                        report.records_after,
                        report.records_before - report.records_after,
                        report.dropped_bytes,
                    );
                }
                other => bail!(
                    "unknown cache action '{other}' (usage: maestro cache compact --cache-file <path>)"
                ),
            }
        }
        "table1" => {
            use maestro::engine::reuse::{table1, Opportunity};
            let layer = maestro::model::layer::Layer::conv2d("ref", 1, 64, 64, 56, 56, 3, 3, 1);
            let rows = table1(&layer);
            let sym = |o: Opportunity| match o {
                Opportunity::Multicast => "Multicast",
                Opportunity::Reduction => "Reduction",
                Opportunity::None => "-",
            };
            let mut t = Table::new(&["spatial", "innermost", "sp F", "sp I", "sp O", "tm F", "tm I", "tm O"]);
            for r in rows {
                t.row(&[
                    r.spatial_dim.to_string(),
                    r.innermost_temporal.to_string(),
                    sym(r.spatial[0]).into(),
                    sym(r.spatial[1]).into(),
                    sym(r.spatial[2]).into(),
                    sym(r.temporal[0]).into(),
                    sym(r.temporal[1]).into(),
                    sym(r.temporal[2]).into(),
                ]);
            }
            print!("{}", t.render());
        }
        other => bail!("unknown subcommand '{other}'\n{}", usage(&spec)),
    }
    Ok(())
}

/// When `--remote ADDR` is set, ship the request to that daemon and
/// print every reply frame (streamed progress included) verbatim —
/// the remote twin of `--json`. Returns whether it ran.
fn run_remote(args: &Args, request: Request) -> Result<bool> {
    let addr = args.opt("remote", "");
    if addr.is_empty() {
        return Ok(false);
    }
    for frame in maestro::service::client::call(&addr, &request)? {
        println!("{frame}");
    }
    Ok(true)
}

/// Print the throughput- and energy-optimal designs of a point set.
fn print_optima(points: &[DesignPoint], macs: f64) {
    if let Some(t) = best(points, Optimize::Throughput, macs) {
        println!("throughput-opt: pes={} bw={} area={:.2}mm2 power={:.0}mW thrpt={:.1}", t.pes, t.bandwidth, t.area_mm2, t.power_mw, t.throughput(macs));
    }
    if let Some(e) = best(points, Optimize::Energy, macs) {
        println!("energy-opt:     pes={} bw={} area={:.2}mm2 power={:.0}mW energy={:.2}uJ", e.pes, e.bandwidth, e.area_mm2, e.power_mw, e.energy_pj / 1e6);
    }
}

/// Resolve --model/--layer into a concrete layer (default: VGG16's
/// first layer). `--layer-model` is accepted as a deprecated alias of
/// `--model` by the parser. Resolution itself lives in the service
/// layer ([`pick_layer_named`]) so the daemon reports identical errors.
fn pick_layer(args: &Args) -> Result<(maestro::model::layer::Layer, String)> {
    let model = args.opt("model", "vgg16");
    let lname = args.opt("layer", "");
    pick_layer_named(&model, &lname)
}

fn pick_hw(args: &Args) -> Result<HwConfig> {
    let mut hw = HwConfig::fig10_default();
    hw.num_pes = args.opt_u64("pes", hw.num_pes)?;
    hw.noc_bandwidth = args.opt_u64("bw", hw.noc_bandwidth)?;
    hw.validate()?;
    Ok(hw)
}
