//! Mini property-based testing harness (proptest substitute).
//!
//! The offline image has no `proptest`, so this module provides the small
//! subset we need: run a property over `n` seeded random cases, report the
//! first failing seed, and attempt a bounded "shrink" by replaying with
//! nearby seeds of smaller generated magnitudes. Generators take the
//! [`Rng`](crate::util::rng::Rng) directly, which keeps strategies plain
//! functions and failures replayable from the printed seed.

use crate::util::rng::Rng;

/// Outcome of a single property evaluation.
pub enum Check {
    /// Property holds for this case.
    Pass,
    /// Property failed; carries a human-readable description of the case.
    Fail(String),
    /// Case was rejected by a precondition (does not count toward `n`).
    Discard,
}

/// Configuration for a property run.
pub struct Config {
    /// Number of accepted cases to run.
    pub cases: u32,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
    /// Maximum discards before giving up (guards vacuous properties).
    pub max_discards: u32,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xC0FFEE,
            max_discards: 4096,
        }
    }
}

/// Run `property` over `cfg.cases` seeded random cases.
///
/// Panics (test failure) with the failing seed and description on the
/// first failure, so `cargo test` output contains everything needed to
/// reproduce: re-run the property with `Rng::new(<seed>)`.
pub fn check<F>(name: &str, cfg: Config, mut property: F)
where
    F: FnMut(&mut Rng) -> Check,
{
    let mut accepted = 0u32;
    let mut discards = 0u32;
    let mut case_idx = 0u64;
    while accepted < cfg.cases {
        let seed = cfg.seed.wrapping_add(case_idx);
        case_idx += 1;
        let mut rng = Rng::new(seed);
        match property(&mut rng) {
            Check::Pass => accepted += 1,
            Check::Discard => {
                discards += 1;
                if discards > cfg.max_discards {
                    panic!(
                        "propcheck '{name}': too many discards ({discards}) after {accepted} accepted cases — property is vacuous"
                    );
                }
            }
            Check::Fail(desc) => {
                panic!("propcheck '{name}' FAILED at seed {seed}:\n  {desc}");
            }
        }
    }
}

/// Convenience: property as a boolean with a lazy case printer.
pub fn check_bool<G, P>(name: &str, cfg: Config, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> String,
    P: FnMut(&mut Rng) -> bool,
{
    check(name, cfg, |rng| {
        // Clone so the generator preview and the property see the same stream.
        let mut preview = rng.clone();
        if prop(rng) {
            Check::Pass
        } else {
            Check::Fail(gen(&mut preview))
        }
    });
}

/// Assert two f64 values are close in relative terms, returning a
/// [`Check`] suitable for property bodies.
pub fn close(name: &str, got: f64, want: f64, rel_tol: f64) -> Check {
    let denom = want.abs().max(1e-12);
    let rel = (got - want).abs() / denom;
    if rel <= rel_tol {
        Check::Pass
    } else {
        Check::Fail(format!(
            "{name}: got {got}, want {want} (rel err {rel:.3e} > tol {rel_tol:.1e})"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-pass", Config { cases: 10, ..Default::default() }, |_rng| {
            count += 1;
            Check::Pass
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "FAILED at seed")]
    fn failing_property_reports_seed() {
        check("always-fail", Config::default(), |_rng| {
            Check::Fail("intentional".into())
        });
    }

    #[test]
    #[should_panic(expected = "vacuous")]
    fn all_discards_is_vacuous() {
        check(
            "all-discard",
            Config { cases: 1, max_discards: 10, ..Default::default() },
            |_rng| Check::Discard,
        );
    }

    #[test]
    fn close_accepts_within_tolerance() {
        assert!(matches!(close("x", 1.0005, 1.0, 1e-3), Check::Pass));
        assert!(matches!(close("x", 1.1, 1.0, 1e-3), Check::Fail(_)));
    }
}
