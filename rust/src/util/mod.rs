//! Shared utilities: deterministic PRNG, mini property-test harness,
//! bench harness, CLI argument parsing, JSON codec, leveled logging,
//! and table formatting.
//!
//! The offline build image ships only the `xla` crate's dependency
//! closure, so these modules stand in for `rand`, `proptest`,
//! `criterion`, `clap` and `serde_json` respectively (see DESIGN.md §4
//! — substitutions).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod log;
pub mod pool;
pub mod propcheck;
pub mod queue;
pub mod rng;
pub mod stablehash;
pub mod table;
