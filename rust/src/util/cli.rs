//! Hand-rolled CLI argument parsing (clap substitute).
//!
//! Supports `program <subcommand> [--flag value] [--switch] [positional..]`
//! with typed accessors and an auto-generated usage string. Unknown flags
//! are errors so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand, `--key value` options, `--switch`
/// booleans, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Specification of one accepted flag, used for validation + usage text.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse `argv[1..]`, validating flags against `spec`. The first
    /// non-flag token is the subcommand when `expect_subcommand` is set.
    pub fn parse(
        argv: &[String],
        spec: &[FlagSpec],
        expect_subcommand: bool,
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                let flag = spec
                    .iter()
                    .find(|f| f.name == name)
                    .with_context(|| format!("unknown flag --{name}\n{}", usage(spec)))?;
                if flag.takes_value {
                    i += 1;
                    let val = argv
                        .get(i)
                        .with_context(|| format!("flag --{name} expects a value"))?;
                    args.options.insert(name.to_string(), val.clone());
                } else {
                    args.switches.push(name.to_string());
                }
            } else if expect_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// String option with default.
    pub fn opt(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required string option.
    pub fn opt_required(&self, name: &str) -> Result<String> {
        self.options
            .get(name)
            .cloned()
            .with_context(|| format!("missing required flag --{name}"))
    }

    /// Typed numeric option with default.
    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad integer '{v}'")),
        }
    }

    /// Typed float option with default.
    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad float '{v}'")),
        }
    }

    /// Boolean switch presence.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Parse a comma-separated list of integers (e.g. `--pes 64,128,256`).
    pub fn opt_u64_list(&self, name: &str, default: &[u64]) -> Result<Vec<u64>> {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<u64>()
                        .with_context(|| format!("--{name}: bad integer '{p}'"))
                })
                .collect(),
        }
    }
}

/// Render a usage block from a flag spec.
pub fn usage(spec: &[FlagSpec]) -> String {
    let mut out = String::from("flags:\n");
    for f in spec {
        let arg = if f.takes_value { " <value>" } else { "" };
        out.push_str(&format!("  --{}{arg}\n      {}\n", f.name, f.help));
    }
    out
}

/// Validate that a value is one of an allowed set (for enum-ish flags).
pub fn expect_one_of(name: &str, value: &str, allowed: &[&str]) -> Result<()> {
    if allowed.contains(&value) {
        Ok(())
    } else {
        bail!("--{name}: '{value}' not in {allowed:?}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "model", takes_value: true, help: "model name" },
            FlagSpec { name: "pes", takes_value: true, help: "PE list" },
            FlagSpec { name: "verbose", takes_value: false, help: "chatty" },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = Args::parse(
            &sv(&["analyze", "--model", "vgg16", "--verbose", "extra"]),
            &spec(),
            true,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("analyze"));
        assert_eq!(a.opt("model", ""), "vgg16");
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&sv(&["--nope"]), &spec(), false).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--model"]), &spec(), false).is_err());
    }

    #[test]
    fn u64_list() {
        let a = Args::parse(&sv(&["--pes", "64, 128,256"]), &spec(), false).unwrap();
        assert_eq!(a.opt_u64_list("pes", &[]).unwrap(), vec![64, 128, 256]);
        assert_eq!(a.opt_u64_list("absent", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn one_of() {
        assert!(expect_one_of("obj", "edp", &["runtime", "energy", "edp"]).is_ok());
        assert!(expect_one_of("obj", "zap", &["runtime", "energy", "edp"]).is_err());
    }
}
