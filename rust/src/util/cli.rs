//! Hand-rolled CLI argument parsing (clap substitute).
//!
//! Supports `program <subcommand> [--flag value] [--switch] [positional..]`
//! with typed accessors and an auto-generated usage string. Unknown flags
//! are errors so typos fail loudly.
//!
//! The flags every analysis-running entry point shares (`network` /
//! `map` / `dse` / `serve`) are specified **once**, in
//! [`common_flags`], so spellings and help text cannot drift between
//! subcommands; retired spellings live in [`aliases`] and are accepted
//! with a deprecation warning instead of an error.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand, `--key value` options, `--switch`
/// booleans, and positional arguments. `warnings` collects deprecation
/// notes (old flag spellings) for the caller to surface.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
    pub warnings: Vec<String>,
}

/// Specification of one accepted flag, used for validation + usage text.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// A retired flag spelling: accepted, rewritten to `canonical`, and
/// warned about. When both spellings appear, the canonical one wins
/// regardless of argument order.
#[derive(Debug, Clone, Copy)]
pub struct AliasSpec {
    pub alias: &'static str,
    pub canonical: &'static str,
}

/// The flag surface shared by every subcommand that runs analyses
/// (`network`, `map`, `dse`, `serve`) — one table, identical spellings
/// and help text everywhere. Subcommand-specific flags are appended by
/// the caller.
pub fn common_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec {
            name: "cache-file",
            takes_value: true,
            help: "warm-start analysis cache file (loaded if present, updated on exit)",
        },
        FlagSpec {
            name: "cache-cap",
            takes_value: true,
            help: "bound the in-memory analysis cache to ~N entries (second-chance eviction; 0 = unbounded)",
        },
        FlagSpec {
            name: "budget",
            takes_value: true,
            help: "max designs admitted to evaluation (0 = unlimited; required for --strategy random)",
        },
        FlagSpec {
            name: "budget-seconds",
            takes_value: true,
            help: "wall-clock cutoff in seconds, checked between search waves/shapes (0 = off)",
        },
        FlagSpec {
            name: "threads",
            takes_value: true,
            help: "search worker threads for dse sweeps and map (default 0 = all cores)",
        },
        FlagSpec {
            name: "seed",
            takes_value: true,
            help: "RNG seed for --strategy random (default 1)",
        },
        FlagSpec {
            name: "objective",
            takes_value: true,
            help: "runtime | energy | edp (default runtime)",
        },
        FlagSpec {
            name: "trace-out",
            takes_value: true,
            help: "write a Chrome trace-event JSON file of span telemetry on exit (serve: on shutdown)",
        },
        FlagSpec {
            name: "trace-sample",
            takes_value: true,
            help: "record every Nth span per thread (default 1 = all; only with --trace-out)",
        },
    ]
}

/// Retired spellings accepted (with a warning) by [`Args::parse`].
pub fn aliases() -> Vec<AliasSpec> {
    vec![AliasSpec { alias: "layer-model", canonical: "model" }]
}

impl Args {
    /// Parse `argv[1..]`, validating flags against `spec`. The first
    /// non-flag token is the subcommand when `expect_subcommand` is set.
    /// Retired spellings from [`aliases`] are rewritten to their
    /// canonical flag and recorded in [`Args::warnings`].
    pub fn parse(
        argv: &[String],
        spec: &[FlagSpec],
        expect_subcommand: bool,
    ) -> Result<Args> {
        Args::parse_with(argv, spec, &aliases(), expect_subcommand)
    }

    /// [`Args::parse`] with an explicit alias table (tests use this to
    /// pin the rewrite rules).
    pub fn parse_with(
        argv: &[String],
        spec: &[FlagSpec],
        aliases: &[AliasSpec],
        expect_subcommand: bool,
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut aliased: Vec<&'static str> = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(mut name) = tok.strip_prefix("--") {
                let mut is_alias = false;
                if let Some(a) = aliases.iter().find(|a| a.alias == name) {
                    args.warnings.push(format!(
                        "--{} is deprecated; use --{}",
                        a.alias, a.canonical
                    ));
                    name = a.canonical;
                    is_alias = true;
                }
                let flag = spec
                    .iter()
                    .find(|f| f.name == name)
                    .with_context(|| format!("unknown flag --{name}\n{}", usage(spec)))?;
                if flag.takes_value {
                    i += 1;
                    let val = argv
                        .get(i)
                        .with_context(|| format!("flag --{name} expects a value"))?;
                    if is_alias {
                        // The canonical spelling always wins: only fill
                        // the slot if no canonical value is present yet,
                        // and remember the fill so a later canonical
                        // occurrence can overwrite it.
                        if !args.options.contains_key(name) || aliased.contains(&flag.name) {
                            args.options.insert(name.to_string(), val.clone());
                            aliased.push(flag.name);
                        }
                    } else {
                        args.options.insert(name.to_string(), val.clone());
                        aliased.retain(|n| *n != flag.name);
                    }
                } else {
                    args.switches.push(name.to_string());
                }
            } else if expect_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// String option with default.
    pub fn opt(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required string option.
    pub fn opt_required(&self, name: &str) -> Result<String> {
        self.options
            .get(name)
            .cloned()
            .with_context(|| format!("missing required flag --{name}"))
    }

    /// Typed numeric option with default.
    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad integer '{v}'")),
        }
    }

    /// Typed float option with default.
    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad float '{v}'")),
        }
    }

    /// Boolean switch presence.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Parse a comma-separated list of integers (e.g. `--pes 64,128,256`).
    pub fn opt_u64_list(&self, name: &str, default: &[u64]) -> Result<Vec<u64>> {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<u64>()
                        .with_context(|| format!("--{name}: bad integer '{p}'"))
                })
                .collect(),
        }
    }
}

/// Render a usage block from a flag spec.
pub fn usage(spec: &[FlagSpec]) -> String {
    let mut out = String::from("flags:\n");
    for f in spec {
        let arg = if f.takes_value { " <value>" } else { "" };
        out.push_str(&format!("  --{}{arg}\n      {}\n", f.name, f.help));
    }
    out
}

/// Validate that a value is one of an allowed set (for enum-ish flags).
pub fn expect_one_of(name: &str, value: &str, allowed: &[&str]) -> Result<()> {
    if allowed.contains(&value) {
        Ok(())
    } else {
        bail!("--{name}: '{value}' not in {allowed:?}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "model", takes_value: true, help: "model name" },
            FlagSpec { name: "pes", takes_value: true, help: "PE list" },
            FlagSpec { name: "verbose", takes_value: false, help: "chatty" },
        ]
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = Args::parse(
            &sv(&["analyze", "--model", "vgg16", "--verbose", "extra"]),
            &spec(),
            true,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("analyze"));
        assert_eq!(a.opt("model", ""), "vgg16");
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&sv(&["--nope"]), &spec(), false).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--model"]), &spec(), false).is_err());
    }

    #[test]
    fn u64_list() {
        let a = Args::parse(&sv(&["--pes", "64, 128,256"]), &spec(), false).unwrap();
        assert_eq!(a.opt_u64_list("pes", &[]).unwrap(), vec![64, 128, 256]);
        assert_eq!(a.opt_u64_list("absent", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn one_of() {
        assert!(expect_one_of("obj", "edp", &["runtime", "energy", "edp"]).is_ok());
        assert!(expect_one_of("obj", "zap", &["runtime", "energy", "edp"]).is_err());
    }

    #[test]
    fn common_flags_cover_the_shared_surface() {
        let names: Vec<&str> = common_flags().iter().map(|f| f.name).collect();
        for expect in [
            "cache-file",
            "cache-cap",
            "budget",
            "budget-seconds",
            "threads",
            "seed",
            "objective",
            "trace-out",
            "trace-sample",
        ] {
            assert!(names.contains(&expect), "missing common flag --{expect}");
        }
    }

    #[test]
    fn alias_rewrites_and_warns() {
        let al = [AliasSpec { alias: "layer-model", canonical: "model" }];
        let a = Args::parse_with(&sv(&["--layer-model", "resnet50"]), &spec(), &al, false).unwrap();
        assert_eq!(a.opt("model", ""), "resnet50");
        assert_eq!(a.warnings.len(), 1);
        assert!(a.warnings[0].contains("deprecated"), "{:?}", a.warnings);
    }

    #[test]
    fn canonical_spelling_beats_alias_in_any_order() {
        let al = [AliasSpec { alias: "layer-model", canonical: "model" }];
        for argv in [
            ["--model", "vgg16", "--layer-model", "resnet50"],
            ["--layer-model", "resnet50", "--model", "vgg16"],
        ] {
            let a = Args::parse_with(&sv(&argv), &spec(), &al, false).unwrap();
            assert_eq!(a.opt("model", ""), "vgg16", "{argv:?}");
        }
    }

    #[test]
    fn unknown_alias_target_still_errors() {
        // An alias whose canonical flag is not in the spec is a typo,
        // not a silently-accepted flag.
        let al = [AliasSpec { alias: "old-nope", canonical: "nope" }];
        assert!(Args::parse_with(&sv(&["--old-nope", "x"]), &spec(), &al, false).is_err());
    }
}
