//! A persistent scoped worker pool executing *waves* of indexed jobs
//! with deterministic slot-order collection — the sweep engine's pool
//! idiom (one pool alive across strategy waves, bounded job queue,
//! panic-safe per-wave barrier), extracted so any sharded search can
//! reuse it. Consumers: [`crate::dse::engine::sweep`] (contiguous
//! shards of (variant, PEs) batches) and the layer-wise mapper
//! (`crate::mapspace`, per-shape candidate chunks).
//!
//! ## Contract
//!
//! * **Determinism** — [`WavePool::run_wave`] returns one result per
//!   job, in job order: results land in their submission slots, never
//!   in completion order. Any merge the caller folds in that order
//!   replays its serial reference exactly — the bit-determinism
//!   contract the sweep has pinned since PR 1 (`rust/tests/
//!   dse_parallel.rs`) and the mapper pins in `rust/tests/mapspace.rs`.
//! * **Persistence** — workers spawn once per pool and stay alive
//!   across waves. Feedback-driven searches issue many small waves
//!   (guided refinement, one wave per mapper shape), and per-wave pool
//!   spawning made thread churn scale with the wave count.
//! * **Panic safety** — a panicking job is caught, its slot filled with
//!   `R::default()` so the wave barrier completes, and the panic
//!   re-raised on the worker; the scope join then propagates it to the
//!   caller instead of deadlocking the wave loop.

use std::sync::mpsc::{Receiver, SyncSender, TrySendError};

use crate::util::queue::JobQueue;

/// A pool of scoped workers mapping jobs `J` to results `R`. Create
/// with [`WavePool::spawn`] inside a [`std::thread::scope`]; dropping
/// it (or letting the scope closure end) closes the job queue, drains
/// the workers, and lets the scope join them.
pub struct WavePool<J, R> {
    job_tx: SyncSender<(J, usize)>,
    /// Keeps the job receiver alive even if every worker died, so
    /// `try_send` can never observe a disconnected queue — a dead pool
    /// is reported through the result channel instead (see
    /// [`WavePool::run_wave`]).
    _job_queue: JobQueue<(J, usize)>,
    res_rx: Receiver<(usize, R)>,
}

impl<J, R> WavePool<J, R>
where
    J: Send,
    R: Send + Default,
{
    /// Spawn `threads.max(1)` workers on `scope`, each looping over
    /// queued jobs with `run`. `run` must be `Copy` (capture only
    /// shared references and `Copy` data — every worker gets its own
    /// copy) and may borrow freely from the scope's environment.
    pub fn spawn<'scope, 'env, F>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        threads: usize,
        run: F,
    ) -> WavePool<J, R>
    where
        J: 'scope,
        R: 'scope,
        F: Fn(J) -> R + Send + Copy + 'scope,
    {
        let threads = threads.max(1);
        let (job_tx, job_queue) = JobQueue::<(J, usize)>::bounded(threads * 2);
        let (res_tx, res_rx) = std::sync::mpsc::channel::<(usize, R)>();
        for _ in 0..threads {
            let queue = job_queue.clone();
            let res_tx = res_tx.clone();
            scope.spawn(move || {
                while let Some((job, slot)) = queue.pop() {
                    // Catch panics so the wave barrier (blocked on this
                    // slot's result) can finish the wave and the scope
                    // join re-raises, instead of hanging. The span's E
                    // event lands during unwind, so traces stay
                    // balanced even across a panicking job.
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                        let _span = crate::obs::trace::span("pool.job");
                        run(job)
                    }));
                    match out {
                        Ok(out) => {
                            if res_tx.send((slot, out)).is_err() {
                                break;
                            }
                        }
                        Err(panic) => {
                            let _ = res_tx.send((slot, R::default()));
                            std::panic::resume_unwind(panic);
                        }
                    }
                }
            });
        }
        // The workers now hold the only result senders: if they all
        // die, `res_rx.recv()` errors instead of blocking forever.
        WavePool { job_tx, _job_queue: job_queue, res_rx }
    }

    /// Execute one wave: submit every job, wait for every result, and
    /// return them in job order. A barrier — the pool is idle again
    /// when this returns, so waves never overlap.
    pub fn run_wave(&self, jobs: Vec<J>) -> Vec<R> {
        let n = jobs.len();
        let mut slots: Vec<Option<R>> = Vec::new();
        slots.resize_with(n, || None);
        // A dead pool (every worker panicked) must never hang the wave:
        // results are drained with `recv` (which errors once every
        // worker dropped its sender) while jobs go out with `try_send`
        // — a full queue yields to draining instead of blocking on
        // workers that may no longer exist.
        let recv_one = |slots: &mut Vec<Option<R>>| {
            let (slot, out) = self
                .res_rx
                .recv()
                .expect("wave pool died (worker panic) before finishing the wave");
            slots[slot] = Some(out);
        };
        let mut received = 0usize;
        for (slot, job) in jobs.into_iter().enumerate() {
            let mut job = (job, slot);
            loop {
                match self.job_tx.try_send(job) {
                    Ok(()) => break,
                    Err(TrySendError::Full(back)) => {
                        job = back;
                        recv_one(&mut slots);
                        received += 1;
                    }
                    // `_job_queue` keeps the receiver alive for the
                    // pool's whole lifetime.
                    Err(TrySendError::Disconnected(_)) => {
                        unreachable!("job queue receiver outlives the pool")
                    }
                }
            }
        }
        for _ in received..n {
            recv_one(&mut slots);
        }
        slots.into_iter().map(|s| s.expect("every wave slot filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waves_return_results_in_job_order() {
        std::thread::scope(|scope| {
            let pool = WavePool::spawn(scope, 4, |j: usize| j * 10);
            // More jobs than queue capacity, several waves on one pool.
            for wave in 0..3usize {
                let jobs: Vec<usize> = (0..37).map(|i| i + wave).collect();
                let want: Vec<usize> = jobs.iter().map(|j| j * 10).collect();
                assert_eq!(pool.run_wave(jobs), want, "wave {wave}");
            }
        });
    }

    #[test]
    fn an_empty_wave_is_a_no_op() {
        std::thread::scope(|scope| {
            let pool = WavePool::spawn(scope, 2, |j: usize| j);
            assert!(pool.run_wave(Vec::new()).is_empty());
            assert_eq!(pool.run_wave(vec![7]), vec![7], "pool still live after an empty wave");
        });
    }

    #[test]
    fn a_panicking_job_propagates_through_the_scope_join() {
        let caught = std::panic::catch_unwind(|| {
            std::thread::scope(|scope| {
                let pool = WavePool::spawn(scope, 2, |j: usize| {
                    assert!(j != 5, "boom");
                    j
                });
                // The wave itself completes (the panicked slot holds the
                // default); the panic re-raises when the scope joins.
                let _ = pool.run_wave((0..8).collect());
            });
        });
        assert!(caught.is_err(), "worker panic must re-raise at the scope join");
    }
}
