//! Process-stable hashing (FNV-1a in 64- and 128-bit widths).
//!
//! The cache subsystem keys dataflows by a structural fingerprint and
//! frames its on-disk records with a checksum; both must hash to the
//! same value in every process that ever reads the file, which rules
//! out `std::collections::hash_map::DefaultHasher` (SipHash with
//! per-process random keys). FNV-1a is tiny, dependency-free, and its
//! constants are fixed by specification — exactly what a persistent
//! cache key needs. It is *not* collision-resistant against adversarial
//! input; cache keys here are derived from trusted in-process
//! structures, and the 128-bit width makes accidental collisions
//! negligible.

/// 64-bit FNV-1a (cache-file record checksums; in-memory shard
/// selection deliberately uses std's hasher instead — see
/// `SharedStore::shard_of`).
#[derive(Debug, Clone, Copy)]
pub struct Fnv64 {
    state: u64,
}

impl Fnv64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x00000100000001b3;

    pub fn new() -> Fnv64 {
        Fnv64 { state: Fnv64::OFFSET }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(Fnv64::PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }

    /// One-shot convenience.
    pub fn hash(bytes: &[u8]) -> u64 {
        let mut h = Fnv64::new();
        h.write(bytes);
        h.finish()
    }
}

/// 128-bit FNV-1a (structural dataflow fingerprints).
#[derive(Debug, Clone, Copy)]
pub struct Fnv128 {
    state: u128,
}

impl Fnv128 {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013b;

    pub fn new() -> Fnv128 {
        Fnv128 { state: Fnv128::OFFSET }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(Fnv128::PRIME);
        }
    }

    pub fn write_u8(&mut self, v: u8) {
        self.write(&[v]);
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u128 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv64_known_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(Fnv64::hash(b""), 0xcbf29ce484222325);
        assert_eq!(Fnv64::hash(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(Fnv64::hash(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv128_distinguishes_order_and_content() {
        let mut a = Fnv128::new();
        a.write_u64(1);
        a.write_u64(2);
        let mut b = Fnv128::new();
        b.write_u64(2);
        b.write_u64(1);
        assert_ne!(a.finish(), b.finish(), "order must matter");
        let mut c = Fnv128::new();
        c.write_u64(1);
        c.write_u64(2);
        assert_eq!(a.finish(), c.finish(), "same input, same hash");
        assert_ne!(Fnv128::new().finish(), a.finish());
    }

    #[test]
    fn incremental_equals_oneshot() {
        let mut h = Fnv64::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), Fnv64::hash(b"foobar"));
    }
}
