//! Deterministic xorshift64* PRNG.
//!
//! Used by the property-test harness and the workload generators. We need
//! reproducible streams across runs (benches record seeds in their
//! output), and the offline image has no `rand` crate, so this implements
//! the classic xorshift64* generator (Vigna, 2016).

/// A small, fast, deterministic PRNG (xorshift64*).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is mapped to a fixed
    /// non-zero constant (xorshift state must never be zero).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Rejection-free modulo is fine for our test-generation purposes.
        self.next_u64() % n
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "Rng::range lo > hi");
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "Rng::pick on empty slice");
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        // Must not get stuck at zero.
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
