//! Minimal hand-rolled JSON (serde substitute), for the `service` wire
//! format and the `--json` CLI emitters.
//!
//! The offline image ships only `anyhow`, so the daemon's
//! newline-delimited frames are encoded and parsed here: one [`Json`]
//! value type, a compact stable-order writer ([`Json::dump`]), and a
//! depth-limited recursive-descent parser ([`Json::parse`]). Scope is
//! deliberately the wire format's needs, not the full spec surface:
//!
//! * Objects preserve **insertion order** (a `Vec` of pairs, not a
//!   map), so encoders are byte-stable — the golden tests in
//!   `rust/tests/service_api.rs` pin exact strings.
//! * Numbers are `f64` (JSON's own model). The writer prints integral
//!   values in the exact-`i64` window without a decimal point and
//!   everything else via Rust's shortest-roundtrip `Display`, so
//!   `parse(dump(x)) == x` bit for bit. Non-finite values encode as
//!   `null` (JSON has no NaN/Inf).
//! * Parsing rejects trailing garbage, unterminated input, and nesting
//!   beyond [`MAX_DEPTH`] — a malformed or adversarial frame must fail
//!   loudly (the daemon turns the error into a structured `ApiError`),
//!   never recurse unboundedly.

use anyhow::{bail, ensure, Result};

/// Nesting bound for the parser: wire frames are a couple of levels
/// deep; anything deeper is garbage, not a request.
pub const MAX_DEPTH: usize = 64;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key-value pairs in insertion order (duplicate keys: first wins
    /// on [`Json::get`], all survive a dump — encoders never emit
    /// duplicates).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Empty object, for builder-style chaining with [`Json::set`].
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a key (builder style). Non-objects are left unchanged.
    pub fn set(mut self, key: &str, value: Json) -> Json {
        if let Json::Obj(pairs) = &mut self {
            pairs.push((key.to_string(), value));
        }
        self
    }

    /// Append a key only when `value` is `Some` (optional wire fields
    /// are *omitted*, not `null`, so golden strings stay short).
    pub fn set_opt(self, key: &str, value: Option<Json>) -> Json {
        match value {
            Some(v) => self.set(key, v),
            None => self,
        }
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn int(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// Field lookup on objects (`None` elsewhere).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Numeric field as `u64` (rejects negatives and non-integers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Compact single-line encoding (no whitespace — one frame, one
    /// line is the daemon's protocol, and string escaping guarantees no
    /// raw newline can appear inside a frame).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(*v, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON value; trailing non-whitespace is an error (a
    /// frame is exactly one value).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        ensure!(pos == bytes.len(), "json: trailing garbage at byte {pos}");
        Ok(value)
    }
}

/// Integral doubles inside the exact-`i64` window print without a
/// decimal point (the wire format's counters and ids); everything else
/// uses Rust's shortest-roundtrip float formatting.
fn write_num(v: f64, out: &mut String) {
    if !v.is_finite() {
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < 9.0e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        out.push_str(&format!("{v}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    ensure!(depth < MAX_DEPTH, "json: nesting deeper than {MAX_DEPTH}");
    skip_ws(bytes, pos);
    let Some(&b) = bytes.get(*pos) else { bail!("json: unexpected end of input") };
    match b {
        b'n' => parse_lit(bytes, pos, "null", Json::Null),
        b't' => parse_lit(bytes, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(bytes, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(bytes, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos, depth + 1)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("json: expected ',' or ']' at byte {pos}"),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut pairs = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            loop {
                skip_ws(bytes, pos);
                ensure!(bytes.get(*pos) == Some(&b'"'), "json: expected object key at byte {pos}");
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                ensure!(bytes.get(*pos) == Some(&b':'), "json: expected ':' at byte {pos}");
                *pos += 1;
                let value = parse_value(bytes, pos, depth + 1)?;
                pairs.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(pairs));
                    }
                    _ => bail!("json: expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(bytes, pos),
        other => bail!("json: unexpected byte {:?} at {pos}", other as char),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("json: bad literal at byte {pos} (expected {lit})")
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).expect("numeric bytes are ASCII");
    let v: f64 = text.parse().map_err(|_| anyhow::anyhow!("json: bad number '{text}'"))?;
    ensure!(v.is_finite(), "json: non-finite number '{text}'");
    Ok(Json::Num(v))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    // Caller verified the opening quote.
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&b) = bytes.get(*pos) else { bail!("json: unterminated string") };
        match b {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                let Some(&esc) = bytes.get(*pos) else { bail!("json: unterminated escape") };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = parse_hex4(bytes, pos)?;
                        // Surrogate pairs: a high surrogate must be
                        // followed by an escaped low surrogate; lone
                        // surrogates become U+FFFD rather than failing
                        // the whole frame.
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            if bytes.get(*pos) == Some(&b'\\') && bytes.get(*pos + 1) == Some(&b'u')
                            {
                                *pos += 2;
                                let lo = parse_hex4(bytes, pos)?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    let code =
                                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(code).unwrap_or('\u{FFFD}')
                                } else {
                                    '\u{FFFD}'
                                }
                            } else {
                                '\u{FFFD}'
                            }
                        } else {
                            char::from_u32(hi).unwrap_or('\u{FFFD}')
                        };
                        out.push(c);
                    }
                    other => bail!("json: bad escape '\\{}'", other as char),
                }
            }
            _ => {
                // Consume one UTF-8 scalar (input is a &str, so the
                // bytes are valid UTF-8 by construction).
                let rest = std::str::from_utf8(&bytes[*pos..]).expect("input was a &str");
                let c = rest.chars().next().expect("non-empty");
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    ensure!(*pos + 4 <= bytes.len(), "json: truncated \\u escape");
    let text = std::str::from_utf8(&bytes[*pos..*pos + 4])
        .map_err(|_| anyhow::anyhow!("json: bad \\u escape"))?;
    let v = u32::from_str_radix(text, 16).map_err(|_| anyhow::anyhow!("json: bad \\u escape"))?;
    *pos += 4;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dump_is_compact_and_ordered() {
        let v = Json::obj()
            .set("b", Json::int(2))
            .set("a", Json::str("x"))
            .set("list", Json::Arr(vec![Json::Null, Json::Bool(true)]));
        assert_eq!(v.dump(), r#"{"b":2,"a":"x","list":[null,true]}"#);
    }

    #[test]
    fn numbers_round_trip() {
        for v in [0.0, 1.0, -7.0, 0.17, 1e-9, 123456789.25, 9.0e18, f64::MIN_POSITIVE] {
            let dumped = Json::Num(v).dump();
            let parsed = Json::parse(&dumped).unwrap();
            assert_eq!(parsed.as_f64().unwrap().to_bits(), v.to_bits(), "{v} via {dumped}");
        }
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }

    #[test]
    fn strings_escape_and_round_trip() {
        let s = "line\nquote\" back\\slash \t unicode: µ 日本 \u{0001}";
        let dumped = Json::str(s).dump();
        assert!(!dumped.contains('\n'), "frames must stay single-line: {dumped}");
        assert_eq!(Json::parse(&dumped).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn parses_nested_with_whitespace() {
        let v = Json::parse(" { \"a\" : [ 1 , { \"b\" : \"c\" } ] , \"d\" : false } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_u64(), Some(1));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn surrogate_pairs_decode() {
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
        assert_eq!(Json::parse(r#""\ud800x""#).unwrap().as_str().unwrap(), "\u{FFFD}x");
    }

    #[test]
    fn malformed_inputs_error() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated", "{\"a\":1} trailing",
            "nan", "01x",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must not parse");
        }
        // Depth bomb: 80 nested arrays exceed MAX_DEPTH.
        let bomb = "[".repeat(80) + &"]".repeat(80);
        assert!(Json::parse(&bomb).is_err());
    }

    #[test]
    fn as_u64_rejects_non_integers() {
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::str("3").as_u64(), None);
    }
}
