//! Leveled logging for the daemon and its background threads: one
//! writer (a locked stderr handle), monotonic-clock timestamps, three
//! levels.
//!
//! This replaces the daemon's ad-hoc `println!` / `--verbose`
//! `eprintln!` mix: every line goes to **stderr** through one lock, so
//! concurrent scheduler / flusher / connection threads can never
//! interleave mid-line, and every line is stamped with seconds since
//! the process log epoch (a monotonic [`Instant`], immune to wall-clock
//! steps). The format is fixed:
//!
//! ```text
//! [+12.345s] INFO serve: listening on 127.0.0.1:7733 (2 worker(s), queue cap 16)
//! ```
//!
//! The default level is [`Level::Info`]; `maestro serve --verbose`
//! raises it to [`Level::Debug`] (per-request completion lines).
//! Filtering is a relaxed atomic load, so a suppressed [`debug`] call
//! costs nothing measurable. Like everything in `obs`, logging is
//! observation-only — no code path reads the level to decide real
//! work.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Info = 1,
    Debug = 2,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// Current filter level as its discriminant (default: Info).
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Set the process-wide filter: lines above `level` are dropped.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Would a line at `level` currently be written?
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Write one line: `[+<monotonic seconds>s] LEVEL module: msg`.
pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = epoch().elapsed().as_secs_f64();
    // One writer: the stderr lock serializes whole lines across
    // threads.
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[+{t:.3}s] {} {module}: {msg}", level.tag());
}

pub fn error(module: &str, msg: &str) {
    log(Level::Error, module, msg);
}

pub fn info(module: &str, msg: &str) {
    log(Level::Info, module, msg);
}

pub fn debug(module: &str, msg: &str) {
    log(Level::Debug, module, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_orders_error_info_debug() {
        // The level is process-global, so exercise the whole ladder in
        // one test and restore the default afterwards.
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(enabled(Level::Debug));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
