//! Aligned text tables and ASCII scatter plots for experiment reports.
//!
//! The benches regenerate the paper's figures as tables/plots on stdout
//! (captured to `bench_output.txt`); this module is the shared renderer.

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn rowd(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * ncols;
        out.push_str(&"-".repeat(total.min(160)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for EXPERIMENTS.md ingestion / plotting elsewhere).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-style precision appropriate to tables.
pub fn num(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1e6 || v.abs() < 1e-2 {
        format!("{v:.3e}")
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

/// ASCII scatter plot: points (x, y) with an optional class label per
/// point rendered as its character. Axes are linear or log10.
pub struct Scatter {
    pub title: String,
    pub xlabel: String,
    pub ylabel: String,
    pub logx: bool,
    pub logy: bool,
    pub points: Vec<(f64, f64, char)>,
}

impl Scatter {
    pub fn new(title: &str, xlabel: &str, ylabel: &str) -> Self {
        Scatter {
            title: title.into(),
            xlabel: xlabel.into(),
            ylabel: ylabel.into(),
            logx: false,
            logy: false,
            points: Vec::new(),
        }
    }

    pub fn point(&mut self, x: f64, y: f64, c: char) {
        if x.is_finite() && y.is_finite() {
            self.points.push((x, y, c));
        }
    }

    /// Render into a `width x height` character grid. Later points
    /// overwrite earlier ones (so marked optima stay visible).
    pub fn render(&self, width: usize, height: usize) -> String {
        if self.points.is_empty() {
            return format!("{}: <no points>\n", self.title);
        }
        let tx = |v: f64| if self.logx { v.max(1e-30).log10() } else { v };
        let ty = |v: f64| if self.logy { v.max(1e-30).log10() } else { v };
        let xs: Vec<f64> = self.points.iter().map(|p| tx(p.0)).collect();
        let ys: Vec<f64> = self.points.iter().map(|p| ty(p.1)).collect();
        let (x0, x1) = min_max(&xs);
        let (y0, y1) = min_max(&ys);
        let xr = (x1 - x0).max(1e-12);
        let yr = (y1 - y0).max(1e-12);
        let mut grid = vec![vec![' '; width]; height];
        for ((x, y), &(_, _, c)) in xs.iter().zip(&ys).zip(&self.points) {
            let col = (((x - x0) / xr) * (width - 1) as f64).round() as usize;
            let row = (((y - y0) / yr) * (height - 1) as f64).round() as usize;
            grid[height - 1 - row][col] = c;
        }
        let mut out = format!("{}  [y: {}, x: {}]\n", self.title, self.ylabel, self.xlabel);
        out.push_str(&format!("  y_max = {}\n", num(y1_orig(self, y1))));
        for row in grid {
            out.push_str("  |");
            out.extend(row);
            out.push('\n');
        }
        out.push_str(&format!(
            "  +{}\n  y_min = {}, x: [{}, {}]\n",
            "-".repeat(width),
            num(y0_orig(self, y0)),
            num(x0_orig(self, x0)),
            num(x0_orig(self, x1)),
        ));
        out
    }
}

fn min_max(v: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in v {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

fn y0_orig(s: &Scatter, v: f64) -> f64 {
    if s.logy { 10f64.powf(v) } else { v }
}
fn y1_orig(s: &Scatter, v: f64) -> f64 {
    if s.logy { 10f64.powf(v) } else { v }
}
fn x0_orig(s: &Scatter, v: f64) -> f64 {
    if s.logx { 10f64.powf(v) } else { v }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("a    bbbb"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a,b", "c"]);
        t.row(&["x\"y".into(), "z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"x\"\"y\""));
    }

    #[test]
    fn scatter_renders_points() {
        let mut s = Scatter::new("t", "x", "y");
        s.point(0.0, 0.0, 'a');
        s.point(1.0, 1.0, 'b');
        let r = s.render(20, 5);
        assert!(r.contains('a'));
        assert!(r.contains('b'));
    }

    #[test]
    fn scatter_empty_ok() {
        let s = Scatter::new("t", "x", "y");
        assert!(s.render(10, 5).contains("no points"));
    }

    #[test]
    fn num_formats() {
        assert_eq!(num(0.0), "0");
        assert!(num(1e9).contains('e'));
        assert_eq!(num(123.456), "123.5");
    }
}
