//! Minimal benchmarking harness (criterion substitute).
//!
//! `cargo bench` targets in this repo are declared with `harness = false`
//! and drive this module directly. Each measurement runs a warm-up, then
//! `samples` timed iterations, and reports min / median / mean / p95 plus
//! derived throughput. Results are printed as aligned text (captured into
//! `bench_output.txt` by the Makefile) — the experiment benches also emit
//! the paper-figure tables around these timings.

use std::time::{Duration, Instant};

/// Result of one benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn min(&self) -> Duration {
        self.samples.iter().copied().min().unwrap_or_default()
    }
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }
    pub fn p95(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        let idx = ((s.len() as f64 * 0.95) as usize).min(s.len().saturating_sub(1));
        s[idx]
    }
}

/// Time `f` with `samples` measured iterations after `warmup` unmeasured
/// ones. The closure's return value is black-boxed to keep the optimizer
/// honest.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: u32, samples: u32, mut f: F) -> Measurement {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut out = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t0 = Instant::now();
        black_box(f());
        out.push(t0.elapsed());
    }
    let m = Measurement { name: name.to_string(), samples: out };
    print_measurement(&m, None);
    m
}

/// Like [`bench`] but also reports `items / sec` throughput where `items`
/// is the amount of work done per iteration (e.g. design points).
pub fn bench_throughput<T, F: FnMut() -> T>(
    name: &str,
    items_per_iter: u64,
    warmup: u32,
    samples: u32,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut out = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t0 = Instant::now();
        black_box(f());
        out.push(t0.elapsed());
    }
    let m = Measurement { name: name.to_string(), samples: out };
    print_measurement(&m, Some(items_per_iter));
    m
}

fn print_measurement(m: &Measurement, items: Option<u64>) {
    let med = m.median();
    let line = format!(
        "bench {:<44} min {:>12} med {:>12} mean {:>12}",
        m.name,
        fmt_dur(m.min()),
        fmt_dur(med),
        fmt_dur(m.mean()),
    );
    match items {
        Some(n) if med.as_nanos() > 0 => {
            let rate = n as f64 / med.as_secs_f64();
            println!("{line}  thrpt {:>14}/s", fmt_rate(rate));
        }
        _ => println!("{line}"),
    }
}

/// Format a duration with an adaptive unit.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Format a rate with an adaptive SI suffix.
pub fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}K", r / 1e3)
    } else {
        format!("{r:.1}")
    }
}

/// Optimizer barrier (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header so bench output is navigable per figure/table.
pub fn section(title: &str) {
    println!();
    println!("==== {title} ====");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let m = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.min() <= m.mean() || m.samples.iter().all(|d| d.as_nanos() == 0));
    }

    #[test]
    fn formats() {
        assert!(fmt_dur(Duration::from_nanos(10)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(10)).contains("us"));
        assert!(fmt_dur(Duration::from_millis(10)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(10)).contains("s"));
        assert_eq!(fmt_rate(2_000_000.0), "2.00M");
    }
}
