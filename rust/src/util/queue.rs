//! A bounded multi-consumer work queue — the `Arc<Mutex<Receiver>>`
//! idiom the coordinator's prep workers proved out, extracted here so
//! the sharded DSE sweep (and any future layer) can share it without a
//! module cycle.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Clone the queue once per worker; [`JobQueue::pop`] blocks until an
/// item arrives or every sender is gone.
pub struct JobQueue<T> {
    rx: Arc<Mutex<Receiver<T>>>,
}

impl<T> Clone for JobQueue<T> {
    fn clone(&self) -> JobQueue<T> {
        JobQueue { rx: Arc::clone(&self.rx) }
    }
}

impl<T: Send> JobQueue<T> {
    /// Bounded queue; feed work through the returned sender and drop it
    /// (and all clones) to close the queue.
    pub fn bounded(cap: usize) -> (SyncSender<T>, JobQueue<T>) {
        let (tx, rx) = sync_channel(cap.max(1));
        (tx, JobQueue { rx: Arc::new(Mutex::new(rx)) })
    }

    /// A queue preloaded with a finite work list and already closed:
    /// consumers drain the items in order, then see `None`.
    pub fn preloaded(items: Vec<T>) -> JobQueue<T> {
        let (tx, queue) = JobQueue::bounded(items.len());
        for item in items {
            tx.send(item).expect("preloaded queue has capacity for every item");
        }
        queue
    }

    /// Next item, or `None` once the queue is closed and drained.
    pub fn pop(&self) -> Option<T> {
        self.rx.lock().unwrap().recv().ok()
    }

    /// Non-blocking pop. `Err(Empty)` means no item *right now*;
    /// `Err(Disconnected)` means the queue is closed and drained — the
    /// distinction a scheduler needs to drain-then-continue vs stop
    /// (plain [`JobQueue::pop`] folds both into `None`).
    pub fn try_pop(&self) -> Result<T, TryRecvError> {
        self.rx.lock().unwrap().try_recv()
    }

    /// Blocking pop with a timeout — the idle tick of a loop that also
    /// watches other state (e.g. the serve scheduler between waves).
    pub fn pop_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        self.rx.lock().unwrap().recv_timeout(timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preloaded_queue_drains_in_order_then_closes() {
        let q = JobQueue::preloaded(vec![1, 2, 3]);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        // Empty lists are fine too.
        let empty = JobQueue::<u32>::preloaded(Vec::new());
        assert_eq!(empty.pop(), None);
    }

    #[test]
    fn shared_queue_consumes_each_item_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let q = JobQueue::preloaded((0..100u64).collect());
        let total = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let q = q.clone();
                let total = &total;
                scope.spawn(move || {
                    while let Some(v) = q.pop() {
                        total.fetch_add(v, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    }
}
