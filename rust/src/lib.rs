//! # maestro — a reproduction of "Understanding Reuse, Performance, and
//! Hardware Cost of DNN Dataflows: A Data-Centric Approach" (MICRO-52).
//!
//! The crate is organised as the paper's system plus every substrate it
//! depends on (see `DESIGN.md` for the inventory):
//!
//! * [`ir`] — the data-centric directive IR (`SpatialMap`, `TemporalMap`,
//!   `Cluster`, data-movement order), a MAESTRO-style DSL parser, a
//!   compute-centric loop-nest notation and its conversion to directives,
//!   and the five evaluation dataflow styles of Table 3.
//! * [`model`] — 7-dimensional tensor/layer descriptions (the *tensor
//!   analysis engine*: dimension coupling), and a model zoo (VGG16,
//!   AlexNet, ResNet50, ResNeXt50, MobileNetV2, UNet, DCGAN).
//! * [`engine`] — the analytical core: cluster analysis, mapping /
//!   iteration-case analysis, reuse analysis, performance analysis with
//!   the NoC pipe model, and cost analysis.
//! * [`hw`] — hardware configuration, Cacti-fit energy model, and the
//!   area/power regression models used by the DSE.
//! * [`sim`] — a cycle-level schedule simulator used as the RTL-substitute
//!   ground truth for Fig 9 style validation.
//! * [`cache`] — the analysis cache subsystem: structural
//!   [`DataflowFingerprint`] identity (no name aliasing), the
//!   [`SharedStore`] concurrent map sweeps and coordinator workers
//!   share, and append-only on-disk persistence for `--cache-file`
//!   warm starts.
//! * [`dse`] — the hardware design-space exploration engine: pluggable
//!   budgeted search strategies (exhaustive / random / Pareto-guided)
//!   over a sharded parallel sweep with §5.2 invalid-design skipping
//!   and streaming Pareto accumulation (see the module docs for the
//!   architecture), plus Pareto extraction and objectives.
//! * [`mapspace`] — the mapping-space subsystem: Table 3 style
//!   templates with declared tileable knobs, programmatic per-layer
//!   tiling enumeration (resolve-validated, fingerprint-deduped), and
//!   the layer-wise [`mapspace::Mapper`] behind `maestro map`. Backs
//!   the DSE's variant axis.
//! * [`runtime`] — PJRT (xla crate, behind the `pjrt` cargo feature)
//!   loader/executor for the AOT-compiled batched evaluator
//!   (`artifacts/dse_eval.hlo.txt`); a stub that falls back to the
//!   scalar backend otherwise.
//! * [`coordinator`] — the L3 orchestration: worker threads, design-point
//!   batching, backpressure, metrics.
//! * [`service`] — DSE-as-a-service: the typed, versioned
//!   request/response API shared by the CLI (`--json`) and the
//!   resident `maestro serve` daemon (warm [`SharedStore`], bounded
//!   backpressure, cooperative cancellation).
//! * [`obs`] — zero-dependency telemetry: a process-wide metrics
//!   registry (counters / gauges / fixed-bucket histograms behind the
//!   daemon's `metrics` request) and span tracing with a Chrome
//!   trace-event exporter (`--trace-out`). Observation-only by
//!   contract: replies and frontiers are bit-identical with telemetry
//!   on, off, or sampled.
//! * [`report`] — table/CSV/ASCII-scatter emitters for the experiment
//!   drivers.
//! * [`util`] — CLI parsing, a mini property-test harness, a bench
//!   harness, and a deterministic PRNG (offline image substitutes for
//!   clap/proptest/criterion).

pub mod cache;
pub mod coordinator;
pub mod dse;
pub mod engine;
pub mod hw;
pub mod ir;
pub mod mapspace;
pub mod model;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod util;

pub use cache::{DataflowFingerprint, SharedStore};
pub use engine::analysis::{analyze_layer, analyze_network, Analyzer, LayerStats, NetworkStats};
pub use hw::config::HwConfig;
pub use ir::dataflow::Dataflow;
pub use mapspace::{Mapper, MapperConfig, StyleTemplate};
pub use model::layer::{Layer, ShapeKey};
pub use model::network::Network;
