//! Span tracing with per-thread buffers and a Chrome trace-event
//! exporter.
//!
//! [`span`] is the whole instrumentation API: it returns a guard that
//! records a `B` (begin) event now and the matching `E` (end) event
//! when dropped. Guards must be dropped on the thread that created
//! them (every call site here is a stack-scoped `let _span = ...`), so
//! each thread's event stream is balanced and its timestamps are
//! nondecreasing by construction — [`validate`] pins both properties
//! on exported traces.
//!
//! "Lock-free enough": each OS thread owns one event buffer behind its
//! own mutex, locked only by that thread while recording and by
//! [`export`] at the end — there is no cross-thread contention on the
//! hot path, and a disabled [`span`] is a single relaxed atomic load.
//! Buffers cap at [`THREAD_EVENT_CAP`] begin events; beyond it, spans
//! are counted as dropped rather than growing without bound (an `E`
//! whose `B` was recorded always lands, so truncation never unbalances
//! a trace).
//!
//! Sampling: [`enable`] takes `sample_every` — record every Nth span
//! *per thread* (1 = all). A sampled-out span skips both its `B` and
//! `E`, so sampled traces stay balanced.
//!
//! The export format is the Chrome trace-event JSON object form
//! (`{"traceEvents": [...]}`), loadable in `chrome://tracing` and
//! Perfetto. Timestamps are microseconds on a process-wide monotonic
//! epoch; `pid` is constant 1; `tid`s are assigned in thread
//! registration order.

use std::cell::{Cell, OnceCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::util::json::Json;

/// Begin events a single thread may buffer before new spans are
/// dropped (counted, never silently lost).
pub const THREAD_EVENT_CAP: usize = 1 << 20;

/// One recorded event. Span names are `&'static str` so recording
/// never allocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Event {
    name: &'static str,
    /// `b'B'` (begin) or `b'E'` (end).
    phase: u8,
    /// Microseconds since the process trace epoch.
    ts_us: u64,
}

/// One thread's event buffer. Only its owner thread pushes; `export`
/// reads under the same lock.
struct ThreadBuf {
    tid: u64,
    events: Mutex<Vec<Event>>,
    dropped: AtomicU64,
}

struct TraceState {
    epoch: Instant,
    sample_every: AtomicU64,
    buffers: Mutex<Vec<Arc<ThreadBuf>>>,
}

/// Fast-path switch, outside the `OnceLock` so a disabled [`span`]
/// costs one load and no initialization.
static ENABLED: AtomicBool = AtomicBool::new(false);

fn state() -> &'static TraceState {
    static STATE: OnceLock<TraceState> = OnceLock::new();
    STATE.get_or_init(|| TraceState {
        epoch: Instant::now(),
        sample_every: AtomicU64::new(1),
        buffers: Mutex::new(Vec::new()),
    })
}

struct Local {
    buf: Arc<ThreadBuf>,
    /// Spans entered on this thread (the per-thread sampling clock).
    seen: Cell<u64>,
}

thread_local! {
    static LOCAL: OnceCell<Local> = const { OnceCell::new() };
}

fn local_init() -> Local {
    let st = state();
    let mut buffers = st.buffers.lock().unwrap();
    let buf = Arc::new(ThreadBuf {
        tid: buffers.len() as u64 + 1,
        events: Mutex::new(Vec::new()),
        dropped: AtomicU64::new(0),
    });
    buffers.push(Arc::clone(&buf));
    Local { buf, seen: Cell::new(0) }
}

/// Turn tracing on. `sample_every` records every Nth span per thread
/// (values below 1 mean 1 = record everything). Sticky until
/// [`disable`]; flipping it mid-run only changes what gets recorded,
/// never what any engine computes.
pub fn enable(sample_every: u64) {
    state().sample_every.store(sample_every.max(1), Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn tracing off (spans become no-ops; buffered events survive
/// until [`clear`]).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Drop every buffered event and dropped-span count (benches and tests
/// isolating runs; registered threads keep their tids).
pub fn clear() {
    let st = state();
    let buffers = st.buffers.lock().unwrap();
    for buf in buffers.iter() {
        buf.events.lock().unwrap().clear();
        buf.dropped.store(0, Ordering::Relaxed);
    }
}

/// RAII span guard: `B` recorded at construction, `E` at drop. Must be
/// dropped on the creating thread (stack scope it).
pub struct SpanGuard {
    /// `Some(name)` when the `B` event was recorded — the `E` event is
    /// emitted iff the `B` was, keeping traces balanced under
    /// sampling, capping, and mid-span disable.
    armed: Option<&'static str>,
}

/// Open a span. With tracing disabled this is one relaxed load and a
/// no-op guard.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { armed: None };
    }
    span_slow(name)
}

fn span_slow(name: &'static str) -> SpanGuard {
    let st = state();
    LOCAL.with(|cell| {
        let local = cell.get_or_init(local_init);
        let n = local.seen.get();
        local.seen.set(n.wrapping_add(1));
        let every = st.sample_every.load(Ordering::Relaxed).max(1);
        if n % every != 0 {
            return SpanGuard { armed: None };
        }
        let ts_us = st.epoch.elapsed().as_micros() as u64;
        let mut events = local.buf.events.lock().unwrap();
        if events.len() >= THREAD_EVENT_CAP {
            local.buf.dropped.fetch_add(1, Ordering::Relaxed);
            return SpanGuard { armed: None };
        }
        events.push(Event { name, phase: b'B', ts_us });
        SpanGuard { armed: Some(name) }
    })
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(name) = self.armed.take() else { return };
        let st = state();
        let ts_us = st.epoch.elapsed().as_micros() as u64;
        LOCAL.with(|cell| {
            // The creating thread recorded the B, so its Local exists;
            // the E lands unconditionally (even past the cap or after
            // disable) so the trace stays balanced.
            if let Some(local) = cell.get() {
                local.buf.events.lock().unwrap().push(Event { name, phase: b'E', ts_us });
            }
        });
    }
}

/// Export every buffered event as a Chrome trace-event JSON object
/// (`{"traceEvents": [...], "otherData": {...}}`). Non-destructive;
/// [`clear`] resets between runs.
pub fn export() -> Json {
    let st = state();
    let buffers = st.buffers.lock().unwrap();
    let mut events: Vec<Json> = Vec::new();
    let mut dropped = 0u64;
    for buf in buffers.iter() {
        dropped += buf.dropped.load(Ordering::Relaxed);
        for e in buf.events.lock().unwrap().iter() {
            events.push(
                Json::obj()
                    .set("name", Json::str(e.name))
                    .set("ph", Json::str(if e.phase == b'B' { "B" } else { "E" }))
                    .set("ts", Json::int(e.ts_us))
                    .set("pid", Json::int(1))
                    .set("tid", Json::int(buf.tid)),
            );
        }
    }
    Json::obj()
        .set("traceEvents", Json::Arr(events))
        .set("otherData", Json::obj().set("dropped_spans", Json::int(dropped)))
}

/// Export to a file (the CLI's `--trace-out`), validating first so a
/// malformed trace can never be written.
pub fn write_file(path: &str) -> Result<TraceSummary> {
    let trace = export();
    let summary = validate(&trace)?;
    std::fs::write(path, trace.dump())
        .map_err(|e| anyhow::anyhow!("trace: cannot write {path}: {e}"))?;
    Ok(summary)
}

/// What [`validate`] measured about a structurally sound trace.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TraceSummary {
    /// Total events across all threads.
    pub events: usize,
    /// Distinct `tid`s seen.
    pub threads: usize,
    /// Deepest `B` nesting on any one thread.
    pub max_depth: usize,
}

/// Structural validator for Chrome trace-event JSON (object form):
/// every event carries `name`/`ph`/`ts`/`pid`/`tid`, phases are `B` or
/// `E`, each thread's `B`/`E` events balance like a bracket sequence
/// with matching names, and each thread's timestamps are
/// nondecreasing in event order. Shared by the `obs_trace` test, the
/// bench smokes, and [`write_file`] itself.
pub fn validate(trace: &Json) -> Result<TraceSummary> {
    let Some(events) = trace.get("traceEvents").and_then(Json::as_arr) else {
        bail!("trace: missing 'traceEvents' array");
    };
    let mut per_thread: std::collections::BTreeMap<u64, (Vec<String>, u64)> =
        std::collections::BTreeMap::new();
    let mut max_depth = 0usize;
    for (i, e) in events.iter().enumerate() {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("trace: event {i} has no 'name'"))?;
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("trace: event {i} has no 'ph'"))?;
        let ts = e
            .get("ts")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("trace: event {i} has no integer 'ts'"))?;
        ensure!(e.get("pid").and_then(Json::as_u64).is_some(), "trace: event {i} has no 'pid'");
        let tid = e
            .get("tid")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("trace: event {i} has no integer 'tid'"))?;
        let (stack, last_ts) = per_thread.entry(tid).or_default();
        ensure!(
            ts >= *last_ts,
            "trace: tid {tid} time went backwards at event {i} ({ts} < {last_ts})"
        );
        *last_ts = ts;
        match ph {
            "B" => {
                stack.push(name.to_string());
                max_depth = max_depth.max(stack.len());
            }
            "E" => {
                let Some(open) = stack.pop() else {
                    bail!("trace: tid {tid} ends '{name}' with no span open (event {i})");
                };
                ensure!(
                    open == name,
                    "trace: tid {tid} ends '{name}' but '{open}' is open (event {i})"
                );
            }
            other => bail!("trace: event {i} has unsupported phase '{other}'"),
        }
    }
    for (tid, (stack, _)) in &per_thread {
        ensure!(
            stack.is_empty(),
            "trace: tid {tid} leaves {} span(s) open ({})",
            stack.len(),
            stack.join(", ")
        );
    }
    Ok(TraceSummary {
        events: events.len(),
        threads: per_thread.len(),
        max_depth,
    })
}
