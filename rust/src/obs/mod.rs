//! Telemetry: process-wide metrics and span tracing for the
//! analysis/DSE/serve stack.
//!
//! Two hand-rolled, zero-dependency halves (the `util::json` policy —
//! the offline image ships only `anyhow`):
//!
//! * [`metrics`] — a process-wide registry of named instruments:
//!   monotonic [`metrics::Counter`]s, last-value [`metrics::Gauge`]s,
//!   and fixed-bucket [`metrics::Histogram`]s. Instruments register
//!   lazily on first use and live for the process; the daemon's
//!   `metrics` request kind serializes a [`metrics::snapshot`] of all
//!   of them. This absorbs the diagnostics that used to live in
//!   scattered per-request counters (cache hit/miss/evict splits,
//!   `profile_hits`, queue depth, pool utilization, wave latencies,
//!   per-request designs/s, `retry_after_ms` quotes) behind stable
//!   names — see the README's instrument table.
//!
//! * [`trace`] — span-based tracing with per-thread event buffers and a
//!   Chrome trace-event JSON exporter. [`trace::span`] returns an RAII
//!   guard that records a `B` (begin) event at construction and the
//!   matching `E` (end) at drop on the same thread, so exported traces
//!   are balanced and per-thread-monotonic by construction
//!   ([`trace::validate`] pins that structurally). Tracing is off by
//!   default — a disabled `span` is one relaxed atomic load — and is
//!   switched on by `--trace-out FILE` (CLI runs and `maestro serve`),
//!   which writes a file loadable in `chrome://tracing` / Perfetto.
//!
//! **The determinism contract carve-out:** telemetry is observation
//! only. Enabling, disabling, or sampling it never changes a reply
//! byte, a streamed frame, or a frontier bit — instruments and spans
//! read clocks and write side buffers, and nothing in the engine ever
//! reads them back. `rust/tests/serve_concurrent.rs` pins replies and
//! stream frames bit-identical with telemetry off, on, and sampled.

pub mod metrics;
pub mod trace;
