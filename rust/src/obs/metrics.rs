//! The process-wide metrics registry: named counters, gauges, and
//! fixed-bucket histograms.
//!
//! Instruments register lazily: the first [`counter`] / [`gauge`] /
//! [`histogram`] call for a name creates the instrument, every later
//! call returns the same `Arc` (hot call sites may cache it). Values
//! are plain relaxed atomics — increments and observations never block
//! each other; only registration and [`snapshot`] take the registry
//! lock. Names are sorted in snapshots so serialized metric frames are
//! byte-stable for a given set of values.
//!
//! Cost policy: the analysis hot path (millions of design evaluations
//! per second) never touches the global registry per evaluation —
//! per-request counters are folded in at request granularity (the
//! daemon's `conclude`), and point-in-time store/scheduler gauges are
//! sampled only when a `metrics` request arrives. Everything here is
//! observation-only: no engine code reads an instrument back.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A last-value-wins gauge (an `f64` stored as its bit pattern, so
/// `set`/`get` are single relaxed atomic ops).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// A fixed-bucket histogram: `bounds` are inclusive upper edges in
/// ascending order, plus one implicit overflow bucket, so `buckets`
/// always has `bounds.len() + 1` slots. Buckets, count, and sum are
/// independent relaxed atomics — a concurrent snapshot may catch them
/// mid-update (off by an observation), which is fine for diagnostics.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        let mut buckets = Vec::with_capacity(bounds.len() + 1);
        buckets.resize_with(bounds.len() + 1, AtomicU64::default);
        Histogram {
            bounds: bounds.to_vec(),
            buckets,
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    pub fn observe(&self, v: f64) {
        let slot =
            self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.buckets[slot].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        add_f64(&self.sum_bits, v);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }
}

/// Atomic `f64 +=` via a compare-exchange loop on the bit pattern.
fn add_f64(cell: &AtomicU64, v: f64) {
    let mut current = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(current) + v).to_bits();
        match cell.compare_exchange_weak(current, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => current = actual,
        }
    }
}

#[derive(Default)]
struct Registry {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

fn registry() -> &'static Mutex<Registry> {
    static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Registry::default()))
}

/// The counter registered under `name` (created on first use).
pub fn counter(name: &str) -> Arc<Counter> {
    let mut reg = registry().lock().unwrap();
    Arc::clone(reg.counters.entry(name.to_string()).or_default())
}

/// The gauge registered under `name` (created on first use).
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut reg = registry().lock().unwrap();
    Arc::clone(reg.gauges.entry(name.to_string()).or_default())
}

/// The histogram registered under `name`. The first call fixes the
/// bucket bounds; later calls return the existing instrument no matter
/// what bounds they pass (one name, one layout — keep call sites
/// agreeing on a single bounds constant).
pub fn histogram(name: &str, bounds: &[f64]) -> Arc<Histogram> {
    let mut reg = registry().lock().unwrap();
    Arc::clone(
        reg.histograms
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new(bounds))),
    )
}

/// One histogram's state in a [`Snapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub name: String,
    pub bounds: Vec<f64>,
    /// Per-bucket counts; `bounds.len() + 1` entries (last = overflow).
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

/// A point-in-time copy of every registered instrument, names sorted.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub histograms: Vec<HistogramSnapshot>,
}

/// Copy every registered instrument's current value (names sorted —
/// `BTreeMap` order — so two snapshots of the same state serialize
/// identically).
pub fn snapshot() -> Snapshot {
    let reg = registry().lock().unwrap();
    Snapshot {
        counters: reg.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect(),
        gauges: reg.gauges.iter().map(|(k, g)| (k.clone(), g.get())).collect(),
        histograms: reg
            .histograms
            .iter()
            .map(|(k, h)| HistogramSnapshot {
                name: k.clone(),
                bounds: h.bounds.clone(),
                buckets: h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
                count: h.count(),
                sum: h.sum(),
            })
            .collect(),
    }
}

/// Zero every registered instrument (tests and benches isolating
/// legs). Registration survives; `Arc`s held by call sites stay valid.
pub fn reset() {
    let reg = registry().lock().unwrap();
    for c in reg.counters.values() {
        c.value.store(0, Ordering::Relaxed);
    }
    for g in reg.gauges.values() {
        g.bits.store(0.0f64.to_bits(), Ordering::Relaxed);
    }
    for h in reg.histograms.values() {
        for b in &h.buckets {
            b.store(0, Ordering::Relaxed);
        }
        h.count.store(0, Ordering::Relaxed);
        h.sum_bits.store(0.0f64.to_bits(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_share_by_name() {
        let a = counter("test.metrics.counter_a");
        let b = counter("test.metrics.counter_a");
        let before = a.get();
        a.inc();
        b.add(4);
        assert_eq!(a.get(), before + 5, "one name must mean one instrument");
    }

    #[test]
    fn gauges_keep_the_last_value() {
        let g = gauge("test.metrics.gauge_a");
        g.set(0.25);
        g.set(7.5);
        assert_eq!(g.get(), 7.5);
        assert_eq!(gauge("test.metrics.gauge_a").get(), 7.5);
    }

    #[test]
    fn histograms_bucket_on_inclusive_upper_edges() {
        let h = histogram("test.metrics.hist_a", &[1.0, 10.0, 100.0]);
        for v in [0.5, 1.0, 5.0, 50.0, 500.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 556.5);
        let snap = snapshot();
        let mine = snap
            .histograms
            .iter()
            .find(|s| s.name == "test.metrics.hist_a")
            .expect("registered histogram appears in the snapshot");
        assert_eq!(mine.bounds, vec![1.0, 10.0, 100.0]);
        // 0.5 and 1.0 land in <=1.0; 5.0 in <=10.0; 50.0 in <=100.0;
        // 500.0 overflows.
        assert_eq!(mine.buckets, vec![2, 1, 1, 1]);
    }

    #[test]
    fn snapshot_names_are_sorted() {
        counter("test.metrics.z_last").inc();
        counter("test.metrics.a_first").inc();
        let snap = snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|(k, _)| k.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted, "snapshot order must be stable for byte-stable frames");
    }

    #[test]
    fn concurrent_observations_all_land() {
        let h = histogram("test.metrics.hist_mt", &[0.5]);
        let c = counter("test.metrics.counter_mt");
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..1000 {
                        h.observe(1.0);
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.sum(), 4000.0);
        assert_eq!(c.get(), 4000);
    }
}
