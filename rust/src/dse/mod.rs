//! Hardware design-space exploration (paper §5.2): strategy-driven,
//! budgeted parameter sweeps with invalid-design skipping, optimization
//! objectives, and Pareto fronts.
//!
//! # Strategy-driven sweep architecture
//!
//! The paper's flagship result covers 480M designs at an effective
//! 0.17M designs/s; that scale rules out both a single thread and a
//! `Vec` of every design point — and, for realistic spaces, exhaustive
//! enumeration itself. [`engine::sweep`] therefore runs waves of
//! candidate batches produced by a pluggable [`strategy::SearchStrategy`]:
//!
//! ```text
//!   SearchStrategy ──(wave of PairBatches, budget-truncated)──┐
//!       JobQueue ──> [worker + Analyzer] ─┐  per shard: build case     │
//!       JobQueue ──> [worker + Analyzer] ─┼─ tables (shape-memoized),  │
//!       JobQueue ──> [worker + Analyzer] ─┘  §5.2 min-cost pruning,    │
//!                                 eval the batch's bandwidths, fold    │
//!                                 into a streaming Pareto frontier     │
//!   shard results ──(merged in shard order)──> frontier + feedback ────┘
//!                                       (next wave refines; empty wave ends)
//! ```
//!
//! * **Strategies** — [`strategy::SearchStrategy::Exhaustive`] emits
//!   the full outer product in one wave (pinned bit-identical to the
//!   pre-strategy engine); `RandomSample` draws a seeded duplicate-free
//!   sample against the budget; `ParetoGuided` iteratively refines a
//!   coarse grid around the evolving frontier and reaches the
//!   exhaustive frontier's objective values at a fraction of the
//!   evaluations (`rust/tests/dse_strategies.rs`). All strategies are
//!   bit-deterministic for a fixed seed and any thread count.
//! * **Budgets** — [`strategy::SearchBudget`] caps admitted candidates
//!   (`max_designs`, deterministic truncation surfaced in
//!   `SweepStats::budget_skipped`) and optionally wall-clock
//!   (`max_seconds`, wave-granular, not bit-deterministic).
//!
//! * **Network workloads** — the unit of work is a whole
//!   [`crate::model::network::Network`] (wrap single layers with
//!   `Network::single`). Each shard owns one
//!   [`crate::engine::analysis::Analyzer`], so a zoo network's repeated
//!   layer shapes are analyzed once per (variant, PEs) pair; the
//!   mem-hit/disk-hit/miss split surfaces in
//!   [`engine::SweepStats::summary`].
//! * **Shared cache** — hand [`engine::SweepConfig::cache`] a
//!   [`crate::cache::SharedStore`] and every shard's Analyzer fronts
//!   the same concurrent map (keyed on structural dataflow
//!   fingerprints): pre-warmed entries — from an earlier sweep or a
//!   `--cache-file` loaded from disk — replay across the pool, and the
//!   sweep's results land in the store for `SharedStore::flush` to
//!   persist. Results stay bit-identical for any thread count and any
//!   pre-warmed state (values are pure functions of their keys).
//! * **Sharding** — each wave's batch list (for the exhaustive
//!   strategy: the (variant, PEs) outer product) is split into
//!   contiguous runs pulled from a bounded
//!   [`crate::util::queue::JobQueue`] (the coordinator's proven
//!   bounded-queue worker idiom, extracted) by a scoped worker pool, so
//!   the effective DSE rate scales with cores.
//! * **Streaming accumulation** — each shard folds its design points
//!   into a [`pareto::ParetoAccumulator`] (runtime-energy frontier over
//!   valid points) plus [`engine::SweepStats`] counters instead of
//!   materializing the space; memory is O(frontier), not O(space).
//! * **Deterministic merge** — shards cover the serial iteration order
//!   and merge in shard-index order, so the frontier, counts, and (with
//!   `keep_all_points`) the full point list are bit-identical for any
//!   thread count and shard size — and identical to the per-layer
//!   aggregation the shape cache replaces. `rust/tests/dse_parallel.rs`
//!   pins this contract (cache hit/miss counters follow the shard
//!   partition and are excluded).
//! * **Skip accounting** — unmappable (variant, PEs) pairs and
//!   budget-pruned pairs are counted separately (`unmappable` vs
//!   `pruned`) and both surface in [`engine::SweepStats::summary`].
//!
//! # Knobs ([`engine::SweepConfig`])
//!
//! * `threads` — worker threads; `0` = one per available core.
//! * `shard_size` — batches per shard; `0` = auto. Load balancing
//!   only; never affects results.
//! * `keep_all_points` — also return every design point (needed by the
//!   Fig 13 scatter plots and small-space tests; costs O(space) memory).
//! * `cache` — optional shared [`crate::cache::SharedStore`]; `None`
//!   keeps the PR 2 per-shard private caches (cleared per pair, memory
//!   bounded for paper-scale spaces). Works for every strategy.
//! * `strategy` / `budget` — which candidates to visit, and how many
//!   (see [`strategy`]).
//!
//! # Reproducing Fig 13
//!
//! ```text
//! cargo run --release -- dse --family kc-p --layer-model vgg16 \
//!     --resolution 14 --threads 0        # scatter + frontier + optima
//! cargo run --release -- dse --family kc-p --layer-model resnet50 \
//!     --network                          # whole-network (shape-deduped) sweep
//! cargo run --release -- dse --family kc-p --strategy guided \
//!     --resolution 20                    # frontier without the full sweep
//! cargo run --release -- dse --family kc-p --strategy random \
//!     --budget 50000 --seed 7            # seeded uniform sample
//! cargo bench --bench fig13_dse          # the full figure (both families)
//! cargo bench --bench dse_rate           # DSE rate + thread scaling
//! DSE_SMOKE=1 cargo bench --bench dse_rate   # CI smoke: tiny space,
//!                                            # writes BENCH_dse_rate.json
//!                                            # (incl. guided-vs-exhaustive)
//! ```

pub mod engine;
pub mod pareto;
pub mod space;
pub mod strategy;

pub use engine::{
    sweep, table_identity, PairTables, SweepConfig, SweepCtx, SweepDriver, SweepOutcome,
    SweepShard, SweepStats, SweepWave,
};
pub use pareto::ParetoAccumulator;
pub use space::DesignSpace;
pub use strategy::{
    plan_single_wave, CandidateEval, CandidateGen, PairBatch, SearchBudget, SearchStrategy,
    WaveFeedback,
};
