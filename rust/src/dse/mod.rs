//! Hardware design-space exploration (paper §5.2): parameter sweeps with
//! invalid-design skipping, optimization objectives, and Pareto fronts.

pub mod engine;
pub mod pareto;
pub mod space;
