//! The DSE engine: flattened case tables, scalar design-point
//! evaluation, and the sharded budget-pruned sweep (paper §5.2's "skips
//! design spaces ... by checking the minimum area and power of all the
//! possible design points from inner loops").
//!
//! The flattened case table is the contract between the Rust scalar
//! evaluator and the AOT-compiled batched evaluator (L1 Pallas kernel):
//! both implement the same formula over the same rows, and an
//! integration test cross-checks them.
//!
//! [`sweep`] splits the (variant, PEs) outer product into contiguous
//! shards executed by a scoped worker pool
//! ([`crate::util::pool::WavePool`], extracted from this engine) that
//! stays alive across strategy waves — a guided or mapper-driven run
//! issues many small waves, and per-wave pool spawning made thread
//! churn scale with the wave count. Each
//! shard folds its survivors into a streaming Pareto frontier +
//! counters, and shards merge deterministically in shard order — see
//! [`crate::dse`] module docs for the architecture.
//!
//! Case tables are bandwidth-invariant (the whole bandwidth axis of a
//! (variant, PEs) pair evaluates one table), so the sweep keeps a
//! sweep-lifetime per-pair table cache shared across shards and waves
//! ([`SweepConfig::reuse_tables`]): a feedback-driven strategy that
//! probes the same pair once per wave (the guided per-pair bandwidth
//! binary search) flattens and analyzes it exactly once. Tables are
//! pure functions of (workload, variant, PEs), so replaying a cached
//! table is bit-identical to rebuilding it and the determinism
//! contract is untouched.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::cache::SharedStore;
use crate::dse::pareto::ParetoAccumulator;
use crate::dse::strategy::{
    self, CandidateEval, CandidateGen, PairBatch, SearchBudget, SearchStrategy, WaveFeedback,
};
use crate::engine::analysis::Analyzer;
use crate::engine::mapping::{build_schedule, macs_per_unit, transition_classes, Advanced};
use crate::engine::noc::reduction_delay;
use crate::engine::reuse::{psum_revisits, tensor_usage};
use crate::hw::area;
use crate::hw::config::{HwConfig, ReductionSupport};
use crate::hw::energy::EnergyModel;
use crate::ir::dataflow::{Dataflow, ResolvedDataflow};
use crate::model::layer::{Layer, ShapeKey};
use crate::model::network::Network;
use crate::model::tensor::{couplings, TensorKind, ALL_TENSORS};
use crate::util::pool::WavePool;

/// Number of features per case row (the AOT artifact's row width).
pub const CASE_FEATURES: usize = 8;

/// One flattened level-0 iteration case.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CaseRow {
    pub occurrences: f64,
    /// Elements entering the level per step (incl. psum re-ingress).
    pub ingress: f64,
    /// Elements leaving per step.
    pub egress: f64,
    /// Compute cycles per step (PE MACs, or flattened inner-level MAC
    /// cycles, incl. reduction-tree delay).
    pub compute: f64,
    /// Inner-level communication volume per step (elements; served at
    /// the per-cluster bandwidth share).
    pub inner_comm: f64,
    /// Inner-level steps (each pays the NoC latency once).
    pub inner_steps: f64,
    /// Level-0 reduction delay adder.
    pub red_delay: f64,
    /// 1.0 for the global-init case (delays add instead of max).
    pub is_init: f64,
}

impl CaseRow {
    pub fn to_features(self) -> [f32; CASE_FEATURES] {
        [
            self.occurrences as f32,
            self.ingress as f32,
            self.egress as f32,
            self.compute as f32,
            self.inner_comm as f32,
            self.inner_steps as f32,
            self.red_delay as f32,
            self.is_init as f32,
        ]
    }
}

/// Bandwidth-independent activity totals (drive the energy model).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Activity {
    pub macs: f64,
    pub l2_reads: f64,
    pub l2_writes: f64,
    pub l1_reads: f64,
    pub l1_writes: f64,
    pub noc_delivered: f64,
}

/// The flattened evaluation table for (workload, dataflow variant, #PEs).
#[derive(Debug, Clone, PartialEq)]
pub struct CaseTable {
    pub rows: Vec<CaseRow>,
    pub activity: Activity,
    /// Per-PE L1 requirement (elements) the DSE places.
    pub l1_req: u64,
    /// L2 staging requirement (elements) the DSE places.
    pub l2_req: u64,
    pub pes: u64,
    /// Top-level cluster count (bandwidth sharing divisor for
    /// inner-level communication).
    pub units0: u64,
}

/// Build the flattened case table for a set of layers (rows concatenate;
/// runtime and energy are additive across layers). One-shot wrapper over
/// [`build_case_table_cached`].
pub fn build_case_table(layers: &[&Layer], dataflow: &Dataflow, pes: u64) -> Result<CaseTable> {
    build_case_table_cached(&mut Analyzer::new(), layers, dataflow, pes)
}

/// Build a case table through a caller-owned [`Analyzer`] (one per sweep
/// shard / coordinator worker): per-layer activity goes through the
/// analyzer's shape cache, and the flattened level-0 row blocks are
/// computed once per distinct [`ShapeKey`] within the call. The table is
/// assembled per member layer in workload order — cloned blocks, not
/// scaled occurrences — so rows, activity sums and buffer maxima are
/// bit-identical to the uncached per-layer path (pinned in
/// `rust/tests/dse_parallel.rs`).
pub fn build_case_table_cached(
    analyzer: &mut Analyzer,
    layers: &[&Layer],
    dataflow: &Dataflow,
    pes: u64,
) -> Result<CaseTable> {
    ensure!(!layers.is_empty(), "case table needs at least one layer");
    // Reference config for activity extraction (bandwidth-independent).
    let hw = HwConfig { num_pes: pes, ..HwConfig::fig10_default() };
    let mut rows = Vec::new();
    let mut activity = Activity::default();
    let mut l1_req = 0u64;
    let mut l2_req = 0u64;
    let mut units0 = 1u64;
    // Per-shape flattened row blocks, local to this (variant, PEs) call.
    let mut blocks: HashMap<ShapeKey, (u64, Vec<CaseRow>)> = HashMap::new();

    for layer in layers {
        // Activity + buffer reqs from the full analytical engine,
        // memoized on the layer's shape. The first sighting of a shape
        // resolves the dataflow once and feeds both the analysis and
        // the flattened row block; replays touch neither.
        let key = layer.shape_key();
        let stats = if blocks.contains_key(&key) {
            analyzer.analyze(layer, dataflow, &hw)?
        } else {
            let resolved = dataflow.resolve(layer, pes)?;
            let stats = analyzer.analyze_with_resolved(layer, dataflow, &hw, &resolved)?;
            let block = flatten_level0(layer, &resolved)?;
            blocks.insert(key, (resolved.levels[0].units, block));
            stats
        };
        activity.macs += stats.macs;
        activity.l2_reads += stats.l2_reads.iter().sum::<f64>();
        activity.l2_writes += stats.l2_writes.iter().sum::<f64>();
        activity.l1_reads += stats.l1_reads;
        activity.l1_writes += stats.l1_writes;
        activity.noc_delivered += stats.noc_delivered;
        l1_req = l1_req.max(stats.l1_req);
        l2_req = l2_req.max(stats.l2_req);

        let (layer_units0, block) = &blocks[&key];
        units0 = units0.max(*layer_units0);
        rows.extend_from_slice(block);
    }

    Ok(CaseTable { rows, activity, l1_req, l2_req, pes, units0 })
}

/// Flatten one layer's level-0 iteration cases into [`CaseRow`]s (the
/// per-shape unit [`build_case_table_cached`] memoizes).
fn flatten_level0(layer: &Layer, resolved: &ResolvedDataflow) -> Result<Vec<CaseRow>> {
    let mut rows = Vec::new();
    {
        // Flattened level-0 rows.
        let level0 = &resolved.levels[0];
        let sched = build_schedule(level0, &level0.parent_tile, layer)?;
        let classes = transition_classes(&sched)?;
        let revisits = psum_revisits(&sched, layer) as f64;
        let coup = couplings(layer);

        // Inner-level totals per one level-0 step, by tile (flattened
        // double-buffering approximation: inner compute and inner
        // communication race; see module docs).
        // Inner-level totals per one level-0 step. `entry` carries the
        // outer transition's filter/input fresh fractions: data retained
        // in PE buffers across outer steps is not re-streamed inside the
        // cluster (mirrors `analysis::analyze_levels`'s entry_fresh).
        let inner_totals = |tile: &crate::ir::dims::DimMap<u64>, entry: [f64; 2]| -> Result<(f64, f64, f64)> {
            if resolved.levels.len() == 1 {
                return Ok((0.0, 0.0, 0.0));
            }
            let inner = &resolved.levels[1];
            let is = build_schedule(inner, tile, layer)?;
            let ics = transition_classes(&is)?;
            let irev = psum_revisits(&is, layer) as f64;
            let mut mac_cycles = 0.0;
            let mut comm = 0.0;
            let mut steps = 0.0;
            for c in &ics {
                let occ = c.occurrences as f64;
                steps += occ;
                let m = macs_per_unit(&is, c, layer) as f64;
                let mut red = 0.0f64;
                let mut ingress = 0.0;
                let mut egress = 0.0;
                for (ci, kind) in ALL_TENSORS.iter().enumerate() {
                    let mut u = tensor_usage(&is, c, &coup[ci], *kind);
                    if u.footprint_unit == 0 {
                        continue;
                    }
                    if *kind == TensorKind::Output {
                        let e = u.unique_fresh();
                        egress += e;
                        ingress += e * (irev - 1.0) / irev;
                        if u.spatially_reduced {
                            red = red.max(reduction_delay(ReductionSupport::Tree, c.active));
                        }
                    } else {
                        u.fresh *= entry[ci];
                        ingress += u.unique_fresh();
                    }
                }
                mac_cycles += occ * ((m * layer.sparsity_macs_scale()).ceil().max(1.0) + red);
                comm += occ * (ingress + egress);
            }
            Ok((mac_cycles, comm, steps))
        };

        for class in &classes {
            let occ = class.occurrences as f64;
            let active = class.active.max(1);
            let mut ingress = 0.0;
            let mut egress = 0.0;
            let mut red = 0.0f64;
            let mut class_fresh = [1.0f64, 1.0];
            for (ci, kind) in ALL_TENSORS.iter().enumerate() {
                let u = tensor_usage(&sched, class, &coup[ci], *kind);
                if *kind != TensorKind::Output {
                    class_fresh[ci] = u.fresh;
                }
                if u.footprint_unit == 0 {
                    continue;
                }
                if *kind == TensorKind::Output {
                    let e = u.unique_fresh();
                    egress += e;
                    ingress += e * (revisits - 1.0) / revisits;
                    if u.spatially_reduced {
                        red = red.max(reduction_delay(ReductionSupport::Tree, active));
                    }
                } else {
                    ingress += u.unique_fresh();
                }
            }
            let (compute, inner_comm, inner_steps) = if resolved.levels.len() > 1 {
                inner_totals(&class.tile, class_fresh)?
            } else {
                let m = macs_per_unit(&sched, class, layer) as f64;
                ((m * layer.sparsity_macs_scale()).ceil().max(1.0), 0.0, 0.0)
            };
            rows.push(CaseRow {
                occurrences: occ,
                ingress,
                egress,
                compute,
                inner_comm,
                inner_steps,
                red_delay: red,
                is_init: if matches!(class.advanced, Advanced::GlobalInit) { 1.0 } else { 0.0 },
            });
        }
    }
    Ok(rows)
}

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub dataflow: String,
    pub pes: u64,
    pub bandwidth: u64,
    /// Placed per-PE L1 (elements).
    pub l1: u64,
    /// Placed L2 (elements).
    pub l2: u64,
    pub runtime: f64,
    pub energy_pj: f64,
    pub area_mm2: f64,
    pub power_mw: f64,
    pub valid: bool,
}

impl DesignPoint {
    pub fn throughput(&self, macs: f64) -> f64 {
        macs / self.runtime.max(1.0)
    }
    pub fn edp(&self) -> f64 {
        self.energy_pj * self.runtime
    }
}

/// Scalar evaluation of a case table at (bandwidth, latency) — the exact
/// formula the AOT batched evaluator implements.
pub fn eval_runtime(table: &CaseTable, bandwidth: u64, latency: u64) -> f64 {
    let bw = bandwidth.max(1) as f64;
    let lat = latency as f64;
    let bw_share = (bandwidth as f64 / table.units0 as f64).max(1.0);
    let mut total = 0.0;
    for r in &table.rows {
        let in_d = if r.ingress > 0.0 { (r.ingress / bw).ceil() + lat } else { 0.0 };
        let out_d = if r.egress > 0.0 { (r.egress / bw).ceil() + lat } else { 0.0 };
        let inner_comm_d = if r.inner_comm > 0.0 {
            (r.inner_comm / bw_share).ceil() + lat * r.inner_steps
        } else {
            0.0
        };
        let cmp = (r.compute + r.red_delay).max(inner_comm_d);
        let delay = if r.is_init > 0.5 { in_d + cmp + out_d } else { in_d.max(cmp).max(out_d) };
        total += r.occurrences * delay;
    }
    total
}

/// Scalar energy evaluation at placed buffer sizes — mirrors
/// `analysis::analyze_layer`'s energy model over the precomputed
/// activity.
pub fn eval_energy(activity: &Activity, l1: u64, l2: u64, noc_hops: u64) -> f64 {
    let em = EnergyModel::for_sizes(l1, l2);
    activity.macs * em.mac_pj
        + activity.l1_reads * em.l1_read_pj
        + activity.l1_writes * em.l1_write_pj
        + activity.l2_reads * em.l2_read_pj
        + activity.l2_writes * em.l2_write_pj
        + activity.noc_delivered * noc_hops.max(1) as f64 * em.noc_hop_pj
}

/// Sweep execution knobs.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// (variant, PEs) pairs per work shard; 0 = auto (`pairs / 64`, at
    /// least 1). The partition affects load balancing only — results
    /// are identical for any shard size.
    pub shard_size: usize,
    /// Also return every evaluated design point (O(space) memory) —
    /// needed by the Fig 13 scatter plots and small-space tests. Large
    /// sweeps should keep the default `false` and use the streaming
    /// frontier, which bounds memory to O(frontier).
    pub keep_all_points: bool,
    /// Shared analysis cache ([`crate::cache::SharedStore`]) consulted
    /// and populated by every shard, replacing the per-shard private
    /// Analyzer caches. Pre-warm it (another sweep, or
    /// `SharedStore::load` from a `--cache-file`) and repeated (shape,
    /// variant, hardware) triples replay instead of re-analyzing;
    /// results are bit-identical either way (values are pure functions
    /// of the key — pinned in `rust/tests/dse_parallel.rs`). `None`
    /// keeps the default per-shard caches, whose per-pair clearing
    /// bounds shard memory for paper-scale spaces.
    pub cache: Option<Arc<SharedStore>>,
    /// Candidate-generation strategy (default [`SearchStrategy::Exhaustive`],
    /// which is pinned bit-identical to the pre-strategy sweep). See
    /// [`crate::dse::strategy`] for the catalogue.
    pub strategy: SearchStrategy,
    /// Evaluation budget (default unlimited). `max_designs` caps the
    /// candidates admitted to evaluation across all waves — the cut is
    /// deterministic and lands in [`SweepStats::budget_skipped`];
    /// `max_seconds` stops between waves (not bit-deterministic).
    pub budget: SearchBudget,
    /// Cooperative cancellation: when set and flipped true, the sweep
    /// stops at the next wave boundary (same granularity as
    /// `budget.max_seconds`) and returns the partial outcome. The
    /// `serve` daemon scopes one flag per request so a client can
    /// abandon a long sweep without killing the process.
    pub cancel: Option<Arc<std::sync::atomic::AtomicBool>>,
    /// Reuse each (variant, PEs) pair's case table across shards and
    /// waves for the lifetime of the sweep (default `true`). Case
    /// tables are bandwidth-invariant, so a strategy that revisits a
    /// pair — the guided per-pair bandwidth binary search touches it
    /// once per wave — replays the cached table instead of
    /// re-flattening and re-analyzing. Tables are pure functions of
    /// (workload, variant, PEs): results are bit-identical either way,
    /// and the skip accounting (`pruned` / `unmappable`) is repeated
    /// per visit exactly as the rebuild path would. `false` restores
    /// the rebuild-every-visit path — the reference the DSE bench
    /// races the reuse path against. Memory is O(visited pairs).
    pub reuse_tables: bool,
    /// Caller-owned per-pair table cache shared *across* sweeps
    /// (overrides `reuse_tables` when set). Pair indices are only
    /// meaningful for one (workload, variant list, PEs list) identity —
    /// see [`table_identity`] — so callers must key shared caches by
    /// that identity. The `serve` daemon promotes the PR 8
    /// sweep-lifetime cache to daemon lifetime this way: two clients
    /// sweeping the same space build each case table once between them.
    pub shared_tables: Option<Arc<PairTables>>,
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig {
            threads: 0,
            shard_size: 0,
            keep_all_points: false,
            cache: None,
            strategy: SearchStrategy::Exhaustive,
            budget: SearchBudget::default(),
            cancel: None,
            reuse_tables: true,
            shared_tables: None,
        }
    }
}

impl SweepConfig {
    /// Single-threaded reference configuration (the determinism oracle).
    pub fn serial() -> SweepConfig {
        SweepConfig { threads: 1, ..SweepConfig::default() }
    }

    fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Sweep statistics (Fig 13 (c)). Under the exhaustive strategy every
/// candidate in the space lands in exactly one of `evaluated`,
/// `pruned`, `unmappable`, or `budget_skipped`; sampling/guided
/// strategies only account for the candidates they selected
/// (`total_designs` stays the nominal space size).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SweepStats {
    /// Search strategy that produced these stats.
    pub strategy: String,
    /// Candidates in the nominal space.
    pub total_designs: u64,
    /// Candidates actually evaluated.
    pub evaluated: u64,
    /// Valid designs (within budget).
    pub valid: u64,
    /// Candidates skipped because the minimum-cost check (smallest
    /// bandwidth, required buffers) already exceeded the area/power
    /// budget (§5.2 pruning).
    pub pruned: u64,
    /// Candidates skipped because the (variant, PEs) pair has no legal
    /// mapping (e.g. cluster size exceeds the PE array).
    pub unmappable: u64,
    /// Candidates a strategy yielded that [`SweepConfig::budget`]'s
    /// `max_designs` refused (waves are truncated deterministically,
    /// so this is part of the determinism contract).
    pub budget_skipped: u64,
    /// Strategy waves executed (1 for exhaustive/random; the guided
    /// strategy runs one per refinement round).
    pub waves: u64,
    /// Analyzer layer-cache hits while building case tables: repeated
    /// layer shapes replayed instead of re-analyzed. Diagnostic only —
    /// the split (unlike hits + misses per pair) depends on the shard
    /// partition and on pre-warmed shared-cache state, so it is
    /// excluded from the determinism contract (see
    /// `rust/tests/dse_parallel.rs`).
    pub cache_hits: u64,
    /// The subset of `cache_hits` served by entries a shared store
    /// loaded from a cache file (warm starts; 0 without
    /// [`SweepConfig::cache`]).
    pub cache_disk_hits: u64,
    /// Analyzer layer-cache misses (= full layer analyses run).
    pub cache_misses: u64,
    /// Entries the shared store's second-chance cap dropped during this
    /// sweep (0 without [`SweepConfig::cache`] or for unbounded stores).
    /// Like the hit/miss split, diagnostic only — excluded from the
    /// determinism contract.
    pub evictions: u64,
    /// The subset of `cache_misses` that skipped the bandwidth-variant
    /// analysis by replaying a memoized
    /// [`crate::engine::profile::ReuseProfile`] (same shape, variant,
    /// and hardware up to bandwidth). Diagnostic only — like the
    /// hit/miss split, it follows the shard partition and warmth and is
    /// excluded from the determinism contract.
    pub profile_hits: u64,
    /// Wall-clock seconds.
    pub seconds: f64,
}

impl SweepStats {
    /// Effective DSE rate: designs covered per second (skipped designs
    /// count — that is the paper's "effective DSE rate").
    pub fn rate(&self) -> f64 {
        self.total_designs as f64 / self.seconds.max(1e-9)
    }

    /// Fold another shard's counters in (wall clock excluded: it is
    /// measured once around the whole sweep).
    fn absorb(&mut self, other: &SweepStats) {
        self.evaluated += other.evaluated;
        self.valid += other.valid;
        self.pruned += other.pruned;
        self.unmappable += other.unmappable;
        self.cache_hits += other.cache_hits;
        self.cache_disk_hits += other.cache_disk_hits;
        self.cache_misses += other.cache_misses;
        self.profile_hits += other.profile_hits;
    }

    /// One-line human summary, including the skip breakdown (pruned /
    /// unmappable / budget-cut) and the layer-cache
    /// mem-hit/disk-hit/miss/eviction split (the segment is rendered by
    /// [`crate::engine::analysis::fmt_cache_counters`], shared with
    /// `MapperStats::summary` so the two reports cannot drift).
    pub fn summary(&self) -> String {
        format!(
            "strategy={} designs={} evaluated={} valid={} pruned={} unmappable={} budget_skipped={} \
             waves={} {} wall={:.2}s rate={}/s",
            if self.strategy.is_empty() { "exhaustive" } else { self.strategy.as_str() },
            self.total_designs,
            self.evaluated,
            self.valid,
            self.pruned,
            self.unmappable,
            self.budget_skipped,
            self.waves,
            crate::engine::analysis::fmt_cache_counters(
                self.cache_hits,
                self.cache_disk_hits,
                self.cache_misses,
                self.evictions,
                self.profile_hits,
            ),
            self.seconds,
            crate::util::benchkit::fmt_rate(self.rate()),
        )
    }
}

/// Result of a [`sweep`].
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// Runtime-energy Pareto frontier over the valid points, sorted by
    /// (runtime, energy, variant, PEs, bandwidth). Identical for any
    /// thread count / shard size.
    pub frontier: Vec<DesignPoint>,
    /// Every evaluated design point in deterministic (variant, PEs,
    /// bandwidth) order; empty unless [`SweepConfig::keep_all_points`].
    pub points: Vec<DesignPoint>,
    pub stats: SweepStats,
}

/// Per-shard fold state: frontier + counters (+ points when kept,
/// + per-candidate feedback when the strategy asks).
#[derive(Debug, Default)]
struct ShardOutcome {
    frontier: ParetoAccumulator,
    points: Vec<DesignPoint>,
    stats: SweepStats,
    feedback: WaveFeedback,
}

/// Cached outcome of building one (variant, PEs) pair's case table.
/// The unmappable marker is cached too, so a strategy revisiting a
/// dead pair repeats the skip without re-attempting the resolve.
#[derive(Debug)]
enum PairTable {
    Ready(Arc<CaseTable>),
    Unmappable,
}

/// Per-pair case-table cache, shared by every shard across every wave
/// (keyed on the pair's serial index, which is only meaningful for one
/// (workload, variant list, PEs list) identity — see
/// [`table_identity`]). Values are pure functions of the key, so a
/// lost race between two shards building the same pair is benign —
/// both compute identical tables. The lock is held only for the
/// lookup/insert, never across a build.
///
/// Lifetime is the owner's choice: [`sweep`] allocates one per sweep
/// (`SweepConfig::reuse_tables`), while the `serve` daemon keeps one
/// per design-space identity for its whole life
/// (`SweepConfig::shared_tables`) so concurrent and repeated requests
/// over the same space share the flattening work.
#[derive(Debug, Default)]
pub struct PairTables {
    map: std::sync::Mutex<HashMap<usize, Arc<PairTable>>>,
}

impl PairTables {
    pub fn new() -> PairTables {
        PairTables::default()
    }

    /// Cached pairs (diagnostic; racy under concurrent fills).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn get(&self, pair: usize) -> Option<Arc<PairTable>> {
        self.map.lock().unwrap().get(&pair).cloned()
    }

    fn put(&self, pair: usize, entry: Arc<PairTable>) {
        self.map.lock().unwrap().insert(pair, entry);
    }
}

/// Identity of the per-pair table keyspace: two (workload, space)
/// combinations with equal identities index bit-identical case tables
/// at every pair serial index, so they may share one [`PairTables`].
/// Hashes the layers' canonical [`ShapeKey`]s in order, the variants'
/// structural fingerprints in order, and the PEs axis — everything a
/// table depends on. Bandwidths, NoC latency, and area/power budgets
/// are deliberately excluded: tables are bandwidth-invariant and
/// budgets only gate evaluation, never table contents.
pub fn table_identity(net: &Network, space: &super::space::DesignSpace) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    net.layers.len().hash(&mut h);
    for layer in &net.layers {
        layer.shape_key().hash(&mut h);
    }
    space.variants.len().hash(&mut h);
    for variant in &space.variants {
        variant.fingerprint().hash(&mut h);
    }
    space.pes.hash(&mut h);
    h.finish()
}

/// Evaluate a contiguous run of strategy batches. Batches arrive in
/// serial pair order (each batch's `bws` ascending), so concatenating
/// any contiguous partition's output replays the single-threaded sweep
/// of the same candidate list exactly — for the exhaustive strategy
/// that is the full serial iteration order of the old engine, bit for
/// bit.
///
/// One [`Analyzer`] serves the whole shard: its layer cache is keyed on
/// (shape, variant structure, hardware), so the repeated shapes of a
/// zoo network are analyzed once per (variant, PEs) pair instead of
/// once per layer, and the scratch allocations amortize across the
/// shard's batches. With a [`SweepConfig::cache`] store, every shard's
/// Analyzer fronts the same map — pre-warmed entries (earlier sweeps,
/// disk) replay across the whole pool, for every strategy.
///
/// Pruning mirrors §5.2: before entering the bandwidth loop for a
/// batch, the minimum achievable area/power (the *space's* smallest
/// bandwidth, required buffers) is checked against the budget; if it
/// already exceeds, the whole batch is skipped but still counted (and
/// reported to feedback-driven strategies as a dead pair).
fn sweep_shard(
    net: &Network,
    space: &super::space::DesignSpace,
    noc_hops: u64,
    batches: &[PairBatch],
    keep_all_points: bool,
    collect_feedback: bool,
    cache: Option<&Arc<SharedStore>>,
    tables: Option<&PairTables>,
) -> ShardOutcome {
    let mut out = ShardOutcome::default();
    let mut analyzer = match cache {
        Some(store) => Analyzer::with_store(Arc::clone(store)),
        None => Analyzer::new(),
    };
    let layers: Vec<&Layer> = net.layers.iter().collect();
    let min_bw = *space.bandwidths.iter().min().unwrap_or(&1);
    for batch in batches {
        let (variant_idx, pes_idx) = space.pair_coords(batch.pair);
        let variant = &space.variants[variant_idx];
        let pes = space.pes[pes_idx];
        let n_candidates = batch.candidates();
        // Sweep-lifetime table reuse: a pair revisited by a later wave
        // (or already built by another shard) replays its cached table
        // — or its cached unmappable verdict — instead of rebuilding.
        let entry = match tables.and_then(|t| t.get(batch.pair)) {
            Some(entry) => entry,
            None => {
                // Private cache: the key includes (variant, pes), so a
                // finished pair's entries can never hit again within
                // this sweep — drop them before each pair (counters
                // survive) to keep shard memory at O(unique shapes). A
                // no-op on a shared store, which retains entries for
                // later sweeps and for persistence.
                analyzer.clear_cache();
                let entry =
                    match build_case_table_cached(&mut analyzer, &layers, variant, pes) {
                        Ok(table) => Arc::new(PairTable::Ready(Arc::new(table))),
                        Err(_) => Arc::new(PairTable::Unmappable),
                    };
                if let Some(t) = tables {
                    t.put(batch.pair, Arc::clone(&entry));
                }
                entry
            }
        };
        let PairTable::Ready(table) = &*entry else {
            out.stats.unmappable += n_candidates;
            if collect_feedback {
                out.feedback.dead_pairs.push(batch.pair);
            }
            continue;
        };
        // Minimum-cost pruning for the whole bandwidth loop.
        let min_ap = area::evaluate(pes, table.l1_req, table.l2_req, min_bw);
        if min_ap.area_mm2 > space.area_budget_mm2 || min_ap.power_mw > space.power_budget_mw {
            out.stats.pruned += n_candidates;
            if collect_feedback {
                out.feedback.dead_pairs.push(batch.pair);
            }
            continue;
        }
        let energy = eval_energy(&table.activity, table.l1_req, table.l2_req, noc_hops);
        for &bwi in &batch.bws {
            let bw = space.bandwidths[bwi];
            out.stats.evaluated += 1;
            let ap = area::evaluate(pes, table.l1_req, table.l2_req, bw);
            let runtime = eval_runtime(table, bw, space.noc_latency);
            // Total power = static (regression) + dynamic (workload
            // energy over runtime; 1 pJ/cycle = 1 mW at 1 GHz).
            let power = ap.power_mw + energy / runtime.max(1.0);
            let valid = ap.area_mm2 <= space.area_budget_mm2 && power <= space.power_budget_mw;
            if valid {
                out.stats.valid += 1;
            }
            if collect_feedback {
                out.feedback.evals.push(CandidateEval {
                    pair: batch.pair,
                    bw: bwi,
                    valid,
                    runtime,
                    energy_pj: energy,
                });
            }
            // Streaming mode: only candidates that would actually join
            // the frontier pay the DesignPoint allocation (invalid or
            // dominated ones are exactly what offer() would reject).
            if !keep_all_points && (!valid || !out.frontier.would_admit(runtime, energy)) {
                continue;
            }
            let point = DesignPoint {
                dataflow: variant.name.clone(),
                pes,
                bandwidth: bw,
                l1: table.l1_req,
                l2: table.l2_req,
                runtime,
                energy_pj: energy,
                area_mm2: ap.area_mm2,
                power_mw: power,
                valid,
            };
            out.frontier.offer(&point);
            if keep_all_points {
                out.points.push(point);
            }
        }
    }
    out.stats.cache_hits = analyzer.cache_hits();
    out.stats.cache_disk_hits = analyzer.disk_hits();
    out.stats.cache_misses = analyzer.cache_misses();
    out.stats.profile_hits = analyzer.profile_hits();
    out
}

/// Mutable sweep state threaded through the wave loop.
struct SweepState {
    frontier: ParetoAccumulator,
    stats: SweepStats,
    points: Vec<DesignPoint>,
    feedback: WaveFeedback,
    /// Candidates the budget still admits.
    remaining: u64,
}

/// One strategy wave, already truncated to the remaining budget and
/// partitioned into contiguous shards. Cheap to clone (two `Arc`s), so
/// an external scheduler can hand `(wave, shard_index)` jobs to a
/// shared pool without copying the batch list.
#[derive(Debug, Clone)]
pub struct SweepWave {
    batches: Arc<Vec<PairBatch>>,
    shards: Arc<Vec<std::ops::Range<usize>>>,
}

impl SweepWave {
    /// Number of shards this wave splits into (= the pool jobs to run).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Candidates admitted to evaluation in this wave.
    pub fn candidates(&self) -> u64 {
        self.batches.iter().map(|b| b.candidates()).sum()
    }
}

/// The outcome of evaluating one shard of a [`SweepWave`] — opaque to
/// schedulers; hand it back to [`SweepDriver::absorb_wave`] in
/// shard-index order. `Default` is the [`WavePool`] panic-fill value
/// (an empty outcome keeps the merge well-formed if a worker dies).
#[derive(Debug, Default)]
pub struct SweepShard(ShardOutcome);

/// The immutable, shareable half of a sweep: everything a worker needs
/// to evaluate a shard. The `serve` daemon's scheduler holds one
/// `Arc<SweepCtx>` per in-flight dse request and interleaves
/// `run_shard` calls from many requests onto one process-wide pool;
/// [`sweep`] uses the same context for its private pool.
pub struct SweepCtx {
    net: Network,
    space: super::space::DesignSpace,
    noc_hops: u64,
    keep_all_points: bool,
    collect_feedback: bool,
    cache: Option<Arc<SharedStore>>,
    tables: Option<Arc<PairTables>>,
}

impl SweepCtx {
    /// Evaluate one shard of a wave. Pure with respect to the driver's
    /// mutable state: any thread may run any shard in any order, and
    /// results absorb deterministically as long as they are handed back
    /// in shard-index order.
    pub fn run_shard(&self, wave: &SweepWave, shard: usize) -> SweepShard {
        let _span = crate::obs::trace::span("dse.shard");
        let range = wave.shards[shard].clone();
        SweepShard(sweep_shard(
            &self.net,
            &self.space,
            self.noc_hops,
            &wave.batches[range],
            self.keep_all_points,
            self.collect_feedback,
            self.cache.as_ref(),
            self.tables.as_deref(),
        ))
    }
}

/// The strategy wave loop, externalized: [`SweepDriver::next_wave`]
/// pulls, budget-truncates, and shard-partitions the next wave;
/// the caller evaluates its shards however it likes (inline, private
/// pool, or the daemon's shared pool) via [`SweepCtx::run_shard`]; and
/// [`SweepDriver::absorb_wave`] merges the shard outcomes **in
/// shard-index order**, which replays the wave's serial batch order
/// exactly — the same determinism contract as the pre-driver engine,
/// now independent of who executes the waves.
pub struct SweepDriver {
    ctx: Arc<SweepCtx>,
    gen: Box<dyn CandidateGen>,
    state: SweepState,
    budget: SearchBudget,
    cancel: Option<Arc<std::sync::atomic::AtomicBool>>,
    shard_size: usize,
    t0: std::time::Instant,
    evictions0: u64,
    done: bool,
}

impl SweepDriver {
    /// Set up a sweep without running it: validates the workload,
    /// instantiates the strategy generator, resolves the analysis
    /// cache (feedback-driven strategies get a sweep-local shared
    /// store when the caller provides none — cross-wave pair revisits
    /// replay instead of re-analyzing, bit-identical either way), and
    /// resolves the per-pair table cache (`shared_tables` wins over a
    /// fresh `reuse_tables` allocation). `config.threads` is ignored —
    /// execution belongs to the caller.
    pub fn new(
        net: &Network,
        space: &super::space::DesignSpace,
        noc_hops: u64,
        config: &SweepConfig,
    ) -> Result<SweepDriver> {
        ensure!(!net.layers.is_empty(), "sweep needs at least one layer");
        let t0 = std::time::Instant::now();
        let gen = config.strategy.generator(space, &config.budget)?;
        let collect_feedback = gen.needs_feedback();
        let cache = match &config.cache {
            Some(store) => Some(Arc::clone(store)),
            None if collect_feedback => Some(Arc::new(SharedStore::new())),
            None => None,
        };
        // Eviction accounting: the store's counter is cumulative across
        // consumers, so record the delta this sweep is responsible for.
        let evictions0 = cache.as_ref().map(|s| s.evictions()).unwrap_or(0);
        let tables = match &config.shared_tables {
            Some(shared) => Some(Arc::clone(shared)),
            None => config.reuse_tables.then(|| Arc::new(PairTables::new())),
        };
        let ctx = Arc::new(SweepCtx {
            net: net.clone(),
            space: space.clone(),
            noc_hops,
            keep_all_points: config.keep_all_points,
            collect_feedback,
            cache,
            tables,
        });
        let state = SweepState {
            frontier: ParetoAccumulator::new(),
            stats: SweepStats {
                total_designs: space.size(),
                strategy: config.strategy.name().to_string(),
                ..SweepStats::default()
            },
            points: Vec::new(),
            feedback: WaveFeedback::default(),
            remaining: if config.budget.max_designs > 0 {
                config.budget.max_designs
            } else {
                u64::MAX
            },
        };
        Ok(SweepDriver {
            ctx,
            gen,
            state,
            budget: config.budget.clone(),
            cancel: config.cancel.clone(),
            shard_size: config.shard_size,
            t0,
            evictions0,
            done: false,
        })
    }

    /// The shared evaluation context for this sweep's shards.
    pub fn ctx(&self) -> Arc<SweepCtx> {
        Arc::clone(&self.ctx)
    }

    /// Pull the next wave: checks the stop conditions (budget
    /// exhausted, wall-clock budget, cancellation, strategy done),
    /// truncates the strategy's wave to the remaining design budget,
    /// and partitions it into contiguous shards (`shard_size` 0 = auto:
    /// `batches / 64`, at least 1). Returns `None` when the sweep is
    /// finished; after that, every call returns `None`.
    ///
    /// Callers must evaluate **all** shards of the returned wave and
    /// hand them to [`SweepDriver::absorb_wave`] before pulling again —
    /// feedback-driven strategies read the previous wave's evals.
    pub fn next_wave(&mut self) -> Option<SweepWave> {
        if self.done {
            return None;
        }
        if self.state.remaining == 0 {
            self.done = true;
            return None;
        }
        if self.budget.max_seconds > 0.0
            && self.t0.elapsed().as_secs_f64() >= self.budget.max_seconds
        {
            self.done = true;
            return None;
        }
        if let Some(cancel) = &self.cancel {
            if cancel.load(std::sync::atomic::Ordering::Relaxed) {
                self.done = true;
                return None;
            }
        }
        let last = std::mem::take(&mut self.state.feedback);
        let mut wave = self.gen.next_wave(&self.state.frontier, &last);
        if wave.is_empty() {
            self.done = true;
            return None;
        }
        self.state.stats.budget_skipped += strategy::truncate_wave(&mut wave, self.state.remaining);
        let admitted: u64 = wave.iter().map(|b| b.candidates()).sum();
        self.state.remaining -= admitted;
        if wave.is_empty() {
            self.done = true;
            return None;
        }
        let n = wave.len();
        let shard_size = if self.shard_size > 0 { self.shard_size } else { (n / 64).max(1) };
        let shards: Vec<std::ops::Range<usize>> = (0..n.div_ceil(shard_size))
            .map(|shard| {
                let start = shard * shard_size;
                start..(start + shard_size).min(n)
            })
            .collect();
        Some(SweepWave { batches: Arc::new(wave), shards: Arc::new(shards) })
    }

    /// Merge one wave's shard outcomes, **in shard-index order** (the
    /// order [`SweepWave`] defined, which [`WavePool::run_wave`]
    /// preserves by construction).
    pub fn absorb_wave(&mut self, shards: Vec<SweepShard>) {
        for SweepShard(shard) in shards {
            self.state.frontier.merge(&shard.frontier);
            self.state.stats.absorb(&shard.stats);
            self.state.points.extend(shard.points);
            if self.ctx.collect_feedback {
                self.state.feedback.evals.extend(shard.feedback.evals);
                self.state.feedback.dead_pairs.extend(shard.feedback.dead_pairs);
            }
        }
        self.state.stats.waves += 1;
    }

    /// Waves absorbed so far.
    pub fn waves(&self) -> u64 {
        self.state.stats.waves
    }

    /// Candidates evaluated so far.
    pub fn evaluated(&self) -> u64 {
        self.state.stats.evaluated
    }

    /// The current frontier, in insertion order (the deterministic
    /// mid-sweep view — after wave `k` it is bit-identical for any
    /// executor, which is what makes streamed frontier deltas safe).
    pub fn frontier_points(&self) -> &[DesignPoint] {
        self.state.frontier.points()
    }

    /// Finalize: eviction delta, wall clock, sorted frontier.
    pub fn finish(mut self) -> SweepOutcome {
        self.state.stats.evictions = self
            .ctx
            .cache
            .as_ref()
            .map(|s| s.evictions().saturating_sub(self.evictions0))
            .unwrap_or(0);
        self.state.stats.seconds = self.t0.elapsed().as_secs_f64();
        SweepOutcome {
            frontier: self.state.frontier.into_sorted(),
            points: self.state.points,
            stats: self.state.stats,
        }
    }
}

/// Run the budget-pruned sweep over a design space, driven by
/// [`SweepConfig::strategy`] and sharded across a scoped worker pool.
///
/// The workload is a whole [`Network`] — the zoo-scale unit of work;
/// wrap a single layer with [`Network::single`]. Each worker shard owns
/// one [`Analyzer`], so repeated layer shapes are analyzed once per
/// (variant, PEs) pair and the hit/miss split surfaces in
/// [`SweepStats`].
///
/// This is the in-process convenience loop over [`SweepDriver`]: the
/// strategy yields candidate **waves** ([`PairBatch`] lists); each
/// wave is truncated to the remaining [`SearchBudget`], split into
/// contiguous shards executed by a persistent
/// [`crate::util::pool::WavePool`] of `config.threads` workers, pruned
/// per §5.2 inside each shard, and folded into a
/// streaming Pareto frontier + [`SweepStats`] counters, so memory
/// stays O(frontier) unless `keep_all_points` asks for the full
/// scatter. Shards merge in shard-index order, which replays the
/// wave's serial order exactly: the frontier, point list, and counts
/// (cache counters aside — they follow the partition) are bit-identical
/// for any thread count and shard size, for every strategy (the
/// exhaustive strategy additionally replays the pre-strategy engine
/// bit for bit — `rust/tests/dse_parallel.rs` pins both). The `serve`
/// daemon drives the same [`SweepDriver`] from its shared scheduler
/// instead, so daemon replies inherit this contract.
pub fn sweep(
    net: &Network,
    space: &super::space::DesignSpace,
    noc_hops: u64,
    config: &SweepConfig,
) -> Result<SweepOutcome> {
    let mut driver = SweepDriver::new(net, space, noc_hops, config)?;
    let threads = config.effective_threads();
    if threads <= 1 {
        // Serial: execute each wave's shards inline, in order.
        let ctx = driver.ctx();
        while let Some(wave) = driver.next_wave() {
            let shards =
                (0..wave.shard_count()).map(|shard| ctx.run_shard(&wave, shard)).collect();
            driver.absorb_wave(shards);
        }
    } else {
        // One persistent [`WavePool`] for the *whole* sweep (the pool
        // was born here and extracted to `util::pool` once the mapper
        // needed it too): feedback-driven strategies run many small
        // waves, and spawning a pool per wave made thread churn scale
        // with the wave count. Each wave enqueues its shards — the same
        // contiguous partition as the serial path — and the pool
        // returns them in shard-index order, so the merge order, and
        // with it the bit-determinism contract, is unchanged.
        let ctx = driver.ctx();
        let ctx: &SweepCtx = &ctx;
        std::thread::scope(|scope| {
            let pool = WavePool::spawn(scope, threads, move |(wave, shard): (SweepWave, usize)| {
                ctx.run_shard(&wave, shard)
            });
            while let Some(wave) = driver.next_wave() {
                let jobs: Vec<(SweepWave, usize)> =
                    (0..wave.shard_count()).map(|shard| (wave.clone(), shard)).collect();
                driver.absorb_wave(pool.run_wave(jobs));
            }
            // Dropping the pool closes its queue, so the workers drain
            // and the scope joins.
        });
    }
    Ok(driver.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::{kc_p_ct, DesignSpace};
    use crate::engine::analysis::analyze_layer;
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    #[test]
    fn case_table_builds_for_styles() {
        let layer = vgg16::conv13();
        for df in styles::all_styles() {
            let t = build_case_table(&[&layer], &df, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
            assert!(!t.rows.is_empty());
            assert!(t.activity.macs > 0.0);
            let occ: f64 = t.rows.iter().map(|r| r.occurrences).sum();
            assert!(occ >= 1.0);
        }
    }

    #[test]
    fn scalar_eval_matches_full_engine_shape() {
        // The flattened evaluator must track the full engine closely for
        // single-level dataflows (where flattening is exact).
        let layer = vgg16::conv13();
        let df = styles::x_p();
        let table = build_case_table(&[&layer], &df, 256).unwrap();
        for bw in [4u64, 16, 64] {
            let hw = HwConfig { noc_bandwidth: bw, ..HwConfig::fig10_default() };
            let full = analyze_layer(&layer, &df, &hw).unwrap();
            let flat = eval_runtime(&table, bw, hw.noc_latency);
            let err = (flat - full.runtime).abs() / full.runtime;
            assert!(err < 0.02, "bw={bw}: flat {flat} vs full {} ({err})", full.runtime);
        }
    }

    #[test]
    fn runtime_monotone_in_bandwidth() {
        let layer = vgg16::conv2();
        let table = build_case_table(&[&layer], &kc_p_ct(64), 256).unwrap();
        let mut prev = f64::INFINITY;
        for bw in [1u64, 2, 4, 8, 16, 32, 64, 128] {
            let rt = eval_runtime(&table, bw, 2);
            assert!(rt <= prev + 1e-6, "bw={bw}: {rt} > {prev}");
            prev = rt;
        }
    }

    #[test]
    fn energy_monotone_in_buffer_sizes() {
        let layer = vgg16::conv2();
        let table = build_case_table(&[&layer], &kc_p_ct(64), 256).unwrap();
        let e1 = eval_energy(&table.activity, 512, 100_000, 2);
        let e2 = eval_energy(&table.activity, 2048, 400_000, 2);
        assert!(e2 > e1);
    }

    #[test]
    fn sweep_produces_valid_and_invalid() {
        let net = Network::single(vgg16::conv13());
        let space = DesignSpace::fig13("kc-p", 6);
        let cfg = SweepConfig { keep_all_points: true, ..SweepConfig::serial() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert!(!out.points.is_empty());
        assert!(out.stats.valid > 0, "no valid designs");
        assert!(out.stats.valid <= out.stats.evaluated);
        assert_eq!(
            out.stats.evaluated + out.stats.pruned + out.stats.unmappable,
            out.stats.total_designs,
            "every candidate lands in exactly one bucket"
        );
        assert!(out.points.iter().any(|p| !p.valid) || out.stats.evaluated < out.stats.total_designs);
        assert!(out.stats.rate() > 0.0);
        let s = out.stats.summary();
        assert!(s.contains("pruned=") && s.contains("unmappable="), "summary surfaces skips: {s}");
    }

    #[test]
    fn sweep_frontier_matches_batch_pareto_front() {
        let net = Network::single(vgg16::conv13());
        let space = DesignSpace::fig13("kc-p", 6);
        let cfg = SweepConfig { keep_all_points: true, ..SweepConfig::serial() };
        let out = sweep(&net, &space, 2, &cfg).unwrap();
        assert!(!out.frontier.is_empty(), "frontier must be populated");
        assert!(out.frontier.iter().all(|p| p.valid));
        let front = crate::dse::pareto::pareto_front(&out.points, |p| p.runtime, |p| p.energy_pj);
        let batch: Vec<&DesignPoint> = front.iter().map(|&i| &out.points[i]).collect();
        assert_eq!(out.frontier.len(), batch.len());
        for (a, b) in out.frontier.iter().zip(&batch) {
            assert_eq!((a.runtime, a.energy_pj), (b.runtime, b.energy_pj));
        }
    }

    #[test]
    fn cached_case_table_bit_identical_to_fresh() {
        // A warmed shared Analyzer must not change any table bit: same
        // rows, activity sums, buffer requirements.
        let net = vgg16::conv_only();
        let layers: Vec<&Layer> = net.layers.iter().collect();
        let mut analyzer = Analyzer::new();
        for &pes in &[64u64, 256] {
            for variant in [kc_p_ct(16), kc_p_ct(64)] {
                let warm1 = build_case_table_cached(&mut analyzer, &layers, &variant, pes).unwrap();
                let warm2 = build_case_table_cached(&mut analyzer, &layers, &variant, pes).unwrap();
                let fresh = build_case_table(&layers, &variant, pes).unwrap();
                assert_eq!(warm1, fresh, "{} pes={pes}: first cached build", variant.name);
                assert_eq!(warm2, fresh, "{} pes={pes}: fully-warm build", variant.name);
            }
        }
        assert!(analyzer.cache_hits() > 0, "the conv stack repeats shapes; hits expected");
    }

    #[test]
    fn network_table_equals_per_layer_concatenation() {
        // The network-level table is the per-layer aggregation, bit for
        // bit: rows concatenate in layer order, activity/requirements
        // accumulate in the same order.
        let net = vgg16::conv_only();
        let layers: Vec<&Layer> = net.layers.iter().collect();
        let variant = kc_p_ct(32);
        let whole = build_case_table(&layers, &variant, 256).unwrap();
        let mut rows = Vec::new();
        let mut activity = Activity::default();
        let (mut l1_req, mut l2_req, mut units0) = (0u64, 0u64, 1u64);
        for layer in &net.layers {
            let single = build_case_table(&[layer], &variant, 256).unwrap();
            rows.extend_from_slice(&single.rows);
            activity.macs += single.activity.macs;
            activity.l2_reads += single.activity.l2_reads;
            activity.l2_writes += single.activity.l2_writes;
            activity.l1_reads += single.activity.l1_reads;
            activity.l1_writes += single.activity.l1_writes;
            activity.noc_delivered += single.activity.noc_delivered;
            l1_req = l1_req.max(single.l1_req);
            l2_req = l2_req.max(single.l2_req);
            units0 = units0.max(single.units0);
        }
        assert_eq!(whole.rows, rows);
        assert_eq!(whole.activity, activity);
        assert_eq!((whole.l1_req, whole.l2_req, whole.units0), (l1_req, l2_req, units0));
    }

    #[test]
    fn network_sweep_surfaces_cache_hits() {
        let net = vgg16::conv_only();
        let space = DesignSpace::ci_smoke("kc-p");
        let out = sweep(&net, &space, 2, &SweepConfig::serial()).unwrap();
        assert!(out.stats.cache_hits > 0, "VGG's repeated conv shapes must hit the layer cache");
        assert!(out.stats.cache_misses > 0);
        let s = out.stats.summary();
        assert!(s.contains("cache="), "summary surfaces the hit/miss split: {s}");
    }

    #[test]
    fn shared_store_sweep_reruns_fully_warm() {
        // Two sweeps over one SharedStore: the second must re-analyze
        // nothing (every triple replays) and still produce identical
        // results.
        let net = vgg16::conv_only();
        let space = DesignSpace::ci_smoke("kc-p");
        let store = Arc::new(SharedStore::new());
        let cfg = SweepConfig {
            keep_all_points: true,
            cache: Some(Arc::clone(&store)),
            ..SweepConfig::serial()
        };
        let cold = sweep(&net, &space, 2, &cfg).unwrap();
        assert!(cold.stats.cache_misses > 0);
        assert!(!store.is_empty(), "shared store must retain the sweep's entries");
        let warm = sweep(&net, &space, 2, &cfg).unwrap();
        assert_eq!(warm.stats.cache_misses, 0, "fully warm rerun must not re-analyze");
        assert_eq!(warm.stats.cache_disk_hits, 0, "no cache file involved");
        assert_eq!(warm.frontier, cold.frontier);
        assert_eq!(warm.points, cold.points);
        assert_eq!(
            (warm.stats.evaluated, warm.stats.valid, warm.stats.pruned, warm.stats.unmappable),
            (cold.stats.evaluated, cold.stats.valid, cold.stats.pruned, cold.stats.unmappable),
        );
        let s = warm.stats.summary();
        assert!(s.contains("d/"), "summary surfaces the disk-hit slot: {s}");
    }

    #[test]
    fn table_reuse_is_bit_identical_to_rebuilding() {
        // The per-pair table cache must be invisible in every
        // non-diagnostic output: frontier, point list, and skip
        // accounting match the rebuild-every-visit reference for both
        // a single-wave and a many-wave (guided) strategy.
        use crate::dse::strategy::SearchStrategy;
        let net = vgg16::conv_only();
        let space = DesignSpace::ci_smoke("kc-p");
        for strategy in [SearchStrategy::Exhaustive, SearchStrategy::ParetoGuided] {
            let on = SweepConfig {
                strategy: strategy.clone(),
                keep_all_points: true,
                ..SweepConfig::serial()
            };
            let off = SweepConfig { reuse_tables: false, ..on.clone() };
            let a = sweep(&net, &space, 2, &on).unwrap();
            let b = sweep(&net, &space, 2, &off).unwrap();
            assert_eq!(a.frontier, b.frontier, "{strategy:?}: frontier");
            assert_eq!(a.points, b.points, "{strategy:?}: point list");
            assert_eq!(
                (a.stats.evaluated, a.stats.valid, a.stats.pruned, a.stats.unmappable),
                (b.stats.evaluated, b.stats.valid, b.stats.pruned, b.stats.unmappable),
                "{strategy:?}: skip accounting"
            );
            assert_eq!(
                (a.stats.budget_skipped, a.stats.waves),
                (b.stats.budget_skipped, b.stats.waves),
                "{strategy:?}: wave accounting"
            );
        }
    }

    #[test]
    fn guided_sweep_builds_each_pair_once() {
        // The guided binary search touches a pair once per wave; with
        // table reuse the pair's layer analyses run only on the first
        // touch, so the sweep requests strictly fewer analyses than the
        // rebuild-every-visit reference.
        use crate::dse::strategy::SearchStrategy;
        let net = vgg16::conv_only();
        let space = DesignSpace::ci_smoke("kc-p");
        let on = SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::serial() };
        let off = SweepConfig { reuse_tables: false, ..on.clone() };
        let a = sweep(&net, &space, 2, &on).unwrap();
        let b = sweep(&net, &space, 2, &off).unwrap();
        assert!(a.stats.waves > 1, "guided refinement must run multiple waves");
        let touched = a.stats.cache_hits + a.stats.cache_misses;
        let rebuilt = b.stats.cache_hits + b.stats.cache_misses;
        assert!(
            touched < rebuilt,
            "table reuse must cut analyzer traffic: {touched} vs {rebuilt}"
        );
    }

    #[test]
    fn daemon_lifetime_shared_tables_are_bit_identical_across_sweeps() {
        // A caller-owned PairTables shared across two whole sweeps (the
        // daemon's promotion of the sweep-lifetime cache) must leave
        // every non-diagnostic output bit-identical to a private-table
        // reference, and the fully-warm second sweep must run zero
        // layer analyses — every pair replays its cached table.
        let net = vgg16::conv_only();
        let space = DesignSpace::ci_smoke("kc-p");
        let shared = Arc::new(PairTables::new());
        let cfg = SweepConfig {
            keep_all_points: true,
            shared_tables: Some(Arc::clone(&shared)),
            ..SweepConfig::serial()
        };
        let first = sweep(&net, &space, 2, &cfg).unwrap();
        assert!(!shared.is_empty(), "first sweep must populate the shared table cache");
        let second = sweep(&net, &space, 2, &cfg).unwrap();
        let reference = sweep(
            &net,
            &space,
            2,
            &SweepConfig { keep_all_points: true, ..SweepConfig::serial() },
        )
        .unwrap();
        for (label, out) in [("first", &first), ("second", &second)] {
            assert_eq!(out.frontier, reference.frontier, "{label}: frontier");
            assert_eq!(out.points, reference.points, "{label}: point list");
            assert_eq!(
                (out.stats.evaluated, out.stats.valid, out.stats.pruned, out.stats.unmappable),
                (
                    reference.stats.evaluated,
                    reference.stats.valid,
                    reference.stats.pruned,
                    reference.stats.unmappable
                ),
                "{label}: skip accounting"
            );
        }
        assert_eq!(
            second.stats.cache_hits + second.stats.cache_misses,
            0,
            "fully shared tables must eliminate analyzer traffic entirely"
        );
    }

    #[test]
    fn table_identity_tracks_workload_and_pair_axes_only() {
        let net = vgg16::conv_only();
        let space = DesignSpace::ci_smoke("kc-p");
        let id = table_identity(&net, &space);
        assert_eq!(id, table_identity(&net, &space), "identity is deterministic in-process");
        let mut bw = space.clone();
        bw.bandwidths = vec![1, 2];
        assert_eq!(id, table_identity(&net, &bw), "bandwidth axis must be excluded");
        let mut pes = space.clone();
        pes.pes.push(8192);
        assert_ne!(id, table_identity(&net, &pes), "PEs axis must be included");
        let single = Network::single(vgg16::conv13());
        assert_ne!(id, table_identity(&single, &space), "workload must be included");
    }

    // The pruned-vs-unmappable accounting scenario lives in
    // rust/tests/dse_parallel.rs (unmappable_and_pruned_pairs_are_
    // distinguished), alongside the determinism contract; the
    // pre-warmed / any-thread-count determinism of shared-store sweeps
    // is pinned there too.
}
