//! Pareto-front extraction and objective-optimal selection over DSE
//! design points (the stars and crosses of Fig 13), plus the streaming
//! [`ParetoAccumulator`] the sharded sweep folds shard results into.

use crate::dse::engine::DesignPoint;

/// Streaming runtime-energy Pareto accumulator: maintains the frontier
/// over *valid* design points one offer at a time, without materializing
/// the full sweep — the memory bound of the sharded sweep engine.
///
/// Ties on exact (runtime, energy) are first-wins, so replaying the same
/// points in the same order always yields the same frontier; the sharded
/// sweep relies on this (shards merge in shard order, which replays the
/// serial iteration order) for thread-count-independent results.
#[derive(Debug, Clone, Default)]
pub struct ParetoAccumulator {
    /// Current frontier, in insertion order.
    points: Vec<DesignPoint>,
}

impl ParetoAccumulator {
    pub fn new() -> ParetoAccumulator {
        ParetoAccumulator::default()
    }

    /// `a` is at least as good as `b` on both objectives.
    fn covers(a: &DesignPoint, b: &DesignPoint) -> bool {
        a.runtime <= b.runtime && a.energy_pj <= b.energy_pj
    }

    /// Offer one point. Invalid or dominated points are dropped; an
    /// accepted point evicts the frontier points it covers. Returns
    /// whether the point joined the frontier.
    pub fn offer(&mut self, p: &DesignPoint) -> bool {
        if !p.valid {
            return false;
        }
        if self.points.iter().any(|q| Self::covers(q, p)) {
            return false;
        }
        self.points.retain(|q| !Self::covers(p, q));
        self.points.push(p.clone());
        true
    }

    /// Would a valid point with these objective values join the current
    /// frontier? Cheap scalar pre-check so hot loops can skip building
    /// the full `DesignPoint` for dominated candidates.
    pub fn would_admit(&self, runtime: f64, energy_pj: f64) -> bool {
        !self.points.iter().any(|q| q.runtime <= runtime && q.energy_pj <= energy_pj)
    }

    /// Fold another accumulator in, offering its points in their stored
    /// (insertion) order so the first-wins tie rule is preserved.
    pub fn merge(&mut self, other: &ParetoAccumulator) {
        for p in &other.points {
            self.offer(p);
        }
    }

    /// The current frontier, in insertion order (the sorted view is
    /// [`into_sorted`](ParetoAccumulator::into_sorted)) — the
    /// mid-sweep read-only view for consumers that want the frontier
    /// points themselves rather than the scalar queries below
    /// ([`would_admit`](ParetoAccumulator::would_admit) /
    /// [`contains_value`](ParetoAccumulator::contains_value), which is
    /// all the built-in guided strategy needs).
    pub fn points(&self) -> &[DesignPoint] {
        &self.points
    }

    /// Exact-match query: does the frontier hold a point with these
    /// objective values? The guided strategy uses this to decide which
    /// settled pairs are worth expanding — values compare bit-for-bit
    /// because they come out of the same deterministic evaluation.
    pub fn contains_value(&self, runtime: f64, energy_pj: f64) -> bool {
        self.points.iter().any(|q| q.runtime == runtime && q.energy_pj == energy_pj)
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The frontier, sorted by (runtime, energy, variant, PEs, bandwidth)
    /// — a total order, so the output is fully deterministic.
    pub fn into_sorted(mut self) -> Vec<DesignPoint> {
        self.points.sort_by(|a, b| {
            a.runtime
                .total_cmp(&b.runtime)
                .then(a.energy_pj.total_cmp(&b.energy_pj))
                .then_with(|| a.dataflow.cmp(&b.dataflow))
                .then(a.pes.cmp(&b.pes))
                .then(a.bandwidth.cmp(&b.bandwidth))
        });
        self.points
    }
}

/// The sorted, deduplicated (runtime, energy) objective values of a
/// point set, as raw bits (`f64::to_bits`) so comparison is exact.
/// This is the "same frontier values" predicate the guided-vs-
/// exhaustive acceptance gate uses (two frontiers can differ in which
/// design realizes a value — tie-breaking picks different bandwidths —
/// while being the same frontier objectively).
pub fn objective_values(points: &[DesignPoint]) -> Vec<(u64, u64)> {
    let mut v: Vec<(u64, u64)> =
        points.iter().map(|p| (p.runtime.to_bits(), p.energy_pj.to_bits())).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Objective for picking a single optimum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimize {
    Throughput,
    Energy,
    Edp,
}

/// The objective value (lower is better).
pub fn objective_value(p: &DesignPoint, o: Optimize, macs: f64) -> f64 {
    match o {
        Optimize::Throughput => -p.throughput(macs),
        Optimize::Energy => p.energy_pj,
        Optimize::Edp => p.edp(),
    }
}

/// Best valid design under an objective. Near-ties (within 0.1% of the
/// optimum) break toward lower runtime — a cheaper design that is also
/// faster is strictly preferable, and flat regions of the energy
/// landscape are common when activity counts dominate.
pub fn best<'a>(points: &'a [DesignPoint], o: Optimize, macs: f64) -> Option<&'a DesignPoint> {
    let opt = points
        .iter()
        .filter(|p| p.valid)
        .map(|p| objective_value(p, o, macs))
        .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))?;
    let tol = opt.abs() * 1e-3;
    points
        .iter()
        .filter(|p| p.valid && objective_value(p, o, macs) <= opt + tol)
        .min_by(|a, b| a.runtime.partial_cmp(&b.runtime).unwrap_or(std::cmp::Ordering::Equal))
}

/// 2-D Pareto front minimizing both `fx` and `fy` over the valid points.
/// Returns indices into `points`, sorted by `fx`.
pub fn pareto_front<FX, FY>(points: &[DesignPoint], fx: FX, fy: FY) -> Vec<usize>
where
    FX: Fn(&DesignPoint) -> f64,
    FY: Fn(&DesignPoint) -> f64,
{
    let mut idx: Vec<usize> = (0..points.len()).filter(|&i| points[i].valid).collect();
    idx.sort_by(|&a, &b| {
        fx(&points[a])
            .partial_cmp(&fx(&points[b]))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(
                fy(&points[a])
                    .partial_cmp(&fy(&points[b]))
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
    });
    let mut front = Vec::new();
    let mut best_y = f64::INFINITY;
    for i in idx {
        let y = fy(&points[i]);
        if y < best_y {
            best_y = y;
            front.push(i);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dp(runtime: f64, energy: f64, valid: bool) -> DesignPoint {
        DesignPoint {
            dataflow: "t".into(),
            pes: 64,
            bandwidth: 16,
            l1: 512,
            l2: 100_000,
            runtime,
            energy_pj: energy,
            area_mm2: 1.0,
            power_mw: 1.0,
            valid,
        }
    }

    #[test]
    fn front_excludes_dominated() {
        let pts = vec![dp(10.0, 10.0, true), dp(5.0, 20.0, true), dp(20.0, 5.0, true), dp(12.0, 12.0, true)];
        let front = pareto_front(&pts, |p| p.runtime, |p| p.energy_pj);
        // (5,20), (10,10), (20,5) are non-dominated; (12,12) dominated by (10,10).
        assert_eq!(front.len(), 3);
        assert!(!front.contains(&3));
    }

    #[test]
    fn front_skips_invalid() {
        let pts = vec![dp(1.0, 1.0, false), dp(5.0, 5.0, true)];
        let front = pareto_front(&pts, |p| p.runtime, |p| p.energy_pj);
        assert_eq!(front, vec![1]);
    }

    #[test]
    fn best_under_objectives() {
        let pts = vec![dp(10.0, 10.0, true), dp(5.0, 40.0, true), dp(40.0, 2.0, true)];
        let macs = 1000.0;
        assert_eq!(best(&pts, Optimize::Throughput, macs).unwrap().runtime, 5.0);
        assert_eq!(best(&pts, Optimize::Energy, macs).unwrap().energy_pj, 2.0);
        // EDP: 100, 200, 80 -> the last.
        assert_eq!(best(&pts, Optimize::Edp, macs).unwrap().runtime, 40.0);
    }

    #[test]
    fn best_none_when_all_invalid() {
        let pts = vec![dp(1.0, 1.0, false)];
        assert!(best(&pts, Optimize::Energy, 1.0).is_none());
    }

    #[test]
    fn accumulator_matches_batch_front() {
        let pts = vec![
            dp(10.0, 10.0, true),
            dp(5.0, 20.0, true),
            dp(20.0, 5.0, true),
            dp(12.0, 12.0, true), // dominated by (10,10)
            dp(3.0, 3.0, false),  // invalid: ignored even though it dominates all
        ];
        let mut acc = ParetoAccumulator::new();
        for p in &pts {
            acc.offer(p);
        }
        let streamed = acc.into_sorted();
        let front = pareto_front(&pts, |p| p.runtime, |p| p.energy_pj);
        let mut batch: Vec<DesignPoint> = front.iter().map(|&i| pts[i].clone()).collect();
        batch.sort_by(|a, b| a.runtime.total_cmp(&b.runtime));
        assert_eq!(streamed, batch);
    }

    #[test]
    fn accumulator_evicts_dominated_and_keeps_first_tie() {
        let mut acc = ParetoAccumulator::new();
        assert!(acc.offer(&dp(10.0, 10.0, true)));
        // Equal point arrives later: first wins.
        let mut tie = dp(10.0, 10.0, true);
        tie.pes = 999;
        assert!(!acc.offer(&tie));
        // A dominating point evicts the incumbent.
        assert!(acc.offer(&dp(8.0, 8.0, true)));
        assert_eq!(acc.len(), 1);
        assert_eq!(acc.into_sorted()[0].runtime, 8.0);
    }

    #[test]
    fn frontier_queries_reflect_membership() {
        let mut acc = ParetoAccumulator::new();
        acc.offer(&dp(10.0, 10.0, true));
        acc.offer(&dp(5.0, 20.0, true));
        assert_eq!(acc.points().len(), 2);
        assert!(acc.contains_value(10.0, 10.0));
        assert!(acc.contains_value(5.0, 20.0));
        assert!(!acc.contains_value(10.0, 20.0), "exact match only");
        // A dominating point evicts: membership follows.
        acc.offer(&dp(4.0, 4.0, true));
        assert!(!acc.contains_value(10.0, 10.0));
        assert!(acc.contains_value(4.0, 4.0));
        assert!(acc.would_admit(3.0, 5.0));
        assert!(!acc.would_admit(4.0, 4.0), "equal values are covered");
    }

    #[test]
    fn accumulator_merge_equals_streaming() {
        // Any contiguous partition, merged in order, must equal the
        // single streaming pass — the sharded sweep's determinism
        // contract.
        let pts: Vec<DesignPoint> = (0..40)
            .map(|i| {
                let x = ((i * 7) % 13) as f64 + 1.0;
                let y = ((i * 11) % 17) as f64 + 1.0;
                dp(x, y, i % 5 != 0)
            })
            .collect();
        let mut whole = ParetoAccumulator::new();
        for p in &pts {
            whole.offer(p);
        }
        for chunk_size in [1usize, 3, 7, 40] {
            let mut merged = ParetoAccumulator::new();
            for chunk in pts.chunks(chunk_size) {
                let mut shard = ParetoAccumulator::new();
                for p in chunk {
                    shard.offer(p);
                }
                merged.merge(&shard);
            }
            assert_eq!(
                merged.clone().into_sorted(),
                whole.clone().into_sorted(),
                "chunk_size {chunk_size}"
            );
        }
    }
}
