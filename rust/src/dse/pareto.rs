//! Pareto-front extraction and objective-optimal selection over DSE
//! design points (the stars and crosses of Fig 13).

use crate::dse::engine::DesignPoint;

/// Objective for picking a single optimum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimize {
    Throughput,
    Energy,
    Edp,
}

/// The objective value (lower is better).
pub fn objective_value(p: &DesignPoint, o: Optimize, macs: f64) -> f64 {
    match o {
        Optimize::Throughput => -p.throughput(macs),
        Optimize::Energy => p.energy_pj,
        Optimize::Edp => p.edp(),
    }
}

/// Best valid design under an objective. Near-ties (within 0.1% of the
/// optimum) break toward lower runtime — a cheaper design that is also
/// faster is strictly preferable, and flat regions of the energy
/// landscape are common when activity counts dominate.
pub fn best<'a>(points: &'a [DesignPoint], o: Optimize, macs: f64) -> Option<&'a DesignPoint> {
    let opt = points
        .iter()
        .filter(|p| p.valid)
        .map(|p| objective_value(p, o, macs))
        .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))?;
    let tol = opt.abs() * 1e-3;
    points
        .iter()
        .filter(|p| p.valid && objective_value(p, o, macs) <= opt + tol)
        .min_by(|a, b| a.runtime.partial_cmp(&b.runtime).unwrap_or(std::cmp::Ordering::Equal))
}

/// 2-D Pareto front minimizing both `fx` and `fy` over the valid points.
/// Returns indices into `points`, sorted by `fx`.
pub fn pareto_front<FX, FY>(points: &[DesignPoint], fx: FX, fy: FY) -> Vec<usize>
where
    FX: Fn(&DesignPoint) -> f64,
    FY: Fn(&DesignPoint) -> f64,
{
    let mut idx: Vec<usize> = (0..points.len()).filter(|&i| points[i].valid).collect();
    idx.sort_by(|&a, &b| {
        fx(&points[a])
            .partial_cmp(&fx(&points[b]))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(
                fy(&points[a])
                    .partial_cmp(&fy(&points[b]))
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
    });
    let mut front = Vec::new();
    let mut best_y = f64::INFINITY;
    for i in idx {
        let y = fy(&points[i]);
        if y < best_y {
            best_y = y;
            front.push(i);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dp(runtime: f64, energy: f64, valid: bool) -> DesignPoint {
        DesignPoint {
            dataflow: "t".into(),
            pes: 64,
            bandwidth: 16,
            l1: 512,
            l2: 100_000,
            runtime,
            energy_pj: energy,
            area_mm2: 1.0,
            power_mw: 1.0,
            valid,
        }
    }

    #[test]
    fn front_excludes_dominated() {
        let pts = vec![dp(10.0, 10.0, true), dp(5.0, 20.0, true), dp(20.0, 5.0, true), dp(12.0, 12.0, true)];
        let front = pareto_front(&pts, |p| p.runtime, |p| p.energy_pj);
        // (5,20), (10,10), (20,5) are non-dominated; (12,12) dominated by (10,10).
        assert_eq!(front.len(), 3);
        assert!(!front.contains(&3));
    }

    #[test]
    fn front_skips_invalid() {
        let pts = vec![dp(1.0, 1.0, false), dp(5.0, 5.0, true)];
        let front = pareto_front(&pts, |p| p.runtime, |p| p.energy_pj);
        assert_eq!(front, vec![1]);
    }

    #[test]
    fn best_under_objectives() {
        let pts = vec![dp(10.0, 10.0, true), dp(5.0, 40.0, true), dp(40.0, 2.0, true)];
        let macs = 1000.0;
        assert_eq!(best(&pts, Optimize::Throughput, macs).unwrap().runtime, 5.0);
        assert_eq!(best(&pts, Optimize::Energy, macs).unwrap().energy_pj, 2.0);
        // EDP: 100, 200, 80 -> the last.
        assert_eq!(best(&pts, Optimize::Edp, macs).unwrap().runtime, 40.0);
    }

    #[test]
    fn best_none_when_all_invalid() {
        let pts = vec![dp(1.0, 1.0, false)];
        assert!(best(&pts, Optimize::Energy, 1.0).is_none());
    }
}
