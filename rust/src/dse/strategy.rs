//! Pluggable DSE search strategies (paper §5.2 at scale): candidate
//! generation over the (variant, PEs, bandwidth) design space, budgeted
//! and wave-based, so exhaustive enumeration is one traversal among
//! several instead of the only one the sweep engine can run.
//!
//! # Model
//!
//! A strategy is a [`CandidateGen`]: the engine repeatedly asks it for
//! the next **wave** of [`PairBatch`]es (candidates grouped by their
//! (variant, PEs) pair — the case-table unit of work), evaluates the
//! wave sharded across the worker pool, merges deterministically, and
//! hands the strategy the updated Pareto frontier plus (for strategies
//! that ask) per-candidate [`WaveFeedback`]. An empty wave ends the
//! sweep. Budgets ([`SearchBudget`]) are enforced by the engine:
//! `max_designs` truncates waves deterministically (the cut candidates
//! are counted in `SweepStats::budget_skipped`), `max_seconds` stops
//! between waves (wall-clock cutoffs are inherently not bit-
//! deterministic; off by default).
//!
//! # Strategies
//!
//! * [`SearchStrategy::Exhaustive`] — one wave containing every pair
//!   with the full bandwidth axis, in serial pair order. Sharded and
//!   merged exactly like the pre-strategy sweep engine: bit-identical
//!   results, pinned by the unchanged determinism tests in
//!   `rust/tests/dse_parallel.rs`.
//! * [`SearchStrategy::RandomSample`] — a uniform, seeded,
//!   duplicate-free sample of `max_designs` candidates (requires a
//!   budget), generated in one wave from `util::rng`'s deterministic
//!   xorshift stream and emitted in serial candidate order — identical
//!   outcome for any thread count.
//! * [`SearchStrategy::ParetoGuided`] — iterative refinement. Wave 0
//!   probes a coarse grid over the (variant, PEs) axes at the top of
//!   the bandwidth axis; every probed pair then binary-searches its
//!   highest *valid* bandwidth (runtime is monotone non-increasing in
//!   bandwidth and energy is bandwidth-independent per pair — both
//!   pinned by engine tests — so that point realizes the pair's best
//!   objective values); pairs whose best-possible value (top-bandwidth
//!   runtime is a lower bound) is already covered by the frontier are
//!   eliminated; pairs whose settled value sits on the frontier expand
//!   their grid neighborhood (±1 PEs, and one step along the variant
//!   axis — *tile-coordinate* adjacency when the space is
//!   mapspace-backed, index ±1 on the legacy pinned axes); and when
//!   refinement dries up, every
//!   still-untouched pair is probed once so no frontier pair can hide.
//!   The per-pair state machine makes duplicate evaluations impossible
//!   (each (pair, bandwidth) is emitted at most once). On convergence
//!   the guided frontier carries exactly the exhaustive frontier's
//!   objective values at a fraction of the evaluations
//!   (`rust/tests/dse_strategies.rs` pins both).
//!
//!   The binary search visits one pair across many waves — historically
//!   each probe re-flattened and re-analyzed the pair's case table, so
//!   a guided probe cost far more than an exhaustive candidate. The
//!   engine's sweep-lifetime per-pair table cache
//!   ([`crate::dse::engine::SweepConfig::reuse_tables`]) now amortizes
//!   that: the pair's table is built on first touch and every later
//!   probe replays it, making a probe's marginal cost one scalar
//!   `eval_runtime` pass.

use anyhow::{bail, ensure, Result};

use crate::dse::pareto::ParetoAccumulator;
use crate::dse::space::{coarse_axis, DesignSpace};
use crate::util::rng::Rng;

/// A batch of candidate designs sharing one (variant, PEs) pair — the
/// unit the engine schedules (one case table per batch). `pair` indexes
/// the serial outer product (`variants[pair / pes.len()]`,
/// `pes[pair % pes.len()]` — see [`DesignSpace::pair_coords`]); `bws`
/// are indices into `space.bandwidths`, strictly ascending.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PairBatch {
    pub pair: usize,
    pub bws: Vec<usize>,
}

impl PairBatch {
    /// Candidates in this batch.
    pub fn candidates(&self) -> u64 {
        self.bws.len() as u64
    }
}

/// One evaluated candidate, reported back to feedback-driven strategies.
#[derive(Debug, Clone, Copy)]
pub struct CandidateEval {
    pub pair: usize,
    /// Bandwidth *index* into `space.bandwidths`.
    pub bw: usize,
    pub valid: bool,
    pub runtime: f64,
    pub energy_pj: f64,
}

/// What the engine reports after each wave (only collected when the
/// strategy's [`CandidateGen::needs_feedback`] says so). Merged in
/// shard order, so the contents are deterministic for any thread count.
#[derive(Debug, Clone, Default)]
pub struct WaveFeedback {
    /// Every evaluated candidate of the wave.
    pub evals: Vec<CandidateEval>,
    /// Pairs whose whole batch was skipped: no legal mapping, or
    /// §5.2-pruned (over budget even at the cheapest bandwidth).
    pub dead_pairs: Vec<usize>,
}

/// Evaluation budget. `0` means unlimited in both fields.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SearchBudget {
    /// Maximum candidates admitted to evaluation (pruned/unmappable
    /// batches count — they were admitted, the §5.2 check skipped
    /// them). Waves are truncated deterministically; the cut lands in
    /// `SweepStats::budget_skipped`.
    pub max_designs: u64,
    /// Wall-clock cutoff in seconds, checked between waves. The one
    /// knob that trades bit-determinism for latency; leave at `0.0`
    /// (off) when reproducibility matters.
    pub max_seconds: f64,
}

/// Which candidate-generation strategy drives the sweep.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SearchStrategy {
    /// Every (variant, PEs, bandwidth) candidate, serial order.
    Exhaustive,
    /// A seeded uniform duplicate-free sample of `max_designs`
    /// candidates (deterministic for a fixed seed, any thread count).
    RandomSample { seed: u64 },
    /// Frontier-guided iterative refinement (see module docs).
    ParetoGuided,
}

impl Default for SearchStrategy {
    fn default() -> SearchStrategy {
        SearchStrategy::Exhaustive
    }
}

impl SearchStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            SearchStrategy::Exhaustive => "exhaustive",
            SearchStrategy::RandomSample { .. } => "random",
            SearchStrategy::ParetoGuided => "guided",
        }
    }

    /// Parse a CLI spelling (`exhaustive | random | guided`); `seed`
    /// feeds the random strategy.
    pub fn parse(name: &str, seed: u64) -> Result<SearchStrategy> {
        Ok(match name {
            "exhaustive" => SearchStrategy::Exhaustive,
            "random" => SearchStrategy::RandomSample { seed },
            "guided" => SearchStrategy::ParetoGuided,
            other => bail!("unknown search strategy '{other}' (exhaustive | random | guided)"),
        })
    }

    /// Build the candidate generator for a space. Fails fast on
    /// nonsensical combinations (random sampling without a budget).
    pub fn generator(&self, space: &DesignSpace, budget: &SearchBudget) -> Result<Box<dyn CandidateGen>> {
        match self {
            SearchStrategy::Exhaustive => Ok(Box::new(ExhaustiveGen {
                n_pairs: space.pairs(),
                n_bw: space.bandwidths.len(),
                emitted: false,
            })),
            SearchStrategy::RandomSample { seed } => {
                ensure!(
                    budget.max_designs > 0,
                    "the random strategy samples against a budget: set max_designs (--budget N)"
                );
                Ok(Box::new(RandomGen {
                    plan: random_plan(space.pairs(), space.bandwidths.len(), budget.max_designs, *seed),
                    emitted: false,
                }))
            }
            SearchStrategy::ParetoGuided => Ok(Box::new(GuidedGen::new(space))),
        }
    }
}

/// Candidate generation: the engine calls [`next_wave`] with the merged
/// Pareto frontier so far and (when [`needs_feedback`]) the previous
/// wave's outcomes; an empty wave ends the sweep.
///
/// [`next_wave`]: CandidateGen::next_wave
/// [`needs_feedback`]: CandidateGen::needs_feedback
pub trait CandidateGen {
    fn next_wave(&mut self, frontier: &ParetoAccumulator, feedback: &WaveFeedback) -> Vec<PairBatch>;

    /// Whether the engine must collect per-candidate [`WaveFeedback`]
    /// (costs one tuple per evaluated candidate per wave).
    fn needs_feedback(&self) -> bool {
        false
    }
}

/// Plan the single wave of a non-feedback strategy (exhaustive or
/// random), budget-truncated — the shape the PJRT/coordinator path
/// turns into `DseJob`s. Feedback-driven strategies (guided) refine
/// against the evolving frontier and only run on the in-process sweep
/// engine; they are rejected here.
pub fn plan_single_wave(
    space: &DesignSpace,
    strategy: &SearchStrategy,
    budget: &SearchBudget,
) -> Result<(Vec<PairBatch>, u64)> {
    let mut gen = strategy.generator(space, budget)?;
    ensure!(
        !gen.needs_feedback(),
        "the {} strategy refines waves against the evolving Pareto frontier and only runs on \
         the in-process sweep engine (drop --pjrt)",
        strategy.name()
    );
    let mut wave = gen.next_wave(&ParetoAccumulator::new(), &WaveFeedback::default());
    let remaining = if budget.max_designs > 0 { budget.max_designs } else { u64::MAX };
    let skipped = truncate_wave(&mut wave, remaining);
    Ok((wave, skipped))
}

/// Deterministically truncate a wave to `remaining` candidates (whole
/// leading batches kept, one possibly split, the rest dropped). Returns
/// how many candidates were cut.
pub(crate) fn truncate_wave(wave: &mut Vec<PairBatch>, remaining: u64) -> u64 {
    let mut left = remaining;
    let mut cut = 0u64;
    let mut kept = Vec::with_capacity(wave.len());
    for mut batch in wave.drain(..) {
        let n = batch.candidates();
        if left >= n {
            left -= n;
            kept.push(batch);
        } else {
            cut += n - left;
            if left > 0 {
                batch.bws.truncate(left as usize);
                left = 0;
                kept.push(batch);
            }
        }
    }
    *wave = kept;
    cut
}

// ---------------------------------------------------------------------
// Exhaustive
// ---------------------------------------------------------------------

struct ExhaustiveGen {
    n_pairs: usize,
    n_bw: usize,
    emitted: bool,
}

impl CandidateGen for ExhaustiveGen {
    fn next_wave(&mut self, _frontier: &ParetoAccumulator, _feedback: &WaveFeedback) -> Vec<PairBatch> {
        if self.emitted {
            return Vec::new();
        }
        self.emitted = true;
        (0..self.n_pairs)
            .map(|pair| PairBatch { pair, bws: (0..self.n_bw).collect() })
            .collect()
    }
}

// ---------------------------------------------------------------------
// Random sampling
// ---------------------------------------------------------------------

struct RandomGen {
    plan: Vec<PairBatch>,
    emitted: bool,
}

impl CandidateGen for RandomGen {
    fn next_wave(&mut self, _frontier: &ParetoAccumulator, _feedback: &WaveFeedback) -> Vec<PairBatch> {
        if self.emitted {
            return Vec::new();
        }
        self.emitted = true;
        std::mem::take(&mut self.plan)
    }
}

/// Sample `min(max_designs, |space|)` distinct candidate ids uniformly
/// (rejection sampling over the deterministic xorshift stream — fine
/// while the budget is well below the space size, which is the whole
/// point of sampling), then group them into serial-order batches. The
/// sorted output makes the plan independent of `HashSet` iteration
/// order, hence bit-stable across runs and thread counts.
fn random_plan(n_pairs: usize, n_bw: usize, max_designs: u64, seed: u64) -> Vec<PairBatch> {
    let total = n_pairs as u64 * n_bw as u64;
    let n = max_designs.min(total);
    let mut ids: Vec<u64>;
    if n == total {
        ids = (0..total).collect();
    } else {
        let mut rng = Rng::new(seed);
        let mut picked = std::collections::HashSet::with_capacity(n as usize);
        while (picked.len() as u64) < n {
            picked.insert(rng.below(total));
        }
        ids = picked.into_iter().collect();
        ids.sort_unstable();
    }
    let mut batches: Vec<PairBatch> = Vec::new();
    for id in ids {
        let pair = (id / n_bw as u64) as usize;
        let bw = (id % n_bw as u64) as usize;
        match batches.last_mut() {
            Some(b) if b.pair == pair => b.bws.push(bw),
            _ => batches.push(PairBatch { pair, bws: vec![bw] }),
        }
    }
    batches
}

// ---------------------------------------------------------------------
// Pareto-guided refinement
// ---------------------------------------------------------------------

/// Per-pair search state. Transitions guarantee each (pair, bandwidth)
/// candidate is emitted at most once.
#[derive(Debug, Clone, Copy)]
enum PairState {
    /// Never scheduled.
    Untouched,
    /// Top-of-axis probe in flight.
    Probing,
    /// Binary search for the highest valid bandwidth index in
    /// `[lo, hi]` (everything above `hi` is known invalid). Sound
    /// because validity is a prefix of the bandwidth axis: area and
    /// power are monotone non-decreasing in bandwidth (linear bus
    /// terms in `hw::area`, and dynamic power = energy/runtime with
    /// runtime monotone non-increasing), so invalid-at-m rules out
    /// everything above and valid-at-m implies valid below.
    /// `lower_runtime` is a lower bound on anything the pair can still
    /// achieve (runtime is monotone non-increasing in bandwidth): the
    /// top-bandwidth runtime initially, tightened by every invalid
    /// probe (all remaining candidate bandwidths sit below it, so they
    /// are at least that slow). Used for dominance elimination.
    /// `last_valid_*` caches the best probed-valid (bw, runtime) so a
    /// collapsed window settles without re-evaluating.
    Searching { lo: usize, hi: usize, lower_runtime: f64, energy_pj: f64, last_valid_bw: usize, last_valid_runtime: f64 },
    /// Highest valid bandwidth found: the pair's best objective values.
    Settled { runtime: f64, energy_pj: f64, expanded: bool },
    /// Unmappable, pruned, bandwidth-exhausted, or dominance-eliminated.
    Dead,
}

/// Sentinel for "no valid bandwidth probed yet" in `last_valid_bw`.
const NO_VALID: usize = usize::MAX;

struct GuidedGen {
    n_variants: usize,
    n_pes: usize,
    n_bw: usize,
    /// Per-pair grid neighbors, snapshotted from
    /// [`DesignSpace::pair_neighbors`] (the single source of the
    /// neighbor rule): ±1 PEs plus one step along the variant axis —
    /// index ±1 on the legacy hand-pinned axes, *tile-coordinate*
    /// adjacency on mapspace-backed axes, so neighborhood expansion
    /// moves one tile step, not one arbitrary list position.
    neighbors: Vec<Vec<usize>>,
    state: Vec<PairState>,
    started: bool,
}

/// The next binary-search probe for a `[lo, hi]` window.
fn probe_of(lo: usize, hi: usize) -> usize {
    if lo == hi {
        lo
    } else {
        (lo + hi + 1) / 2
    }
}

impl GuidedGen {
    fn new(space: &DesignSpace) -> GuidedGen {
        GuidedGen {
            n_variants: space.variants.len(),
            n_pes: space.pes.len(),
            n_bw: space.bandwidths.len(),
            neighbors: (0..space.pairs()).map(|p| space.pair_neighbors(p)).collect(),
            state: vec![PairState::Untouched; space.pairs()],
            started: false,
        }
    }

    fn absorb(&mut self, feedback: &WaveFeedback) {
        let top = self.n_bw - 1;
        for &dead in &feedback.dead_pairs {
            self.state[dead] = PairState::Dead;
        }
        for ev in &feedback.evals {
            self.state[ev.pair] = match self.state[ev.pair] {
                PairState::Probing => {
                    if ev.valid {
                        PairState::Settled { runtime: ev.runtime, energy_pj: ev.energy_pj, expanded: false }
                    } else if top == 0 {
                        PairState::Dead
                    } else {
                        PairState::Searching {
                            lo: 0,
                            hi: top - 1,
                            lower_runtime: ev.runtime,
                            energy_pj: ev.energy_pj,
                            last_valid_bw: NO_VALID,
                            last_valid_runtime: 0.0,
                        }
                    }
                }
                PairState::Searching { lo, hi, lower_runtime, energy_pj, last_valid_bw, last_valid_runtime } => {
                    let m = probe_of(lo, hi);
                    debug_assert_eq!(m, ev.bw, "guided feedback must match the scheduled probe");
                    if ev.valid {
                        if m == hi {
                            // Everything above `hi` is invalid: this is
                            // the highest valid bandwidth.
                            PairState::Settled { runtime: ev.runtime, energy_pj, expanded: false }
                        } else {
                            PairState::Searching {
                                lo: m,
                                hi,
                                lower_runtime,
                                energy_pj,
                                last_valid_bw: m,
                                last_valid_runtime: ev.runtime,
                            }
                        }
                    } else if m == lo {
                        // lo == hi == m and even that is invalid: the
                        // pair has no valid bandwidth at all.
                        PairState::Dead
                    } else if lo == m - 1 && last_valid_bw == lo {
                        // Window collapsed onto an already-probed valid
                        // index: settle without re-evaluating it.
                        PairState::Settled { runtime: last_valid_runtime, energy_pj, expanded: false }
                    } else {
                        // Every remaining candidate bandwidth sits below
                        // the invalid probe, so it is at least that slow:
                        // the invalid runtime tightens the elimination
                        // bound.
                        PairState::Searching {
                            lo,
                            hi: m - 1,
                            lower_runtime: lower_runtime.max(ev.runtime),
                            energy_pj,
                            last_valid_bw,
                            last_valid_runtime,
                        }
                    }
                }
                // A pair can reach Dead (pruned batch) and still have a
                // stale eval in flight conceptually; keep it dead.
                other => other,
            };
        }
    }
}

impl CandidateGen for GuidedGen {
    fn needs_feedback(&self) -> bool {
        true
    }

    fn next_wave(&mut self, frontier: &ParetoAccumulator, feedback: &WaveFeedback) -> Vec<PairBatch> {
        if self.n_bw == 0 || self.state.is_empty() {
            return Vec::new();
        }
        let top = self.n_bw - 1;
        if !self.started {
            // Wave 0: coarse grid over the pair axes, probed at the top
            // of the bandwidth axis (lowest achievable runtime — the
            // strongest dominance bound a single probe can buy).
            self.started = true;
            let mut wave = Vec::new();
            for v in coarse_axis(self.n_variants) {
                for p in coarse_axis(self.n_pes) {
                    let pair = v * self.n_pes + p;
                    self.state[pair] = PairState::Probing;
                    wave.push(PairBatch { pair, bws: vec![top] });
                }
            }
            wave.sort_by_key(|b| b.pair);
            return wave;
        }

        self.absorb(feedback);

        // Dominance elimination: a still-searching pair whose best
        // possible point (top-bandwidth runtime, bandwidth-independent
        // energy) is already covered by the frontier can never join it.
        for s in self.state.iter_mut() {
            if let PairState::Searching { lower_runtime, energy_pj, .. } = *s {
                if !frontier.would_admit(lower_runtime, energy_pj) {
                    *s = PairState::Dead;
                }
            }
        }

        let mut wave = Vec::new();
        // Continue every live binary search.
        for (pair, s) in self.state.iter().enumerate() {
            if let PairState::Searching { lo, hi, .. } = *s {
                wave.push(PairBatch { pair, bws: vec![probe_of(lo, hi)] });
            }
        }
        // Expand the grid neighborhood of pairs whose settled value sits
        // on the current frontier (each pair expands once).
        let mut expand = Vec::new();
        for (pair, s) in self.state.iter_mut().enumerate() {
            if let PairState::Settled { runtime, energy_pj, expanded } = s {
                if !*expanded && frontier.contains_value(*runtime, *energy_pj) {
                    *expanded = true;
                    expand.push(pair);
                }
            }
        }
        for pair in expand {
            for &n in &self.neighbors[pair] {
                if matches!(self.state[n], PairState::Untouched) {
                    self.state[n] = PairState::Probing;
                    wave.push(PairBatch { pair: n, bws: vec![top] });
                }
            }
        }
        // Completeness: when refinement dries up, probe every pair the
        // grid and expansions never reached — a frontier pair outside
        // the explored neighborhood would otherwise stay invisible.
        if wave.is_empty() {
            for (pair, s) in self.state.iter_mut().enumerate() {
                if matches!(s, PairState::Untouched) {
                    *s = PairState::Probing;
                    wave.push(PairBatch { pair, bws: vec![top] });
                }
            }
        }
        wave.sort_by_key(|b| b.pair);
        wave
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wave_candidates(wave: &[PairBatch]) -> u64 {
        wave.iter().map(|b| b.candidates()).sum()
    }

    #[test]
    fn exhaustive_emits_every_candidate_once_in_serial_order() {
        let space = DesignSpace::ci_smoke("kc-p");
        let mut gen = SearchStrategy::Exhaustive
            .generator(&space, &SearchBudget::default())
            .unwrap();
        let wave = gen.next_wave(&ParetoAccumulator::new(), &WaveFeedback::default());
        assert_eq!(wave.len(), space.pairs());
        assert_eq!(wave_candidates(&wave), space.size());
        for (i, b) in wave.iter().enumerate() {
            assert_eq!(b.pair, i);
            assert_eq!(b.bws, (0..space.bandwidths.len()).collect::<Vec<_>>());
        }
        assert!(gen.next_wave(&ParetoAccumulator::new(), &WaveFeedback::default()).is_empty());
    }

    #[test]
    fn random_plan_is_seeded_deduped_and_in_bounds() {
        let a = random_plan(7, 5, 20, 99);
        let b = random_plan(7, 5, 20, 99);
        assert_eq!(a, b, "same seed, same plan");
        assert_eq!(wave_candidates(&a), 20);
        let mut seen = std::collections::HashSet::new();
        for batch in &a {
            assert!(batch.pair < 7);
            assert!(batch.bws.windows(2).all(|w| w[0] < w[1]), "ascending bws");
            for &bw in &batch.bws {
                assert!(bw < 5);
                assert!(seen.insert((batch.pair, bw)), "no duplicate candidates");
            }
        }
        assert!(a.windows(2).all(|w| w[0].pair < w[1].pair), "serial pair order");
        let c = random_plan(7, 5, 20, 100);
        assert_ne!(a, c, "different seed explores a different sample");
    }

    #[test]
    fn random_plan_budget_above_space_degenerates_to_exhaustive() {
        let plan = random_plan(3, 4, 1000, 1);
        assert_eq!(wave_candidates(&plan), 12);
        assert_eq!(plan.len(), 3);
    }

    #[test]
    fn random_strategy_requires_budget() {
        let space = DesignSpace::ci_smoke("kc-p");
        assert!(SearchStrategy::RandomSample { seed: 1 }
            .generator(&space, &SearchBudget::default())
            .is_err());
    }

    #[test]
    fn truncate_wave_cuts_deterministically() {
        let mk = || {
            vec![
                PairBatch { pair: 0, bws: vec![0, 1, 2] },
                PairBatch { pair: 1, bws: vec![0, 1] },
                PairBatch { pair: 2, bws: vec![3] },
            ]
        };
        let mut w = mk();
        assert_eq!(truncate_wave(&mut w, 10), 0);
        assert_eq!(w, mk());
        let mut w = mk();
        assert_eq!(truncate_wave(&mut w, 4), 2);
        assert_eq!(
            w,
            vec![PairBatch { pair: 0, bws: vec![0, 1, 2] }, PairBatch { pair: 1, bws: vec![0] }]
        );
        let mut w = mk();
        assert_eq!(truncate_wave(&mut w, 0), 6);
        assert!(w.is_empty());
    }

    #[test]
    fn plan_single_wave_rejects_guided() {
        let space = DesignSpace::ci_smoke("kc-p");
        let err = plan_single_wave(&space, &SearchStrategy::ParetoGuided, &SearchBudget::default());
        assert!(err.is_err());
        let (wave, skipped) =
            plan_single_wave(&space, &SearchStrategy::Exhaustive, &SearchBudget { max_designs: 7, ..SearchBudget::default() })
                .unwrap();
        assert_eq!(wave_candidates(&wave), 7);
        assert_eq!(skipped, space.size() - 7);
    }

    #[test]
    fn probe_of_always_makes_progress() {
        // Any window either collapses (lo == hi) or probes strictly
        // inside it, so binary searches terminate and never repeat.
        for lo in 0..6usize {
            for hi in lo..6usize {
                let m = probe_of(lo, hi);
                assert!(m >= lo && m <= hi);
                if lo < hi {
                    assert!(m > lo, "upper-mid probe must move off lo");
                }
            }
        }
    }

    #[test]
    fn guided_wave0_is_a_coarse_grid_at_top_bandwidth() {
        let space = DesignSpace::ci_smoke("kc-p");
        let mut gen = SearchStrategy::ParetoGuided
            .generator(&space, &SearchBudget::default())
            .unwrap();
        assert!(gen.needs_feedback());
        let wave = gen.next_wave(&ParetoAccumulator::new(), &WaveFeedback::default());
        assert!(!wave.is_empty());
        assert!(wave.len() <= space.pairs());
        let top = space.bandwidths.len() - 1;
        for b in &wave {
            assert_eq!(b.bws, vec![top], "wave 0 probes the top of the bandwidth axis");
        }
        let expected = coarse_axis(space.variants.len()).len() * coarse_axis(space.pes.len()).len();
        assert_eq!(wave.len(), expected);
    }
}
