//! Design-space definition (paper §5.2): the four swept hardware
//! parameters (#PEs, L1 size, L2 size, NoC bandwidth) plus dataflow
//! *mapping variants* (tile-size knobs of the Table 3 styles), under an
//! area/power budget.
//!
//! Note on buffer sizing: following §5.2 ("the DSE tool places the exact
//! amount buffers MAESTRO reported"), L1/L2 capacities are *derived* from
//! each mapping variant's buffer requirement rather than swept blindly —
//! the buffer axis of the space is explored through the mapping variants
//! (KC-P's C-tile, YX-P's X-tile, YR-P's C/K tiles), which is what makes
//! "larger buffers do not always provide higher throughput" visible in
//! Fig 13.

use crate::ir::dataflow::Dataflow;
use crate::ir::dims::Dim::*;
use crate::ir::directive::{Directive as D, Extent as E};

/// A swept design space.
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub pes: Vec<u64>,
    pub bandwidths: Vec<u64>,
    pub noc_latency: u64,
    pub variants: Vec<Dataflow>,
    /// Area budget, mm^2 (Fig 13 uses Eyeriss's 16 mm^2).
    pub area_budget_mm2: f64,
    /// Power budget, mW (450 mW).
    pub power_budget_mw: f64,
}

impl DesignSpace {
    /// Number of candidate designs (before validity filtering).
    pub fn size(&self) -> u64 {
        (self.pairs() * self.bandwidths.len()) as u64
    }

    /// Number of (variant, PEs) pairs — the outer product the sharded
    /// sweep splits into work shards.
    pub fn pairs(&self) -> usize {
        self.variants.len() * self.pes.len()
    }

    /// A seconds-scale Fig 13 space for CI smoke runs and tests.
    pub fn ci_smoke(family: &str) -> DesignSpace {
        DesignSpace::fig13(family, 5)
    }

    /// The Fig 13 space for a dataflow family ("kc-p" or "yr-p"), at a
    /// given sweep resolution (designs grow ~ resolution^2).
    pub fn fig13(family: &str, resolution: usize) -> DesignSpace {
        let pes = geometric_range(8, 2048, resolution);
        let bandwidths = geometric_range(1, 256, resolution);
        let variants = match family {
            "kc-p" => kc_p_variants(),
            "yr-p" => yr_p_variants(),
            "yx-p" => yx_p_variants(),
            _ => kc_p_variants(),
        };
        DesignSpace {
            pes,
            bandwidths,
            noc_latency: 2,
            variants,
            area_budget_mm2: 16.0,
            power_budget_mw: 450.0,
        }
    }
}

/// `n` geometrically spaced integers in `[lo, hi]` (deduplicated).
pub fn geometric_range(lo: u64, hi: u64, n: usize) -> Vec<u64> {
    assert!(lo >= 1 && hi >= lo && n >= 2);
    let (lof, hif) = (lo as f64, hi as f64);
    let mut out: Vec<u64> = (0..n)
        .map(|i| {
            let t = i as f64 / (n - 1) as f64;
            (lof * (hif / lof).powf(t)).round() as u64
        })
        .collect();
    out.dedup();
    out
}

/// KC-P (NVDLA-like) with a parametric C-tile / cluster size.
pub fn kc_p_ct(ct: u64) -> Dataflow {
    Dataflow::new(
        &format!("KC-P(ct={ct})"),
        vec![
            D::spatial(E::lit(1), E::lit(1), K),
            D::temporal(E::lit(ct), E::lit(ct), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::temporal(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::cluster(E::lit(ct)),
            D::spatial(E::lit(1), E::lit(1), C),
        ],
    )
}

/// YR-P (Eyeriss-like) with parametric C/K tiles.
pub fn yr_p_ck(c_tile: u64, k_tile: u64) -> Dataflow {
    Dataflow::new(
        &format!("YR-P(c={c_tile},k={k_tile})"),
        vec![
            D::temporal(E::lit(c_tile), E::lit(c_tile), C),
            D::temporal(E::lit(k_tile), E::lit(k_tile), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::sz(R)),
            D::spatial(E::lit(1), E::lit(1), Y),
            D::spatial(E::lit(1), E::lit(1), R),
        ],
    )
}

/// YX-P (ShiDianNao-like) with a parametric X tile.
pub fn yx_p_xt(xt: u64) -> Dataflow {
    Dataflow::new(
        &format!("YX-P(xt={xt})"),
        vec![
            D::temporal(E::lit(1), E::lit(1), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz_plus(S, xt as i64 - 1), E::lit(xt), X),
            D::temporal(E::lit(1), E::lit(1), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::lit(xt)),
            D::spatial(E::sz(S), E::lit(1), X),
        ],
    )
}

/// The default KC-P mapping-variant sweep.
pub fn kc_p_variants() -> Vec<Dataflow> {
    [4, 8, 16, 32, 64, 128].iter().map(|&ct| kc_p_ct(ct)).collect()
}

/// The default YR-P variant sweep.
pub fn yr_p_variants() -> Vec<Dataflow> {
    let mut v = Vec::new();
    for c in [1, 2, 4, 8] {
        for k in [1, 2, 4] {
            v.push(yr_p_ck(c, k));
        }
    }
    v
}

/// The default YX-P variant sweep.
pub fn yx_p_variants() -> Vec<Dataflow> {
    [2, 4, 8, 16, 32].iter().map(|&xt| yx_p_xt(xt)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::vgg16;

    #[test]
    fn geometric_range_shape() {
        let r = geometric_range(8, 2048, 9);
        assert_eq!(r.first(), Some(&8));
        assert_eq!(r.last(), Some(&2048));
        assert!(r.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn kc_variants_resolve() {
        let layer = vgg16::conv13();
        for df in kc_p_variants() {
            df.resolve(&layer, 512).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn yr_variants_resolve() {
        let layer = vgg16::conv2();
        for df in yr_p_variants() {
            df.resolve(&layer, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn yx_variants_resolve() {
        let layer = vgg16::conv2();
        for df in yx_p_variants() {
            df.resolve(&layer, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn fig13_space_is_nontrivial() {
        let s = DesignSpace::fig13("kc-p", 16);
        assert!(s.size() > 500);
        assert_eq!(s.size(), (s.pairs() * s.bandwidths.len()) as u64);
    }

    #[test]
    fn ci_smoke_space_is_small() {
        let s = DesignSpace::ci_smoke("kc-p");
        assert!(s.size() < 500, "smoke space must finish in seconds, got {}", s.size());
        assert!(s.pairs() >= 4, "still enough pairs to exercise sharding");
    }
}
