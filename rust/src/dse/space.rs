//! Design-space definition (paper §5.2): the four swept hardware
//! parameters (#PEs, L1 size, L2 size, NoC bandwidth) plus dataflow
//! *mapping variants* (tile-size knobs of the Table 3 styles), under an
//! area/power budget.
//!
//! The variant axis is backed by the [`crate::mapspace`] subsystem: the
//! pinned `fig13`/`ci_smoke` spaces instantiate the legacy hand-picked
//! tile-value grids through the style templates (bit-identical to the
//! pre-mapspace lists), while [`DesignSpace::mapspace`] *generates* the
//! axis by enumerating a template's legal tilings against a layer shape
//! and carries tile-coordinate adjacency for the guided strategy.
//!
//! Note on buffer sizing: following §5.2 ("the DSE tool places the exact
//! amount buffers MAESTRO reported"), L1/L2 capacities are *derived* from
//! each mapping variant's buffer requirement rather than swept blindly —
//! the buffer axis of the space is explored through the mapping variants
//! (KC-P's C-tile, YX-P's X-tile, YR-P's C/K tiles), which is what makes
//! "larger buffers do not always provide higher throughput" visible in
//! Fig 13.

use anyhow::{Context, Result};

use crate::ir::dataflow::Dataflow;
// The parametric Table 3 constructors moved to `ir::styles` (they are
// style definitions); re-exported here for the existing callers.
pub use crate::ir::styles::{kc_p_ct, yr_p_ck, yx_p_xt};
use crate::mapspace::{self, StyleTemplate};
use crate::model::layer::Layer;

/// A swept design space.
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub pes: Vec<u64>,
    pub bandwidths: Vec<u64>,
    pub noc_latency: u64,
    pub variants: Vec<Dataflow>,
    /// Tile-coordinate adjacency of the variant axis (parallel to
    /// `variants`; see [`mapspace::tile_adjacency`]). Empty means the
    /// axis is an ordered 1-D list and neighbors are index ±1 — the
    /// legacy fig13 spaces, whose hand-pinned value lists are already
    /// tile-sorted. [`DesignSpace::mapspace`] fills it, and the guided
    /// strategy expands frontier neighborhoods along it.
    pub variant_adjacency: Vec<Vec<usize>>,
    /// Area budget, mm^2 (Fig 13 uses Eyeriss's 16 mm^2).
    pub area_budget_mm2: f64,
    /// Power budget, mW (450 mW).
    pub power_budget_mw: f64,
}

impl DesignSpace {
    /// Number of candidate designs (before validity filtering).
    pub fn size(&self) -> u64 {
        (self.pairs() * self.bandwidths.len()) as u64
    }

    /// Number of (variant, PEs) pairs — the outer product the sharded
    /// sweep splits into work shards.
    pub fn pairs(&self) -> usize {
        self.variants.len() * self.pes.len()
    }

    /// Serial pair index of (variant index, PEs index) — the order the
    /// exhaustive sweep walks and every strategy batch refers to.
    pub fn pair_index(&self, variant_idx: usize, pes_idx: usize) -> usize {
        variant_idx * self.pes.len() + pes_idx
    }

    /// Inverse of [`pair_index`](DesignSpace::pair_index):
    /// `(variant index, PEs index)`.
    pub fn pair_coords(&self, pair: usize) -> (usize, usize) {
        (pair / self.pes.len(), pair % self.pes.len())
    }

    /// Neighbors of a variant index along the variant axis: the tile
    /// adjacency when this space carries one ([`DesignSpace::mapspace`]),
    /// otherwise index ±1. Deterministic order (tile neighbors first,
    /// ascending).
    pub fn variant_neighbors(&self, v: usize) -> Vec<usize> {
        if !self.variant_adjacency.is_empty() {
            return self.variant_adjacency[v].clone();
        }
        let mut out = Vec::with_capacity(2);
        if v > 0 {
            out.push(v - 1);
        }
        if v + 1 < self.variants.len() {
            out.push(v + 1);
        }
        out
    }

    /// Grid neighbors of a pair — one step along the variant axis
    /// ([`DesignSpace::variant_neighbors`], tile-coordinate adjacency
    /// when available) or ±1 PEs — the neighborhood the guided strategy
    /// expands around frontier pairs. Deterministic order.
    pub fn pair_neighbors(&self, pair: usize) -> Vec<usize> {
        let n_pes = self.pes.len();
        let (v, p) = (pair / n_pes, pair % n_pes);
        let mut out = Vec::with_capacity(4);
        for v2 in self.variant_neighbors(v) {
            out.push(v2 * n_pes + p);
        }
        if p > 0 {
            out.push(pair - 1);
        }
        if p + 1 < n_pes {
            out.push(pair + 1);
        }
        out
    }

    /// A seconds-scale Fig 13 space for CI smoke runs and tests.
    pub fn ci_smoke(family: &str) -> DesignSpace {
        DesignSpace::fig13(family, 5)
    }

    /// The Fig 13 space for a dataflow family ("kc-p" or "yr-p"), at a
    /// given sweep resolution (designs grow ~ resolution^2).
    pub fn fig13(family: &str, resolution: usize) -> DesignSpace {
        DesignSpace::fig13_axes(family, resolution, resolution)
    }

    /// [`fig13`](DesignSpace::fig13) with independent axis resolutions:
    /// `pes_resolution` points on the PE axis, `bw_resolution` on the
    /// bandwidth axis — sampling strategies care about the axes
    /// separately (a deep bandwidth axis is cheap per pair, a deep PE
    /// axis is not).
    pub fn fig13_axes(family: &str, pes_resolution: usize, bw_resolution: usize) -> DesignSpace {
        let pes = geometric_range(8, 2048, pes_resolution);
        let bandwidths = bandwidth_axis(bw_resolution);
        let variants = match family {
            "kc-p" => kc_p_variants(),
            "yr-p" => yr_p_variants(),
            "yx-p" => yx_p_variants(),
            _ => kc_p_variants(),
        };
        DesignSpace {
            pes,
            bandwidths,
            noc_latency: 2,
            variants,
            variant_adjacency: Vec::new(),
            area_budget_mm2: 16.0,
            power_budget_mw: 450.0,
        }
    }

    /// A design space whose variant axis is *generated*: the family's
    /// [`StyleTemplate`] enumerated against `layer`'s shape at the
    /// deepest PE point of the axis (tilings that need more PEs than
    /// the axis offers would be unmappable everywhere; shallower PE
    /// points can still find individual pairs unmappable — the sweep's
    /// `unmappable` accounting covers them). The enumeration is
    /// resolve-validated, fingerprint-deduplicated, and deterministic,
    /// and the space carries the tile-coordinate adjacency the guided
    /// strategy uses for neighborhood expansion. `fig13`/`ci_smoke`
    /// remain the hand-pinned compatibility spaces.
    pub fn mapspace(
        family: &str,
        layer: &Layer,
        tile_resolution: usize,
        pes_resolution: usize,
        bw_resolution: usize,
    ) -> Result<DesignSpace> {
        let template = StyleTemplate::by_name(family)
            .with_context(|| format!("unknown mapspace family '{family}' (c-p | x-p | yx-p | yr-p | kc-p)"))?;
        let pes = geometric_range(8, 2048, pes_resolution);
        let bandwidths = bandwidth_axis(bw_resolution);
        let max_pes = *pes.last().expect("non-empty PE axis");
        let en = mapspace::enumerate(&template, layer, max_pes, tile_resolution);
        anyhow::ensure!(
            !en.dataflows.is_empty(),
            "mapspace '{family}' has no tiling that resolves on layer '{}'",
            layer.name
        );
        let variant_adjacency = mapspace::tile_adjacency(&en.coords, &en.template_of);
        Ok(DesignSpace {
            pes,
            bandwidths,
            noc_latency: 2,
            variants: en.dataflows,
            variant_adjacency,
            area_budget_mm2: 16.0,
            power_budget_mw: 450.0,
        })
    }
}

/// Axis-aligned grid neighbors (±1 variant index, ±1 PEs index) of a
/// serial pair index, in deterministic order.
pub fn grid_neighbors(n_variants: usize, n_pes: usize, pair: usize) -> Vec<usize> {
    let v = pair / n_pes;
    let p = pair % n_pes;
    debug_assert!(v < n_variants);
    let mut out = Vec::with_capacity(4);
    if v > 0 {
        out.push((v - 1) * n_pes + p);
    }
    if v + 1 < n_variants {
        out.push((v + 1) * n_pes + p);
    }
    if p > 0 {
        out.push(pair - 1);
    }
    if p + 1 < n_pes {
        out.push(pair + 1);
    }
    out
}

/// The canonical bandwidth axis of every built space: `resolution`
/// geometrically spaced points in `[1, 256]` elements/cycle (the Fig 13
/// range). One definition shared by [`DesignSpace::fig13_axes`] and
/// [`DesignSpace::mapspace`] — and by the profile-vs-monolithic bench —
/// so the axis can never drift between the hand-pinned and generated
/// spaces.
pub fn bandwidth_axis(resolution: usize) -> Vec<u64> {
    geometric_range(1, 256, resolution)
}

/// A coarse subsample of an axis of `n` indices: every `ceil(n/4)`-th
/// index plus the last, so any axis contributes at most ~5 points to
/// the guided strategy's wave-0 grid while its extremes stay covered.
pub fn coarse_axis(n: usize) -> Vec<usize> {
    assert!(n > 0, "coarse_axis of an empty axis");
    let step = n.div_ceil(4);
    let mut out: Vec<usize> = (0..n).step_by(step).collect();
    if *out.last().unwrap() != n - 1 {
        out.push(n - 1);
    }
    out
}

/// `n` geometrically spaced integers in `[lo, hi]` (deduplicated).
pub fn geometric_range(lo: u64, hi: u64, n: usize) -> Vec<u64> {
    assert!(lo >= 1 && hi >= lo && n >= 2);
    let (lof, hif) = (lo as f64, hi as f64);
    let mut out: Vec<u64> = (0..n)
        .map(|i| {
            let t = i as f64 / (n - 1) as f64;
            (lof * (hif / lof).powf(t)).round() as u64
        })
        .collect();
    out.dedup();
    out
}

// ---------------------------------------------------------------------
// The pinned fig13/ci_smoke variant lists (mapspace compatibility path)
// ---------------------------------------------------------------------
//
// These are the hand-picked tile-value grids the fig13 pins were
// recorded against, now instantiated through the mapspace style
// templates instead of hand-coded loops. `instantiate_grid` applies no
// filtering and no dedup, so the lists are bit-identical to the
// pre-mapspace ones (same names, same directives, same fingerprints —
// pinned in `rust/tests/mapspace.rs`). Spaces that want the *generated*
// variant axis use [`DesignSpace::mapspace`].

/// The default KC-P mapping-variant sweep (pinned value grid).
pub fn kc_p_variants() -> Vec<Dataflow> {
    StyleTemplate::kc_p().instantiate_grid(&[&[4, 8, 16, 32, 64, 128]])
}

/// The default YR-P variant sweep (pinned value grid).
pub fn yr_p_variants() -> Vec<Dataflow> {
    StyleTemplate::yr_p().instantiate_grid(&[&[1, 2, 4, 8], &[1, 2, 4]])
}

/// The default YX-P variant sweep (pinned value grid).
pub fn yx_p_variants() -> Vec<Dataflow> {
    StyleTemplate::yx_p().instantiate_grid(&[&[2, 4, 8, 16, 32]])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::vgg16;

    #[test]
    fn geometric_range_shape() {
        let r = geometric_range(8, 2048, 9);
        assert_eq!(r.first(), Some(&8));
        assert_eq!(r.last(), Some(&2048));
        assert!(r.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn bandwidth_axis_is_the_shared_fig13_axis() {
        for n in [2usize, 5, 8, 9, 16] {
            let axis = bandwidth_axis(n);
            assert_eq!(axis.first(), Some(&1));
            assert_eq!(axis.last(), Some(&256));
            assert!(axis.len() <= n);
            assert_eq!(axis, geometric_range(1, 256, n));
        }
        // Both constructed spaces ride the same axis.
        assert_eq!(DesignSpace::fig13_axes("kc-p", 4, 9).bandwidths, bandwidth_axis(9));
        let ms = DesignSpace::mapspace("kc-p", &vgg16::conv2(), 3, 4, 7).expect("mapspace");
        assert_eq!(ms.bandwidths, bandwidth_axis(7));
    }

    #[test]
    fn kc_variants_resolve() {
        let layer = vgg16::conv13();
        for df in kc_p_variants() {
            df.resolve(&layer, 512).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn yr_variants_resolve() {
        let layer = vgg16::conv2();
        for df in yr_p_variants() {
            df.resolve(&layer, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn yx_variants_resolve() {
        let layer = vgg16::conv2();
        for df in yx_p_variants() {
            df.resolve(&layer, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn fig13_space_is_nontrivial() {
        let s = DesignSpace::fig13("kc-p", 16);
        assert!(s.size() > 500);
        assert_eq!(s.size(), (s.pairs() * s.bandwidths.len()) as u64);
    }

    #[test]
    fn pair_indexing_roundtrips_and_matches_serial_order() {
        let s = DesignSpace::ci_smoke("kc-p");
        let mut serial = 0usize;
        for v in 0..s.variants.len() {
            for p in 0..s.pes.len() {
                assert_eq!(s.pair_index(v, p), serial);
                assert_eq!(s.pair_coords(serial), (v, p));
                serial += 1;
            }
        }
        assert_eq!(serial, s.pairs());
    }

    #[test]
    fn grid_neighbors_are_axis_aligned_and_in_bounds() {
        let (nv, np) = (3usize, 4usize);
        let space = DesignSpace {
            variants: DesignSpace::ci_smoke("kc-p").variants[..nv].to_vec(),
            pes: vec![8, 32, 128, 512],
            bandwidths: vec![1, 16],
            noc_latency: 2,
            variant_adjacency: Vec::new(),
            area_budget_mm2: 16.0,
            power_budget_mw: 450.0,
        };
        for pair in 0..nv * np {
            let (v, p) = (pair / np, pair % np);
            let ns = grid_neighbors(nv, np, pair);
            assert_eq!(
                space.pair_neighbors(pair),
                ns,
                "without tile adjacency, pair_neighbors matches grid_neighbors exactly"
            );
            let expected = usize::from(v > 0)
                + usize::from(v + 1 < nv)
                + usize::from(p > 0)
                + usize::from(p + 1 < np);
            assert_eq!(ns.len(), expected, "pair {pair}");
            for n in ns {
                assert!(n < nv * np);
                let (nv2, np2) = (n / np, n % np);
                let d = nv2.abs_diff(v) + np2.abs_diff(p);
                assert_eq!(d, 1, "neighbor {n} of {pair} must differ by one grid step");
            }
        }
    }

    #[test]
    fn coarse_axis_covers_extremes_and_stays_small() {
        for n in 1..40usize {
            let c = coarse_axis(n);
            assert_eq!(c[0], 0);
            assert_eq!(*c.last().unwrap(), n - 1);
            assert!(c.windows(2).all(|w| w[0] < w[1]));
            assert!(c.len() <= 5, "n={n}: {c:?}");
            assert!(c.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fig13_axes_decouples_resolutions() {
        let s = DesignSpace::fig13_axes("kc-p", 4, 9);
        assert_eq!(s.pes.len(), 4);
        assert_eq!(s.bandwidths.len(), 9);
        let square = DesignSpace::fig13("kc-p", 6);
        assert_eq!(square.pes.len(), square.bandwidths.len());
    }

    #[test]
    fn mapspace_backed_space_generates_and_carries_adjacency() {
        let layer = vgg16::conv13();
        let s = DesignSpace::mapspace("kc-p", &layer, 5, 4, 3).unwrap();
        assert!(s.variants.len() >= 2, "C=512 offers several legal C tiles");
        assert_eq!(s.variant_adjacency.len(), s.variants.len());
        assert_eq!(s.pes.len(), 4);
        assert_eq!(s.bandwidths.len(), 3);
        // Every generated variant resolves at the deepest PE point.
        let max_pes = *s.pes.last().unwrap();
        for v in &s.variants {
            v.resolve(&layer, max_pes).unwrap_or_else(|e| panic!("{}: {e}", v.name));
        }
        // Adjacency: in-bounds, irreflexive, symmetric; pair_neighbors
        // routes through it.
        for (i, ns) in s.variant_adjacency.iter().enumerate() {
            for &j in ns {
                assert!(j < s.variants.len() && j != i);
                assert!(s.variant_adjacency[j].contains(&i), "adjacency must be symmetric");
            }
            assert_eq!(s.variant_neighbors(i), *ns);
        }
        // A one-knob mapspace axis is a sorted line: interior variants
        // have exactly two tile neighbors.
        if s.variants.len() >= 3 {
            assert_eq!(s.variant_adjacency[1].len(), 2);
        }
        assert!(DesignSpace::mapspace("zz-p", &layer, 5, 4, 3).is_err());
    }

    #[test]
    fn ci_smoke_space_is_small() {
        let s = DesignSpace::ci_smoke("kc-p");
        assert!(s.size() < 500, "smoke space must finish in seconds, got {}", s.size());
        assert!(s.pairs() >= 4, "still enough pairs to exercise sharding");
    }
}
