//! Design-space definition (paper §5.2): the four swept hardware
//! parameters (#PEs, L1 size, L2 size, NoC bandwidth) plus dataflow
//! *mapping variants* (tile-size knobs of the Table 3 styles), under an
//! area/power budget.
//!
//! Note on buffer sizing: following §5.2 ("the DSE tool places the exact
//! amount buffers MAESTRO reported"), L1/L2 capacities are *derived* from
//! each mapping variant's buffer requirement rather than swept blindly —
//! the buffer axis of the space is explored through the mapping variants
//! (KC-P's C-tile, YX-P's X-tile, YR-P's C/K tiles), which is what makes
//! "larger buffers do not always provide higher throughput" visible in
//! Fig 13.

use crate::ir::dataflow::Dataflow;
use crate::ir::dims::Dim::*;
use crate::ir::directive::{Directive as D, Extent as E};

/// A swept design space.
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub pes: Vec<u64>,
    pub bandwidths: Vec<u64>,
    pub noc_latency: u64,
    pub variants: Vec<Dataflow>,
    /// Area budget, mm^2 (Fig 13 uses Eyeriss's 16 mm^2).
    pub area_budget_mm2: f64,
    /// Power budget, mW (450 mW).
    pub power_budget_mw: f64,
}

impl DesignSpace {
    /// Number of candidate designs (before validity filtering).
    pub fn size(&self) -> u64 {
        (self.pairs() * self.bandwidths.len()) as u64
    }

    /// Number of (variant, PEs) pairs — the outer product the sharded
    /// sweep splits into work shards.
    pub fn pairs(&self) -> usize {
        self.variants.len() * self.pes.len()
    }

    /// Serial pair index of (variant index, PEs index) — the order the
    /// exhaustive sweep walks and every strategy batch refers to.
    pub fn pair_index(&self, variant_idx: usize, pes_idx: usize) -> usize {
        variant_idx * self.pes.len() + pes_idx
    }

    /// Inverse of [`pair_index`](DesignSpace::pair_index):
    /// `(variant index, PEs index)`.
    pub fn pair_coords(&self, pair: usize) -> (usize, usize) {
        (pair / self.pes.len(), pair % self.pes.len())
    }

    /// Axis-aligned grid neighbors of a pair (±1 variant, ±1 PEs) —
    /// the neighborhood the guided strategy expands around frontier
    /// pairs. Deterministic order.
    pub fn pair_neighbors(&self, pair: usize) -> Vec<usize> {
        grid_neighbors(self.variants.len(), self.pes.len(), pair)
    }

    /// A seconds-scale Fig 13 space for CI smoke runs and tests.
    pub fn ci_smoke(family: &str) -> DesignSpace {
        DesignSpace::fig13(family, 5)
    }

    /// The Fig 13 space for a dataflow family ("kc-p" or "yr-p"), at a
    /// given sweep resolution (designs grow ~ resolution^2).
    pub fn fig13(family: &str, resolution: usize) -> DesignSpace {
        DesignSpace::fig13_axes(family, resolution, resolution)
    }

    /// [`fig13`](DesignSpace::fig13) with independent axis resolutions:
    /// `pes_resolution` points on the PE axis, `bw_resolution` on the
    /// bandwidth axis — sampling strategies care about the axes
    /// separately (a deep bandwidth axis is cheap per pair, a deep PE
    /// axis is not).
    pub fn fig13_axes(family: &str, pes_resolution: usize, bw_resolution: usize) -> DesignSpace {
        let pes = geometric_range(8, 2048, pes_resolution);
        let bandwidths = geometric_range(1, 256, bw_resolution);
        let variants = match family {
            "kc-p" => kc_p_variants(),
            "yr-p" => yr_p_variants(),
            "yx-p" => yx_p_variants(),
            _ => kc_p_variants(),
        };
        DesignSpace {
            pes,
            bandwidths,
            noc_latency: 2,
            variants,
            area_budget_mm2: 16.0,
            power_budget_mw: 450.0,
        }
    }
}

/// Axis-aligned grid neighbors (±1 variant index, ±1 PEs index) of a
/// serial pair index, in deterministic order.
pub fn grid_neighbors(n_variants: usize, n_pes: usize, pair: usize) -> Vec<usize> {
    let v = pair / n_pes;
    let p = pair % n_pes;
    debug_assert!(v < n_variants);
    let mut out = Vec::with_capacity(4);
    if v > 0 {
        out.push((v - 1) * n_pes + p);
    }
    if v + 1 < n_variants {
        out.push((v + 1) * n_pes + p);
    }
    if p > 0 {
        out.push(pair - 1);
    }
    if p + 1 < n_pes {
        out.push(pair + 1);
    }
    out
}

/// A coarse subsample of an axis of `n` indices: every `ceil(n/4)`-th
/// index plus the last, so any axis contributes at most ~5 points to
/// the guided strategy's wave-0 grid while its extremes stay covered.
pub fn coarse_axis(n: usize) -> Vec<usize> {
    assert!(n > 0, "coarse_axis of an empty axis");
    let step = n.div_ceil(4);
    let mut out: Vec<usize> = (0..n).step_by(step).collect();
    if *out.last().unwrap() != n - 1 {
        out.push(n - 1);
    }
    out
}

/// `n` geometrically spaced integers in `[lo, hi]` (deduplicated).
pub fn geometric_range(lo: u64, hi: u64, n: usize) -> Vec<u64> {
    assert!(lo >= 1 && hi >= lo && n >= 2);
    let (lof, hif) = (lo as f64, hi as f64);
    let mut out: Vec<u64> = (0..n)
        .map(|i| {
            let t = i as f64 / (n - 1) as f64;
            (lof * (hif / lof).powf(t)).round() as u64
        })
        .collect();
    out.dedup();
    out
}

/// KC-P (NVDLA-like) with a parametric C-tile / cluster size.
pub fn kc_p_ct(ct: u64) -> Dataflow {
    Dataflow::new(
        &format!("KC-P(ct={ct})"),
        vec![
            D::spatial(E::lit(1), E::lit(1), K),
            D::temporal(E::lit(ct), E::lit(ct), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::temporal(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::cluster(E::lit(ct)),
            D::spatial(E::lit(1), E::lit(1), C),
        ],
    )
}

/// YR-P (Eyeriss-like) with parametric C/K tiles.
pub fn yr_p_ck(c_tile: u64, k_tile: u64) -> Dataflow {
    Dataflow::new(
        &format!("YR-P(c={c_tile},k={k_tile})"),
        vec![
            D::temporal(E::lit(c_tile), E::lit(c_tile), C),
            D::temporal(E::lit(k_tile), E::lit(k_tile), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::sz(R)),
            D::spatial(E::lit(1), E::lit(1), Y),
            D::spatial(E::lit(1), E::lit(1), R),
        ],
    )
}

/// YX-P (ShiDianNao-like) with a parametric X tile.
pub fn yx_p_xt(xt: u64) -> Dataflow {
    Dataflow::new(
        &format!("YX-P(xt={xt})"),
        vec![
            D::temporal(E::lit(1), E::lit(1), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz_plus(S, xt as i64 - 1), E::lit(xt), X),
            D::temporal(E::lit(1), E::lit(1), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::lit(xt)),
            D::spatial(E::sz(S), E::lit(1), X),
        ],
    )
}

/// The default KC-P mapping-variant sweep.
pub fn kc_p_variants() -> Vec<Dataflow> {
    [4, 8, 16, 32, 64, 128].iter().map(|&ct| kc_p_ct(ct)).collect()
}

/// The default YR-P variant sweep.
pub fn yr_p_variants() -> Vec<Dataflow> {
    let mut v = Vec::new();
    for c in [1, 2, 4, 8] {
        for k in [1, 2, 4] {
            v.push(yr_p_ck(c, k));
        }
    }
    v
}

/// The default YX-P variant sweep.
pub fn yx_p_variants() -> Vec<Dataflow> {
    [2, 4, 8, 16, 32].iter().map(|&xt| yx_p_xt(xt)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::vgg16;

    #[test]
    fn geometric_range_shape() {
        let r = geometric_range(8, 2048, 9);
        assert_eq!(r.first(), Some(&8));
        assert_eq!(r.last(), Some(&2048));
        assert!(r.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn kc_variants_resolve() {
        let layer = vgg16::conv13();
        for df in kc_p_variants() {
            df.resolve(&layer, 512).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn yr_variants_resolve() {
        let layer = vgg16::conv2();
        for df in yr_p_variants() {
            df.resolve(&layer, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn yx_variants_resolve() {
        let layer = vgg16::conv2();
        for df in yx_p_variants() {
            df.resolve(&layer, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn fig13_space_is_nontrivial() {
        let s = DesignSpace::fig13("kc-p", 16);
        assert!(s.size() > 500);
        assert_eq!(s.size(), (s.pairs() * s.bandwidths.len()) as u64);
    }

    #[test]
    fn pair_indexing_roundtrips_and_matches_serial_order() {
        let s = DesignSpace::ci_smoke("kc-p");
        let mut serial = 0usize;
        for v in 0..s.variants.len() {
            for p in 0..s.pes.len() {
                assert_eq!(s.pair_index(v, p), serial);
                assert_eq!(s.pair_coords(serial), (v, p));
                serial += 1;
            }
        }
        assert_eq!(serial, s.pairs());
    }

    #[test]
    fn grid_neighbors_are_axis_aligned_and_in_bounds() {
        let (nv, np) = (3usize, 4usize);
        let space = DesignSpace {
            variants: DesignSpace::ci_smoke("kc-p").variants[..nv].to_vec(),
            pes: vec![8, 32, 128, 512],
            bandwidths: vec![1, 16],
            noc_latency: 2,
            area_budget_mm2: 16.0,
            power_budget_mw: 450.0,
        };
        for pair in 0..nv * np {
            let (v, p) = (pair / np, pair % np);
            let ns = grid_neighbors(nv, np, pair);
            assert_eq!(space.pair_neighbors(pair), ns, "the method delegates to grid_neighbors");
            let expected = usize::from(v > 0)
                + usize::from(v + 1 < nv)
                + usize::from(p > 0)
                + usize::from(p + 1 < np);
            assert_eq!(ns.len(), expected, "pair {pair}");
            for n in ns {
                assert!(n < nv * np);
                let (nv2, np2) = (n / np, n % np);
                let d = nv2.abs_diff(v) + np2.abs_diff(p);
                assert_eq!(d, 1, "neighbor {n} of {pair} must differ by one grid step");
            }
        }
    }

    #[test]
    fn coarse_axis_covers_extremes_and_stays_small() {
        for n in 1..40usize {
            let c = coarse_axis(n);
            assert_eq!(c[0], 0);
            assert_eq!(*c.last().unwrap(), n - 1);
            assert!(c.windows(2).all(|w| w[0] < w[1]));
            assert!(c.len() <= 5, "n={n}: {c:?}");
            assert!(c.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fig13_axes_decouples_resolutions() {
        let s = DesignSpace::fig13_axes("kc-p", 4, 9);
        assert_eq!(s.pes.len(), 4);
        assert_eq!(s.bandwidths.len(), 9);
        let square = DesignSpace::fig13("kc-p", 6);
        assert_eq!(square.pes.len(), square.bandwidths.len());
    }

    #[test]
    fn ci_smoke_space_is_small() {
        let s = DesignSpace::ci_smoke("kc-p");
        assert!(s.size() < 500, "smoke space must finish in seconds, got {}", s.size());
        assert!(s.pairs() >= 4, "still enough pairs to exercise sharding");
    }
}
