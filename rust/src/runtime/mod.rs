//! PJRT runtime: load the AOT-compiled batched DSE evaluator
//! (`artifacts/dse_eval.hlo.txt`, produced once by `make artifacts` from
//! the L1 Pallas kernel + L2 JAX graph) and execute it from the Rust hot
//! path. Python is never on this path — the HLO text is compiled by the
//! `xla` crate's PJRT CPU client at startup.
//!
//! The artifact contract (shapes, scalar layout, formulas) is shared
//! with `python/compile/model.py`; [`scalars_layout`] documents it and
//! integration tests cross-check the numbers against the scalar Rust
//! evaluator in [`crate::dse::engine`].
//!
//! The `xla` crate (and its native XLA toolchain) is only required when
//! the `pjrt` cargo feature is enabled (see Cargo.toml for how to wire
//! the dependency in); the default build ships a stub [`BatchEvaluator`]
//! whose `load` always errors, so every caller falls back to the scalar
//! path and a clean checkout builds with `anyhow` alone.

use std::path::Path;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{ensure, Context};

use crate::dse::engine::CaseTable;
#[cfg(feature = "pjrt")]
use crate::dse::engine::CASE_FEATURES;
use crate::hw::area;
use crate::hw::energy;

/// Maximum case rows per artifact invocation (must match
/// `python/compile/model.py:C_MAX`).
pub const C_MAX: usize = 128;
/// Design points per invocation (must match `model.py:D_MAX`).
pub const D_MAX: usize = 512;
/// Scalar vector width (must match `model.py:S_WIDTH`).
pub const S_WIDTH: usize = 32;

/// One design point input: bandwidth, latency, placed L1/L2 (elements).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignIn {
    pub bandwidth: f64,
    pub latency: f64,
    pub l1: f64,
    pub l2: f64,
}

/// One evaluated output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOut {
    pub runtime: f64,
    pub energy_pj: f64,
    pub area_mm2: f64,
    pub power_mw: f64,
    pub valid: bool,
}

/// Build the scalar input vector for a case table + budgets.
/// Layout (indices):
/// ```text
///  0 units0            1 activity.macs      2 activity.l2_reads
///  3 activity.l2_writes 4 activity.l1_reads 5 activity.l1_writes
///  6 activity.noc      7 noc_hops           8 pes
///  9 area_budget      10 power_budget
/// 11 L1_A  12 L1_B  13 L2_A  14 L2_B  15 write_factor
/// 16 mac_pj  17 noc_hop_pj
/// 18 pe_area 19 sram_area 20 bus_area 21 arb_area
/// 22 pe_power 23 sram_power 24 bus_power 25 arb_power
/// 26..31 reserved (0)
/// ```
pub fn scalars_layout(
    table: &CaseTable,
    noc_hops: u64,
    area_budget: f64,
    power_budget: f64,
) -> [f32; S_WIDTH] {
    let mut s = [0f32; S_WIDTH];
    s[0] = table.units0 as f32;
    s[1] = table.activity.macs as f32;
    s[2] = table.activity.l2_reads as f32;
    s[3] = table.activity.l2_writes as f32;
    s[4] = table.activity.l1_reads as f32;
    s[5] = table.activity.l1_writes as f32;
    s[6] = table.activity.noc_delivered as f32;
    s[7] = noc_hops as f32;
    s[8] = table.pes as f32;
    s[9] = area_budget as f32;
    s[10] = power_budget as f32;
    // Energy-curve constants from the Rust model — one source of truth
    // for both evaluators.
    s[11] = energy::L1_A as f32;
    s[12] = energy::L1_B as f32;
    s[13] = energy::L2_A as f32;
    s[14] = energy::L2_B as f32;
    s[15] = energy::WRITE_FACTOR as f32;
    s[16] = 0.2; // mac pJ
    s[17] = 0.06; // NoC hop pJ
    let ac = area::coefficients();
    for (i, v) in ac.iter().enumerate() {
        s[18 + i] = *v as f32;
    }
    s
}

/// The compiled batched evaluator.
#[cfg(feature = "pjrt")]
pub struct BatchEvaluator {
    exe: xla::PjRtLoadedExecutable,
}

/// Stub compiled without the `pjrt` feature: [`BatchEvaluator::load`]
/// always errors, so callers (coordinator, examples) drop to the scalar
/// backend.
#[cfg(not(feature = "pjrt"))]
pub struct BatchEvaluator {
    _private: (),
}

impl BatchEvaluator {
    /// Default artifact location relative to the repo root.
    pub fn default_path() -> std::path::PathBuf {
        std::path::PathBuf::from(
            std::env::var("MAESTRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        )
        .join("dse_eval.hlo.txt")
    }
}

#[cfg(not(feature = "pjrt"))]
impl BatchEvaluator {
    /// Always errors: PJRT support is not compiled in.
    pub fn load(path: &Path) -> Result<BatchEvaluator> {
        anyhow::bail!(
            "PJRT support not compiled in — wire the `xla` dependency in (see the note under \
             [features] in Cargo.toml) and rebuild with `--features pjrt`; cannot load {}",
            path.display()
        )
    }

    /// Unreachable without a successful [`BatchEvaluator::load`].
    pub fn evaluate(
        &self,
        _table: &CaseTable,
        _designs: &[DesignIn],
        _noc_hops: u64,
        _area_budget: f64,
        _power_budget: f64,
    ) -> Result<Vec<EvalOut>> {
        anyhow::bail!("PJRT support not compiled in")
    }
}

#[cfg(feature = "pjrt")]
impl BatchEvaluator {
    /// Load + compile the HLO-text artifact on the PJRT CPU client.
    pub fn load(path: &Path) -> Result<BatchEvaluator> {
        ensure!(path.exists(), "artifact not found: {} (run `make artifacts`)", path.display());
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling artifact")?;
        Ok(BatchEvaluator { exe })
    }

    /// Evaluate up to [`D_MAX`] designs against a case table. Larger
    /// design lists are chunked by the coordinator, larger case tables
    /// are row-chunked here (runtime is additive across row chunks;
    /// energy/area/validity come from the scalar inputs and are computed
    /// on the first chunk only).
    pub fn evaluate(
        &self,
        table: &CaseTable,
        designs: &[DesignIn],
        noc_hops: u64,
        area_budget: f64,
        power_budget: f64,
    ) -> Result<Vec<EvalOut>> {
        ensure!(designs.len() <= D_MAX, "at most {D_MAX} designs per call");
        let mut out: Vec<EvalOut> = vec![
            EvalOut { runtime: 0.0, energy_pj: 0.0, area_mm2: 0.0, power_mw: 0.0, valid: false };
            designs.len()
        ];
        let n_chunks = table.rows.len().div_ceil(C_MAX).max(1);
        let mut chunk0_runtime = vec![0f64; designs.len()];
        for chunk in 0..n_chunks {
            let rows = &table.rows[chunk * C_MAX..((chunk + 1) * C_MAX).min(table.rows.len())];
            // Case tensor, zero-padded (occurrences 0 contribute nothing).
            let mut cases = vec![0f32; C_MAX * CASE_FEATURES];
            for (i, r) in rows.iter().enumerate() {
                cases[i * CASE_FEATURES..(i + 1) * CASE_FEATURES].copy_from_slice(&r.to_features());
            }
            // Design tensor, padded by repeating the first design.
            let mut dvec = vec![0f32; D_MAX * 4];
            for i in 0..D_MAX {
                let d = designs[i.min(designs.len() - 1)];
                dvec[i * 4] = d.bandwidth as f32;
                dvec[i * 4 + 1] = d.latency as f32;
                dvec[i * 4 + 2] = d.l1 as f32;
                dvec[i * 4 + 3] = d.l2 as f32;
            }
            let mut scal = scalars_layout(table, noc_hops, area_budget, power_budget);
            if chunk > 0 {
                // Energy/area already counted on chunk 0.
                for v in scal[1..8].iter_mut() {
                    *v = 0.0;
                }
            }
            let c_lit = xla::Literal::vec1(&cases).reshape(&[C_MAX as i64, CASE_FEATURES as i64])?;
            let d_lit = xla::Literal::vec1(&dvec).reshape(&[D_MAX as i64, 4])?;
            let s_lit = xla::Literal::vec1(&scal);
            let result = self.exe.execute::<xla::Literal>(&[c_lit, d_lit, s_lit])?[0][0]
                .to_literal_sync()?;
            let parts = result.to_tuple()?;
            ensure!(parts.len() == 5, "artifact must return 5 outputs, got {}", parts.len());
            let runtime = parts[0].to_vec::<f32>()?;
            let energy = parts[1].to_vec::<f32>()?;
            let area_v = parts[2].to_vec::<f32>()?;
            let power_v = parts[3].to_vec::<f32>()?;
            let valid_v = parts[4].to_vec::<f32>()?;
            for (i, o) in out.iter_mut().enumerate() {
                o.runtime += runtime[i] as f64;
                if chunk == 0 {
                    chunk0_runtime[i] = (runtime[i] as f64).max(1.0);
                    o.energy_pj = energy[i] as f64;
                    o.area_mm2 = area_v[i] as f64;
                    o.power_mw = power_v[i] as f64;
                    o.valid = valid_v[i] > 0.5;
                }
            }
        }
        // Multi-chunk tables: the kernel computed the dynamic-power term
        // against chunk 0's runtime only; rebase it onto the summed
        // runtime and re-check the power budget.
        if n_chunks > 1 {
            for (i, o) in out.iter_mut().enumerate() {
                let static_power = o.power_mw - o.energy_pj / chunk0_runtime[i];
                o.power_mw = static_power + o.energy_pj / o.runtime.max(1.0);
                o.valid = o.area_mm2 <= area_budget && o.power_mw <= power_budget;
            }
        }
        Ok(out)
    }
}

/// Scalar (pure-Rust) reference of the artifact formulas — used as the
/// fallback backend and the cross-check oracle.
pub fn evaluate_scalar(
    table: &CaseTable,
    designs: &[DesignIn],
    noc_hops: u64,
    area_budget: f64,
    power_budget: f64,
) -> Vec<EvalOut> {
    use crate::dse::engine::{eval_energy, eval_runtime};
    designs
        .iter()
        .map(|d| {
            let runtime = eval_runtime(table, d.bandwidth as u64, d.latency as u64);
            let energy = eval_energy(&table.activity, d.l1 as u64, d.l2 as u64, noc_hops);
            let ap = area::evaluate(table.pes, d.l1 as u64, d.l2 as u64, d.bandwidth as u64);
            // Total power = static regression + dynamic (1 pJ/cycle =
            // 1 mW at the 1 GHz reference clock).
            let power = ap.power_mw + energy / runtime.max(1.0);
            EvalOut {
                runtime,
                energy_pj: energy,
                area_mm2: ap.area_mm2,
                power_mw: power,
                valid: ap.area_mm2 <= area_budget && power <= power_budget,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::engine::build_case_table;
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    #[test]
    fn scalar_layout_is_stable() {
        let layer = vgg16::conv13();
        let table = build_case_table(&[&layer], &styles::x_p(), 64).unwrap();
        let s = scalars_layout(&table, 2, 16.0, 450.0);
        assert_eq!(s[0], table.units0 as f32);
        assert_eq!(s[8], 64.0);
        assert_eq!(s[9], 16.0);
        // Energy anchors: L1 curve at 1024 elements ~ 1.2 pJ.
        let l1 = s[11] as f64 + s[12] as f64 * (1024f64).sqrt();
        assert!((l1 - 1.2).abs() < 0.1, "l1 curve {l1}");
    }

    #[test]
    fn evaluate_scalar_consistent_with_dse_engine() {
        let layer = vgg16::conv13();
        let table = build_case_table(&[&layer], &styles::kc_p(), 256).unwrap();
        let d = DesignIn { bandwidth: 16.0, latency: 2.0, l1: table.l1_req as f64, l2: table.l2_req as f64 };
        let out = evaluate_scalar(&table, &[d], 2, 16.0, 450.0);
        let want = crate::dse::engine::eval_runtime(&table, 16, 2);
        assert_eq!(out[0].runtime, want);
    }

    #[test]
    fn loading_missing_artifact_errors_cleanly() {
        assert!(BatchEvaluator::load(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
