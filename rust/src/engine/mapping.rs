//! Mapping analysis: per-level dimension schedules and the iteration-case
//! enumeration (Fig 8's `ExtractDataIterationCases`).
//!
//! Normative semantics are DESIGN.md §6. In brief: each cluster level is
//! a loop nest over its dimension maps (directive order, outermost
//! first). Spatially-mapped dims distribute positions across the level's
//! units and contribute a *fold* pseudo-loop when positions exceed units.
//! Every (full/edge position) combination together with "which loop just
//! incremented" forms a *transition class* — the unit of accounting for
//! runtime, traffic and energy. Classes are exact: their occurrence-
//! weighted MAC counts sum to the layer's MAC total (a property test
//! enforces this).
//!
//! Windowed activation dims (Y sliding against R, X against S) are
//! iterated in *output space*: a map of `(size, offset)` over Y with
//! window `w = parent R tile` produces `(size − w)/stride + 1` output
//! rows per position and must advance by exactly `size − w + stride`
//! input rows (gapless, non-overlapping outputs — validated at resolve
//! time and re-checked here).
//!
//! Shape-determinism contract: everything built here reads only a
//! layer's `ShapeKey` fields (dimensions, stride, windowing derived
//! from the op) — never its name. `engine::analysis::Analyzer` relies
//! on this to replay cached schedules/statistics across same-shaped
//! layers; a change that makes schedules depend on non-shape state must
//! extend `model::layer::ShapeKey` accordingly.

use anyhow::{bail, ensure, Result};

use crate::ir::dataflow::ResolvedLevel;
use crate::ir::dims::{Dim, DimMap};
use crate::model::layer::Layer;

/// How a dimension's indices advance at one level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DimSched {
    pub dim: Dim,
    pub spatial: bool,
    /// Input-space chunk per position.
    pub size: u64,
    /// Input-space step between positions (stride-scaled).
    pub offset: u64,
    /// Windowed (output-space) semantics?
    pub windowed: bool,
    /// Window extent (parent tile of the partner dim) when windowed.
    pub win: u64,
    /// Layer stride (1 for non-activation dims).
    pub stride: u64,
    /// Number of full positions.
    pub positions_full: u64,
    /// Input-space size of the trailing edge position (0 = none).
    pub edge_in: u64,
    /// Outputs (or elements, for non-windowed dims) per full position.
    pub out_per_pos: u64,
    /// Outputs/elements at the edge position.
    pub out_edge: u64,
    /// Member of the level's joint spatial group?
    pub joint_spatial: bool,
}

impl DimSched {
    pub fn total_positions(&self) -> u64 {
        self.positions_full + if self.edge_in > 0 { 1 } else { 0 }
    }

    pub fn has_edge(&self) -> bool {
        self.edge_in > 0
    }

    /// Input-space tile size in a given state.
    pub fn in_size(&self, state: PosState) -> u64 {
        match state {
            PosState::Normal => self.size,
            PosState::Edge => self.edge_in,
        }
    }

    /// Output-space (or element) count in a given state.
    pub fn out_size(&self, state: PosState) -> u64 {
        match state {
            PosState::Normal => self.out_per_pos,
            PosState::Edge => self.out_edge,
        }
    }

    /// Fresh input-space elements when *this* dim increments into
    /// `state` (overlap with the previous position subtracted).
    pub fn fresh_in(&self, state: PosState) -> u64 {
        let overlap = self.size.saturating_sub(self.offset);
        match state {
            PosState::Normal => self.size - overlap.min(self.size - 1),
            PosState::Edge => self.edge_in.saturating_sub(overlap).max(if self.edge_in > 0 { 1 } else { 0 }),
        }
    }
}

/// Position state of one loop within a transition class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PosState {
    /// Any full position.
    Normal,
    /// The trailing partial position.
    Edge,
}

/// The loop that advanced to create a step (or the global first step).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Advanced {
    /// The very first step of the level's schedule.
    GlobalInit,
    /// Temporal loop over `dims[idx]` incremented.
    Temporal { idx: usize },
    /// The spatial fold loop advanced (all spatial dims jump together).
    Fold,
}

/// One level's schedule: ordered loops + the spatial fold.
#[derive(Debug, Clone)]
pub struct LevelSchedule {
    /// Loop dims in directive order (outermost first); every canonical
    /// dim appears exactly once.
    pub dims: Vec<DimSched>,
    /// Units (sub-clusters / PEs) at this level.
    pub units: u64,
    /// Spatial positions jointly distributed across units.
    pub spatial_positions: u64,
    /// Full folds of the spatial loop (each `units` wide).
    pub folds_full: u64,
    /// Active units in the trailing partial fold (0 = exact fit).
    pub fold_edge_units: u64,
    /// Index (into `dims`) where the fold loop sits in the order
    /// (= position of the first spatial map); None if level has no
    /// spatial map.
    pub fold_order_idx: Option<usize>,
    /// The parent tile this schedule iterates over.
    pub parent_tile: DimMap<u64>,
}

impl LevelSchedule {
    pub fn fold_total(&self) -> u64 {
        self.folds_full + if self.fold_edge_units > 0 { 1 } else { 0 }
    }

    /// Active units in a fold state.
    pub fn active_units(&self, fold_state: PosState) -> u64 {
        match fold_state {
            PosState::Normal => self.units.min(self.spatial_positions.max(1)),
            PosState::Edge => self.fold_edge_units,
        }
    }

    pub fn spatial_dims(&self) -> Vec<&DimSched> {
        self.dims.iter().filter(|d| d.spatial).collect()
    }

    pub fn sched_of(&self, dim: Dim) -> &DimSched {
        self.dims.iter().find(|d| d.dim == dim).expect("every dim scheduled")
    }

    /// Total steps of this level's schedule (product of temporal position
    /// counts and fold count).
    pub fn total_steps(&self) -> u64 {
        let mut steps = self.fold_total().max(1);
        for d in &self.dims {
            if !d.spatial {
                steps *= d.total_positions();
            }
        }
        steps
    }
}

/// One transition class: a set of schedule steps sharing tile sizes,
/// active units and the advanced loop. `occurrences` steps of the level
/// behave identically for performance/cost purposes.
#[derive(Debug, Clone)]
pub struct TransitionClass {
    pub advanced: Advanced,
    /// Per-loop position state, parallel to `LevelSchedule::dims`
    /// (spatial dims are always `Normal` — spatial edges are rejected at
    /// build time).
    pub states: Vec<PosState>,
    /// Fold-loop state.
    pub fold_state: PosState,
    pub occurrences: u64,
    /// Per-dim input-space tile, per unit, for this class.
    pub tile: DimMap<u64>,
    /// Active units.
    pub active: u64,
}

/// Build the schedule for a resolved level against a concrete parent
/// tile (which may be smaller than the one the level was resolved with,
/// when an outer edge class recurses into it).
pub fn build_schedule(
    level: &ResolvedLevel,
    parent_tile: &DimMap<u64>,
    layer: &Layer,
) -> Result<LevelSchedule> {
    let mut dims = Vec::with_capacity(level.maps.len());
    // Joint windowed spatial pair (Eyeriss diagonal): act+win both spatial.
    let spatial_set: Vec<Dim> = level.maps.iter().filter(|m| m.spatial).map(|m| m.dim).collect();
    let joint_pair = |d: Dim| -> bool {
        match d.window_partner() {
            Some(w) => spatial_set.contains(&d) && spatial_set.contains(&w),
            None => d.is_window() && {
                // R's partner is Y, S's is X.
                let act = if d == Dim::R { Dim::Y } else { Dim::X };
                spatial_set.contains(&d) && spatial_set.contains(&act)
            },
        }
    };

    for m in &level.maps {
        let total = parent_tile.get(m.dim).max(1);
        let size = m.size.min(total);
        let offset = m.offset;
        let stride = if matches!(m.dim, Dim::Y | Dim::X) { layer.stride } else { 1 };
        let is_joint = m.spatial && joint_pair(m.dim);

        let windowed = layer.windowed(m.dim)
            && matches!(m.dim, Dim::Y | Dim::X)
            && !is_joint;
        let sched = if windowed {
            let win_dim = m.dim.window_partner().unwrap();
            let win = parent_tile.get(win_dim).min(total).max(1);
            ensure!(
                size >= win,
                "{} tile {size} smaller than its {win_dim} window {win} (and not jointly spatial)",
                m.dim
            );
            // Total outputs available in the parent tile.
            let out_total = (total - win) / stride + 1;
            if size >= total {
                DimSched {
                    dim: m.dim,
                    spatial: m.spatial,
                    size: total,
                    offset: total.max(1),
                    windowed: true,
                    win,
                    stride,
                    positions_full: 1,
                    edge_in: 0,
                    out_per_pos: out_total,
                    out_edge: 0,
                    joint_spatial: false,
                }
            } else {
                // Gapless, non-overlapping output tiling requires
                // offset == size - win + stride; sliding-window maps are
                // *augmented* to that step (the paper's cluster analysis
                // engine handles "stride handling, and so on" — a user
                // offset of 1 means "slide", and the window geometry
                // fixes the only valid slide distance).
                ensure!(
                    offset <= size - win + 1,
                    "windowed map {} size {size} offset {offset}: offset would skip outputs (max gapless step {})",
                    m.dim,
                    size - win + 1
                );
                let offset = size - win + stride;
                let out_per_pos = (size - win) / stride + 1;
                let positions_full = out_total / out_per_pos;
                let rem_out = out_total % out_per_pos;
                let edge_in = if rem_out > 0 { win + (rem_out - 1) * stride } else { 0 };
                DimSched {
                    dim: m.dim,
                    spatial: m.spatial,
                    size,
                    offset,
                    windowed: true,
                    win,
                    stride,
                    positions_full,
                    edge_in,
                    out_per_pos,
                    out_edge: rem_out,
                    joint_spatial: false,
                }
            }
        } else {
            // Direct dims: positions tile the extent exactly; offsets
            // must equal size (gapless, no recompute). Joint spatial
            // windowed pairs additionally require size 1 (the Eyeriss
            // diagonal is the supported joint pattern).
            if is_joint {
                ensure!(
                    size == 1 && offset == 1,
                    "joint spatial map on {} must be SpatialMap(1,1) (Eyeriss-diagonal pattern)",
                    m.dim
                );
            } else if size < total {
                ensure!(
                    offset == size,
                    "direct map {} size {size} offset {offset}: offset must equal size (offset < size recomputes data, > size skips it)",
                    m.dim
                );
            }
            let size = size.min(total);
            let positions_full = total / size;
            let rem = total % size;
            DimSched {
                dim: m.dim,
                spatial: m.spatial,
                size,
                offset: size,
                windowed: false,
                win: 1,
                stride,
                positions_full,
                edge_in: rem,
                out_per_pos: size,
                out_edge: rem,
                joint_spatial: is_joint,
            }
        };
        if sched.spatial {
            ensure!(
                !sched.has_edge(),
                "spatial map on {} leaves a partial edge position; choose a size/offset that tiles the extent exactly",
                m.dim
            );
        }
        dims.push(sched);
    }

    // Spatial joint position count: all spatial dims advance together;
    // their position counts must agree (or be 1 for degenerate dims).
    let spatials: Vec<&DimSched> = dims.iter().filter(|d| d.spatial).collect();
    let mut spatial_positions = 1;
    let mut fold_order_idx = None;
    if !spatials.is_empty() {
        let counts: Vec<u64> = spatials.iter().map(|d| d.total_positions()).collect();
        spatial_positions = *counts.iter().max().unwrap();
        for (d, &c) in spatials.iter().zip(&counts) {
            ensure!(
                c == spatial_positions || c == 1,
                "joint spatial maps disagree on position count ({} has {c}, group has {spatial_positions})",
                d.dim
            );
        }
        fold_order_idx = dims.iter().position(|d| d.spatial);
    }
    let units = level.units.max(1);
    let (folds_full, fold_edge_units) = if spatial_positions <= units {
        (1, 0)
    } else {
        (spatial_positions / units, spatial_positions % units)
    };

    Ok(LevelSchedule {
        dims,
        units,
        spatial_positions,
        folds_full,
        fold_edge_units,
        fold_order_idx,
        parent_tile: *parent_tile,
    })
}

/// Enumerate all transition classes of a level schedule. Exactness: the
/// occurrence sum equals [`LevelSchedule::total_steps`].
pub fn transition_classes(s: &LevelSchedule) -> Result<Vec<TransitionClass>> {
    // The loop order: temporal dims in directive order, with the fold
    // loop spliced at fold_order_idx. Represent loops as (LoopRef).
    #[derive(Clone, Copy, PartialEq)]
    enum LoopRef {
        Dim(usize),
        Fold,
    }
    let mut order: Vec<LoopRef> = Vec::new();
    for (i, d) in s.dims.iter().enumerate() {
        if Some(i) == s.fold_order_idx {
            order.push(LoopRef::Fold);
        }
        if !d.spatial {
            order.push(LoopRef::Dim(i));
        }
    }
    if s.fold_order_idx.is_some() && !order.contains(&LoopRef::Fold) {
        order.push(LoopRef::Fold);
    }
    // Position counts per loop.
    let count = |l: &LoopRef| -> u64 {
        match l {
            LoopRef::Dim(i) => s.dims[*i].total_positions(),
            LoopRef::Fold => s.fold_total(),
        }
    };
    let edge_of = |l: &LoopRef| -> bool {
        match l {
            LoopRef::Dim(i) => s.dims[*i].has_edge(),
            LoopRef::Fold => s.fold_edge_units > 0,
        }
    };

    // Enumerate state vectors over loops-with-edges x advanced loop.
    let edged: Vec<usize> = (0..order.len()).filter(|&i| edge_of(&order[i])).collect();
    ensure!(edged.len() <= 12, "too many edged loops ({})", edged.len());
    let mut classes = Vec::new();

    let build_class = |states_by_loop: &dyn Fn(usize) -> PosState,
                       advanced: Advanced,
                       occ: u64|
     -> TransitionClass {
        let mut tile: DimMap<u64> = DimMap::filled(1);
        let mut dim_states = vec![PosState::Normal; s.dims.len()];
        let mut fold_state = PosState::Normal;
        for (li, l) in order.iter().enumerate() {
            let st = states_by_loop(li);
            match l {
                LoopRef::Dim(i) => {
                    dim_states[*i] = st;
                    tile.set(s.dims[*i].dim, s.dims[*i].in_size(st));
                }
                LoopRef::Fold => fold_state = st,
            }
        }
        for d in s.dims.iter().filter(|d| d.spatial) {
            tile.set(d.dim, d.size);
        }
        let active = s.active_units(fold_state);
        TransitionClass { advanced, states: dim_states, fold_state, occurrences: occ, tile, active }
    };

    // Global init: every loop at position 0 (Normal unless the loop has
    // only an edge position, which cannot happen: positions_full >= 1).
    classes.push(build_class(&|_| PosState::Normal, Advanced::GlobalInit, 1));

    // For each advanced loop a, and each assignment of Normal/Edge to
    // the edged loops compatible with the transition (inner loops reset
    // to Normal; the advanced loop's target state; outer loops free):
    for (ai, a) in order.iter().enumerate() {
        let a_total = count(a);
        if a_total <= 1 {
            continue;
        }
        // Target states for the advanced loop.
        let mut targets = vec![(PosState::Normal, a_total - 1 - if edge_of(a) { 1 } else { 0 })];
        if edge_of(a) {
            targets.push((PosState::Edge, 1));
        }
        // Free (outer) edged loops.
        let free: Vec<usize> = edged.iter().copied().filter(|&e| e < ai).collect();
        for (a_state, a_transitions) in targets {
            if a_transitions == 0 {
                continue;
            }
            for mask in 0..(1u32 << free.len()) {
                let state_of = |li: usize| -> PosState {
                    if li == ai {
                        a_state
                    } else if li > ai {
                        PosState::Normal // inner loops reset
                    } else if free.iter().position(|&e| e == li).map(|k| mask >> k & 1 == 1).unwrap_or(false) {
                        PosState::Edge
                    } else {
                        PosState::Normal
                    }
                };
                // Occurrences: advanced transitions x outer loop position
                // counts matching the state assignment.
                let mut occ = a_transitions;
                for (li, l) in order.iter().enumerate().take(ai) {
                    let c = match state_of(li) {
                        PosState::Normal => {
                            let t = count(l);
                            t - if edge_of(l) { 1 } else { 0 }
                        }
                        PosState::Edge => 1,
                    };
                    occ = occ.saturating_mul(c);
                }
                if occ == 0 {
                    continue;
                }
                let advanced = match a {
                    LoopRef::Dim(i) => Advanced::Temporal { idx: *i },
                    LoopRef::Fold => Advanced::Fold,
                };
                classes.push(build_class(&state_of, advanced, occ));
            }
        }
    }

    // Exactness check: sum of occurrences == total steps.
    let total: u64 = classes.iter().map(|c| c.occurrences).sum();
    let want = s.total_steps();
    if total != want {
        bail!("transition class enumeration inexact: {total} != {want}");
    }
    Ok(classes)
}

/// Exact per-unit MAC count of one class's tile: the product over dims of
/// per-dim contributions, with windowed pairs contributing
/// `out_rows x window-partner tile` and joint pairs contributing their
/// diagonal count.
pub fn macs_per_unit(s: &LevelSchedule, class: &TransitionClass, layer: &Layer) -> u64 {
    let mut macs: u64 = 1;
    for d in &s.dims {
        let state = class.states[s.dims.iter().position(|x| x.dim == d.dim).unwrap()];
        match d.dim {
            Dim::Y | Dim::X => {
                if d.joint_spatial {
                    // Joint diagonal: one (act, win) pair per unit.
                    macs *= 1;
                } else if d.windowed {
                    macs *= d.out_size(if d.spatial { PosState::Normal } else { state });
                } else {
                    // Non-windowed activation dim (FC/residual): direct.
                    macs *= d.in_size(state);
                }
            }
            Dim::R | Dim::S => {
                if d.joint_spatial {
                    macs *= d.size; // 1, by validation
                } else {
                    macs *= d.in_size(if d.spatial { PosState::Normal } else { state });
                }
            }
            _ => {
                macs *= d.in_size(if d.spatial { PosState::Normal } else { state });
            }
        }
    }
    // Depthwise: K is the channel multiplier and C both iterate; the
    // formula above already multiplies both, matching Layer::macs.
    let _ = layer;
    macs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    fn sched_for(df: &crate::ir::dataflow::Dataflow, layer: &Layer, pes: u64) -> LevelSchedule {
        let r = df.resolve(layer, pes).unwrap();
        build_schedule(&r.levels[0], &r.levels[0].parent_tile, layer).unwrap()
    }

    #[test]
    fn cp_schedule_shape() {
        let layer = vgg16::conv2();
        let s = sched_for(&styles::c_p(), &layer, 256);
        // C spatially mapped: 64 positions over C=64, all on 64 of 256 units.
        assert_eq!(s.spatial_positions, 64);
        assert_eq!(s.folds_full, 1);
        assert_eq!(s.fold_edge_units, 0);
        assert_eq!(s.active_units(PosState::Normal), 64);
        // Y windowed: size 3 (=R), offset 1, 224 output rows.
        let y = s.sched_of(Dim::Y);
        assert!(y.windowed);
        assert_eq!(y.out_per_pos, 1);
        assert_eq!(y.positions_full, 224);
    }

    #[test]
    fn class_occurrences_sum_to_steps() {
        let layer = vgg16::conv2();
        for df in styles::all_styles() {
            let r = df.resolve(&layer, 256).unwrap();
            for level in &r.levels {
                let s = build_schedule(level, &level.parent_tile, &layer).unwrap();
                let classes = transition_classes(&s).unwrap();
                let sum: u64 = classes.iter().map(|c| c.occurrences).sum();
                assert_eq!(sum, s.total_steps(), "{} level", df.name);
            }
        }
    }

    #[test]
    fn mac_conservation_single_level() {
        // Single-level dataflows: class MACs x active units must equal
        // the layer MAC total exactly.
        let layer = vgg16::conv2();
        for df in [styles::c_p(), styles::x_p()] {
            let r = df.resolve(&layer, 256).unwrap();
            let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
            let classes = transition_classes(&s).unwrap();
            let total: u64 = classes
                .iter()
                .map(|c| c.occurrences * c.active * macs_per_unit(&s, c, &layer))
                .sum();
            assert_eq!(total, layer.macs(), "{}", df.name);
        }
    }

    #[test]
    fn fold_arises_when_positions_exceed_units() {
        let layer = vgg16::conv2(); // K = 64
        // KC-P level 0: K spatial (64 positions) over 256/64 = 4 clusters.
        let r = styles::kc_p().resolve(&layer, 256).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        assert_eq!(s.spatial_positions, 64);
        assert_eq!(s.units, 4);
        assert_eq!(s.folds_full, 16);
        assert_eq!(s.fold_edge_units, 0);
    }

    #[test]
    fn edge_positions_detected() {
        // C=100 with TemporalMap(64,64) C -> edge of 36.
        let layer = crate::model::layer::Layer::conv2d("t", 1, 8, 100, 10, 10, 3, 3, 1);
        let r = styles::kc_p().resolve(&layer, 256).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let c = s.sched_of(Dim::C);
        assert_eq!(c.positions_full, 1);
        assert_eq!(c.edge_in, 36);
        assert_eq!(c.total_positions(), 2);
    }

    #[test]
    fn yr_joint_inner_level() {
        let layer = vgg16::conv2();
        let r = styles::yr_p().resolve(&layer, 256).unwrap();
        let inner = build_schedule(&r.levels[1], &r.levels[1].parent_tile, &layer).unwrap();
        let y = inner.sched_of(Dim::Y);
        let rr = inner.sched_of(Dim::R);
        assert!(y.joint_spatial && rr.joint_spatial);
        assert_eq!(inner.spatial_positions, 3);
        assert_eq!(inner.units, 3);
    }

    #[test]
    fn windowed_bad_offset_rejected() {
        use crate::ir::directive::{Directive as D, Extent as E};
        let layer = vgg16::conv2();
        // Y size 4 (win 3) covers 2 output rows per position; offset 3
        // would skip output rows.
        let df = crate::ir::dataflow::Dataflow::new(
            "bad-window",
            vec![
                D::spatial(E::lit(1), E::lit(1), Dim::K),
                D::temporal(E::lit(4), E::lit(3), Dim::Y),
            ],
        );
        let r = df.resolve(&layer, 8);
        if let Ok(r) = r {
            assert!(build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).is_err());
        }
    }

    #[test]
    fn windowed_offset_is_augmented() {
        use crate::ir::directive::{Directive as D, Extent as E};
        let layer = vgg16::conv2();
        // Y size 4 (win 3) with slide offset 1: augmented to the only
        // valid step, size - win + stride = 2.
        let df = crate::ir::dataflow::Dataflow::new(
            "slide",
            vec![
                D::spatial(E::lit(1), E::lit(1), Dim::K),
                D::temporal(E::lit(4), E::lit(1), Dim::Y),
            ],
        );
        let r = df.resolve(&layer, 8).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        assert_eq!(s.sched_of(Dim::Y).offset, 2);
        assert_eq!(s.sched_of(Dim::Y).out_per_pos, 2);
    }

    #[test]
    fn stride_two_windows() {
        let layer = crate::model::layer::Layer::conv2d("s2", 1, 8, 4, 11, 11, 3, 3, 2);
        use crate::ir::directive::{Directive as D, Extent as E};
        let df = crate::ir::dataflow::Dataflow::new(
            "w",
            vec![
                D::spatial(E::lit(1), E::lit(1), Dim::K),
                D::temporal(E::sz(Dim::R), E::lit(1), Dim::Y),
                D::temporal(E::sz(Dim::S), E::lit(1), Dim::X),
            ],
        );
        let r = df.resolve(&layer, 8).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let y = s.sched_of(Dim::Y);
        assert_eq!(y.positions_full, 5); // (11-3)/2+1
        assert_eq!(y.out_per_pos, 1);
        assert_eq!(y.offset, 2);
    }
}
