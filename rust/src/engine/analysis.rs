//! Performance + cost analysis (Fig 8): walks a resolved dataflow's
//! cluster levels recursively — "the outstanding delay of a cluster
//! level becomes the computation delay of the next cluster level above"
//! (§4.4) — accumulating runtime with double buffering, buffer access
//! counts, buffer size requirements, NoC bandwidth needs, and energy.
//!
//! # The Analyzer pipeline
//!
//! All analysis is a pure function of `(ShapeKey, dataflow structure,
//! HwConfig)` — layer and dataflow *names* never reach a formula.
//! [`Analyzer`] exploits that: it owns the recursion's scratch memo
//! (reused across calls instead of reallocated), computes through the
//! two-phase split of [`super::profile`] (a bandwidth-invariant
//! [`ReuseProfile`] memo keyed on [`crate::cache::ProfileKey`] sits
//! under the full-key store, making bandwidth-axis sweeps near-free),
//! and fronts a
//! [`SharedStore`] keyed on [`crate::cache::CacheKey`] (canonical
//! shape x structural [`DataflowFingerprint`](crate::cache::DataflowFingerprint)
//! x hardware), so whole-network analysis evaluates each distinct
//! layer shape once and replays the rest (ResNet-50's repeated
//! bottlenecks, VGG's conv stacks). The store is private per Analyzer
//! by default; `Analyzer::with_store` shares one across sweep shards /
//! coordinator workers and is what `--cache-file` warm starts flow
//! through (see [`crate::cache`]). [`analyze_network`] /
//! [`adaptive_network`] and the DSE case-table builder all route
//! through it; cached and uncached results are bit-identical (pinned by
//! tests here and in `rust/tests/dse_parallel.rs`).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::cache::{CacheKey, CacheValue, HwProfileKey, ProfileKey, SharedStore};
use crate::hw::config::{HwConfig, ReductionSupport};
use crate::hw::energy::EnergyModel;
use crate::ir::dataflow::{Dataflow, ResolvedDataflow, ResolvedLevel};
use crate::ir::dims::DimMap;
use crate::model::layer::Layer;
use crate::model::network::Network;
use crate::model::tensor::{couplings, tensor_elements, TensorKind, ALL_TENSORS};

use super::mapping::{build_schedule, macs_per_unit, transition_classes, Advanced};
use super::noc::{level_bandwidth, pipe_delay, reduction_delay};
use super::profile::ReuseProfile;
use super::reuse::{psum_revisits, tensor_usage};

/// Energy split in picojoules (Fig 12's stack).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub mac: f64,
    pub l1: f64,
    pub l2: f64,
    pub noc: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.mac + self.l1 + self.l2 + self.noc
    }
}

/// Full analysis result for one (layer, dataflow, hardware) triple.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerStats {
    pub layer: String,
    pub dataflow: String,
    /// Total cycles.
    pub runtime: f64,
    /// MACs performed (exact; equals `layer.macs()` — tested).
    pub macs: f64,
    /// Effective PE utilization: macs / (runtime x PEs x throughput).
    pub util: f64,
    /// L2 (upstream, global buffer) reads per tensor [F, I, O].
    pub l2_reads: [f64; 3],
    /// L2 writes per tensor [F, I, O].
    pub l2_writes: [f64; 3],
    /// Elements written into local (L1 / cluster) buffers.
    pub l1_fills: f64,
    /// L1 operand + psum accesses driven by MACs.
    pub l1_reads: f64,
    pub l1_writes: f64,
    /// Elements moved over the NoC (delivered volume).
    pub noc_delivered: f64,
    /// Per-PE L1 requirement (elements, double-buffered).
    pub l1_req: u64,
    /// L2 staging requirement (elements, double-buffered).
    pub l2_req: u64,
    /// Peak NoC bandwidth demand (elements/cycle) to stay
    /// compute-bound.
    pub peak_bw_need: f64,
    pub energy: EnergyBreakdown,
}

impl LayerStats {
    /// Reuse factor of a tensor: local accesses per L2 fetch (Fig 11).
    pub fn reuse_factor(&self, t: TensorKind) -> f64 {
        let idx = t_idx(t);
        let fetches = if t == TensorKind::Output {
            self.l2_writes[idx].max(1.0)
        } else {
            self.l2_reads[idx].max(1.0)
        };
        self.macs / fetches
    }

    /// Throughput in MACs/cycle.
    pub fn throughput(&self) -> f64 {
        self.macs / self.runtime.max(1.0)
    }

    /// Energy-delay product (pJ x cycles).
    pub fn edp(&self) -> f64 {
        self.energy.total() * self.runtime
    }
}

pub(crate) fn t_idx(t: TensorKind) -> usize {
    match t {
        TensorKind::Filter => 0,
        TensorKind::Input => 1,
        TensorKind::Output => 2,
    }
}

/// Traffic/energy contributions of one executed subtree.
#[derive(Debug, Clone, Default)]
struct SubOut {
    runtime: f64,
    macs: f64,
    l2_reads: [f64; 3],
    l2_writes: [f64; 3],
    l1_cluster_reads: f64,
    l1_fills: f64,
    noc_delivered: f64,
    l1_req: u64,
    l2_req: u64,
    peak_bw_need: f64,
}

/// A reusable analysis context: owns the recursive engine's scratch
/// memo (allocated once, cleared per call) and fronts a [`SharedStore`]
/// keyed on `(ShapeKey, DataflowFingerprint, HwKey)`, with per-Analyzer
/// hit/miss/disk-hit counters.
///
/// The memoization key carries the dataflow's *structural fingerprint*,
/// never its name: hand-built dataflows that share a name but differ in
/// directives get distinct entries, and structurally identical
/// dataflows under different names share one (the replayed stats are
/// re-labeled with the caller's names).
///
/// Failed analyses are cached too (as the rendered error chain), so a
/// shape that cannot map under a dataflow is diagnosed once per
/// network, not once per layer; replayed failures name the layer (and,
/// when it differs, the dataflow) they were diagnosed on.
///
/// Underneath the full-key store sits a second, per-Analyzer memo of
/// bandwidth-invariant [`ReuseProfile`]s keyed by
/// [`crate::cache::ProfileKey`] (the cache key minus `noc_bandwidth`):
/// a full-key miss that differs from earlier work only in NoC
/// bandwidth skips the whole reuse walk and replays the profile's
/// bandwidth-dependent math (`ReuseProfile::finalize`), bit-identical
/// to a fresh analysis. Profile replays are counted in
/// [`Analyzer::profile_hits`] — a diagnostic counter, excluded from
/// the determinism contract like the hit/miss split. Profiles never
/// persist and never cross Analyzers; the full-key store (and with it
/// disk warm starts and the serve daemon's warm-hit accounting) is
/// untouched.
#[derive(Debug)]
pub struct Analyzer {
    store: Arc<SharedStore>,
    /// Whether `store` is shared with other consumers — a shared store
    /// must never be cleared from one shard under the others.
    shared: bool,
    /// The profile builder's memo (cleared per build; the allocation is
    /// reused across calls).
    scratch: HashMap<ScratchKey, usize>,
    /// Bandwidth-invariant profiles, layered under the full-key store.
    profiles: HashMap<ProfileKey, ProfileEntry>,
    hits: u64,
    disk_hits: u64,
    misses: u64,
    profile_hits: u64,
}

/// A memoized profile build: the profile itself, or the build failure
/// (bandwidth-invariant — resolution and schedule construction never
/// read `noc_bandwidth`) with the names it was diagnosed under.
#[derive(Debug)]
enum ProfileEntry {
    Ready(Arc<ReuseProfile>),
    Failed { layer: String, dataflow: String, message: String },
}

impl Default for Analyzer {
    fn default() -> Analyzer {
        Analyzer::new()
    }
}

impl Analyzer {
    /// An Analyzer over its own private store (the PR 2 behavior).
    pub fn new() -> Analyzer {
        Analyzer {
            store: Arc::new(SharedStore::new()),
            shared: false,
            scratch: HashMap::new(),
            profiles: HashMap::new(),
            hits: 0,
            disk_hits: 0,
            misses: 0,
            profile_hits: 0,
        }
    }

    /// An Analyzer over a caller-provided [`SharedStore`] — the shape
    /// sweep shards, coordinator prep workers, and `--cache-file` warm
    /// starts use to pool results. [`Analyzer::clear_cache`] becomes a
    /// no-op (the store outlives this Analyzer by design); counters
    /// stay per-Analyzer.
    pub fn with_store(store: Arc<SharedStore>) -> Analyzer {
        Analyzer {
            store,
            shared: true,
            scratch: HashMap::new(),
            profiles: HashMap::new(),
            hits: 0,
            disk_hits: 0,
            misses: 0,
            profile_hits: 0,
        }
    }

    /// The store this Analyzer reads and populates.
    pub fn store(&self) -> &Arc<SharedStore> {
        &self.store
    }

    /// Analyze one (layer, dataflow, hardware) triple, memoized on
    /// (canonical shape, structural dataflow fingerprint, hardware).
    /// Cache hits are bit-identical to a fresh analysis; only the
    /// reported `layer` and `dataflow` names are rewritten to the
    /// caller's.
    pub fn analyze(&mut self, layer: &Layer, dataflow: &Dataflow, hw: &HwConfig) -> Result<LayerStats> {
        self.analyze_inner(layer, dataflow, hw, None)
    }

    /// As [`Analyzer::analyze`], but reuses a dataflow the caller
    /// already resolved against this layer at `hw.num_pes` PEs, so a
    /// cache miss skips the internal re-resolution. The caller must
    /// guarantee `resolved` came from `dataflow.resolve(layer,
    /// hw.num_pes)` — used by the DSE case-table builder, which needs
    /// the resolution for its flattened rows anyway.
    pub(crate) fn analyze_with_resolved(
        &mut self,
        layer: &Layer,
        dataflow: &Dataflow,
        hw: &HwConfig,
        resolved: &ResolvedDataflow,
    ) -> Result<LayerStats> {
        self.analyze_inner(layer, dataflow, hw, Some(resolved))
    }

    fn analyze_inner(
        &mut self,
        layer: &Layer,
        dataflow: &Dataflow,
        hw: &HwConfig,
        resolved: Option<&ResolvedDataflow>,
    ) -> Result<LayerStats> {
        let key = CacheKey::new(layer.shape_key(), dataflow.fingerprint(), hw);
        if let Some(hit) = self.store.get(&key) {
            self.hits += 1;
            if hit.from_disk {
                self.disk_hits += 1;
            }
            return match hit.value {
                CacheValue::Stats(mut s) => {
                    // Names are diagnostics, not identity: re-label the
                    // replay with the caller's layer and dataflow.
                    s.layer = layer.name.clone();
                    s.dataflow = dataflow.name.clone();
                    Ok(s)
                }
                // Error chains embed the names they were produced
                // under; when replaying for a different layer (or a
                // structurally identical dataflow with another name),
                // say so instead of misattributing the message.
                CacheValue::Failure { layer: diagnosed_on, dataflow: diagnosed_df, message } => {
                    let mut msg = message;
                    if diagnosed_on != layer.name {
                        msg = format!("{msg} (diagnosed on same-shape layer '{diagnosed_on}')");
                    }
                    if diagnosed_df != dataflow.name {
                        msg = format!(
                            "{msg} (under structurally identical dataflow '{diagnosed_df}')"
                        );
                    }
                    Err(anyhow!("{msg}"))
                }
            };
        }
        self.misses += 1;
        // The profile memo sits under the full-key store: reuse the
        // key's already-computed shape + fingerprint, dropping only the
        // bandwidth from the hardware component.
        let pkey = ProfileKey { shape: key.shape, dataflow: key.dataflow, hw: HwProfileKey::of(hw) };
        let out = match resolved {
            Some(r) => self.compute_resolved(layer, r, hw, pkey),
            None => self.compute(layer, dataflow, hw, pkey),
        };
        match &out {
            Ok(s) => self.store.insert(key, CacheValue::Stats(s.clone())),
            Err(e) => self.store.insert(
                key,
                CacheValue::Failure {
                    layer: layer.name.clone(),
                    dataflow: dataflow.name.clone(),
                    message: format!("{e:#}"),
                },
            ),
        };
        out
    }

    /// Two-phase compute: validation first (it reads `noc_bandwidth`,
    /// so it must run even on a profile hit), then either replay a
    /// memoized bandwidth-invariant [`ReuseProfile`] or build one. The
    /// result is bit-identical to the former monolithic body (pinned
    /// by `rust/tests/properties.rs` against [`analyze_layer`], which
    /// stays monolithic as the reference implementation).
    fn compute(
        &mut self,
        layer: &Layer,
        dataflow: &Dataflow,
        hw: &HwConfig,
        pkey: ProfileKey,
    ) -> Result<LayerStats> {
        hw.validate()?;
        layer.validate()?;
        if let Some(out) = self.finalize_memoized(&pkey, &layer.name, &dataflow.name, hw) {
            return out;
        }
        // Profile miss: resolve, then run the bandwidth-invariant walk
        // once. Resolution failures are bandwidth-invariant too, so
        // they memoize under the same key.
        let built = {
            let _span = crate::obs::trace::span("profile.build");
            dataflow
                .resolve(layer, hw.num_pes)
                .and_then(|r| ReuseProfile::build_with(layer, &r, hw, &mut self.scratch))
        };
        self.memoize_and_finalize(pkey, built, &layer.name, &dataflow.name, hw)
    }

    /// Entry for callers that resolved the dataflow themselves (the
    /// case-table builder): validation has not run yet on this path,
    /// so it happens here.
    fn compute_resolved(
        &mut self,
        layer: &Layer,
        resolved: &ResolvedDataflow,
        hw: &HwConfig,
        pkey: ProfileKey,
    ) -> Result<LayerStats> {
        hw.validate()?;
        layer.validate()?;
        if let Some(out) = self.finalize_memoized(&pkey, &layer.name, &resolved.name, hw) {
            return out;
        }
        let built = {
            let _span = crate::obs::trace::span("profile.build");
            ReuseProfile::build_with(layer, resolved, hw, &mut self.scratch)
        };
        self.memoize_and_finalize(pkey, built, &layer.name, &resolved.name, hw)
    }

    /// Replay a memoized profile (or memoized build failure) at `hw`,
    /// relabeled with the caller's names — the same convention as
    /// full-key store hits. `None` means profile miss.
    fn finalize_memoized(
        &mut self,
        pkey: &ProfileKey,
        layer_name: &str,
        dataflow_name: &str,
        hw: &HwConfig,
    ) -> Option<Result<LayerStats>> {
        let entry = self.profiles.get(pkey)?;
        self.profile_hits += 1;
        Some(match entry {
            ProfileEntry::Ready(p) => {
                let _span = crate::obs::trace::span("profile.finalize");
                let mut s = p.finalize(hw);
                s.layer = layer_name.to_string();
                s.dataflow = dataflow_name.to_string();
                Ok(s)
            }
            ProfileEntry::Failed { layer: diagnosed_on, dataflow: diagnosed_df, message } => {
                let mut msg = message.clone();
                if diagnosed_on != layer_name {
                    msg = format!("{msg} (diagnosed on same-shape layer '{diagnosed_on}')");
                }
                if diagnosed_df != dataflow_name {
                    msg = format!("{msg} (under structurally identical dataflow '{diagnosed_df}')");
                }
                Err(anyhow!("{msg}"))
            }
        })
    }

    /// Record a fresh profile build under `pkey` and finalize it at
    /// `hw` (successes), or record the failure and propagate the
    /// original error chain unchanged.
    fn memoize_and_finalize(
        &mut self,
        pkey: ProfileKey,
        built: Result<ReuseProfile>,
        layer_name: &str,
        dataflow_name: &str,
        hw: &HwConfig,
    ) -> Result<LayerStats> {
        match built {
            Ok(p) => {
                let _span = crate::obs::trace::span("profile.finalize");
                let mut s = p.finalize(hw);
                s.layer = layer_name.to_string();
                s.dataflow = dataflow_name.to_string();
                self.profiles.insert(pkey, ProfileEntry::Ready(Arc::new(p)));
                Ok(s)
            }
            Err(e) => {
                self.profiles.insert(
                    pkey,
                    ProfileEntry::Failed {
                        layer: layer_name.to_string(),
                        dataflow: dataflow_name.to_string(),
                        message: format!("{e:#}"),
                    },
                );
                Err(e)
            }
        }
    }

    /// Layer-cache hits by this Analyzer since construction (or
    /// [`Analyzer::reset`]).
    pub fn cache_hits(&self) -> u64 {
        self.hits
    }

    /// Layer-cache misses (= full analyses actually run).
    pub fn cache_misses(&self) -> u64 {
        self.misses
    }

    /// The subset of [`Analyzer::cache_hits`] served by entries loaded
    /// from a cache file (warm starts).
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits
    }

    /// Full-key misses that replayed a memoized bandwidth-invariant
    /// profile instead of re-running the reuse walk (diagnostic only,
    /// like the hit/miss split — excluded from the determinism
    /// contract). A subset of [`Analyzer::cache_misses`].
    pub fn profile_hits(&self) -> u64 {
        self.profile_hits
    }

    /// Distinct (shape, dataflow, hardware) entries in the store.
    pub fn cache_len(&self) -> usize {
        self.store.len()
    }

    /// Drop cached per-layer results but keep the hit/miss counters and
    /// the scratch allocation. DSE shards with *private* caches call
    /// this between (variant, PEs) pairs: the cache key includes the
    /// dataflow and PE count, so entries from a finished pair can never
    /// hit again — clearing bounds memory to O(unique shapes) instead
    /// of O(pairs x shapes). A no-op on a shared store, whose entries
    /// belong to every consumer (and to the persistence layer).
    ///
    /// The profile memo is dropped unconditionally: it is per-Analyzer
    /// (never shared, never persisted), and its keys carry the dataflow
    /// fingerprint and PE count, so entries from a finished pair can
    /// never hit again — clearing bounds it the same way.
    pub fn clear_cache(&mut self) {
        if !self.shared {
            self.store.clear();
        }
        self.profiles.clear();
    }

    /// Drop all cached results (private stores only) and zero the
    /// counters.
    pub fn reset(&mut self) {
        if !self.shared {
            self.store.clear();
        }
        self.scratch.clear();
        self.profiles.clear();
        self.hits = 0;
        self.disk_hits = 0;
        self.misses = 0;
        self.profile_hits = 0;
    }
}

/// Analyze a layer under a dataflow and hardware config (one-shot; use
/// an [`Analyzer`] to memoize across repeated shapes).
pub fn analyze_layer(layer: &Layer, dataflow: &Dataflow, hw: &HwConfig) -> Result<LayerStats> {
    hw.validate()?;
    layer.validate()?;
    let resolved = dataflow.resolve(layer, hw.num_pes)?;
    analyze_resolved(layer, &resolved, hw)
}

/// Analyze with an already-resolved dataflow (used by the DSE hot path
/// to amortize resolution).
pub fn analyze_resolved(
    layer: &Layer,
    resolved: &ResolvedDataflow,
    hw: &HwConfig,
) -> Result<LayerStats> {
    let mut cache: HashMap<ScratchKey, SubOut> = HashMap::new();
    analyze_resolved_with(layer, resolved, hw, &mut cache)
}

/// The core entry: analyze against a caller-provided (cleared) scratch
/// memo, so a long-lived [`Analyzer`] can reuse one allocation.
fn analyze_resolved_with(
    layer: &Layer,
    resolved: &ResolvedDataflow,
    hw: &HwConfig,
    cache: &mut HashMap<ScratchKey, SubOut>,
) -> Result<LayerStats> {
    let top_tile = resolved.levels[0].parent_tile;
    let out = analyze_levels(&resolved.levels, &top_tile, [1.0, 1.0, 1.0], layer, hw, 0, 1, cache)?;

    ensure!(out.macs > 0.0, "no MACs analyzed");
    let mac_scale = layer.sparsity_macs_scale();
    let macs = out.macs * mac_scale;
    let runtime = out.runtime.max(1.0);

    // Energy from activity counts (Fig 12's model: activity x Cacti
    // energies). L1 operand traffic: 2 operand reads + 1 psum
    // read-modify-write pair per MAC, plus the fills counted above.
    let em = EnergyModel::for_sizes(hw.l1_size, hw.l2_size);
    let l1_reads = 3.0 * macs + out.l1_cluster_reads;
    let l1_writes = macs + out.l1_fills;
    let l2r: f64 = out.l2_reads.iter().sum();
    let l2w: f64 = out.l2_writes.iter().sum();
    let energy = EnergyBreakdown {
        mac: macs * em.mac_pj,
        l1: l1_reads * em.l1_read_pj + l1_writes * em.l1_write_pj,
        l2: l2r * em.l2_read_pj + l2w * em.l2_write_pj,
        noc: out.noc_delivered * hw.noc_latency.max(1) as f64 * em.noc_hop_pj,
    };

    Ok(LayerStats {
        layer: layer.name.clone(),
        dataflow: resolved.name.clone(),
        runtime,
        macs,
        util: macs / (runtime * (hw.num_pes * hw.pe_throughput) as f64),
        l2_reads: out.l2_reads,
        l2_writes: out.l2_writes,
        l1_fills: out.l1_fills,
        l1_reads,
        l1_writes,
        noc_delivered: out.noc_delivered,
        l1_req: out.l1_req,
        l2_req: out.l2_req,
        peak_bw_need: out.peak_bw_need,
        energy,
    })
}

/// Key of the recursion's per-call scratch memo (distinct from the
/// cross-call [`crate::cache::CacheKey`]): (remaining levels, parent
/// tile, entry fresh fractions). Shared with the two-phase profile
/// builder ([`super::profile`]), whose arena mirrors this memo's
/// structure one node per unique key.
pub(crate) type ScratchKey = (usize, [u64; 7], [u64; 3]);

/// Recursive core: analyze `levels[0]` over `parent_tile`; deeper levels
/// provide the per-step compute delay.
///
/// `entry_fresh` carries the *outer* transition's fresh fractions for
/// [filter, input, output]: data a PE retained from the previous outer
/// step is not re-streamed inside the cluster, so inner ingress of the
/// pure input tensors scales by the outer fresh fraction. Outputs always
/// carry 1.0 — partial sums flow upward on every visit (accumulation
/// traffic repeats even when the output coordinates do not change).
fn analyze_levels(
    levels: &[ResolvedLevel],
    parent_tile: &DimMap<u64>,
    entry_fresh: [f64; 3],
    layer: &Layer,
    hw: &HwConfig,
    depth: usize,
    outer_units: u64,
    cache: &mut HashMap<ScratchKey, SubOut>,
) -> Result<SubOut> {
    let key = (
        levels.len(),
        tile_key(parent_tile),
        [entry_fresh[0].to_bits(), entry_fresh[1].to_bits(), entry_fresh[2].to_bits()],
    );
    if let Some(hit) = cache.get(&key) {
        return Ok(hit.clone());
    }

    let level = &levels[0];
    let sched = build_schedule(level, parent_tile, layer)?;
    let classes = transition_classes(&sched)?;
    let revisits = psum_revisits(&sched, layer) as f64;
    let coup = couplings(layer);
    let bw = level_bandwidth(hw, outer_units);
    let inner_units = outer_units * sched.units;

    let mut out = SubOut::default();
    let mut l1_working_max: u64 = 0;
    let mut l2_working_max: f64 = 0.0;

    for class in &classes {
        let occ = class.occurrences as f64;
        let active = class.active.max(1);

        // ---- Tensor usages ------------------------------------------
        // Fresh fractions chain through `entry_fresh`: data the level
        // retained across the *outer* step is not re-streamed here.
        let mut ingress_total = 0.0; // parent-buffer reads this step
        let mut egress_total = 0.0; // parent-buffer writes this step
        let mut delivered_total = 0.0; // into this level's unit buffers
        let mut red_delay = 0.0f64;
        let mut footprint_sum: u64 = 0;
        let mut class_fresh = [1.0f64, 1.0, 1.0];

        for (ci, kind) in ALL_TENSORS.iter().enumerate() {
            let mut u = tensor_usage(&sched, class, &coup[ci], *kind);
            if *kind != TensorKind::Output {
                u.fresh *= entry_fresh[ci];
            }
            class_fresh[ci] = u.fresh;
            if u.footprint_unit == 0 {
                continue;
            }
            footprint_sum += u.footprint_unit;
            match *kind {
                TensorKind::Output => {
                    // Egress volume: reduced across units when spatial
                    // reduction exists and is supported.
                    let reduced = u.spatially_reduced;
                    let egress_unique = if reduced && hw.reduction == ReductionSupport::None {
                        // Unsupported: every unit sends its psums up.
                        u.fresh * (u.footprint_unit * active) as f64
                    } else {
                        u.unique_fresh()
                    };
                    // Partial-sum revisits: all but the final visit come
                    // back down for further accumulation (parent RMW).
                    let psum_ingress = egress_unique * (revisits - 1.0) / revisits;
                    egress_total += egress_unique;
                    ingress_total += psum_ingress;
                    out.l2_writes[t_idx(*kind)] += occ * egress_unique;
                    out.l2_reads[t_idx(*kind)] += occ * psum_ingress;
                    delivered_total += psum_ingress;
                    if reduced && hw.reduction != ReductionSupport::None {
                        red_delay = red_delay.max(reduction_delay(hw.reduction, active));
                    } else if reduced {
                        red_delay = red_delay.max(reduction_delay(ReductionSupport::None, active));
                    }
                }
                _ => {
                    let unique = if hw.multicast {
                        u.unique_fresh()
                    } else {
                        u.delivered_fresh(active)
                    };
                    ingress_total += unique;
                    delivered_total += u.delivered_fresh(active);
                    out.l2_reads[t_idx(*kind)] += occ * unique;
                }
            }
        }

        // ---- Compute delay: recurse or PE base case -----------------
        let (compute_delay, macs_unit, inner) = if levels.len() > 1 {
            let inner_entry = [class_fresh[0], class_fresh[1], 1.0];
            let sub = analyze_levels(&levels[1..], &class.tile, inner_entry, layer, hw, depth + 1, inner_units, cache)?;
            let d = sub.runtime;
            let m = sub.macs;
            (d, m, Some(sub))
        } else {
            let m = macs_per_unit(&sched, class, layer) as f64;
            let d = (m * layer.sparsity_macs_scale() / hw.pe_throughput as f64).ceil().max(1.0);
            (d, m, None)
        };

        // ---- Delays (pipe model + double buffering, Fig 8) ----------
        let in_delay = pipe_delay(ingress_total, bw, hw.noc_latency);
        let out_delay = pipe_delay(egress_total, bw, hw.noc_latency);
        let cmp_delay = compute_delay + red_delay;
        let delay = if matches!(class.advanced, Advanced::GlobalInit) {
            in_delay + cmp_delay + out_delay
        } else {
            in_delay.max(cmp_delay).max(out_delay)
        };
        out.runtime += occ * delay;
        out.macs += occ * macs_unit * active as f64;
        out.l1_fills += occ * delivered_total;
        out.noc_delivered += occ * (delivered_total + egress_total);
        out.peak_bw_need = out
            .peak_bw_need
            .max((ingress_total + egress_total) / cmp_delay.max(1.0));

        // ---- Inner-level traffic scaled by this class ---------------
        if let Some(sub) = inner {
            let scale = occ * active as f64;
            // Inner ingress draws on this level's unit buffers (cluster
            // scratch): charge as L1-class accesses, not L2.
            out.l1_cluster_reads += scale * (sub.l2_reads.iter().sum::<f64>() + sub.l2_writes.iter().sum::<f64>());
            out.l1_fills += scale * sub.l1_fills;
            out.l1_cluster_reads += scale * sub.l1_cluster_reads;
            out.noc_delivered += scale * sub.noc_delivered;
            out.l1_req = out.l1_req.max(sub.l1_req);
        }

        // ---- Working sets for buffer sizing --------------------------
        l1_working_max = l1_working_max.max(footprint_sum);
        l2_working_max = l2_working_max.max(ingress_total + egress_total);
    }

    // Buffer requirements (double buffering, Fig 8's 2x max rule).
    if levels.len() == 1 {
        out.l1_req = out.l1_req.max(2 * l1_working_max);
    }
    if depth == 0 {
        out.l2_req = (2.0 * l2_working_max).ceil() as u64;
    }

    cache.insert(key, out.clone());
    Ok(out)
}

pub(crate) fn tile_key(t: &DimMap<u64>) -> [u64; 7] {
    let mut k = [0u64; 7];
    for (i, (_, v)) in t.iter().enumerate() {
        k[i] = v;
    }
    k
}

/// A layer dropped from a network analysis, with its diagnostic — the
/// `pruned` vs `unmappable` split of the DSE, mirrored at the network
/// level so `skip_invalid` never discards silently.
#[derive(Debug, Clone, PartialEq)]
pub struct SkippedLayer {
    pub layer: String,
    pub reason: String,
}

/// Whole-network aggregate.
#[derive(Debug, Clone)]
pub struct NetworkStats {
    pub network: String,
    pub dataflow: String,
    pub per_layer: Vec<LayerStats>,
    /// Layers dropped (with diagnostics) when `skip_invalid` was set,
    /// or — for adaptive selection — when no candidate dataflow mapped.
    pub skipped: Vec<SkippedLayer>,
    pub runtime: f64,
    pub energy: EnergyBreakdown,
    pub macs: f64,
}

/// Analyze every layer of a network under one dataflow; layers the
/// dataflow cannot resolve on (e.g. cluster size exceeding PEs) are
/// returned as errors unless `skip_invalid`, in which case they are
/// recorded in [`NetworkStats::skipped`] with their diagnostics.
pub fn analyze_network(
    net: &Network,
    dataflow: &Dataflow,
    hw: &HwConfig,
    skip_invalid: bool,
) -> Result<NetworkStats> {
    analyze_network_with(&mut Analyzer::new(), net, dataflow, hw, skip_invalid)
}

/// [`analyze_network`] against a caller-owned [`Analyzer`], so repeated
/// shapes — within this network and across successive calls at the same
/// hardware — are analyzed once. Results are bit-identical to the
/// one-shot path.
pub fn analyze_network_with(
    analyzer: &mut Analyzer,
    net: &Network,
    dataflow: &Dataflow,
    hw: &HwConfig,
    skip_invalid: bool,
) -> Result<NetworkStats> {
    let mut per_layer = Vec::new();
    let mut skipped = Vec::new();
    for layer in &net.layers {
        match analyzer.analyze(layer, dataflow, hw) {
            Ok(s) => per_layer.push(s),
            Err(e) if skip_invalid => {
                skipped.push(SkippedLayer { layer: layer.name.clone(), reason: format!("{e:#}") });
            }
            Err(e) => return Err(e.context(format!("layer {}", layer.name))),
        }
    }
    ensure!(!per_layer.is_empty(), "no layer analyzable under {}", dataflow.name);
    Ok(fold_network_stats(&net.name, &dataflow.name, per_layer, skipped))
}

/// Objective for dataflow selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Runtime,
    Energy,
    Edp,
}

impl Objective {
    /// Parse a CLI spelling; unknown spellings fall back to `Runtime`
    /// (the historical CLI default).
    pub fn parse(s: &str) -> Objective {
        match s {
            "energy" => Objective::Energy,
            "edp" => Objective::Edp,
            _ => Objective::Runtime,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Runtime => "runtime",
            Objective::Energy => "energy",
            Objective::Edp => "edp",
        }
    }
}

/// The uniform cache-counter segment of every stats summary line —
/// mem-hits / disk-hits / misses / evictions / profile-replays, spelled
/// `cache=Xh/Yd/Zm/Ee/Pp`. Shared by [`SweepStats::summary`]
/// (`crate::dse::engine`), [`MapperStats::summary`]
/// (`crate::mapspace::mapper`), and the service layer, so the split
/// can never drift between the sweep and mapper reports again. The
/// whole segment is diagnostic (excluded from the determinism
/// contract); keeping every counter inside the one space-free
/// `cache=` token is load-bearing — CI's thread-determinism smoke
/// strips exactly that token.
pub fn fmt_cache_counters(hits: u64, disk_hits: u64, misses: u64, evictions: u64, profile_hits: u64) -> String {
    format!("cache={hits}h/{disk_hits}d/{misses}m/{evictions}e/{profile_hits}p")
}

/// The scalar a layer's stats score under an objective (lower is
/// better) — the comparison rule shared by [`adaptive_network`] and the
/// mapspace mapper ([`crate::mapspace::Mapper`]).
pub fn objective_score(s: &LayerStats, o: Objective) -> f64 {
    match o {
        Objective::Runtime => s.runtime,
        Objective::Energy => s.energy.total(),
        Objective::Edp => s.edp(),
    }
}

/// Fold per-layer results into a [`NetworkStats`] (runtime/MACs/energy
/// are additive across layers) — shared by the network analyzers here
/// and the mapspace mapper.
pub(crate) fn fold_network_stats(
    network: &str,
    dataflow: &str,
    per_layer: Vec<LayerStats>,
    skipped: Vec<SkippedLayer>,
) -> NetworkStats {
    let runtime = per_layer.iter().map(|s| s.runtime).sum();
    let macs = per_layer.iter().map(|s| s.macs).sum();
    let energy = per_layer.iter().fold(EnergyBreakdown::default(), |a, s| EnergyBreakdown {
        mac: a.mac + s.energy.mac,
        l1: a.l1 + s.energy.l1,
        l2: a.l2 + s.energy.l2,
        noc: a.noc + s.energy.noc,
    });
    NetworkStats {
        network: network.to_string(),
        dataflow: dataflow.to_string(),
        per_layer,
        skipped,
        runtime,
        energy,
        macs,
    }
}

/// Adaptive dataflow (§5.1): per layer, choose the best of the candidate
/// dataflows under the objective. Returns the per-layer winners.
pub fn adaptive_network(
    net: &Network,
    candidates: &[Dataflow],
    hw: &HwConfig,
    objective: Objective,
) -> Result<NetworkStats> {
    adaptive_network_with(&mut Analyzer::new(), net, candidates, hw, objective)
}

/// [`adaptive_network`] against a caller-owned [`Analyzer`]: each
/// (unique shape, candidate) pair is analyzed once, so a network with
/// `s` distinct shapes costs `s x candidates` analyses instead of
/// `layers x candidates`. Layers no candidate maps are recorded in
/// [`NetworkStats::skipped`] with the last candidate's diagnostic.
pub fn adaptive_network_with(
    analyzer: &mut Analyzer,
    net: &Network,
    candidates: &[Dataflow],
    hw: &HwConfig,
    objective: Objective,
) -> Result<NetworkStats> {
    ensure!(!candidates.is_empty(), "adaptive: no candidate dataflows");
    let mut per_layer: Vec<LayerStats> = Vec::new();
    let mut skipped: Vec<SkippedLayer> = Vec::new();
    for layer in &net.layers {
        let mut best: Option<LayerStats> = None;
        let mut last_err: Option<String> = None;
        for df in candidates {
            match analyzer.analyze(layer, df, hw) {
                Ok(s) => {
                    let better = match &best {
                        None => true,
                        Some(b) => objective_score(&s, objective) < objective_score(b, objective),
                    };
                    if better {
                        best = Some(s);
                    }
                }
                Err(e) => last_err = Some(format!("{e:#}")),
            }
        }
        match best {
            Some(b) => per_layer.push(b),
            None => skipped.push(SkippedLayer {
                layer: layer.name.clone(),
                reason: last_err.unwrap_or_else(|| "no candidate dataflow mapped".into()),
            }),
        }
    }
    ensure!(!per_layer.is_empty(), "adaptive: nothing analyzable");
    Ok(fold_network_stats(&net.name, "adaptive", per_layer, skipped))
}

/// The algorithmic maximum reuse factor of a tensor (Fig 11's "A" bars):
/// MACs / tensor size.
pub fn algorithmic_max_reuse(layer: &Layer, t: TensorKind) -> f64 {
    let size = tensor_elements(layer, t).max(1);
    layer.macs() as f64 / size as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    fn hw() -> HwConfig {
        HwConfig::fig10_default()
    }

    #[test]
    fn cache_counter_segment_is_uniform() {
        assert_eq!(fmt_cache_counters(3, 1, 2, 0, 4), "cache=3h/1d/2m/0e/4p");
        assert_eq!(fmt_cache_counters(0, 0, 0, 7, 0), "cache=0h/0d/0m/7e/0p");
    }

    #[test]
    fn mac_conservation_all_styles() {
        let layer = vgg16::conv13();
        for df in styles::all_styles() {
            let s = analyze_layer(&layer, &df, &hw()).unwrap_or_else(|e| panic!("{}: {e}", df.name));
            assert!(
                (s.macs - layer.macs() as f64).abs() < 1e-6 * layer.macs() as f64,
                "{}: macs {} != {}",
                df.name,
                s.macs,
                layer.macs()
            );
        }
    }

    #[test]
    fn runtime_at_least_compute_roofline() {
        let layer = vgg16::conv2();
        let h = hw();
        for df in styles::all_styles() {
            let s = analyze_layer(&layer, &df, &h).unwrap();
            let roofline = layer.macs() as f64 / (h.num_pes * h.pe_throughput) as f64;
            assert!(s.runtime >= roofline * 0.99, "{}: runtime {} < roofline {roofline}", df.name, s.runtime);
            assert!(s.util <= 1.0 + 1e-9, "{}: util {}", df.name, s.util);
        }
    }

    #[test]
    fn l2_reads_cover_tensor_sizes() {
        use crate::model::tensor::tensor_elements;
        let layer = vgg16::conv13();
        for df in styles::all_styles() {
            let s = analyze_layer(&layer, &df, &hw()).unwrap();
            // Every tensor must be fetched at least once...
            assert!(
                s.l2_reads[0] >= 0.999 * tensor_elements(&layer, TensorKind::Filter) as f64,
                "{}: filter reads {}",
                df.name,
                s.l2_reads[0]
            );
            assert!(
                s.l2_reads[1] >= 0.999 * tensor_elements(&layer, TensorKind::Input) as f64,
                "{}: input reads {}",
                df.name,
                s.l2_reads[1]
            );
            // ...and outputs written at least once.
            assert!(
                s.l2_writes[2] >= 0.999 * tensor_elements(&layer, TensorKind::Output) as f64,
                "{}: output writes {}",
                df.name,
                s.l2_writes[2]
            );
        }
    }

    #[test]
    fn weight_stationary_fetches_weights_once() {
        // X-P fetches each filter element exactly once from L2.
        use crate::model::tensor::tensor_elements;
        let layer = vgg16::conv13();
        let s = analyze_layer(&layer, &styles::x_p(), &hw()).unwrap();
        let fsize = tensor_elements(&layer, TensorKind::Filter) as f64;
        assert!(
            (s.l2_reads[0] - fsize).abs() / fsize < 0.01,
            "X-P filter reads {} vs size {fsize}",
            s.l2_reads[0]
        );
    }

    #[test]
    fn no_multicast_increases_energy_and_reads() {
        let layer = vgg16::conv2();
        let mut h = hw();
        let base = analyze_layer(&layer, &styles::kc_p(), &h).unwrap();
        h.multicast = false;
        let nom = analyze_layer(&layer, &styles::kc_p(), &h).unwrap();
        assert!(nom.l2_reads[1] > base.l2_reads[1] * 1.5, "input reads should blow up without multicast");
        assert!(nom.energy.total() > base.energy.total());
    }

    #[test]
    fn no_reduction_support_increases_egress() {
        // C-P spatially reduces outputs at level 0 (across C-parallel
        // PEs); without hardware support every PE sends its psums to L2.
        let layer = vgg16::conv2();
        let mut h = hw();
        let base = analyze_layer(&layer, &styles::c_p(), &h).unwrap();
        h.reduction = ReductionSupport::None;
        let nor = analyze_layer(&layer, &styles::c_p(), &h).unwrap();
        assert!(
            nor.l2_writes[2] > base.l2_writes[2] * 1.5,
            "no-reduction writes {} vs base {}",
            nor.l2_writes[2],
            base.l2_writes[2]
        );
        assert!(nor.energy.total() > base.energy.total());
    }

    #[test]
    fn smaller_bandwidth_never_faster() {
        let layer = vgg16::conv2();
        let mut h = hw();
        let fast = analyze_layer(&layer, &styles::yx_p(), &h).unwrap();
        h.noc_bandwidth = 2;
        let slow = analyze_layer(&layer, &styles::yx_p(), &h).unwrap();
        assert!(slow.runtime >= fast.runtime);
    }

    #[test]
    fn network_analysis_aggregates() {
        let net = vgg16::conv_only();
        let s = analyze_network(&net, &styles::kc_p(), &hw(), false).unwrap();
        assert_eq!(s.per_layer.len(), net.layers.len());
        assert!(s.skipped.is_empty());
        let sum: f64 = s.per_layer.iter().map(|l| l.runtime).sum();
        assert!((s.runtime - sum).abs() < 1e-6);
    }

    #[test]
    fn cached_stats_bit_identical_to_uncached() {
        let layer = vgg16::conv13();
        let h = hw();
        for df in styles::all_styles() {
            let fresh = analyze_layer(&layer, &df, &h).unwrap();
            let mut analyzer = Analyzer::new();
            let miss = analyzer.analyze(&layer, &df, &h).unwrap();
            let hit = analyzer.analyze(&layer, &df, &h).unwrap();
            assert_eq!(miss, fresh, "{}: analyzer miss must equal the free path", df.name);
            assert_eq!(hit, fresh, "{}: cache hit must be bit-identical", df.name);
        }
    }

    #[test]
    fn analyzer_memoizes_across_layer_names() {
        let a = crate::model::layer::Layer::conv2d("first", 1, 128, 64, 58, 58, 3, 3, 1);
        let b = crate::model::layer::Layer::conv2d("second", 1, 128, 64, 58, 58, 3, 3, 1);
        let mut analyzer = Analyzer::new();
        let sa = analyzer.analyze(&a, &styles::kc_p(), &hw()).unwrap();
        let sb = analyzer.analyze(&b, &styles::kc_p(), &hw()).unwrap();
        assert_eq!((analyzer.cache_misses(), analyzer.cache_hits()), (1, 1));
        assert_eq!(analyzer.cache_len(), 1);
        assert_eq!(sb.layer, "second", "hit must carry the caller's layer name");
        let renamed = LayerStats { layer: sa.layer.clone(), ..sb.clone() };
        assert_eq!(renamed, sa, "numbers must match exactly");
    }

    #[test]
    fn same_name_different_structure_dataflows_do_not_alias() {
        // The regression the structural fingerprint exists for: two
        // hand-built dataflows sharing one name but differing in
        // directives must get distinct cache entries and distinct
        // stats — under the old name-keyed cache the second analysis
        // would replay the first's numbers.
        let layer = vgg16::conv13();
        let h = hw();
        let mut kc = styles::kc_p();
        let mut xp = styles::x_p();
        kc.name = "dup".into();
        xp.name = "dup".into();
        let mut analyzer = Analyzer::new();
        let sa = analyzer.analyze(&layer, &kc, &h).unwrap();
        let sb = analyzer.analyze(&layer, &xp, &h).unwrap();
        assert_eq!((analyzer.cache_misses(), analyzer.cache_hits()), (2, 0));
        assert_eq!(analyzer.cache_len(), 2, "distinct structures must occupy distinct entries");
        assert_eq!(sa, analyze_layer(&layer, &kc, &h).unwrap(), "first structure: fresh numbers");
        assert_eq!(sb, analyze_layer(&layer, &xp, &h).unwrap(), "second structure: fresh numbers");
        assert_ne!(sa, sb, "the two structures really do behave differently");
    }

    #[test]
    fn different_name_same_structure_dataflows_share_one_entry() {
        let layer = vgg16::conv13();
        let h = hw();
        let kc = styles::kc_p();
        let mut alias = kc.clone();
        alias.name = "kc-p-by-another-name".into();
        let mut analyzer = Analyzer::new();
        let sa = analyzer.analyze(&layer, &kc, &h).unwrap();
        let sb = analyzer.analyze(&layer, &alias, &h).unwrap();
        assert_eq!((analyzer.cache_misses(), analyzer.cache_hits()), (1, 1));
        assert_eq!(analyzer.cache_len(), 1, "identical structures must share one entry");
        assert_eq!(sb.dataflow, "kc-p-by-another-name", "hit must carry the caller's dataflow name");
        let relabeled = LayerStats { dataflow: sa.dataflow.clone(), ..sb.clone() };
        assert_eq!(relabeled, sa, "numbers must match exactly");
    }

    #[test]
    fn analyzer_caches_failures_with_diagnostics() {
        // kc-p needs a 64-wide C cluster: 8 PEs cannot host it.
        let mut h = hw();
        h.num_pes = 8;
        let layer = vgg16::conv13();
        let mut analyzer = Analyzer::new();
        let e1 = analyzer.analyze(&layer, &styles::kc_p(), &h).unwrap_err().to_string();
        let e2 = analyzer.analyze(&layer, &styles::kc_p(), &h).unwrap_err().to_string();
        assert_eq!((analyzer.cache_misses(), analyzer.cache_hits()), (1, 1));
        assert!(!e1.is_empty() && e2.contains("exceed"), "diagnostic survives the cache: {e2}");
    }

    #[test]
    fn bandwidth_axis_replays_one_profile() {
        // Sweeping only noc_bandwidth: every point is a full-key miss
        // (distinct HwKey), but all points after the first replay one
        // bandwidth-invariant profile — and stay bit-identical to a
        // fresh monolithic analysis.
        let layer = vgg16::conv2();
        let df = styles::kc_p();
        let mut analyzer = Analyzer::new();
        let bws = [1u64, 4, 16, 64, 256];
        for (i, bw) in bws.iter().enumerate() {
            let h = HwConfig { noc_bandwidth: *bw, ..hw() };
            let got = analyzer.analyze(&layer, &df, &h).unwrap();
            assert_eq!(got, analyze_layer(&layer, &df, &h).unwrap(), "bw={bw}");
            assert_eq!(analyzer.cache_misses(), (i + 1) as u64);
            assert_eq!(analyzer.profile_hits(), i as u64, "bw={bw}");
        }
        // Replaying a seen bandwidth hits the full-key store first and
        // never reaches the profile memo.
        let h = HwConfig { noc_bandwidth: 16, ..hw() };
        analyzer.analyze(&layer, &df, &h).unwrap();
        assert_eq!(analyzer.cache_hits(), 1);
        assert_eq!(analyzer.profile_hits(), (bws.len() - 1) as u64);
    }

    #[test]
    fn profile_failure_replays_keep_their_diagnostics() {
        // kc-p cannot host its 64-wide C cluster on 8 PEs; the failure
        // is bandwidth-invariant, so a second bandwidth point replays
        // the memoized diagnosis instead of re-resolving.
        let layer = vgg16::conv13();
        let mut h = hw();
        h.num_pes = 8;
        let mut analyzer = Analyzer::new();
        let e1 = format!("{:#}", analyzer.analyze(&layer, &styles::kc_p(), &h).unwrap_err());
        h.noc_bandwidth = 4;
        let e2 = format!("{:#}", analyzer.analyze(&layer, &styles::kc_p(), &h).unwrap_err());
        assert_eq!(analyzer.profile_hits(), 1);
        assert_eq!(analyzer.cache_misses(), 2, "distinct bandwidths are distinct full keys");
        assert_eq!(e1, e2, "same layer + dataflow: replayed diagnosis renders identically");
        assert!(e2.contains("exceed"), "{e2}");
    }

    #[test]
    fn profile_hits_still_validate_hardware() {
        // hw.validate() reads noc_bandwidth, so it must run even when
        // the bandwidth-invariant profile is already memoized.
        let layer = vgg16::conv2();
        let df = styles::kc_p();
        let mut analyzer = Analyzer::new();
        analyzer.analyze(&layer, &df, &hw()).unwrap();
        let mut bad = hw();
        bad.noc_bandwidth = 0;
        let err = analyzer.analyze(&layer, &df, &bad).unwrap_err().to_string();
        assert!(err.contains("noc_bandwidth"), "{err}");
    }

    #[test]
    fn memoized_network_matches_per_layer_loop() {
        // Whole-network analysis through the shared Analyzer must equal
        // the naive per-layer loop bit for bit.
        let net = crate::model::zoo::by_name("resnet50").unwrap();
        let h = hw();
        let df = styles::kc_p();
        let stats = analyze_network(&net, &df, &h, true).unwrap();
        let mut idx = 0;
        for layer in &net.layers {
            match analyze_layer(layer, &df, &h) {
                Ok(want) => {
                    assert_eq!(stats.per_layer[idx], want, "layer {}", layer.name);
                    idx += 1;
                }
                Err(_) => assert!(stats.skipped.iter().any(|s| s.layer == layer.name)),
            }
        }
        assert_eq!(idx, stats.per_layer.len());
        assert_eq!(stats.per_layer.len() + stats.skipped.len(), net.layers.len());
    }

    #[test]
    fn skipped_layers_are_recorded_not_silent() {
        use crate::model::layer::Layer;
        // "bad" fails validation (activation smaller than filter) and
        // must land in `skipped` with a diagnostic, not vanish.
        let net = Network::new(
            "mixed",
            vec![
                Layer::conv2d("ok", 1, 64, 16, 30, 30, 3, 3, 1),
                Layer::conv2d("bad", 1, 8, 4, 2, 2, 3, 3, 1),
            ],
        );
        let s = analyze_network(&net, &styles::kc_p(), &hw(), true).unwrap();
        assert_eq!(s.per_layer.len(), 1);
        assert_eq!(s.skipped.len(), 1);
        assert_eq!(s.skipped[0].layer, "bad");
        assert!(!s.skipped[0].reason.is_empty());
        // Without skip_invalid the same network is a hard error naming
        // the layer.
        let err = analyze_network(&net, &styles::kc_p(), &hw(), false).unwrap_err();
        assert!(format!("{err:#}").contains("bad"));
    }

    #[test]
    fn replayed_failure_diagnostics_name_their_source_layer() {
        use crate::model::layer::Layer;
        // Two shape-identical unmappable layers: the second's diagnosis
        // is a cache replay and must say which layer it came from
        // instead of silently misattributing "bad_a"'s message.
        let net = Network::new(
            "bad-twins",
            vec![
                Layer::conv2d("ok", 1, 64, 16, 30, 30, 3, 3, 1),
                Layer::conv2d("bad_a", 1, 8, 4, 2, 2, 3, 3, 1),
                Layer::conv2d("bad_b", 1, 8, 4, 2, 2, 3, 3, 1),
            ],
        );
        let s = analyze_network(&net, &styles::kc_p(), &hw(), true).unwrap();
        assert_eq!(s.skipped.len(), 2);
        assert_eq!(s.skipped[0].layer, "bad_a");
        assert!(!s.skipped[0].reason.contains("same-shape"), "{}", s.skipped[0].reason);
        assert_eq!(s.skipped[1].layer, "bad_b");
        assert!(
            s.skipped[1].reason.contains("diagnosed on same-shape layer 'bad_a'"),
            "replay must name its source: {}",
            s.skipped[1].reason
        );
    }

    #[test]
    fn adaptive_never_worse_than_best_single() {
        let net = crate::model::zoo::by_name("mobilenetv2").unwrap();
        let h = hw();
        let cands = styles::all_styles();
        let adaptive = adaptive_network(&net, &cands, &h, Objective::Runtime).unwrap();
        for df in &cands {
            if let Ok(s) = analyze_network(&net, df, &h, true) {
                if s.per_layer.len() == adaptive.per_layer.len() {
                    assert!(
                        adaptive.runtime <= s.runtime * 1.0001,
                        "adaptive {} vs {} {}",
                        adaptive.runtime,
                        df.name,
                        s.runtime
                    );
                }
            }
        }
    }

    #[test]
    fn reuse_factor_below_algorithmic_max() {
        let layer = vgg16::conv2();
        for df in styles::all_styles() {
            let s = analyze_layer(&layer, &df, &hw()).unwrap();
            for t in [TensorKind::Filter, TensorKind::Input] {
                let max = algorithmic_max_reuse(&layer, t);
                let r = s.reuse_factor(t);
                assert!(
                    r <= max * 1.001,
                    "{} {:?}: reuse {r} > algorithmic max {max}",
                    df.name,
                    t
                );
            }
        }
    }

    #[test]
    fn fc_layer_analyzable() {
        let layer = crate::model::layer::Layer::fully_connected("fc", 1, 1000, 4096);
        for df in styles::all_styles() {
            if let Ok(s) = analyze_layer(&layer, &df, &hw()) {
                assert!((s.macs - layer.macs() as f64).abs() < 1.0, "{}", df.name);
            }
        }
    }

    #[test]
    fn depthwise_analyzable() {
        let layer = crate::model::zoo::mobilenet_v2::dwconv_exemplar();
        let s = analyze_layer(&layer, &styles::yr_p(), &hw()).unwrap();
        assert!((s.macs - layer.macs() as f64).abs() < 1e-6 * layer.macs() as f64);
    }
}
