//! Reuse analysis (paper §3.3, §4.1): for each (transition class, tensor)
//! compute the per-unit footprint, the *fresh* fraction (new data this
//! step — its complement is temporal reuse), and the *unique* union
//! across the level's active units (its gap to `footprint x active` is
//! spatial reuse: multicast for inputs, reduction for outputs).
//!
//! Also generates the qualitative reuse-opportunity matrix of Table 1
//! from the same rules, which a unit test checks against the paper.

use crate::ir::dims::Dim;
use crate::model::layer::Layer;
use crate::model::tensor::{couplings, Coupling, TensorDim, TensorKind};

use super::mapping::{Advanced, DimSched, LevelSchedule, PosState, TransitionClass};

/// Quantitative usage of one tensor in one transition class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TensorUsage {
    /// Elements resident per unit per step.
    pub footprint_unit: u64,
    /// Union of footprints across the class's active units.
    pub unique_union: u64,
    /// Fraction of the footprint that is new this step (0 = fully
    /// temporally reused / stationary).
    pub fresh: f64,
    /// Spatial reduction applies (outputs whose coordinates are
    /// invariant across units while a reduction dim varies spatially).
    pub spatially_reduced: bool,
}

impl TensorUsage {
    /// New elements read from the parent buffer this step (multicast
    /// collapsing duplicates across units).
    pub fn unique_fresh(&self) -> f64 {
        self.fresh * self.unique_union as f64
    }

    /// New elements delivered into unit buffers this step (before any
    /// multicast collapse), for `active` units.
    pub fn delivered_fresh(&self, active: u64) -> f64 {
        self.fresh * (self.footprint_unit * active) as f64
    }
}

/// Does advancing `d` move the *output* tensor (directly coupled, or the
/// activation side of a windowed coupling)? The window side (R/S) and
/// uncoupled dims (C for normal conv) are reduction dims instead.
pub fn output_advancing(coupling: &Coupling, d: Dim) -> bool {
    coupling.dims.iter().any(|td| match td {
        TensorDim::Direct(x) => *x == d,
        TensorDim::Windowed { act, .. } => *act == d,
    })
}

/// Is `d` a reduction dimension for this layer (contributes to outputs
/// without addressing them)?
pub fn is_reduction_dim(layer: &Layer, d: Dim) -> bool {
    let [f, i, o] = couplings(layer);
    (f.couples(d) || i.couples(d)) && !output_advancing(&o, d)
}

/// Compute the usage of one tensor in one class of a level schedule.
pub fn tensor_usage(
    s: &LevelSchedule,
    class: &TransitionClass,
    coupling: &Coupling,
    kind: TensorKind,
) -> TensorUsage {
    if coupling.dims.is_empty() {
        return TensorUsage { footprint_unit: 0, unique_union: 0, fresh: 0.0, spatially_reduced: false };
    }
    let state_of = |d: Dim| -> PosState {
        let idx = s.dims.iter().position(|x| x.dim == d).expect("dim scheduled");
        if s.dims[idx].spatial {
            PosState::Normal
        } else {
            class.states[idx]
        }
    };
    let sched_of = |d: Dim| -> &DimSched { s.sched_of(d) };
    let active = class.active.max(1);

    // --- Footprint and union, per tensor dimension -----------------
    let mut footprint: u64 = 1;
    let mut union: u64 = 1;
    for td in &coupling.dims {
        let (len_unit, len_union) = match td {
            TensorDim::Direct(d) => {
                let ds = sched_of(*d);
                let len = ds.in_size(state_of(*d));
                let uni = if ds.spatial {
                    // Units hold consecutive positions offset apart:
                    // union length collapses halo overlap.
                    (active - 1) * ds.offset + len
                } else {
                    len
                };
                (len, uni)
            }
            TensorDim::Windowed { act, win } => {
                let a = sched_of(*act);
                let w = sched_of(*win);
                if a.joint_spatial && w.joint_spatial {
                    // Eyeriss diagonal: act - win invariant across units.
                    (1, 1)
                } else {
                    let rows = if a.windowed { a.out_size(state_of(*act)) } else {
                        // Degenerate (FC-like): single output element.
                        1
                    };
                    let uni = if a.spatial {
                        // Units compute disjoint output chunks.
                        active * rows.max(1)
                    } else {
                        rows
                    };
                    (rows.max(1), uni.max(1))
                }
            }
        };
        footprint = footprint.saturating_mul(len_unit.max(1));
        union = union.saturating_mul(len_union.max(1));
    }

    // --- Fresh fraction --------------------------------------------
    let fresh = fresh_fraction(s, class, coupling, kind);

    // --- Spatial reduction (outputs only) ---------------------------
    let spatially_reduced = kind == TensorKind::Output
        && active > 1
        && union < footprint.saturating_mul(active)
        && s.dims.iter().any(|d| {
            d.spatial && {
                let layer_agnostic_reduction = {
                    // A spatial dim is a reduction dim for this tensor if
                    // it does not advance it but couples the computation:
                    // conservative check via coupling absence.
                    !output_advancing(coupling, d.dim)
                };
                layer_agnostic_reduction
            }
        });

    TensorUsage { footprint_unit: footprint, unique_union: union, fresh, spatially_reduced }
}

/// Fresh-data fraction for a tensor at a transition class (DESIGN.md
/// §6.3 rules).
fn fresh_fraction(
    s: &LevelSchedule,
    class: &TransitionClass,
    coupling: &Coupling,
    kind: TensorKind,
) -> f64 {
    // Order of loops, with the fold spliced in, matching mapping.rs.
    #[derive(Clone, Copy, PartialEq)]
    enum L {
        Dim(usize),
        Fold,
    }
    let mut order: Vec<L> = Vec::new();
    for (i, d) in s.dims.iter().enumerate() {
        if Some(i) == s.fold_order_idx {
            order.push(L::Fold);
        }
        if !d.spatial {
            order.push(L::Dim(i));
        }
    }
    if s.fold_order_idx.is_some() && !order.contains(&L::Fold) {
        order.push(L::Fold);
    }

    let loop_couples = |l: &L| -> bool {
        match l {
            L::Dim(i) => {
                let d = s.dims[*i].dim;
                if kind == TensorKind::Output {
                    output_advancing(coupling, d)
                } else {
                    coupling.couples(d)
                }
            }
            L::Fold => s.dims.iter().filter(|d| d.spatial).any(|d| {
                if kind == TensorKind::Output {
                    output_advancing(coupling, d.dim)
                } else {
                    coupling.couples(d.dim)
                }
            }),
        }
    };
    let loop_positions = |l: &L| -> u64 {
        match l {
            L::Dim(i) => s.dims[*i].total_positions(),
            L::Fold => s.fold_total(),
        }
    };

    match class.advanced {
        Advanced::GlobalInit => 1.0,
        Advanced::Fold => {
            // Inner temporal loops (after the fold in order) reset too.
            let fold_pos = order.iter().position(|l| *l == L::Fold).unwrap();
            let inner_restream = order[fold_pos + 1..]
                .iter()
                .any(|l| loop_positions(l) > 1 && loop_couples(l));
            if loop_couples(&L::Fold) || inner_restream {
                1.0
            } else {
                0.0
            }
        }
        Advanced::Temporal { idx } => {
            let pos = order
                .iter()
                .position(|l| matches!(l, L::Dim(i) if *i == idx))
                .expect("advanced loop in order");
            // Inner coupled loops reset -> full restream.
            let inner_restream = order[pos + 1..]
                .iter()
                .any(|l| loop_positions(l) > 1 && loop_couples(l));
            if kind == TensorKind::Output {
                // Output tiles are disjoint across advancing positions;
                // reduction-dim advances revisit the same outputs
                // (accounted via the psum revisit factor in analysis).
                let d = s.dims[idx].dim;
                return if output_advancing(coupling, d) || inner_restream { 1.0 } else { 0.0 };
            }
            if inner_restream {
                return 1.0;
            }
            let d = &s.dims[idx];
            if coupling.couples(d.dim) {
                let state = class.states[idx];
                let fresh = d.fresh_in(state) as f64;
                let size = d.in_size(state).max(1) as f64;
                (fresh / size).clamp(0.0, 1.0)
            } else {
                0.0
            }
        }
    }
}

/// Psum revisit factor of a level schedule: the product of position
/// counts of reduction loops *outer* to the innermost output-advancing
/// loop. Egressed output tiles are final with fraction `1/revisits`;
/// the rest are partial sums that re-enter later (read-modify-write at
/// the parent buffer).
pub fn psum_revisits(s: &LevelSchedule, layer: &Layer) -> u64 {
    let [_, _, o] = couplings(layer);
    #[derive(Clone, Copy, PartialEq)]
    enum L {
        Dim(usize),
        Fold,
    }
    let mut order: Vec<L> = Vec::new();
    for (i, d) in s.dims.iter().enumerate() {
        if Some(i) == s.fold_order_idx {
            order.push(L::Fold);
        }
        if !d.spatial {
            order.push(L::Dim(i));
        }
    }
    if s.fold_order_idx.is_some() && !order.contains(&L::Fold) {
        order.push(L::Fold);
    }
    let advancing = |l: &L| -> bool {
        match l {
            L::Dim(i) => output_advancing(&o, s.dims[*i].dim),
            L::Fold => s.dims.iter().filter(|d| d.spatial).any(|d| output_advancing(&o, d.dim)),
        }
    };
    let positions = |l: &L| -> u64 {
        match l {
            L::Dim(i) => s.dims[*i].total_positions(),
            L::Fold => s.fold_total(),
        }
    };
    let reduction = |l: &L| -> bool {
        match l {
            L::Dim(i) => is_reduction_dim(layer, s.dims[*i].dim),
            L::Fold => s.dims.iter().filter(|d| d.spatial).any(|d| is_reduction_dim(layer, d.dim)),
        }
    };
    // Innermost advancing loop with >1 positions.
    let innermost_adv = order
        .iter()
        .rposition(|l| advancing(l) && positions(l) > 1)
        .unwrap_or(0);
    order[..innermost_adv]
        .iter()
        .filter(|l| reduction(l) && positions(l) > 1)
        .map(|l| positions(l))
        .product::<u64>()
        .max(1)
}

// ---------------------------------------------------------------------
// Table 1: qualitative reuse opportunities.
// ---------------------------------------------------------------------

/// Qualitative reuse opportunity of one tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Opportunity {
    Multicast,
    Reduction,
    None,
}

/// One row of Table 1: reuse opportunity per tensor for a choice of
/// spatially-mapped dim and innermost temporally-mapped dim.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub spatial_dim: Dim,
    pub innermost_temporal: Dim,
    /// (filter, input, output) spatial opportunities.
    pub spatial: [Opportunity; 3],
    /// (filter, input, output) temporal opportunities.
    pub temporal: [Opportunity; 3],
}

/// Generate Table 1 for standard CONV2D coupling: for each spatially
/// mapped dim and each innermost temporal dim, which tensors can be
/// multicast (spatially or temporally) and which reduced.
///
/// Rules (derived from the same machinery as the quantitative engine):
/// a tensor *not coupled* to the spatial dim is spatially multicast; the
/// output is spatially *reduced* when the spatial dim is a reduction
/// dim. Temporally: a tensor not coupled to the innermost temporal dim
/// is temporally multicast (stationary); the output is temporally
/// reduced when that dim is a reduction dim.
pub fn table1(layer: &Layer) -> Vec<Table1Row> {
    let [f, i, o] = couplings(layer);
    let couples = |c: &Coupling, kind: TensorKind, d: Dim| -> bool {
        if kind == TensorKind::Output {
            output_advancing(c, d)
        } else {
            c.couples(d)
        }
    };
    let spatial_dims = [Dim::K, Dim::C, Dim::R, Dim::Y];
    let mut rows = Vec::new();
    for sd in spatial_dims {
        for td in spatial_dims {
            if td == sd {
                continue;
            }
            let spatial = [
                (TensorKind::Filter, &f),
                (TensorKind::Input, &i),
                (TensorKind::Output, &o),
            ]
            .map(|(kind, c)| {
                if !couples(c, kind, sd) {
                    Opportunity::Multicast
                } else if kind == TensorKind::Output && is_reduction_dim(layer, sd) {
                    Opportunity::Reduction
                } else {
                    Opportunity::None
                }
            });
            let temporal = [
                (TensorKind::Filter, &f),
                (TensorKind::Input, &i),
                (TensorKind::Output, &o),
            ]
            .map(|(kind, c)| {
                if !couples(c, kind, td) {
                    Opportunity::Multicast
                } else if kind == TensorKind::Output && is_reduction_dim(layer, td) {
                    Opportunity::Reduction
                } else {
                    Opportunity::None
                }
            });
            // An output that is a reduction target temporally: the output
            // is *coupled-invariant* while the reduction dim iterates —
            // the paper marks this as a Reduction opportunity on O.
            let mut temporal = temporal;
            if is_reduction_dim(layer, td) {
                temporal[2] = Opportunity::Reduction;
            }
            let mut spatial = spatial;
            if is_reduction_dim(layer, sd) {
                spatial[2] = Opportunity::Reduction;
            }
            rows.push(Table1Row { spatial_dim: sd, innermost_temporal: td, spatial, temporal });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::mapping::{build_schedule, transition_classes};
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    fn conv() -> Layer {
        vgg16::conv2()
    }

    #[test]
    fn table1_matches_paper_conv2d() {
        let rows = table1(&conv());
        let find = |sd: Dim, td: Dim| -> &Table1Row {
            rows.iter()
                .find(|r| r.spatial_dim == sd && r.innermost_temporal == td)
                .unwrap()
        };
        use Opportunity::{Multicast, Reduction};
        let no = Opportunity::None;
        // Paper Table 1, spatial K row: Input multicast; with innermost C:
        // output temporal reduction.
        let r = find(Dim::K, Dim::C);
        assert_eq!(r.spatial, [no, Multicast, no]);
        assert_eq!(r.temporal[2], Reduction);
        // Spatial C: output spatially reduced.
        let r = find(Dim::C, Dim::K);
        assert_eq!(r.spatial[2], Reduction);
        // Spatial C, filter+input coupled -> no multicast on them.
        assert_eq!(r.spatial[0], no);
        assert_eq!(r.spatial[1], no);
        // Innermost K: filter coupled (no reuse), input multicast.
        assert_eq!(r.temporal[0], no);
        assert_eq!(r.temporal[1], Multicast);
        // Spatial R: input not R-coupled -> multicast.
        let r = find(Dim::R, Dim::K);
        assert_eq!(r.spatial[1], Multicast);
        // Spatial R is a reduction dim -> output spatially reduced.
        assert_eq!(r.spatial[2], Reduction);
        // Spatial Y row: filter multicast; innermost C: output reduction.
        let r = find(Dim::Y, Dim::C);
        assert_eq!(r.spatial[0], Multicast);
        assert_eq!(r.temporal[2], Reduction);
    }

    #[test]
    fn weight_stationary_filter_not_fresh() {
        // X-P: filter fresh only on K/C advances, never on Y.
        let layer = conv();
        let r = styles::x_p().resolve(&layer, 64).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let classes = transition_classes(&s).unwrap();
        let [f, _, _] = couplings(&layer);
        for c in &classes {
            if let Advanced::Temporal { idx } = c.advanced {
                let d = s.dims[idx].dim;
                let u = tensor_usage(&s, c, &f, TensorKind::Filter);
                if d == Dim::Y {
                    assert_eq!(u.fresh, 0.0, "filter must be stationary across Y steps");
                }
                if d == Dim::K || d == Dim::C {
                    assert_eq!(u.fresh, 1.0, "filter fully fresh on {d}");
                }
            }
        }
    }

    #[test]
    fn sliding_window_partial_input_reuse() {
        // X-P: input fresh on Y advance = offset/size = 1/3 for R=3.
        let layer = conv();
        let r = styles::x_p().resolve(&layer, 64).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let classes = transition_classes(&s).unwrap();
        let [_, i, _] = couplings(&layer);
        let mut saw_y = false;
        for c in &classes {
            if let Advanced::Temporal { idx } = c.advanced {
                if s.dims[idx].dim == Dim::Y && c.states[idx] == PosState::Normal {
                    let u = tensor_usage(&s, c, &i, TensorKind::Input);
                    // X-P folds X spatially; if X folds>1 the reset
                    // restreams; with enough PEs folds==1 and the Y
                    // advance shows the 1/3 halo reuse.
                    if s.fold_total() == 1 {
                        assert!((u.fresh - 1.0 / 3.0).abs() < 1e-9, "fresh={}", u.fresh);
                    }
                    saw_y = true;
                }
            }
        }
        assert!(saw_y);
    }

    #[test]
    fn c_spatial_reduces_outputs() {
        // C-P: outputs spatially reduced across C-parallel units.
        let layer = conv();
        let r = styles::c_p().resolve(&layer, 64).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let classes = transition_classes(&s).unwrap();
        let [_, _, o] = couplings(&layer);
        let u = tensor_usage(&s, &classes[0], &o, TensorKind::Output);
        assert!(u.spatially_reduced);
        assert_eq!(u.unique_union, u.footprint_unit); // invariant across units
    }

    #[test]
    fn k_spatial_outputs_disjoint() {
        let layer = conv();
        let r = styles::kc_p().resolve(&layer, 256).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let classes = transition_classes(&s).unwrap();
        let [_, _, o] = couplings(&layer);
        let c0 = &classes[0];
        let u = tensor_usage(&s, c0, &o, TensorKind::Output);
        assert!(!u.spatially_reduced);
        assert_eq!(u.unique_union, u.footprint_unit * c0.active);
    }

    #[test]
    fn input_multicast_when_k_spatial() {
        let layer = conv();
        let r = styles::kc_p().resolve(&layer, 256).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let classes = transition_classes(&s).unwrap();
        let [_, i, _] = couplings(&layer);
        let u = tensor_usage(&s, &classes[0], &i, TensorKind::Input);
        assert_eq!(u.unique_union, u.footprint_unit, "input identical across K units");
    }

    #[test]
    fn halo_collapses_union() {
        // X-P: X spatial size S=3 offset 1 -> union over a units =
        // (a-1) + 3 << 3a.
        let layer = conv();
        let r = styles::x_p().resolve(&layer, 64).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        let classes = transition_classes(&s).unwrap();
        let [_, i, _] = couplings(&layer);
        let c0 = &classes[0];
        let u = tensor_usage(&s, c0, &i, TensorKind::Input);
        let a = c0.active;
        // Footprint along X = 3, union along X = (a-1)+3; other dims equal.
        assert_eq!(u.unique_union * 3, u.footprint_unit * ((a - 1) + 3));
    }

    #[test]
    fn psum_revisit_factors() {
        let layer = conv();
        // X-P: C iterates outside Y (innermost advancing = X-fold/Y):
        // every output revisited C times.
        let r = styles::x_p().resolve(&layer, 64).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        assert_eq!(psum_revisits(&s, &layer), layer.c);
        // C-P: C is spatial (inside nothing temporal) -> innermost
        // advancing loops are Y/X; no reduction loop outer to them except
        // none (K outermost is advancing; C is the fold, which sits at
        // the spatial map position - innermost). Revisits = 1.
        let r = styles::c_p().resolve(&layer, 256).unwrap();
        let s = build_schedule(&r.levels[0], &r.levels[0].parent_tile, &layer).unwrap();
        assert_eq!(psum_revisits(&s, &layer), 1);
    }
}
