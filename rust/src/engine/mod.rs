//! The MAESTRO analytical core — the five engines of Fig 7:
//!
//! * tensor analysis lives in [`crate::model::tensor`] (dimension
//!   coupling);
//! * [`mapping`] — cluster + mapping analysis: per-level dimension
//!   schedules and the Init/Steady/Edge iteration-case (transition
//!   class) enumeration of Fig 8;
//! * [`reuse`] — the reuse analysis engine: per-(class, tensor)
//!   footprints, fresh-data fractions, and spatial uniqueness (multicast
//!   / reduction detection), plus the qualitative Table 1 generator;
//! * [`noc`] — the pipe NoC model (§4.2);
//! * [`analysis`] — recursive performance + cost analysis (runtime,
//!   buffer accesses and sizing, energy, bandwidth requirements), layer
//!   and network entry points, and the adaptive-dataflow selector;
//! * [`profile`] — the two-phase split of that analysis: a
//!   bandwidth-invariant [`profile::ReuseProfile`] built once per
//!   (shape, dataflow, hardware-minus-bandwidth), finalized per
//!   bandwidth point (bit-identical to the monolithic path).

pub mod analysis;
pub mod mapping;
pub mod noc;
pub mod profile;
pub mod reuse;
