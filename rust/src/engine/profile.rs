//! Two-phase analysis: bandwidth-invariant [`ReuseProfile`]s.
//!
//! Of everything [`super::analysis`] computes, only the pipe-model NoC
//! delays depend on `HwConfig::noc_bandwidth`: `pipe_delay(elements,
//! level_bandwidth(hw, outer_units), noc_latency)` and the values
//! derived from it (per-class delays, runtime, utilization,
//! `peak_bw_need`). Every reuse quantity — the resolved schedule, the
//! transition classes, per-class ingress/egress volumes, reduction
//! fan-in delays, MAC counts and leaf compute delays, buffer access
//! counts, and the double-buffered buffer requirements — is a pure
//! function of `(shape, dataflow structure, hardware minus
//! noc_bandwidth)`.
//!
//! This module splits the analysis on exactly that line. Phase one,
//! [`ReuseProfile::build`], runs the same recursive cluster walk as the
//! monolithic engine and records its bandwidth-invariant product: an
//! arena of per-(sub-level, tile, entry-freshness) nodes (one per
//! unique scratch-memo key, children before parents), each holding its
//! `outer_units` and a per-transition-class replay record (occurrences,
//! ingress/egress totals, reduction delay, init-vs-steady delay rule,
//! and the compute term — a precomputed leaf delay or a reference to
//! the inner node). Phase two, [`ReuseProfile::finalize`], replays only
//! the bandwidth-dependent math: per-node `level_bandwidth`, per-class
//! `pipe_delay` in/out, the init/steady delay combination, runtime
//! accumulation bottom-up through the arena, `peak_bw_need`,
//! utilization, and the `EnergyBreakdown` assembly.
//!
//! # Bit-identity contract
//!
//! `ReuseProfile::build(layer, resolved, hw)?.finalize(hw)` is
//! **bit-identical** to the monolithic
//! [`super::analysis::analyze_layer`] for every input: the build phase
//! performs the identical floating-point operations in the identical
//! order for every bandwidth-invariant quantity, and finalize replays
//! the remaining operations verbatim (same accumulation order over the
//! same class sequence). Because outputs are unchanged bit for bit,
//! `cache::persist::ANALYSIS_VERSION` is deliberately **not** bumped by
//! this split — persisted `LayerStats` from the monolithic engine
//! remain valid. The contract is pinned by
//! `rust/tests/properties.rs` (random (shape, style, hw, bw) tuples,
//! finalize vs monolithic field-for-field by bit pattern) and by every
//! pre-existing determinism test, which all route through
//! [`super::analysis::Analyzer`] and therefore through this module.
//!
//! # Why this matters
//!
//! The DSE design space is `(variant, PEs) pairs x bandwidths`: an
//! R-point bandwidth axis used to cost R full analyses per pair, and
//! now costs one profile build plus R O(classes) finalizes. The
//! `Analyzer` memoizes profiles under
//! [`crate::cache::ProfileKey`] ([`crate::cache::HwProfileKey`] drops
//! `noc_bandwidth`), layered *under* the full-key `LayerStats` store —
//! so disk persistence and warm-hit accounting are untouched, and a
//! full-key miss that differs from a previous analysis only in
//! bandwidth becomes a near-free finalize (surfaced as
//! `profile_hits`, a diagnostic counter).

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::hw::config::{HwConfig, ReductionSupport};
use crate::hw::energy::EnergyModel;
use crate::ir::dataflow::{ResolvedDataflow, ResolvedLevel};
use crate::ir::dims::DimMap;
use crate::model::layer::Layer;
use crate::model::tensor::{couplings, TensorKind, ALL_TENSORS};

use super::analysis::{t_idx, tile_key, EnergyBreakdown, LayerStats, ScratchKey};
use super::mapping::{build_schedule, macs_per_unit, transition_classes, Advanced};
use super::noc::{level_bandwidth, pipe_delay, reduction_delay};
use super::reuse::{psum_revisits, tensor_usage};

/// The compute term of one transition class: either a leaf (PE-level)
/// delay — bandwidth-invariant, precomputed at build time — or the
/// runtime of an inner cluster level, which depends on bandwidth and is
/// resolved at finalize time through the arena.
#[derive(Debug, Clone, Copy)]
enum ComputeRef {
    /// Leaf compute delay in cycles (already ceil'd and clamped).
    Leaf(f64),
    /// Index of the inner node whose finalized runtime is this class's
    /// compute delay. Always less than the referencing node's own index
    /// (children are pushed before parents).
    Inner(usize),
}

/// Bandwidth-invariant replay record for one transition class.
#[derive(Debug, Clone, Copy)]
struct ClassRecord {
    /// Occurrences of this class (as f64, the form the accumulation
    /// uses).
    occ: f64,
    /// Parent-buffer read volume per step (ingress_total).
    ingress: f64,
    /// Parent-buffer write volume per step (egress_total).
    egress: f64,
    /// Spatial-reduction delay (fan-in dependent, bandwidth-invariant).
    red_delay: f64,
    /// Whether this is the GlobalInit class (serialized in+compute+out
    /// instead of the steady-state max).
    global_init: bool,
    compute: ComputeRef,
}

/// Bandwidth-invariant totals of one node's subtree — the `SubOut`
/// fields that do not depend on `noc_bandwidth`.
#[derive(Debug, Clone, Copy, Default)]
struct Invariant {
    macs: f64,
    l2_reads: [f64; 3],
    l2_writes: [f64; 3],
    l1_cluster_reads: f64,
    l1_fills: f64,
    noc_delivered: f64,
    l1_req: u64,
    l2_req: u64,
}

/// One arena node: a unique (remaining levels, parent tile, entry
/// freshness) subtree of the recursive walk.
#[derive(Debug, Clone)]
struct ProfileNode {
    /// Product of units above this level — `level_bandwidth`'s divisor.
    outer_units: u64,
    classes: Vec<ClassRecord>,
    inv: Invariant,
}

/// The bandwidth-invariant product of analyzing one (layer, resolved
/// dataflow, hardware-minus-bandwidth) triple. Build once, then
/// [`finalize`](ReuseProfile::finalize) per bandwidth point.
#[derive(Debug, Clone)]
pub struct ReuseProfile {
    /// Layer name at build time (callers relabel, as with cache hits).
    layer: String,
    /// Resolved dataflow name at build time.
    dataflow: String,
    /// `layer.sparsity_macs_scale()` captured at build time.
    mac_scale: f64,
    /// Nodes in finalize order: every `ComputeRef::Inner(j)` satisfies
    /// `j < i` for its owner `i`; the root is last.
    nodes: Vec<ProfileNode>,
}

impl ReuseProfile {
    /// Phase one: run the bandwidth-invariant walk over an
    /// already-resolved dataflow. Fails exactly where the monolithic
    /// engine fails (schedule construction, class enumeration, "no MACs
    /// analyzed") — bandwidth-invariant failures, so callers may cache
    /// them under the same profile key.
    pub fn build(layer: &Layer, resolved: &ResolvedDataflow, hw: &HwConfig) -> Result<ReuseProfile> {
        let mut memo = HashMap::new();
        ReuseProfile::build_with(layer, resolved, hw, &mut memo)
    }

    /// As [`ReuseProfile::build`], against a caller-owned (cleared
    /// here) memo so a long-lived `Analyzer` reuses one allocation.
    pub(crate) fn build_with(
        layer: &Layer,
        resolved: &ResolvedDataflow,
        hw: &HwConfig,
        memo: &mut HashMap<ScratchKey, usize>,
    ) -> Result<ReuseProfile> {
        memo.clear();
        let mut nodes = Vec::new();
        let top_tile = resolved.levels[0].parent_tile;
        let root = profile_levels(
            &resolved.levels,
            &top_tile,
            [1.0, 1.0, 1.0],
            layer,
            hw,
            0,
            1,
            memo,
            &mut nodes,
        )?;
        debug_assert_eq!(root, nodes.len() - 1, "root must be the last node pushed");
        ensure!(nodes[root].inv.macs > 0.0, "no MACs analyzed");
        Ok(ReuseProfile {
            layer: layer.name.clone(),
            dataflow: resolved.name.clone(),
            mac_scale: layer.sparsity_macs_scale(),
            nodes,
        })
    }

    /// Phase two: replay the bandwidth-dependent math for one hardware
    /// point. `hw` must agree with the build hardware on every field
    /// except `noc_bandwidth` (the `Analyzer` enforces this via
    /// [`crate::cache::ProfileKey`]); the result is bit-identical to
    /// the monolithic analysis at `hw`.
    pub fn finalize(&self, hw: &HwConfig) -> LayerStats {
        // Per-node runtimes, bottom-up: children precede parents in the
        // arena, so a single forward pass resolves every ComputeRef.
        let mut runtimes = vec![0.0f64; self.nodes.len()];
        let mut peaks = vec![0.0f64; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            let bw = level_bandwidth(hw, node.outer_units);
            let mut runtime = 0.0f64;
            let mut peak = 0.0f64;
            for class in &node.classes {
                let compute_delay = match class.compute {
                    ComputeRef::Leaf(d) => d,
                    ComputeRef::Inner(j) => runtimes[j],
                };
                let in_delay = pipe_delay(class.ingress, bw, hw.noc_latency);
                let out_delay = pipe_delay(class.egress, bw, hw.noc_latency);
                let cmp_delay = compute_delay + class.red_delay;
                let delay = if class.global_init {
                    in_delay + cmp_delay + out_delay
                } else {
                    in_delay.max(cmp_delay).max(out_delay)
                };
                runtime += class.occ * delay;
                peak = peak.max((class.ingress + class.egress) / cmp_delay.max(1.0));
            }
            runtimes[i] = runtime;
            peaks[i] = peak;
        }
        let root = self.nodes.len() - 1;
        let inv = &self.nodes[root].inv;

        let macs = inv.macs * self.mac_scale;
        let runtime = runtimes[root].max(1.0);

        // Identical assembly to the monolithic path (same expressions,
        // same order — see analysis::analyze_resolved_with).
        let em = EnergyModel::for_sizes(hw.l1_size, hw.l2_size);
        let l1_reads = 3.0 * macs + inv.l1_cluster_reads;
        let l1_writes = macs + inv.l1_fills;
        let l2r: f64 = inv.l2_reads.iter().sum();
        let l2w: f64 = inv.l2_writes.iter().sum();
        let energy = EnergyBreakdown {
            mac: macs * em.mac_pj,
            l1: l1_reads * em.l1_read_pj + l1_writes * em.l1_write_pj,
            l2: l2r * em.l2_read_pj + l2w * em.l2_write_pj,
            noc: inv.noc_delivered * hw.noc_latency.max(1) as f64 * em.noc_hop_pj,
        };

        LayerStats {
            layer: self.layer.clone(),
            dataflow: self.dataflow.clone(),
            runtime,
            macs,
            util: macs / (runtime * (hw.num_pes * hw.pe_throughput) as f64),
            l2_reads: inv.l2_reads,
            l2_writes: inv.l2_writes,
            l1_fills: inv.l1_fills,
            l1_reads,
            l1_writes,
            noc_delivered: inv.noc_delivered,
            l1_req: inv.l1_req,
            l2_req: inv.l2_req,
            peak_bw_need: peaks[root],
            energy,
        }
    }

    /// Arena size (unique subtrees) — diagnostics and tests.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

/// The bandwidth-invariant mirror of `analysis::analyze_levels`: same
/// schedule build, class enumeration, tensor-usage accounting, and
/// recursion (including the scratch-memo structure — one node per
/// unique key), but it records replay terms instead of combining them
/// with pipe delays. Returns the arena index of this subtree's node.
#[allow(clippy::too_many_arguments)]
fn profile_levels(
    levels: &[ResolvedLevel],
    parent_tile: &DimMap<u64>,
    entry_fresh: [f64; 3],
    layer: &Layer,
    hw: &HwConfig,
    depth: usize,
    outer_units: u64,
    memo: &mut HashMap<ScratchKey, usize>,
    nodes: &mut Vec<ProfileNode>,
) -> Result<usize> {
    let key = (
        levels.len(),
        tile_key(parent_tile),
        [entry_fresh[0].to_bits(), entry_fresh[1].to_bits(), entry_fresh[2].to_bits()],
    );
    if let Some(&idx) = memo.get(&key) {
        return Ok(idx);
    }

    let level = &levels[0];
    let sched = build_schedule(level, parent_tile, layer)?;
    let classes = transition_classes(&sched)?;
    let revisits = psum_revisits(&sched, layer) as f64;
    let coup = couplings(layer);
    let inner_units = outer_units * sched.units;

    let mut inv = Invariant::default();
    let mut records = Vec::with_capacity(classes.len());
    let mut l1_working_max: u64 = 0;
    let mut l2_working_max: f64 = 0.0;

    for class in &classes {
        let occ = class.occurrences as f64;
        let active = class.active.max(1);

        let mut ingress_total = 0.0;
        let mut egress_total = 0.0;
        let mut delivered_total = 0.0;
        let mut red_delay = 0.0f64;
        let mut footprint_sum: u64 = 0;
        let mut class_fresh = [1.0f64, 1.0, 1.0];

        for (ci, kind) in ALL_TENSORS.iter().enumerate() {
            let mut u = tensor_usage(&sched, class, &coup[ci], *kind);
            if *kind != TensorKind::Output {
                u.fresh *= entry_fresh[ci];
            }
            class_fresh[ci] = u.fresh;
            if u.footprint_unit == 0 {
                continue;
            }
            footprint_sum += u.footprint_unit;
            match *kind {
                TensorKind::Output => {
                    let reduced = u.spatially_reduced;
                    let egress_unique = if reduced && hw.reduction == ReductionSupport::None {
                        u.fresh * (u.footprint_unit * active) as f64
                    } else {
                        u.unique_fresh()
                    };
                    let psum_ingress = egress_unique * (revisits - 1.0) / revisits;
                    egress_total += egress_unique;
                    ingress_total += psum_ingress;
                    inv.l2_writes[t_idx(*kind)] += occ * egress_unique;
                    inv.l2_reads[t_idx(*kind)] += occ * psum_ingress;
                    delivered_total += psum_ingress;
                    if reduced && hw.reduction != ReductionSupport::None {
                        red_delay = red_delay.max(reduction_delay(hw.reduction, active));
                    } else if reduced {
                        red_delay = red_delay.max(reduction_delay(ReductionSupport::None, active));
                    }
                }
                _ => {
                    let unique = if hw.multicast {
                        u.unique_fresh()
                    } else {
                        u.delivered_fresh(active)
                    };
                    ingress_total += unique;
                    delivered_total += u.delivered_fresh(active);
                    inv.l2_reads[t_idx(*kind)] += occ * unique;
                }
            }
        }

        let (compute, macs_unit, inner_idx) = if levels.len() > 1 {
            let inner_entry = [class_fresh[0], class_fresh[1], 1.0];
            let j = profile_levels(
                &levels[1..],
                &class.tile,
                inner_entry,
                layer,
                hw,
                depth + 1,
                inner_units,
                memo,
                nodes,
            )?;
            (ComputeRef::Inner(j), nodes[j].inv.macs, Some(j))
        } else {
            let m = macs_per_unit(&sched, class, layer) as f64;
            let d = (m * layer.sparsity_macs_scale() / hw.pe_throughput as f64).ceil().max(1.0);
            (ComputeRef::Leaf(d), m, None)
        };

        inv.macs += occ * macs_unit * active as f64;
        inv.l1_fills += occ * delivered_total;
        inv.noc_delivered += occ * (delivered_total + egress_total);

        if let Some(j) = inner_idx {
            let sub = nodes[j].inv;
            let scale = occ * active as f64;
            inv.l1_cluster_reads +=
                scale * (sub.l2_reads.iter().sum::<f64>() + sub.l2_writes.iter().sum::<f64>());
            inv.l1_fills += scale * sub.l1_fills;
            inv.l1_cluster_reads += scale * sub.l1_cluster_reads;
            inv.noc_delivered += scale * sub.noc_delivered;
            inv.l1_req = inv.l1_req.max(sub.l1_req);
        }

        l1_working_max = l1_working_max.max(footprint_sum);
        l2_working_max = l2_working_max.max(ingress_total + egress_total);

        records.push(ClassRecord {
            occ,
            ingress: ingress_total,
            egress: egress_total,
            red_delay,
            global_init: matches!(class.advanced, Advanced::GlobalInit),
            compute,
        });
    }

    if levels.len() == 1 {
        inv.l1_req = inv.l1_req.max(2 * l1_working_max);
    }
    if depth == 0 {
        inv.l2_req = (2.0 * l2_working_max).ceil() as u64;
    }

    let idx = nodes.len();
    nodes.push(ProfileNode { outer_units, classes: records, inv });
    memo.insert(key, idx);
    Ok(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::analysis::analyze_layer;
    use crate::ir::styles;
    use crate::model::zoo::vgg16;

    fn bits_equal(a: &LayerStats, b: &LayerStats) -> bool {
        fn fb(x: f64, y: f64) -> bool {
            x.to_bits() == y.to_bits()
        }
        a.layer == b.layer
            && a.dataflow == b.dataflow
            && fb(a.runtime, b.runtime)
            && fb(a.macs, b.macs)
            && fb(a.util, b.util)
            && a.l2_reads.iter().zip(&b.l2_reads).all(|(x, y)| fb(*x, *y))
            && a.l2_writes.iter().zip(&b.l2_writes).all(|(x, y)| fb(*x, *y))
            && fb(a.l1_fills, b.l1_fills)
            && fb(a.l1_reads, b.l1_reads)
            && fb(a.l1_writes, b.l1_writes)
            && fb(a.noc_delivered, b.noc_delivered)
            && a.l1_req == b.l1_req
            && a.l2_req == b.l2_req
            && fb(a.peak_bw_need, b.peak_bw_need)
            && fb(a.energy.mac, b.energy.mac)
            && fb(a.energy.l1, b.energy.l1)
            && fb(a.energy.l2, b.energy.l2)
            && fb(a.energy.noc, b.energy.noc)
    }

    #[test]
    fn finalize_matches_monolithic_at_build_bandwidth() {
        let layer = vgg16::conv2();
        let hw = HwConfig::fig10_default();
        for df in styles::all_styles() {
            let Ok(resolved) = df.resolve(&layer, hw.num_pes) else { continue };
            let profile = ReuseProfile::build(&layer, &resolved, &hw).unwrap();
            let fresh = analyze_layer(&layer, &df, &hw).unwrap();
            assert!(
                bits_equal(&profile.finalize(&hw), &fresh),
                "{}: finalize diverged from monolithic",
                df.name
            );
        }
    }

    #[test]
    fn one_profile_serves_the_whole_bandwidth_axis() {
        let layer = vgg16::conv2();
        let base = HwConfig::fig10_default();
        let df = styles::kc_p();
        let resolved = df.resolve(&layer, base.num_pes).unwrap();
        let profile = ReuseProfile::build(&layer, &resolved, &base).unwrap();
        for bw in [1u64, 2, 4, 7, 16, 33, 64, 128, 256] {
            let hw = HwConfig { noc_bandwidth: bw, ..base.clone() };
            let fresh = analyze_layer(&layer, &df, &hw).unwrap();
            assert!(
                bits_equal(&profile.finalize(&hw), &fresh),
                "bw={bw}: finalize diverged from monolithic"
            );
        }
    }

    #[test]
    fn build_fails_where_the_monolithic_engine_fails() {
        // A spatial extent larger than the PE array cannot resolve; the
        // failure happens at resolve time for both paths. Profiles must
        // also reproduce the "no MACs analyzed" class of failure —
        // exercised indirectly: any layer/dataflow pair that analyzes
        // monolithically must profile, and vice versa.
        let layer = vgg16::conv2();
        let hw = HwConfig::fig10_default();
        for df in styles::all_styles() {
            let mono = analyze_layer(&layer, &df, &hw);
            match df.resolve(&layer, hw.num_pes) {
                Ok(resolved) => {
                    let built = ReuseProfile::build(&layer, &resolved, &hw);
                    assert_eq!(mono.is_ok(), built.is_ok(), "{}", df.name);
                }
                Err(_) => assert!(mono.is_err(), "{}", df.name),
            }
        }
    }

    #[test]
    fn arena_orders_children_before_parents() {
        let layer = vgg16::conv2();
        let hw = HwConfig::fig10_default();
        // yr-p carries an inner cluster level, so the arena has depth.
        let df = styles::yr_p();
        let resolved = df.resolve(&layer, hw.num_pes).unwrap();
        let profile = ReuseProfile::build(&layer, &resolved, &hw).unwrap();
        assert!(profile.node_count() >= 2, "expected a multi-node arena");
        for (i, node) in profile.nodes.iter().enumerate() {
            for class in &node.classes {
                if let ComputeRef::Inner(j) = class.compute {
                    assert!(j < i, "node {i} references not-yet-finalized node {j}");
                }
            }
        }
    }
}
