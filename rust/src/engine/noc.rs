//! The analytical NoC model (paper §4.2): a *pipe* with two parameters —
//! width (bandwidth, elements/cycle) and length (average latency,
//! cycles) — plus the spatial reuse-support switches of Table 2.

use crate::hw::config::{HwConfig, ReductionSupport};

/// Pipe-model delay for moving `elements` through a pipe of `bandwidth`
/// elements/cycle and `latency` cycles: pipelined, so the latency is paid
/// once per transfer.
pub fn pipe_delay(elements: f64, bandwidth: u64, latency: u64) -> f64 {
    if elements <= 0.0 {
        return 0.0;
    }
    (elements / bandwidth.max(1) as f64).ceil() + latency as f64
}

/// Extra cycles to spatially reduce partial sums across `fan_in` units
/// (Table 2's fan-in column).
pub fn reduction_delay(support: ReductionSupport, fan_in: u64) -> f64 {
    if fan_in <= 1 {
        return 0.0;
    }
    match support {
        // No hardware: reduction is serialized through the parent buffer;
        // the traffic cost is charged separately (egress x fan_in), the
        // serialization shows up as a fan_in-deep merge.
        ReductionSupport::None => fan_in as f64,
        ReductionSupport::Tree => (fan_in as f64).log2().ceil(),
        ReductionSupport::Forward => (fan_in - 1) as f64,
    }
}

/// Effective bandwidth share of one sub-group at a hierarchy level:
/// the top level sees the full pipe; each of `outer_units` inner groups
/// shares it (bisection view, §4.2's guidance for hierarchical NoCs).
pub fn level_bandwidth(hw: &HwConfig, outer_units: u64) -> u64 {
    (hw.noc_bandwidth / outer_units.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipe_delay_basics() {
        assert_eq!(pipe_delay(0.0, 16, 2), 0.0);
        assert_eq!(pipe_delay(16.0, 16, 2), 3.0); // 1 + latency 2
        assert_eq!(pipe_delay(17.0, 16, 2), 4.0); // ceil(17/16) + 2
    }

    #[test]
    fn reduction_delays() {
        assert_eq!(reduction_delay(ReductionSupport::Tree, 64), 6.0);
        assert_eq!(reduction_delay(ReductionSupport::Forward, 64), 63.0);
        assert_eq!(reduction_delay(ReductionSupport::None, 64), 64.0);
        assert_eq!(reduction_delay(ReductionSupport::Tree, 1), 0.0);
    }

    #[test]
    fn bandwidth_sharing() {
        let hw = HwConfig::fig10_default(); // bw 16
        assert_eq!(level_bandwidth(&hw, 1), 16);
        assert_eq!(level_bandwidth(&hw, 4), 4);
        assert_eq!(level_bandwidth(&hw, 64), 1); // floor at 1
    }
}
