//! The five evaluation dataflows of Table 3, plus the Fig 6
//! row-stationary pedagogical example.
//!
//! Names follow the paper's partitioning-strategy convention (spatial
//! dims from the upper-most cluster level). Two cells of Table 3 contain
//! obvious typos (`TemporalMap(Sz(S),Sz(R)) R` in YR-P and
//! `TemporalMap(Sz(R),Sz(S)) S` in KC-P); we use the intended
//! `(Sz(R),Sz(R)) R` / `(Sz(S),Sz(S)) S` forms, which match the cited
//! accelerators.

use super::dataflow::Dataflow;
use super::dims::Dim::*;
use super::directive::{Directive as D, Extent as E};

/// C-Partitioned: input-channel parallelism, large spatial reduction,
/// no local reuse (Table 3 row 1).
pub fn c_p() -> Dataflow {
    Dataflow::new(
        "C-P",
        vec![
            D::temporal(E::lit(1), E::lit(1), K),
            D::temporal(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::spatial(E::lit(1), E::lit(1), C),
        ],
    )
}

/// X-Partitioned: input-column parallelism, weight-stationary, spatial
/// halo reuse (Table 3 row 2).
pub fn x_p() -> Dataflow {
    Dataflow::new(
        "X-P",
        vec![
            D::temporal(E::lit(1), E::lit(1), K),
            D::temporal(E::lit(1), E::lit(1), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::temporal(E::sz(R), E::lit(1), Y),
            D::spatial(E::sz(S), E::lit(1), X),
        ],
    )
}

/// YX-Partitioned: 2D activation parallelism, output-stationary —
/// ShiDianNao-motivated (Table 3 row 3).
pub fn yx_p() -> Dataflow {
    Dataflow::new(
        "YX-P",
        vec![
            D::temporal(E::lit(1), E::lit(1), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz_plus(S, 7), E::lit(8), X), // 8 + Sz(S) - 1
            D::temporal(E::lit(1), E::lit(1), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::lit(8)),
            D::spatial(E::sz(S), E::lit(1), X),
        ],
    )
}

/// YR-Partitioned: activation-row + filter-row parallelism,
/// row-stationary — Eyeriss-motivated (Table 3 row 4).
pub fn yr_p() -> Dataflow {
    Dataflow::new(
        "YR-P",
        vec![
            D::temporal(E::lit(2), E::lit(2), C),
            D::temporal(E::lit(2), E::lit(2), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::sz(R)),
            D::spatial(E::lit(1), E::lit(1), Y),
            D::spatial(E::lit(1), E::lit(1), R),
        ],
    )
}

/// KC-Partitioned: input/output channel parallelism, 64-way spatial
/// reduction, weight-stationary — NVDLA-motivated (Table 3 row 5).
pub fn kc_p() -> Dataflow {
    Dataflow::new(
        "KC-P",
        vec![
            D::spatial(E::lit(1), E::lit(1), K),
            D::temporal(E::lit(64), E::lit(64), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::temporal(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::cluster(E::lit(64)),
            D::spatial(E::lit(1), E::lit(1), C),
        ],
    )
}

/// The Fig 6 row-stationary example on a 6-PE accelerator: two clusters
/// of three PEs (used by the extended-example test and docs).
pub fn row_stationary_fig6() -> Dataflow {
    Dataflow::new(
        "row-stationary-fig6",
        vec![
            D::temporal(E::lit(1), E::lit(1), K),
            D::temporal(E::lit(1), E::lit(1), C),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::cluster(E::sz(R)),
            D::spatial(E::lit(1), E::lit(1), Y),
            D::spatial(E::lit(1), E::lit(1), R),
        ],
    )
}

// ---------------------------------------------------------------------
// Tileable forms of the Table 3 styles
// ---------------------------------------------------------------------
//
// The fixed styles above pin one tile binding each (KC-P's 64-wide C
// cluster, YR-P's 2x2 C/K tiles, YX-P's 8-wide X tile). The paper's
// §2.4 point is that those bindings are *mappings*, not part of the
// dataflow: the functions below expose the same styles with their
// tileable dimensions as parameters. `mapspace::StyleTemplate` declares
// which knobs each style has and enumerates legal bindings per layer
// shape; the DSE's variant axis (`dse::space`) instantiates these too.

/// KC-P (NVDLA-like) with a parametric C-tile / cluster size. `ct = 64`
/// reproduces [`kc_p`] exactly (same structural fingerprint).
pub fn kc_p_ct(ct: u64) -> Dataflow {
    Dataflow::new(
        &format!("KC-P(ct={ct})"),
        vec![
            D::spatial(E::lit(1), E::lit(1), K),
            D::temporal(E::lit(ct), E::lit(ct), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::temporal(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::cluster(E::lit(ct)),
            D::spatial(E::lit(1), E::lit(1), C),
        ],
    )
}

/// YR-P (Eyeriss-like) with parametric C/K tiles. `(2, 2)` reproduces
/// [`yr_p`] exactly.
pub fn yr_p_ck(c_tile: u64, k_tile: u64) -> Dataflow {
    Dataflow::new(
        &format!("YR-P(c={c_tile},k={k_tile})"),
        vec![
            D::temporal(E::lit(c_tile), E::lit(c_tile), C),
            D::temporal(E::lit(k_tile), E::lit(k_tile), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz(S), E::lit(1), X),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::sz(R)),
            D::spatial(E::lit(1), E::lit(1), Y),
            D::spatial(E::lit(1), E::lit(1), R),
        ],
    )
}

/// YX-P (ShiDianNao-like) with a parametric X tile. `xt = 8` reproduces
/// [`yx_p`] exactly.
pub fn yx_p_xt(xt: u64) -> Dataflow {
    Dataflow::new(
        &format!("YX-P(xt={xt})"),
        vec![
            D::temporal(E::lit(1), E::lit(1), K),
            D::spatial(E::sz(R), E::lit(1), Y),
            D::temporal(E::sz_plus(S, xt as i64 - 1), E::lit(xt), X),
            D::temporal(E::lit(1), E::lit(1), C),
            D::temporal(E::sz(R), E::sz(R), R),
            D::temporal(E::sz(S), E::sz(S), S),
            D::cluster(E::lit(xt)),
            D::spatial(E::sz(S), E::lit(1), X),
        ],
    )
}

/// The five Table 3 dataflows, in the paper's order.
pub fn all_styles() -> Vec<Dataflow> {
    vec![c_p(), x_p(), yx_p(), yr_p(), kc_p()]
}

/// Look a style up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Dataflow> {
    match name.to_ascii_lowercase().replace('_', "-").as_str() {
        "c-p" | "cp" => Some(c_p()),
        "x-p" | "xp" => Some(x_p()),
        "yx-p" | "yxp" => Some(yx_p()),
        "yr-p" | "yrp" => Some(yr_p()),
        "kc-p" | "kcp" => Some(kc_p()),
        "row-stationary-fig6" => Some(row_stationary_fig6()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser;
    use crate::model::zoo::vgg16;

    #[test]
    fn all_styles_are_structurally_valid() {
        for df in all_styles() {
            df.validate_structure().unwrap_or_else(|e| panic!("{}: {e}", df.name));
        }
    }

    #[test]
    fn all_styles_resolve_on_vgg16_conv2_at_256_pes() {
        let layer = vgg16::conv2();
        for df in all_styles() {
            let r = df.resolve(&layer, 256).unwrap_or_else(|e| panic!("{}: {e}", df.name));
            assert!(r.addressable_pes() <= 256, "{}", df.name);
        }
    }

    #[test]
    fn styles_roundtrip_through_dsl() {
        for df in all_styles() {
            let text = parser::emit(&df);
            let back = parser::parse_dataflow(&text).unwrap();
            assert_eq!(df, back, "DSL round-trip for {}", df.name);
        }
    }

    #[test]
    fn cluster_structure() {
        // Single-level: C-P, X-P. Two-level: YX-P, YR-P, KC-P.
        assert_eq!(c_p().levels().unwrap().len(), 1);
        assert_eq!(x_p().levels().unwrap().len(), 1);
        assert_eq!(yx_p().levels().unwrap().len(), 2);
        assert_eq!(yr_p().levels().unwrap().len(), 2);
        assert_eq!(kc_p().levels().unwrap().len(), 2);
    }

    #[test]
    fn kc_p_units_at_256() {
        let r = kc_p().resolve(&vgg16::conv13(), 256).unwrap();
        assert_eq!(r.levels[0].units, 4); // 256/64 K-clusters
        assert_eq!(r.levels[1].units, 64); // C-parallel PEs
    }

    #[test]
    fn yr_p_joint_inner_spatial() {
        let r = yr_p().resolve(&vgg16::conv2(), 256).unwrap();
        let inner = &r.levels[1];
        let spatial = inner.spatial_maps();
        assert_eq!(spatial.len(), 2);
        assert_eq!(spatial[0].dim, crate::ir::dims::Dim::Y);
        assert_eq!(spatial[1].dim, crate::ir::dims::Dim::R);
        assert_eq!(inner.units, 3); // Cluster(Sz(R)), R = 3
    }

    #[test]
    fn tileable_forms_at_table3_defaults_match_the_fixed_styles() {
        // The parametric constructors instantiated at the Table 3
        // bindings must be structurally identical to the fixed styles
        // (same fingerprint — names differ, structure must not).
        assert_eq!(kc_p_ct(64).fingerprint(), kc_p().fingerprint());
        assert_eq!(yr_p_ck(2, 2).fingerprint(), yr_p().fingerprint());
        assert_eq!(yx_p_xt(8).fingerprint(), yx_p().fingerprint());
        // And at any other binding they must differ.
        assert_ne!(kc_p_ct(32).fingerprint(), kc_p().fingerprint());
        assert_ne!(yr_p_ck(2, 4).fingerprint(), yr_p().fingerprint());
        assert_ne!(yx_p_xt(16).fingerprint(), yx_p().fingerprint());
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(by_name("kc-p").unwrap().name, "KC-P");
        assert_eq!(by_name("KC_P").unwrap().name, "KC-P");
        assert!(by_name("zz-p").is_none());
    }
}
