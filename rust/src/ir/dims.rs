//! The seven canonical DNN tensor dimensions (paper Figure 1).
//!
//! MAESTRO uses an *input-centric* view: `Y`/`X` index input activation
//! rows/columns; output rows/columns are derived as `Y' = (Y - R)/stride
//! + 1` (§4.1 "it aligns with MAESTRO's input-centric cost model").

use std::fmt;

use anyhow::{bail, Result};

/// A DNN tensor dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dim {
    /// Batch.
    N,
    /// Output channels.
    K,
    /// Input channels.
    C,
    /// Input activation rows.
    Y,
    /// Input activation columns.
    X,
    /// Filter rows.
    R,
    /// Filter columns.
    S,
}

/// All dimensions in canonical order (outermost-first convention used by
/// the default loop nest N → K → C → Y → X → R → S).
pub const ALL_DIMS: [Dim; 7] = [Dim::N, Dim::K, Dim::C, Dim::Y, Dim::X, Dim::R, Dim::S];

impl Dim {
    /// Parse from the DSL's single-letter name.
    pub fn parse(s: &str) -> Result<Dim> {
        Ok(match s.trim() {
            "N" => Dim::N,
            "K" => Dim::K,
            "C" => Dim::C,
            "Y" => Dim::Y,
            "X" => Dim::X,
            "R" => Dim::R,
            "S" => Dim::S,
            // Output-centric aliases: Y'/X' are accepted and normalized to
            // the input-centric Y/X (paper Table 1: "X/Y should be
            // interpreted as X'/Y' as appropriate").
            "Y'" => Dim::Y,
            "X'" => Dim::X,
            other => bail!("unknown dimension '{other}' (expected N,K,C,Y,X,R,S)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dim::N => "N",
            Dim::K => "K",
            Dim::C => "C",
            Dim::Y => "Y",
            Dim::X => "X",
            Dim::R => "R",
            Dim::S => "S",
        }
    }

    /// The sliding-window partner: Y is windowed by R, X by S.
    pub fn window_partner(&self) -> Option<Dim> {
        match self {
            Dim::Y => Some(Dim::R),
            Dim::X => Some(Dim::S),
            _ => None,
        }
    }

    /// True for the filter dims that window an activation dim.
    pub fn is_window(&self) -> bool {
        matches!(self, Dim::R | Dim::S)
    }

    /// Index into `ALL_DIMS` (stable across the codebase; used for dense
    /// per-dimension arrays in the hot engines).
    pub fn index(&self) -> usize {
        match self {
            Dim::N => 0,
            Dim::K => 1,
            Dim::C => 2,
            Dim::Y => 3,
            Dim::X => 4,
            Dim::R => 5,
            Dim::S => 6,
        }
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A dense per-dimension map (one slot per canonical dim). Cheaper and
/// more ergonomic than `HashMap<Dim, T>` in the analysis hot paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DimMap<T> {
    slots: [T; 7],
}

impl<T: Copy + Default> Default for DimMap<T> {
    fn default() -> Self {
        DimMap { slots: [T::default(); 7] }
    }
}

impl<T: Copy> DimMap<T> {
    pub fn filled(value: T) -> Self {
        DimMap { slots: [value; 7] }
    }

    pub fn get(&self, d: Dim) -> T {
        self.slots[d.index()]
    }

    pub fn set(&mut self, d: Dim, v: T) {
        self.slots[d.index()] = v;
    }

    pub fn iter(&self) -> impl Iterator<Item = (Dim, T)> + '_ {
        ALL_DIMS.iter().map(move |&d| (d, self.get(d)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for d in ALL_DIMS {
            assert_eq!(Dim::parse(d.name()).unwrap(), d);
        }
    }

    #[test]
    fn output_aliases_normalize() {
        assert_eq!(Dim::parse("Y'").unwrap(), Dim::Y);
        assert_eq!(Dim::parse("X'").unwrap(), Dim::X);
    }

    #[test]
    fn unknown_dim_errors() {
        assert!(Dim::parse("Z").is_err());
    }

    #[test]
    fn window_partners() {
        assert_eq!(Dim::Y.window_partner(), Some(Dim::R));
        assert_eq!(Dim::X.window_partner(), Some(Dim::S));
        assert_eq!(Dim::K.window_partner(), None);
        assert!(Dim::R.is_window() && Dim::S.is_window());
    }

    #[test]
    fn dimmap_roundtrip() {
        let mut m: DimMap<u64> = DimMap::default();
        for (i, d) in ALL_DIMS.iter().enumerate() {
            m.set(*d, i as u64 * 10);
        }
        for (i, d) in ALL_DIMS.iter().enumerate() {
            assert_eq!(m.get(*d), i as u64 * 10);
        }
        assert_eq!(m.iter().count(), 7);
    }

    #[test]
    fn indices_are_canonical() {
        for (i, d) in ALL_DIMS.iter().enumerate() {
            assert_eq!(d.index(), i);
        }
    }
}
