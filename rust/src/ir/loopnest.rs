//! Compute-centric loop-nest notation (paper §2.5) and its conversion to
//! data-centric directives — the auto-generation path §3.2 envisions
//! ("the data-centric representation could be either auto-generated from
//! a loop nest version of the dataflow ... or manually written").
//!
//! A loop nest is an ordered list of loops, outermost first, each either
//! `for` (temporal) or `parallel_for` (spatial), with a tile size. Tiled
//! dims appear as two loops (outer tile loop + inner intra-tile loop);
//! the conversion collapses the *innermost* occurrence of each dim into a
//! map whose size is the tile extent and whose offset equals the tile
//! step, and inserts `Cluster` boundaries at `parallel_for` transitions
//! below the first spatial loop.

use std::fmt;

use anyhow::{ensure, Result};

use super::dataflow::Dataflow;
use super::dims::Dim;
use super::directive::{Directive, Extent};

/// One loop in a compute-centric nest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Loop {
    pub dim: Dim,
    /// Trip extent of this loop in elements of `dim` (symbolic `Sz` loops
    /// use the full dimension).
    pub extent: Extent,
    /// Step between consecutive iterations (= tile size of loops nested
    /// inside over the same dim, or 1).
    pub step: Extent,
    /// `parallel_for` vs `for`.
    pub parallel: bool,
}

/// A compute-centric schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoopNest {
    pub name: String,
    pub loops: Vec<Loop>,
}

impl LoopNest {
    pub fn new(name: &str, loops: Vec<Loop>) -> LoopNest {
        LoopNest { name: name.into(), loops }
    }

    /// Convert into data-centric directives.
    ///
    /// Each loop becomes a map over its dim with `size = step_of_loop`
    /// interpreted as the chunk handed downward and `offset = step`;
    /// `parallel_for` becomes `SpatialMap`. A run of sequential loops
    /// after a parallel run maps inside the same cluster level; a *new*
    /// parallel run after sequential loops opens a new cluster level via
    /// `Cluster`, whose size the caller supplies per level (hardware
    /// fan-out is not part of the loop nest).
    pub fn to_dataflow(&self, cluster_sizes: &[Extent]) -> Result<Dataflow> {
        ensure!(!self.loops.is_empty(), "loop nest '{}' is empty", self.name);
        let mut directives = Vec::new();
        let mut cluster_iter = cluster_sizes.iter();
        let mut prev_parallel = self.loops[0].parallel;
        let mut seen_sequential_since_parallel = !self.loops[0].parallel;
        for l in &self.loops {
            // A parallel loop appearing after sequential loops (below an
            // earlier parallel loop) starts a nested cluster level.
            if l.parallel && !prev_parallel && seen_sequential_since_parallel && !directives.is_empty()
                && directives.iter().any(|d: &Directive| d.is_spatial())
            {
                let size = cluster_iter
                    .next()
                    .copied()
                    .unwrap_or(Extent::sz(l.dim));
                directives.push(Directive::cluster(size));
            }
            let map = if l.parallel {
                Directive::spatial(l.step, l.step, l.dim)
            } else {
                Directive::temporal(l.step, l.step, l.dim)
            };
            directives.push(map);
            if l.parallel {
                seen_sequential_since_parallel = false;
            } else {
                seen_sequential_since_parallel = true;
            }
            prev_parallel = l.parallel;
        }
        let df = Dataflow::new(&self.name, directives);
        df.validate_structure()?;
        Ok(df)
    }
}

impl fmt::Display for LoopNest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "// loop nest {}", self.name)?;
        for (i, l) in self.loops.iter().enumerate() {
            let kw = if l.parallel { "parallel_for" } else { "for" };
            writeln!(
                f,
                "{:indent$}{kw} {} in 0..{} step {}",
                "",
                l.dim,
                l.extent,
                l.step,
                indent = i * 2
            )?;
        }
        Ok(())
    }
}

/// Parse the textual loop-nest form:
///
/// ```text
/// loopnest os-1d
/// parallel_for X step 1
/// for S step 1
/// ```
pub fn parse(text: &str) -> Result<LoopNest> {
    let mut name = String::from("unnamed");
    let mut loops = Vec::new();
    for raw in text.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("loopnest ") {
            name = rest.trim().into();
            continue;
        }
        let (parallel, rest) = if let Some(r) = line.strip_prefix("parallel_for ") {
            (true, r)
        } else if let Some(r) = line.strip_prefix("for ") {
            (false, r)
        } else {
            anyhow::bail!("loop nest line not understood: '{line}'");
        };
        let toks: Vec<&str> = rest.split_whitespace().collect();
        ensure!(
            toks.len() == 3 && toks[1] == "step",
            "expected '<dim> step <n>': '{line}'"
        );
        let dim = Dim::parse(toks[0])?;
        let step = super::parser::parse_extent(toks[2])?;
        loops.push(Loop { dim, extent: Extent::sz(dim), step, parallel });
    }
    ensure!(!loops.is_empty(), "loop nest has no loops");
    Ok(LoopNest::new(&name, loops))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_output_stationary() {
        // Figure 4(b): parallel over X' chunks, temporal over S.
        let nest = LoopNest::new(
            "os-1d",
            vec![
                Loop { dim: Dim::X, extent: Extent::sz(Dim::X), step: Extent::lit(2), parallel: true },
                Loop { dim: Dim::S, extent: Extent::sz(Dim::S), step: Extent::lit(3), parallel: false },
            ],
        );
        let df = nest.to_dataflow(&[]).unwrap();
        assert_eq!(df.directives.len(), 2);
        assert_eq!(df.directives[0], Directive::spatial(Extent::lit(2), Extent::lit(2), Dim::X));
        assert_eq!(df.directives[1], Directive::temporal(Extent::lit(3), Extent::lit(3), Dim::S));
    }

    #[test]
    fn nested_parallel_inserts_cluster() {
        let nest = LoopNest::new(
            "two-level",
            vec![
                Loop { dim: Dim::K, extent: Extent::sz(Dim::K), step: Extent::lit(1), parallel: true },
                Loop { dim: Dim::C, extent: Extent::sz(Dim::C), step: Extent::lit(64), parallel: false },
                Loop { dim: Dim::C, extent: Extent::lit(64), step: Extent::lit(1), parallel: true },
            ],
        );
        let df = nest.to_dataflow(&[Extent::lit(64)]).unwrap();
        assert!(df.directives.iter().any(|d| d.is_cluster()));
        // Structure: SpatialMap K; TemporalMap C; Cluster(64); SpatialMap C.
        assert_eq!(df.directives.len(), 4);
    }

    #[test]
    fn parse_text_form() {
        let nest = parse("loopnest ws\nfor K step 1\nparallel_for X step 2\nfor S step 3\n").unwrap();
        assert_eq!(nest.name, "ws");
        assert_eq!(nest.loops.len(), 3);
        assert!(nest.loops[1].parallel);
        let df = nest.to_dataflow(&[]).unwrap();
        assert_eq!(df.directives.len(), 3);
    }

    #[test]
    fn display_renders_nest() {
        let nest = parse("loopnest x\nfor K step 1\n").unwrap();
        assert!(nest.to_string().contains("for K in 0..Sz(K) step 1"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("loopnest x\nwhile K step 1\n").is_err());
        assert!(parse("loopnest x\nfor K by 1\n").is_err());
        assert!(parse("loopnest empty\n").is_err());
    }
}
