//! Text format for dataflows — a MAESTRO-style DSL (parse + emit).
//!
//! ```text
//! Dataflow kc-p {
//!   SpatialMap(1,1) K;
//!   TemporalMap(64,64) C;
//!   TemporalMap(Sz(R),1) Y;
//!   TemporalMap(8+Sz(S)-1,8) X;   # arithmetic over Sz() is allowed
//!   Cluster(64);
//!   SpatialMap(1,1) C;
//! }
//! ```
//!
//! Extents are integer expressions over literals and at most one `Sz(dim)`
//! term (Table 3's `8+Sz(S)-1`). `#` or `//` start comments. Several
//! dataflow blocks may appear in one file.

use anyhow::{bail, ensure, Context, Result};

use super::dataflow::Dataflow;
use super::dims::Dim;
use super::directive::{Directive, Extent};

/// Parse every `Dataflow name { ... }` block in `text`.
pub fn parse_dataflows(text: &str) -> Result<Vec<Dataflow>> {
    let clean = strip_comments(text);
    let mut out = Vec::new();
    let mut rest = clean.as_str();
    loop {
        let Some(start) = rest.find("Dataflow") else { break };
        let after = &rest[start + "Dataflow".len()..];
        let open = after.find('{').context("Dataflow: missing '{'")?;
        let name = after[..open].trim().to_string();
        ensure!(!name.is_empty(), "Dataflow block without a name");
        let body_start = open + 1;
        let close = after[body_start..]
            .find('}')
            .with_context(|| format!("Dataflow {name}: missing '}}'"))?;
        let body = &after[body_start..body_start + close];
        let directives = parse_directives(body).with_context(|| format!("in dataflow '{name}'"))?;
        let df = Dataflow::new(&name, directives);
        df.validate_structure()?;
        out.push(df);
        rest = &after[body_start + close + 1..];
    }
    ensure!(!out.is_empty(), "no 'Dataflow name {{ ... }}' blocks found");
    Ok(out)
}

/// Parse a single dataflow (first block in the text).
pub fn parse_dataflow(text: &str) -> Result<Dataflow> {
    Ok(parse_dataflows(text)?.remove(0))
}

/// Emit the DSL text for a dataflow (round-trips through the parser).
pub fn emit(df: &Dataflow) -> String {
    let mut s = format!("Dataflow {} {{\n", df.name);
    for d in &df.directives {
        s.push_str(&format!("  {d};\n"));
    }
    s.push_str("}\n");
    s
}

fn strip_comments(text: &str) -> String {
    text.lines()
        .map(|l| {
            let l = l.split('#').next().unwrap_or("");
            l.split("//").next().unwrap_or("")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

fn parse_directives(body: &str) -> Result<Vec<Directive>> {
    let mut out = Vec::new();
    for stmt in body.split(';') {
        let stmt = stmt.trim();
        if stmt.is_empty() {
            continue;
        }
        out.push(parse_directive(stmt)?);
    }
    ensure!(!out.is_empty(), "empty dataflow body");
    Ok(out)
}

fn parse_directive(stmt: &str) -> Result<Directive> {
    let (head, rest) = stmt
        .split_once('(')
        .with_context(|| format!("directive '{stmt}': missing '('"))?;
    let close = rest
        .rfind(')')
        .with_context(|| format!("directive '{stmt}': missing ')'"))?;
    let args = &rest[..close];
    let tail = rest[close + 1..].trim();
    match head.trim() {
        "Cluster" => {
            ensure!(tail.is_empty(), "Cluster takes no dimension: '{stmt}'");
            Ok(Directive::cluster(parse_extent(args)?))
        }
        kind @ ("SpatialMap" | "TemporalMap") => {
            let (a, b) = split_top_level_comma(args)
                .with_context(|| format!("directive '{stmt}': expected (size, offset)"))?;
            let size = parse_extent(&a)?;
            let offset = parse_extent(&b)?;
            let dim = Dim::parse(tail)
                .with_context(|| format!("directive '{stmt}': bad dimension"))?;
            Ok(if kind == "SpatialMap" {
                Directive::spatial(size, offset, dim)
            } else {
                Directive::temporal(size, offset, dim)
            })
        }
        other => bail!("unknown directive '{other}' in '{stmt}'"),
    }
}

/// Split "a, b" at the comma that is not inside `Sz(...)` parens.
fn split_top_level_comma(args: &str) -> Result<(String, String)> {
    let mut depth = 0i32;
    for (i, ch) in args.char_indices() {
        match ch {
            '(' => depth += 1,
            ')' => depth -= 1,
            ',' if depth == 0 => {
                return Ok((args[..i].to_string(), args[i + 1..].to_string()));
            }
            _ => {}
        }
    }
    bail!("expected two comma-separated extents in '({args})'")
}

/// Parse an extent expression: `±term ± term ...` where a term is an
/// integer literal or `Sz(dim)`. At most one `Sz` term.
pub fn parse_extent(expr: &str) -> Result<Extent> {
    let expr = expr.trim();
    ensure!(!expr.is_empty(), "empty extent");
    let mut lit: i64 = 0;
    let mut sz_dim: Option<Dim> = None;
    // Tokenize into signed terms.
    let mut rest = expr;
    let mut sign = 1i64;
    while !rest.is_empty() {
        rest = rest.trim_start();
        if let Some(r) = rest.strip_prefix('+') {
            sign = 1;
            rest = r;
            continue;
        }
        if let Some(r) = rest.strip_prefix('-') {
            sign = -1;
            rest = r;
            continue;
        }
        if let Some(r) = rest.strip_prefix("Sz(") {
            let close = r.find(')').with_context(|| format!("extent '{expr}': Sz missing ')'"))?;
            let dim = Dim::parse(&r[..close])?;
            ensure!(sign == 1, "extent '{expr}': negative Sz() term unsupported");
            ensure!(sz_dim.is_none(), "extent '{expr}': at most one Sz() term");
            sz_dim = Some(dim);
            rest = &r[close + 1..];
            sign = 1;
            continue;
        }
        // Integer literal.
        let end = rest
            .char_indices()
            .take_while(|(_, c)| c.is_ascii_digit())
            .map(|(i, c)| i + c.len_utf8())
            .last()
            .with_context(|| format!("extent '{expr}': expected number or Sz(dim) at '{rest}'"))?;
        let v: i64 = rest[..end].parse()?;
        lit += sign * v;
        rest = &rest[end..];
        sign = 1;
    }
    Ok(match sz_dim {
        Some(dim) => Extent::sz_plus(dim, lit),
        None => {
            ensure!(lit > 0, "extent '{expr}' must be positive (got {lit})");
            Extent::lit(lit as u64)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const KC_P: &str = "
# NVDLA-like
Dataflow kc-p {
  SpatialMap(1,1) K;
  TemporalMap(64,64) C;
  TemporalMap(Sz(R),Sz(R)) R;
  TemporalMap(Sz(S),Sz(S)) S;
  TemporalMap(Sz(R),1) Y;
  TemporalMap(Sz(S),1) X;
  Cluster(64);
  SpatialMap(1,1) C;
}";

    #[test]
    fn parse_kc_p() {
        let df = parse_dataflow(KC_P).unwrap();
        assert_eq!(df.name, "kc-p");
        assert_eq!(df.directives.len(), 8);
        assert!(df.directives[6].is_cluster());
    }

    #[test]
    fn roundtrip_through_emit() {
        let df = parse_dataflow(KC_P).unwrap();
        let df2 = parse_dataflow(&emit(&df)).unwrap();
        assert_eq!(df, df2);
    }

    #[test]
    fn extent_arithmetic() {
        use crate::ir::dims::Dim;
        assert_eq!(parse_extent("8").unwrap(), Extent::lit(8));
        assert_eq!(parse_extent("Sz(R)").unwrap(), Extent::sz(Dim::R));
        assert_eq!(parse_extent("8+Sz(S)-1").unwrap(), Extent::sz_plus(Dim::S, 7));
        assert_eq!(parse_extent(" Sz(S) - 1 ").unwrap(), Extent::sz_plus(Dim::S, -1));
        assert!(parse_extent("Sz(R)+Sz(S)").is_err());
        assert!(parse_extent("0").is_err());
        assert!(parse_extent("q").is_err());
    }

    #[test]
    fn yx_p_windowed_extent() {
        let df = parse_dataflow(
            "Dataflow yx {
               SpatialMap(Sz(R),1) Y;
               TemporalMap(8+Sz(S)-1,8) X;
               Cluster(8);
               SpatialMap(Sz(S),1) X;
             }",
        )
        .unwrap();
        assert_eq!(df.directives.len(), 4);
    }

    #[test]
    fn multiple_blocks() {
        let text = format!("{KC_P}\nDataflow other {{ SpatialMap(1,1) K; }}");
        let dfs = parse_dataflows(&text).unwrap();
        assert_eq!(dfs.len(), 2);
        assert_eq!(dfs[1].name, "other");
    }

    #[test]
    fn errors_are_informative() {
        assert!(parse_dataflow("Dataflow x { Blorp(1,1) K; }").is_err());
        assert!(parse_dataflow("Dataflow x { SpatialMap(1) K; }").is_err());
        assert!(parse_dataflow("no blocks here").is_err());
        assert!(parse_dataflow("Dataflow x { Cluster(4) K; }").is_err());
    }
}
