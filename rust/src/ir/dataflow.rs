//! A dataflow: an ordered list of directives, split into cluster levels,
//! with validation and resolution against a concrete layer.
//!
//! Semantics (DESIGN.md §6.2, derived from paper §3):
//!
//! * Directives are listed outermost-first. `Cluster(n)` closes the
//!   current level; directives above it map across the logical clusters it
//!   creates, directives below map within one cluster.
//! * Level 0 distributes across `⌊PEs / Π cluster_sizes⌋` top-level
//!   clusters; level `i ≥ 1` across `cluster_size_i` sub-units.
//! * Each level receives a *parent tile* per dimension (level 0: the full
//!   layer). `Sz(d)` extents resolve against the parent tile, so the same
//!   dataflow text adapts to any layer — the paper's dataflow-vs-mapping
//!   distinction.
//! * Dimensions a level does not mention are auto-augmented as fully
//!   unrolled `TemporalMap(tile, tile)` (the paper's cluster analysis
//!   engine "augment[s] the given dataflow descriptions for missing
//!   directives").
//! * Consecutive `SpatialMap`s within one level distribute **jointly**:
//!   the same sub-cluster index drives both dims (the Eyeriss diagonal of
//!   Fig 6 — `SpatialMap(1,1) Y; SpatialMap(1,1) R`).

use std::fmt;

use anyhow::{ensure, Context, Result};

use super::dims::{Dim, DimMap, ALL_DIMS};
use super::directive::{Directive, Extent, ResolvedMap};
use crate::model::layer::Layer;

/// A dataflow description: named, ordered directives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Dataflow {
    pub name: String,
    pub directives: Vec<Directive>,
}

/// One cluster level of a dataflow, before resolution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LevelSpec {
    /// Maps in data-movement order (outermost first).
    pub maps: Vec<Directive>,
    /// Size of the cluster created *below* this level (None for the
    /// innermost level, whose units are PEs).
    pub cluster_below: Option<Extent>,
}

/// A fully resolved cluster level for a specific layer + PE count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResolvedLevel {
    /// Number of parallel sub-units at this level (clusters or PEs).
    pub units: u64,
    /// Maps in order, outermost first. Every canonical dim appears
    /// exactly once (auto-augmented maps included).
    pub maps: Vec<ResolvedMap>,
    /// The per-dimension tile this level hands each sub-unit per step
    /// (= resolved map size, clamped to the parent tile).
    pub tile: DimMap<u64>,
    /// The parent tile this level iterates over.
    pub parent_tile: DimMap<u64>,
}

impl ResolvedLevel {
    /// The spatial maps of this level (jointly distributed).
    pub fn spatial_maps(&self) -> Vec<ResolvedMap> {
        self.maps.iter().copied().filter(|m| m.spatial).collect()
    }

    /// Temporal maps, outermost first.
    pub fn temporal_maps(&self) -> Vec<ResolvedMap> {
        self.maps.iter().copied().filter(|m| !m.spatial).collect()
    }

    /// The map for a given dim (always present after augmentation).
    pub fn map_of(&self, d: Dim) -> ResolvedMap {
        self.maps
            .iter()
            .copied()
            .find(|m| m.dim == d)
            .expect("augmented level must contain every dim")
    }
}

/// A dataflow resolved against (layer, total PEs): one [`ResolvedLevel`]
/// per cluster level, outermost first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResolvedDataflow {
    pub name: String,
    pub levels: Vec<ResolvedLevel>,
}

impl ResolvedDataflow {
    /// Total PEs actually addressable by the resolved hierarchy
    /// (Π units over levels).
    pub fn addressable_pes(&self) -> u64 {
        self.levels.iter().map(|l| l.units).product()
    }
}

impl Dataflow {
    pub fn new(name: &str, directives: Vec<Directive>) -> Dataflow {
        Dataflow { name: name.to_string(), directives }
    }

    /// Structural identity of this dataflow: a stable hash over the
    /// ordered directive list, ignoring the name. This — not the name —
    /// is what every analysis cache keys on, so hand-built dataflows
    /// that share a name but differ in structure cannot alias (see
    /// `cache::key`).
    pub fn fingerprint(&self) -> crate::cache::DataflowFingerprint {
        crate::cache::DataflowFingerprint::of(self)
    }

    /// Split the directive list into cluster levels.
    pub fn levels(&self) -> Result<Vec<LevelSpec>> {
        let mut levels = Vec::new();
        let mut current = Vec::new();
        for d in &self.directives {
            match d {
                Directive::Cluster { size } => {
                    ensure!(
                        !current.is_empty(),
                        "dataflow '{}': Cluster directive with no maps above it",
                        self.name
                    );
                    levels.push(LevelSpec { maps: current, cluster_below: Some(*size) });
                    current = Vec::new();
                }
                other => current.push(other.clone()),
            }
        }
        ensure!(
            !current.is_empty(),
            "dataflow '{}': trailing Cluster directive with no maps below it",
            self.name
        );
        levels.push(LevelSpec { maps: current, cluster_below: None });
        Ok(levels)
    }

    /// Structural validation that does not need a layer: each level maps
    /// each dim at most once; map directives only; at least one spatial or
    /// temporal map per level.
    pub fn validate_structure(&self) -> Result<()> {
        for (li, level) in self.levels()?.iter().enumerate() {
            let mut seen: Vec<Dim> = Vec::new();
            for m in &level.maps {
                let d = m
                    .dim()
                    .with_context(|| format!("dataflow '{}': non-map directive inside level {li}", self.name))?;
                ensure!(
                    !seen.contains(&d),
                    "dataflow '{}': dim {d} mapped twice in level {li}",
                    self.name
                );
                seen.push(d);
            }
            // Spatial maps must be consecutive (joint distribution shares
            // one sub-cluster index; interleaving with temporal maps would
            // be ambiguous).
            let spatial_idx: Vec<usize> = level
                .maps
                .iter()
                .enumerate()
                .filter(|(_, m)| m.is_spatial())
                .map(|(i, _)| i)
                .collect();
            for w in spatial_idx.windows(2) {
                ensure!(
                    w[1] == w[0] + 1,
                    "dataflow '{}': spatial maps in level {li} must be consecutive (joint distribution)",
                    self.name
                );
            }
        }
        Ok(())
    }

    /// Resolve against a layer and a total PE count, producing concrete
    /// per-level maps, tiles and unit counts. Also validates coverage
    /// (every output element is produced by some step) and PE divisibility.
    pub fn resolve(&self, layer: &Layer, total_pes: u64) -> Result<ResolvedDataflow> {
        self.validate_structure()?;
        ensure!(total_pes > 0, "resolve: total_pes must be > 0");
        let specs = self.levels()?;

        // --- Unit counts per level ------------------------------------
        // Cluster extents resolve against the *layer* (Table 3 uses
        // Cluster(Sz(R))); level-0 units = floor(P / product(cluster sizes)).
        let layer_dim = |d: Dim| layer.dim(d);
        let mut cluster_sizes = Vec::new();
        for spec in &specs {
            if let Some(ext) = &spec.cluster_below {
                let sz = ext.resolve(&layer_dim)?;
                ensure!(sz > 0, "dataflow '{}': Cluster size resolved to 0", self.name);
                cluster_sizes.push(sz);
            }
        }
        let inner_product: u64 = cluster_sizes.iter().product();
        ensure!(
            inner_product <= total_pes,
            "dataflow '{}': cluster sizes (product {inner_product}) exceed total PEs {total_pes}",
            self.name
        );
        let mut units_per_level = vec![(total_pes / inner_product).max(1)];
        units_per_level.extend(cluster_sizes.iter().copied());

        // --- Per-level resolution --------------------------------------
        let mut parent_tile: DimMap<u64> = DimMap::default();
        for d in ALL_DIMS {
            parent_tile.set(d, layer.dim(d));
        }
        let mut levels = Vec::new();
        for (li, spec) in specs.iter().enumerate() {
            let level = resolve_level(
                &self.name,
                li,
                spec,
                &parent_tile,
                units_per_level[li],
                layer,
            )?;
            parent_tile = level.tile;
            levels.push(level);
        }

        let resolved = ResolvedDataflow { name: self.name.clone(), levels };
        validate_coverage(&resolved, layer).with_context(|| {
            format!("dataflow '{}' fails coverage validation on layer '{}'", self.name, layer.name)
        })?;
        Ok(resolved)
    }
}

impl fmt::Display for Dataflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Dataflow {} {{", self.name)?;
        for d in &self.directives {
            writeln!(f, "  {d};")?;
        }
        write!(f, "}}")
    }
}

/// Resolve one level: concrete extents, stride handling, augmentation of
/// missing dims as fully-unrolled temporal maps.
fn resolve_level(
    name: &str,
    li: usize,
    spec: &LevelSpec,
    parent_tile: &DimMap<u64>,
    units: u64,
    _layer: &Layer,
) -> Result<ResolvedLevel> {
    let parent = |d: Dim| parent_tile.get(d);
    let mut maps: Vec<ResolvedMap> = Vec::new();
    for m in &spec.maps {
        let (size_ext, offset_ext, dim, spatial) = match m {
            Directive::SpatialMap { size, offset, dim } => (size, offset, *dim, true),
            Directive::TemporalMap { size, offset, dim } => (size, offset, *dim, false),
            Directive::Cluster { .. } => unreachable!("validated earlier"),
        };
        let total = parent(dim);
        let size = size_ext.resolve(&parent)?.min(total.max(1)).max(1);
        let offset = offset_ext.resolve(&parent)?;
        ensure!(size > 0, "dataflow '{name}': level {li} {dim} map size 0");
        ensure!(offset > 0, "dataflow '{name}': level {li} {dim} map offset 0");
        // Stride handling happens in the schedule builder (the cluster
        // analysis engine "augments the given dataflow descriptions for
        // ... stride handling"): windowed offsets are derived from the
        // window geometry there, so user offsets stay untouched here.
        maps.push(ResolvedMap { dim, size, offset, spatial });
    }

    // Augment missing dims as fully-unrolled temporal maps, appended at
    // the innermost position in canonical order. A fully-unrolled map has
    // exactly one step, so its position among other unrolled maps does
    // not affect the schedule; placing them innermost matches MAESTRO's
    // convention (Fig 6 directives "with asterisks").
    for d in ALL_DIMS {
        if !maps.iter().any(|m| m.dim == d) {
            let t = parent(d).max(1);
            maps.push(ResolvedMap { dim: d, size: t, offset: t, spatial: false });
        }
    }

    // The tile handed to each sub-unit per step = map size per dim.
    let mut tile: DimMap<u64> = DimMap::default();
    for m in &maps {
        tile.set(m.dim, m.size);
    }

    Ok(ResolvedLevel { units, maps, tile, parent_tile: *parent_tile, })
}

/// Coverage validation: every map must cover its parent-tile extent
/// without skipping indices a downstream consumer needs.
///
/// * Non-windowed dims: consecutive positions must not leave gaps
///   (`offset ≤ size`).
/// * Windowed activation dims (Y with R below, X with S below): output
///   positions must be contiguous (`offset ≤ size − window + 1`, where
///   `window` is the parent R/S tile iterated at or below this level),
///   scaled by stride.
fn validate_coverage(rdf: &ResolvedDataflow, layer: &Layer) -> Result<()> {
    for (li, level) in rdf.levels.iter().enumerate() {
        for m in &level.maps {
            let total = level.parent_tile.get(m.dim);
            if m.size >= total {
                continue; // single position, trivially covered
            }
            let window = match m.dim.window_partner() {
                Some(w) if layer.windowed(m.dim) => level.parent_tile.get(w).min(m.size),
                _ => 1,
            };
            // Windowed dims: a position covers (size - window + 1)
            // output steps, so a larger offset skips outputs. (Stride is
            // applied in the schedule builder; user offsets are in
            // output steps.)
            let max_gapless = (m.size - window + 1).max(1);
            ensure!(
                m.offset <= max_gapless,
                "level {li}: {m} skips data over extent {total} (offset {} > max gapless step {max_gapless})",
                m.offset
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::Layer;

    fn conv_layer() -> Layer {
        Layer::conv2d("t", 1, 16, 8, 10, 10, 3, 3, 1)
    }

    fn df_simple() -> Dataflow {
        // Output-stationary 1D-ish: spatial over K, temporal over C.
        Dataflow::new(
            "simple",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::temporal(Extent::lit(1), Extent::lit(1), Dim::C),
                Directive::temporal(Extent::sz(Dim::R), Extent::lit(1), Dim::Y),
                Directive::temporal(Extent::sz(Dim::S), Extent::lit(1), Dim::X),
            ],
        )
    }

    #[test]
    fn levels_split_on_cluster() {
        let df = Dataflow::new(
            "two-level",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::cluster(Extent::lit(4)),
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::C),
            ],
        );
        let levels = df.levels().unwrap();
        assert_eq!(levels.len(), 2);
        assert!(levels[0].cluster_below.is_some());
        assert!(levels[1].cluster_below.is_none());
    }

    #[test]
    fn trailing_cluster_rejected() {
        let df = Dataflow::new(
            "bad",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::cluster(Extent::lit(4)),
            ],
        );
        assert!(df.levels().is_err());
    }

    #[test]
    fn duplicate_dim_rejected() {
        let df = Dataflow::new(
            "dup",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::temporal(Extent::lit(1), Extent::lit(1), Dim::K),
            ],
        );
        assert!(df.validate_structure().is_err());
    }

    #[test]
    fn nonconsecutive_spatial_rejected() {
        let df = Dataflow::new(
            "split-spatial",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::Y),
                Directive::temporal(Extent::lit(1), Extent::lit(1), Dim::C),
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::R),
            ],
        );
        assert!(df.validate_structure().is_err());
    }

    #[test]
    fn resolve_augments_missing_dims() {
        let layer = conv_layer();
        let r = df_simple().resolve(&layer, 8).unwrap();
        assert_eq!(r.levels.len(), 1);
        let level = &r.levels[0];
        // All 7 dims present after augmentation.
        assert_eq!(level.maps.len(), 7);
        // N, R, S were missing: fully unrolled.
        assert_eq!(level.map_of(Dim::R).size, 3);
        assert_eq!(level.map_of(Dim::N).size, 1);
        assert_eq!(level.units, 8);
    }

    #[test]
    fn resolve_two_level_units() {
        let layer = conv_layer();
        let df = Dataflow::new(
            "kc",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::temporal(Extent::sz(Dim::R), Extent::lit(1), Dim::Y),
                Directive::temporal(Extent::sz(Dim::S), Extent::lit(1), Dim::X),
                Directive::cluster(Extent::lit(4)),
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::C),
            ],
        );
        let r = df.resolve(&layer, 64).unwrap();
        assert_eq!(r.levels.len(), 2);
        assert_eq!(r.levels[0].units, 16); // 64 / 4
        assert_eq!(r.levels[1].units, 4);
        assert_eq!(r.addressable_pes(), 64);
        // Inner level parent tile: C tile from level 0 = full C (augmented).
        assert_eq!(r.levels[1].parent_tile.get(Dim::C), 8);
    }

    #[test]
    fn cluster_larger_than_pes_rejected() {
        let layer = conv_layer();
        let df = Dataflow::new(
            "big-cluster",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::cluster(Extent::lit(128)),
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::C),
            ],
        );
        assert!(df.resolve(&layer, 64).is_err());
    }

    #[test]
    fn coverage_rejects_gapping_offset() {
        let layer = conv_layer();
        // Y window of 3 (R=3) but offset 4: output rows skipped.
        let df = Dataflow::new(
            "gappy",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::temporal(Extent::sz(Dim::R), Extent::lit(4), Dim::Y),
            ],
        );
        assert!(df.resolve(&layer, 8).is_err());
    }

    #[test]
    fn stride_kept_for_schedule_builder() {
        let layer = Layer::conv2d("s2", 1, 16, 8, 11, 11, 3, 3, 2);
        let df = Dataflow::new(
            "win",
            vec![
                Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K),
                Directive::temporal(Extent::sz(Dim::R), Extent::lit(1), Dim::Y),
                Directive::temporal(Extent::sz(Dim::S), Extent::lit(1), Dim::X),
            ],
        );
        let r = df.resolve(&layer, 8).unwrap();
        // Resolution keeps the user's slide offset; the schedule builder
        // derives the stride-aware step (engine::mapping tests cover it).
        assert_eq!(r.levels[0].map_of(Dim::Y).offset, 1);
        assert_eq!(r.levels[0].map_of(Dim::Y).size, 3);
    }

    #[test]
    fn display_roundtrips_shape() {
        let s = df_simple().to_string();
        assert!(s.contains("SpatialMap(1,1) K"));
        assert!(s.contains("TemporalMap(Sz(R),1) Y"));
    }
}
