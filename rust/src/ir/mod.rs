//! The data-centric dataflow intermediate representation (paper §3).
//!
//! * [`dims`] — the seven canonical DNN dimensions (N, K, C, Y, X, R, S).
//! * [`directive`] — `SpatialMap`, `TemporalMap` and `Cluster` directives.
//! * [`dataflow`] — an ordered directive list with validation,
//!   canonicalization, and per-cluster-level splitting.
//! * [`parser`] — the MAESTRO-style DSL text format (parse + emit).
//! * [`loopnest`] — the compute-centric loop-nest notation of §2.5 and its
//!   conversion into data-centric directives (§3.2 envisions exactly this
//!   auto-generation path).
//! * [`styles`] — the five evaluation dataflows of Table 3 (C-P, X-P,
//!   YX-P, YR-P, KC-P) plus the Fig 6 row-stationary example.

pub mod dataflow;
pub mod dims;
pub mod directive;
pub mod loopnest;
pub mod parser;
pub mod styles;
