//! The data-centric directives (paper §3.1): `SpatialMap(size, offset) d`,
//! `TemporalMap(size, offset) d`, and `Cluster(n)`.
//!
//! Sizes may be *symbolic* (`Sz(R)` in Table 3): they resolve against a
//! concrete layer's dimension sizes at analysis time, which is exactly the
//! paper's dataflow-vs-mapping distinction (§2.4 — schedules that differ
//! only in concrete bounds are instances of the same dataflow).

use std::fmt;

use anyhow::{bail, Result};

use super::dims::Dim;
use crate::util::stablehash::Fnv128;

/// A map size/offset that is either a literal or a reference to a layer
/// dimension's full size (`Sz(R)`), optionally with an additive adjustment
/// (Table 3 YX-P uses `8 + Sz(S) - 1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Extent {
    /// A literal count.
    Lit(u64),
    /// `Sz(dim) + adjust` — resolved against the layer at analysis time.
    /// `adjust` may be negative (e.g. `Sz(S) - 1`).
    SzOf { dim: Dim, adjust: i64 },
}

impl Extent {
    pub fn lit(v: u64) -> Extent {
        Extent::Lit(v)
    }

    pub fn sz(dim: Dim) -> Extent {
        Extent::SzOf { dim, adjust: 0 }
    }

    pub fn sz_plus(dim: Dim, adjust: i64) -> Extent {
        Extent::SzOf { dim, adjust }
    }

    /// Feed this extent's structure into a dataflow fingerprint hash
    /// (see `cache::key`). Tag-prefixed and fixed-width per variant, so
    /// `Lit(3)` and `Sz(R)` hash apart even when they would resolve to
    /// the same count on some layer — they adapt differently elsewhere.
    pub fn fingerprint_into(&self, h: &mut Fnv128) {
        match *self {
            Extent::Lit(v) => {
                h.write_u8(0);
                h.write_u64(v);
            }
            Extent::SzOf { dim, adjust } => {
                h.write_u8(1);
                h.write_u8(dim.index() as u8);
                h.write_i64(adjust);
            }
        }
    }

    /// Resolve against a layer-dimension lookup.
    pub fn resolve(&self, dim_size: &dyn Fn(Dim) -> u64) -> Result<u64> {
        match *self {
            Extent::Lit(v) => Ok(v),
            Extent::SzOf { dim, adjust } => {
                let base = dim_size(dim) as i64 + adjust;
                if base <= 0 {
                    bail!("extent Sz({dim}){adjust:+} resolved to non-positive {base}");
                }
                Ok(base as u64)
            }
        }
    }
}

impl fmt::Display for Extent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Extent::Lit(v) => write!(f, "{v}"),
            Extent::SzOf { dim, adjust } if adjust == 0 => write!(f, "Sz({dim})"),
            Extent::SzOf { dim, adjust } => write!(f, "Sz({dim}){adjust:+}"),
        }
    }
}

/// One dataflow directive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Directive {
    /// Distribute `dim` across sub-clusters: sub-cluster `p` covers
    /// indices `[p*offset, p*offset + size)` (folding over time when
    /// sub-clusters run out — §3.2).
    SpatialMap { size: Extent, offset: Extent, dim: Dim },
    /// Distribute `dim` across time steps within each sub-cluster; all
    /// sub-clusters see identical indices per step.
    TemporalMap { size: Extent, offset: Extent, dim: Dim },
    /// Close the current cluster level: group the units below into
    /// logical clusters of `size` (§3.2 "PE clustering").
    Cluster { size: Extent },
}

impl Directive {
    pub fn spatial(size: Extent, offset: Extent, dim: Dim) -> Directive {
        Directive::SpatialMap { size, offset, dim }
    }

    pub fn temporal(size: Extent, offset: Extent, dim: Dim) -> Directive {
        Directive::TemporalMap { size, offset, dim }
    }

    pub fn cluster(size: Extent) -> Directive {
        Directive::Cluster { size }
    }

    /// Feed this directive's structure into a dataflow fingerprint
    /// hash: kind tag, mapped dim, then the size/offset extents
    /// (cluster directives contribute their size, so cluster structure
    /// is part of the fingerprint).
    pub fn fingerprint_into(&self, h: &mut Fnv128) {
        match self {
            Directive::SpatialMap { size, offset, dim } => {
                h.write_u8(1);
                h.write_u8(dim.index() as u8);
                size.fingerprint_into(h);
                offset.fingerprint_into(h);
            }
            Directive::TemporalMap { size, offset, dim } => {
                h.write_u8(2);
                h.write_u8(dim.index() as u8);
                size.fingerprint_into(h);
                offset.fingerprint_into(h);
            }
            Directive::Cluster { size } => {
                h.write_u8(3);
                size.fingerprint_into(h);
            }
        }
    }

    /// The mapped dimension, if this is a map directive.
    pub fn dim(&self) -> Option<Dim> {
        match self {
            Directive::SpatialMap { dim, .. } | Directive::TemporalMap { dim, .. } => Some(*dim),
            Directive::Cluster { .. } => None,
        }
    }

    pub fn is_spatial(&self) -> bool {
        matches!(self, Directive::SpatialMap { .. })
    }

    pub fn is_temporal(&self) -> bool {
        matches!(self, Directive::TemporalMap { .. })
    }

    pub fn is_cluster(&self) -> bool {
        matches!(self, Directive::Cluster { .. })
    }
}

impl fmt::Display for Directive {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Directive::SpatialMap { size, offset, dim } => {
                write!(f, "SpatialMap({size},{offset}) {dim}")
            }
            Directive::TemporalMap { size, offset, dim } => {
                write!(f, "TemporalMap({size},{offset}) {dim}")
            }
            Directive::Cluster { size } => write!(f, "Cluster({size})"),
        }
    }
}

/// A map directive with its extents resolved to concrete counts for a
/// specific layer. This is what the analysis engines operate on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResolvedMap {
    pub dim: Dim,
    pub size: u64,
    pub offset: u64,
    pub spatial: bool,
}

impl ResolvedMap {
    /// Number of map positions needed to cover a dimension of extent
    /// `total`: full positions first, plus one partial *edge* position if
    /// the tail is not covered. Matches §6.2 in DESIGN.md.
    pub fn positions(&self, total: u64) -> MapPositions {
        let size = self.size.min(total);
        if size >= total {
            return MapPositions { full: 1, edge_size: 0 };
        }
        // Positions whose window [p*offset, p*offset+size) fits entirely.
        let full = (total - size) / self.offset + 1;
        let covered = (full - 1) * self.offset + size;
        let edge = total.saturating_sub(covered);
        MapPositions { full, edge_size: edge.min(size) }
    }
}

/// Coverage of a dimension by a map: `full` complete positions and an
/// optional trailing partial position of `edge_size` indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MapPositions {
    pub full: u64,
    pub edge_size: u64,
}

impl MapPositions {
    pub fn total(&self) -> u64 {
        self.full + if self.edge_size > 0 { 1 } else { 0 }
    }
}

impl fmt::Display for ResolvedMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let kind = if self.spatial { "SpatialMap" } else { "TemporalMap" };
        write!(f, "{kind}({},{}) {}", self.size, self.offset, self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dim6(_d: Dim) -> u64 {
        6
    }

    #[test]
    fn extent_resolution() {
        assert_eq!(Extent::lit(4).resolve(&dim6).unwrap(), 4);
        assert_eq!(Extent::sz(Dim::R).resolve(&dim6).unwrap(), 6);
        assert_eq!(Extent::sz_plus(Dim::S, -1).resolve(&dim6).unwrap(), 5);
        assert!(Extent::sz_plus(Dim::S, -6).resolve(&dim6).is_err());
    }

    #[test]
    fn extent_display() {
        assert_eq!(Extent::lit(3).to_string(), "3");
        assert_eq!(Extent::sz(Dim::R).to_string(), "Sz(R)");
        assert_eq!(Extent::sz_plus(Dim::S, -1).to_string(), "Sz(S)-1");
        assert_eq!(Extent::sz_plus(Dim::X, 7).to_string(), "Sz(X)+7");
    }

    #[test]
    fn directive_display() {
        let d = Directive::spatial(Extent::lit(1), Extent::lit(1), Dim::K);
        assert_eq!(d.to_string(), "SpatialMap(1,1) K");
        let c = Directive::cluster(Extent::lit(64));
        assert_eq!(c.to_string(), "Cluster(64)");
    }

    #[test]
    fn positions_exact_cover() {
        // size 2, offset 2 over extent 6: positions at 0,2,4 — all full.
        let m = ResolvedMap { dim: Dim::X, size: 2, offset: 2, spatial: false };
        let p = m.positions(6);
        assert_eq!(p.full, 3);
        assert_eq!(p.edge_size, 0);
        assert_eq!(p.total(), 3);
    }

    #[test]
    fn positions_with_edge() {
        // size 2, offset 2 over extent 7: full at 0,2,4 then edge of 1 at 6.
        let m = ResolvedMap { dim: Dim::X, size: 2, offset: 2, spatial: false };
        let p = m.positions(7);
        assert_eq!(p.full, 3);
        assert_eq!(p.edge_size, 1);
        assert_eq!(p.total(), 4);
    }

    #[test]
    fn positions_overlapping_window() {
        // Sliding window: size 3, offset 1 over extent 6: positions 0..=3 full.
        let m = ResolvedMap { dim: Dim::Y, size: 3, offset: 1, spatial: false };
        let p = m.positions(6);
        assert_eq!(p.full, 4);
        assert_eq!(p.edge_size, 0);
    }

    #[test]
    fn positions_size_covers_all() {
        let m = ResolvedMap { dim: Dim::C, size: 10, offset: 10, spatial: false };
        let p = m.positions(6);
        assert_eq!(p.full, 1);
        assert_eq!(p.edge_size, 0);
    }
}
